"""llama2-7b — the paper's own evaluation model (Q4_0 weight-only quant):
32L d4096 32H (MHA) d_ff=11008 vocab 32000."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
)
