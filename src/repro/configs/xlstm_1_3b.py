"""xlstm-1.3b [ssm] — 48 blocks d2048 4H vocab 50304; mLSTM:sLSTM = 7:1,
no separate FFN (projections live inside the blocks).  Sub-quadratic:
eligible for long_500k. [arXiv:2405.04517; unverified]"""

from .base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    mlp="none",
    mixer_pattern=("mlstm",) * 7 + ("slstm",),
    xlstm=XLSTMConfig(conv_kernel=4, qk_dim_factor=0.5, proj_factor=2.0,
                      chunk=64, slstm_every=8),
    sub_quadratic=True,
)
