"""starcoder2-15b [dense] — 40L d6144 48H (GQA kv=4) d_ff=24576 vocab 49152,
LayerNorm + non-gated GeLU MLP, RoPE base 1e5. [arXiv:2402.19173; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    norm="layernorm",
    mlp="gelu",
    rope_theta=100_000.0,
)
