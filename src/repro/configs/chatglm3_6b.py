"""chatglm3-6b [dense] — 28L d4096 32H (GQA kv=2) d_ff=13696 vocab 65024,
2D RoPE (rotary on half the head dims), QKV bias. [arXiv:2406.12793; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_fraction=0.5,
    qkv_bias=True,
)
