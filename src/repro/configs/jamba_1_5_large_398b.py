"""jamba-1.5-large-398b [hybrid] — 72L d8192 64H (GQA kv=8) d_ff=24576,
vocab 65536; Mamba:attention = 7:1 interleave, MoE (16e top-2) every other
layer.  Sub-quadratic (Mamba majority): eligible for long_500k.
[arXiv:2403.19887; hf]"""

from .base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    mixer_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    moe=MoEConfig(n_experts=16, top_k=2, every=2, capacity_factor=1.25),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=32),
    sub_quadratic=True,
)
