"""Config registry: assigned architectures x input shapes.

``get_config(arch)`` returns the exact published configuration;
``reduced_config(arch)`` returns a family-preserving shrunken version for
CPU smoke tests; ``SHAPES``/``cells()`` enumerate the assigned
(architecture x input-shape) grid with the long_500k sub-quadratic rule.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from importlib import import_module
from typing import Iterator, Optional

from .base import ModelConfig, MoEConfig, SSMConfig, XLSTMConfig

ARCHS = (
    "granite-moe-1b-a400m",
    "llama4-maverick-400b-a17b",
    "granite-8b",
    "chatglm3-6b",
    "starcoder2-15b",
    "olmo-1b",
    "xlstm-1.3b",
    "jamba-1.5-large-398b",
    "internvl2-26b",
    "musicgen-medium",
)
EXTRA_ARCHS = ("llama2-7b",)  # the paper's own model

_MODULES = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "granite-8b": "granite_8b",
    "chatglm3-6b": "chatglm3_6b",
    "starcoder2-15b": "starcoder2_15b",
    "olmo-1b": "olmo_1b",
    "xlstm-1.3b": "xlstm_1_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "internvl2-26b": "internvl2_26b",
    "musicgen-medium": "musicgen_medium",
    "llama2-7b": "llama2_7b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return import_module(f".{_MODULES[arch]}", __package__).CONFIG


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str   # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_supported(cfg: ModelConfig, shape: str) -> bool:
    """long_500k runs only for sub-quadratic archs (SSM/hybrid); pure
    full-attention archs skip it (recorded per cell in EXPERIMENTS.md)."""
    if shape == "long_500k":
        return cfg.sub_quadratic
    return True


def cells(include_skipped: bool = False) -> Iterator[tuple[str, str, bool]]:
    """All 40 assigned (arch, shape) cells; yields (arch, shape, supported)."""
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok = shape_supported(cfg, shape)
            if ok or include_skipped:
                yield arch, shape, ok


def reduced_config(arch: str) -> ModelConfig:
    """Family-preserving shrink for CPU smoke tests: same mixer pattern,
    norm, MLP kind, GQA structure and MoE-ness — tiny dims."""
    cfg = get_config(arch)
    period_len = len(cfg.period())
    n_layers = period_len * min(2, cfg.n_periods)
    n_heads = 4
    n_kv = max(1, round(n_heads * cfg.n_kv_heads / cfg.n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    changes: dict = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        head_dim=None,
        attn_chunk=16,
        n_prefix=8 if cfg.n_prefix else 0,
        dtype="float32",
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=min(8, cfg.moe.n_experts),
            top_k=min(cfg.moe.top_k, min(8, cfg.moe.n_experts)),
            d_ff=64,
        )
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, chunk=8)
    if cfg.xlstm is not None:
        changes["xlstm"] = dataclasses.replace(cfg.xlstm, chunk=8)
    return dataclasses.replace(cfg, **changes)


__all__ = [
    "ARCHS",
    "EXTRA_ARCHS",
    "SHAPES",
    "ShapeSpec",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "XLSTMConfig",
    "get_config",
    "reduced_config",
    "cells",
    "shape_supported",
]
