"""musicgen-medium [audio] — 48L d1536 24H (MHA kv=24) d_ff=6144 vocab 2048,
decoder-only over EnCodec tokens.  The EnCodec frontend is a STUB:
input_specs provides precomputed frame embeddings (embed_input=True).
[arXiv:2306.05284; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    norm="layernorm",
    mlp="gelu",
    embed_input=True,
)
