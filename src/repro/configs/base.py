"""Model configuration schema shared by all assigned architectures."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    every: int = 1            # MoE ffn on layers where (idx % every) == every-1
    capacity_factor: float = 1.25
    shared_expert: bool = False  # llama4-style always-on shared expert
    d_ff: Optional[int] = None   # per-expert hidden dim (defaults to cfg.d_ff)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 64           # selective-scan chunk length (memory control)


@dataclass(frozen=True)
class XLSTMConfig:
    conv_kernel: int = 4
    qk_dim_factor: float = 0.5
    proj_factor: float = 2.0  # mLSTM up-projection factor
    chunk: int = 64           # mLSTM chunkwise-parallel length
    slstm_every: int = 8      # one sLSTM block per this many blocks (7:1)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None
    norm: str = "rmsnorm"     # rmsnorm | layernorm | nonparam_ln
    mlp: str = "swiglu"       # swiglu | gelu | none
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0   # chatglm applies rotary to half the dims
    qkv_bias: bool = False
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # mixer kind per layer within one period; tiled to n_layers.
    # kinds: "attn", "mamba", "mlstm", "slstm"
    mixer_pattern: Tuple[str, ...] = ("attn",)

    # Modality stubs (backbone-only archs): number of precomputed prefix
    # embeddings (vlm) or whether token input is replaced by frame
    # embeddings entirely (audio).
    n_prefix: int = 0
    embed_input: bool = False   # True: forward consumes (B, T, d) embeddings

    dtype: str = "bfloat16"
    attn_chunk: int = 512       # query-chunk size for memory-bounded attention
    sub_quadratic: bool = False # eligible for long_500k cells

    # ------------------------------------------------------------------ #
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def cdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def mixer_at(self, idx: int) -> str:
        return self.mixer_pattern[idx % len(self.mixer_pattern)]

    def ffn_at(self, idx: int) -> str:
        """'moe' | 'dense' | 'none' for layer idx."""
        if self.moe is not None and (idx % self.moe.every) == self.moe.every - 1:
            return "moe"
        return "none" if self.mlp == "none" else "dense"

    def layer_plan(self) -> Tuple[Tuple[str, str], ...]:
        """Full per-layer (mixer, ffn) plan of length n_layers."""
        return tuple(
            (self.mixer_at(i), self.ffn_at(i)) for i in range(self.n_layers)
        )

    def period(self) -> Tuple[Tuple[str, str], ...]:
        """Smallest repeating (mixer, ffn) unit — the scan body."""
        plan = self.layer_plan()
        for plen in range(1, self.n_layers + 1):
            if self.n_layers % plen:
                continue
            if all(plan[i] == plan[i % plen] for i in range(self.n_layers)):
                return plan[:plen]
        return plan

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period())

    def param_count(self) -> int:
        """Approximate parameter count N (for 6ND model-flops)."""
        d, hd = self.d_model, self.hd
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for mixer, ffn in self.layer_plan():
            if mixer == "attn":
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            elif mixer == "mamba":
                di = self.ssm.expand * d
                total += 2 * d * di + di * (self.ssm.d_conv + 2 * self.ssm.d_state + 2) + di * d
            elif mixer == "mlstm":
                x = self.xlstm
                di = int(x.proj_factor * d)
                dv = di // self.n_heads
                dq = max(8, int(x.qk_dim_factor * dv))
                # up+gate, block-diag q/k/v, down
                total += 2 * d * di + di * (2 * dq + dv) + di * d
            elif mixer == "slstm":
                dh = d // self.n_heads
                total += 4 * d * d + self.n_heads * dh * 4 * dh + d * d
            if ffn == "dense":
                mult = 3 if self.mlp == "swiglu" else 2
                total += mult * d * self.d_ff
            elif ffn == "moe":
                m = self.moe
                dff = m.d_ff or self.d_ff
                total += m.n_experts * 3 * d * dff + d * m.n_experts
                if m.shared_expert:
                    total += 3 * d * dff
            total += 2 * d  # norms
        return total

    def active_param_count(self) -> int:
        """Active N per token (MoE: only routed-to experts count)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dff = m.d_ff or self.d_ff
        total = self.param_count()
        n_moe_layers = sum(1 for _, f in self.layer_plan() if f == "moe")
        total -= n_moe_layers * (m.n_experts - m.top_k) * 3 * self.d_model * dff
        return total
