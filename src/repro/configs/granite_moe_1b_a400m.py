"""granite-moe-1b-a400m [moe] — 24L d1024 16H (GQA kv=8) d_ff=512/expert,
vocab 49155, MoE 32 experts top-8, MoE on every layer (no dense MLP).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    mlp="none",
    moe=MoEConfig(n_experts=32, top_k=8, every=1, capacity_factor=1.25),
)
