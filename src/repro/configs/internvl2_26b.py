"""internvl2-26b [vlm] — InternLM2-20B backbone: 48L d6144 48H (GQA kv=8)
d_ff=16384 vocab 92553.  InternViT frontend is a STUB: input_specs provides
precomputed patch embeddings (n_prefix tokens). [arXiv:2404.16821; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    n_prefix=256,
)
