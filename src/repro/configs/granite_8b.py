"""granite-8b [dense] — 36L d4096 32H (GQA kv=8) d_ff=14336 vocab 49152,
llama-architecture code model. [arXiv:2405.04324; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
)
