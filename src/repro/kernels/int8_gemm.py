"""Pallas TPU kernel: u8 x s8 -> s32 GEMM (the paper's INT8 GEMM hot-spot).

The paper's AVX-VNNI micro-kernel (``vpdpbusd``: u8 activations x s8 weights
accumulated in s32) maps onto the TPU MXU's int8 systolic path.  TPU-native
rethink (not a port): instead of per-core row ranges, the work decomposition
is a (M/bm, N/bn) parallel grid with an arbitrary (sequential) K reduction,
accumulated in a VMEM scratch tile; tile shapes are MXU-aligned multiples of
(32, 128) for int8 operands.

Block shapes are parameters so the dynamic tuner (repro.core.tuner) can pick
among candidates — the TPU analogue of the paper's per-ISA ratio tables.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships this under the TPU-prefixed name
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

__all__ = ["int8_gemm_pallas", "DEFAULT_BLOCKS", "CANDIDATE_BLOCKS"]

# (bm, bn, bk) candidates, MXU-aligned. VMEM use per step:
#   a: bm*bk + w: bn*bk bytes (int8) + acc: bm*bn*4 bytes.
DEFAULT_BLOCKS = (128, 128, 256)
CANDIDATE_BLOCKS = (
    (128, 128, 256),
    (256, 128, 128),
    (128, 256, 128),
    (64, 128, 512),
    (256, 256, 256),
)


def _kernel(a_ref, w_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU int8 path: s32 accumulation.
    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32).T,
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def int8_gemm_pallas(
    a_u8: jax.Array,
    w_s8: jax.Array,
    *,
    blocks: tuple[int, int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    """``a_u8`` (M, K) u8 x ``w_s8`` (N, K) s8 -> (M, N) s32.

    M, N, K must be divisible by the block shape (the ops.py wrapper pads).
    """
    m, k = a_u8.shape
    n, k2 = w_s8.shape
    if k != k2:
        raise ValueError(f"K mismatch: {k} vs {k2}")
    bm, bn, bk = blocks
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{n},{k}) not divisible by blocks {blocks}")
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a_u8, w_s8)
