"""Compiled balanced decode: zero-callback shard lowering of the trunk.

The io_callback bridge (:func:`~repro.kernels.dispatch.bridged_linear`)
pays one Python round trip per projection of every decode step — the
raw-speed ceiling ROADMAP names.  This module removes it while keeping the
paper's measure -> EMA -> split loop intact, by splitting the loop across
the jit boundary the way the paper splits it across the parallel region:

* **Before the step** (host): the ratio table is planned once per call
  site and materialized as device int32 boundary arrays — a
  :class:`~repro.runtime.OffsetSnapshot` — passed *as arguments* into the
  jitted step.  Balance is decided before the parallel work starts.
* **Inside the step** (device): every projection lowers as ONE Pallas
  grid over the full (M, N) output — no host shard loop, no callbacks.
  Grid tiles map onto cores by the boundary array (core ``c`` owns output
  rows ``[b[c], b[c+1])``); the Q4 decode GEMV additionally streams its
  packed weight tiles through the double-buffered kernel
  (:func:`~repro.kernels.q4_matmul.q4_matmul_pallas_db`), prefetching
  tile ``k+1`` while tile ``k`` computes.  A per-shard cost accumulator —
  the boundary differences, traced into the program — rides out of the
  step as an extra output, so what the host learns from is what the
  device actually executed.
* **After the step** (host): :meth:`CompiledDispatcher.feedback` replays
  each recorded region through the owning dispatcher's virtual worker
  pools — same per-core time model, same Eq. 2 EMA updates, same
  bytes/busy bandwidth accounting as the bridged path (two-level
  socket-then-core for a :class:`~repro.topology.TopologyDispatcher`) —
  and refreshes the snapshot for the next step.

:class:`CompiledDispatcher` wraps a flat
:class:`~repro.kernels.dispatch.HybridKernelDispatcher` or a
:class:`~repro.topology.TopologyDispatcher` (duck-typed to avoid the
package cycle) and is what :class:`~repro.models.balanced.BalancedTrunk`
binds to in ``mode="compiled"``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.int8 import quantize_u8_dynamic, u8s8_matmul_decompose
from repro.quant.q4 import BYTES_PER_ELEM, GROUP, QuantizedLinear
from repro.runtime import KernelSpec, OffsetSnapshot, OffsetSpec, Plan

from . import ops
from .dispatch import GEMV_ISA, kernel_key
from .q4_matmul import DEFAULT_BLOCKS as _Q4_DEFAULT
from .q4_matmul import q4_matmul_pallas_db

__all__ = ["CompiledDispatcher", "CompiledSpec", "q4_blocks"]

# Per-kernel shard granularities, matching the bridged kernel entries
# (HybridKernelDispatcher.q4_matmul/int8_gemm/f32_matmul defaults) so the
# compiled and bridged paths plan over identical grain sizes.
_GRANULARITY = {"q4_matmul": 8, "int8_gemm": 16, "f32_matmul": 1}


def q4_blocks(k: int) -> tuple:
    """The deterministic block config the compiled Q4 lowering pins for a
    reduction dim ``k`` — DEFAULT_BLOCKS with the ops-layer bk fixup, so a
    bridged trunk pinned to the same tuple is bit-identical."""
    bm, bn, bk = _Q4_DEFAULT
    if k % bk:
        bk = GROUP
        for cand in (1024, 512, 256, 128, 64, 32):
            if k % cand == 0:
                bk = cand
                break
    return (bm, bn, bk)


@dataclass(frozen=True)
class CompiledSpec:
    """One registered compiled call site: everything the feedback replay
    needs that is static at trace time.  ``name`` keys the offset snapshot
    (and the tape's device records carry only ``spec_id`` — all other
    fields are recovered host-side from this registry)."""

    spec_id: int
    name: str        # snapshot key: "<isa>/<kind>@<kernel>:<N>x<K>"
    kernel: str      # "q4_matmul" | "int8_gemm" | "f32_matmul"
    isa: str
    key: str         # ratio-table key (kernel_key(isa, kind))
    kind: str
    n: int
    k: int
    granularity: int


def _introspect(layer):
    """(kernel, K, placement-registry weight object) for a balanced layer
    (duck-typed on the bank classes' storage attributes)."""
    qw = getattr(layer, "qw", None)
    if qw is not None:  # BalancedQuantLinear
        return "q4_matmul", qw.in_features, qw
    w = getattr(layer, "w", None)
    if w is None:
        raise TypeError(f"not a balanced linear: {type(layer).__name__}")
    if hasattr(w, "q"):  # BalancedLinear (QuantizedWeightI8)
        return "int8_gemm", int(w.q.shape[1]), w.q
    return "f32_matmul", int(w.shape[1]), w  # BalancedFp32Linear


class CompiledDispatcher:
    """Compiled (zero-callback) lowering + between-step feedback replay
    over an existing balanced dispatcher.

    One instance owns one :class:`OffsetSnapshot` (planned from the same
    Balancers the bridged path uses, so compiled and bridged trunks share
    ratio state), a spec registry, and the trace-time cost tape.  For a
    socket-local topology dispatcher the snapshot concatenates per-socket
    core plans (outer socket split first, then each socket's per-core
    split), and feedback replays both levels — the two-level accounting is
    preserved without any host work inside the step.
    """

    def __init__(self, dispatcher, *, double_buffer: bool = True):
        self.dispatcher = dispatcher
        self.double_buffer = double_buffer
        sds = getattr(dispatcher, "socket_dispatchers", None)
        self._topo = sds is not None and bool(getattr(
            dispatcher, "socket_local", False))
        self._oblivious = sds is not None and not self._topo
        if self._topo:
            self.interpret = dispatcher.socket_dispatchers[0].interpret
            self._socket_cores = [d.n_workers
                                  for d in dispatcher.socket_dispatchers]
            self.n_workers = sum(self._socket_cores)
        elif self._oblivious:
            self.interpret = dispatcher.flat.interpret
            self._socket_cores = None
            self.n_workers = dispatcher.flat.n_workers
        else:
            self.interpret = dispatcher.interpret
            self._socket_cores = None
            self.n_workers = dispatcher.n_workers
        self.snapshot = OffsetSnapshot(self._plan_counts)
        self._specs: List[CompiledSpec] = []
        self._by_name: Dict[str, CompiledSpec] = {}
        self._weights: Dict[int, object] = {}    # spec_id -> placement handle
        self._tape: Optional[list] = None

    # -------------------------------------------------------- registration --
    def spec_for(self, layer, isa: str, kind: str) -> CompiledSpec:
        """The registered spec for one balanced layer under one (ISA,
        kind) — created (and its offset spec registered) on first use."""
        kernel, k, wobj = _introspect(layer)
        n = int(layer.out_features)
        key = kernel_key(isa, kind)
        name = f"{key}@{kernel}:{n}x{k}"
        spec = self._by_name.get(name)
        if spec is not None:
            if spec.kernel != kernel or spec.k != k:
                raise ValueError(
                    f"compiled spec {name!r} re-registered with a different "
                    f"kernel/shape")
            return spec
        g = _GRANULARITY[kernel]
        spec = CompiledSpec(spec_id=len(self._specs), name=name,
                            kernel=kernel, isa=isa, key=key, kind=kind,
                            n=n, k=k, granularity=g)
        self._specs.append(spec)
        self._by_name[name] = spec
        self._weights[spec.spec_id] = wobj
        self.snapshot.register(OffsetSpec(name=name, total=n, granularity=g))
        return spec

    # ------------------------------------------------------------ planning --
    def _kernel_spec(self, spec: CompiledSpec, m: int) -> KernelSpec:
        """The runtime KernelSpec for one replayed region (work model
        identical to the bridged kernel entries)."""
        if spec.kernel == "q4_matmul":
            bpr = spec.k * BYTES_PER_ELEM
            work = bpr if spec.isa == GEMV_ISA else 2.0 * m * spec.k
        elif spec.kernel == "int8_gemm":
            work = 2.0 * m * spec.k if spec.isa != GEMV_ISA else float(spec.k)
        else:
            bpr = 4.0 * spec.k
            work = bpr if spec.isa == GEMV_ISA else 2.0 * m * spec.k
        return KernelSpec(spec.kernel, isa=spec.isa,
                          granularity=spec.granularity,
                          work_per_unit=work, key=spec.key)

    def _bytes_per_unit(self, spec: CompiledSpec) -> float:
        if spec.kernel == "q4_matmul":
            return spec.k * BYTES_PER_ELEM
        if spec.kernel == "int8_gemm":
            return float(spec.k)
        return 4.0 * spec.k

    def _plan_counts(self, ospec: OffsetSpec) -> np.ndarray:
        """Snapshot planner: per-core counts from the current ratio state,
        through the same cached Balancers the bridged path plans with."""
        spec = self._by_name[ospec.name]
        kspec = self._kernel_spec(spec, m=1)  # work model irrelevant to plan
        if self._topo:
            topo = self.dispatcher
            outer = topo._balancer(kspec).plan(spec.n).counts
            parts = [topo.socket_dispatchers[s]._balancer(kspec)
                     .plan(int(c)).counts
                     for s, c in enumerate(outer)]
            return np.concatenate(parts)
        flat = self.dispatcher.flat if self._oblivious else self.dispatcher
        return flat._balancer(kspec).plan(spec.n).counts

    def refresh(self) -> Dict[str, jax.Array]:
        """Re-plan every registered call site from the current ratio
        tables; returns the new device offset snapshot (pass it into the
        next jitted step)."""
        return self.snapshot.refresh()

    # ----------------------------------------------------------- cost tape --
    def tape_begin(self) -> list:
        """Open the trace-time cost tape (call at the top of a traced step
        function).  Every compiled projection traced until
        :meth:`tape_end` appends its per-core shard sizes."""
        self._tape = []
        return self._tape

    def tape_end(self, tape: list) -> list:
        """Close the tape and return its records — make them an output of
        the jitted step, then hand the concrete values to
        :meth:`feedback` after the step runs."""
        if tape is not self._tape:
            raise RuntimeError("mismatched compiled cost tape")
        self._tape = None
        return list(tape)

    def _record(self, spec: CompiledSpec, m: int, offsets) -> None:
        src = offsets if offsets is not None else self.snapshot.device()
        bounds = src[spec.name]
        sizes = (bounds[1:] - bounds[:-1]).astype(jnp.int32)
        if self._tape is not None:
            self._tape.append({
                "spec": jnp.asarray(spec.spec_id, jnp.int32),
                "m": jnp.asarray(m, jnp.int32),
                "sizes": sizes,
            })

    # ------------------------------------------------------- traced kernels --
    def apply(self, layer, x: jax.Array, *, isa: str, kind: str,
              offsets=None) -> jax.Array:
        """One compiled balanced projection ``y = x @ W.T`` — fully
        traceable: the real quantized kernels run as one monolithic grid,
        the per-core boundaries from ``offsets`` (or the snapshot's
        current device arrays) are folded into the cost tape."""
        spec = self.spec_for(layer, isa, kind)
        dtype = x.dtype
        unflatten = x.ndim == 3
        if unflatten:
            b, s, _ = x.shape
            x = x.reshape(b * s, x.shape[-1])
        x32 = x.astype(jnp.float32)
        if spec.kernel == "q4_matmul":
            y = self._q4(x32, layer.qw, spec)
        elif spec.kernel == "int8_gemm":
            qa = quantize_u8_dynamic(x32)
            acc = ops.int8_gemm(qa.q, layer.w.q, interpret=self.interpret)
            y = u8s8_matmul_decompose(qa, layer.w, acc)
        else:
            # layer.w is a host numpy array; it constant-folds into each
            # trace (caching the converted array would leak one trace's
            # constant into the next).
            y = x32 @ jnp.asarray(layer.w, jnp.float32).T
        self._record(spec, int(x32.shape[0]), offsets)
        y = y.astype(dtype)
        return y.reshape(b, s, -1) if unflatten else y

    def _q4(self, x: jax.Array, qw: QuantizedLinear,
            spec: CompiledSpec) -> jax.Array:
        blocks = q4_blocks(spec.k)
        if not self.double_buffer:
            return ops.q4_matmul(x, qw, blocks=blocks,
                                 interpret=self.interpret)
        bm, bn, _ = blocks
        m, k = x.shape
        n = qw.packed.shape[0]
        mp, np_ = ops._round_up(m, bm), ops._round_up(n, bn)
        out = q4_matmul_pallas_db(
            ops._pad_to(x, mp, k),
            QuantizedLinear(ops._pad_to(qw.packed, np_, k // 2),
                            ops._pad_to(qw.scales, np_, k // GROUP)),
            blocks=blocks, interpret=self.interpret)
        return out[:m, :n]

    # ------------------------------------------------------------ feedback --
    def feedback(self, records, update: bool = True) -> Dict[str, jax.Array]:
        """Replay one step's recorded regions through the dispatcher's
        virtual pools — per-shard modelled times feed the Eq. 2 EMA
        updates, bytes/busy accounting accrues exactly as the bridged path
        would — then refresh the offset snapshot for the next step.
        ``records`` is the (concrete) cost-tape output of the step."""
        for rec in records:
            spec = self._specs[int(np.asarray(rec["spec"]))]
            m = int(np.asarray(rec["m"]))
            counts = np.asarray(rec["sizes"], dtype=np.int64)
            if int(counts.sum()) != spec.n:
                raise ValueError(
                    f"device shard sizes for {spec.name!r} cover "
                    f"{int(counts.sum())} rows, expected {spec.n}")
            if self._topo:
                self._replay_topology(spec, m, counts, update)
            else:
                self._replay_flat(spec, m, counts, update)
        return self.refresh()

    def _replay_flat(self, spec: CompiledSpec, m: int, counts: np.ndarray,
                     update: bool) -> None:
        kspec = self._kernel_spec(spec, m)
        plan = Plan(counts=counts, key=kspec.table_key,
                    granularity=spec.granularity)
        if self._oblivious:
            topo = self.dispatcher
            st = topo.flat.dispatch(
                kspec, spec.n, None,
                bytes_per_unit=self._bytes_per_unit(spec),
                work_scale=topo._oblivious_scale(spec.isa),
                update=update, plan=plan)
            if topo.keep_stats:
                topo.stats.append(st)
            return
        disp = self.dispatcher
        # A threaded dispatcher has no time model to replay against (its
        # bridged path measures real wall time, which the compiled step
        # does not observe per shard) — keep accounting but skip updates.
        model_ok = disp.machine is not None
        disp.dispatch(kspec, spec.n, None,
                      bytes_per_unit=self._bytes_per_unit(spec),
                      update=update and model_ok, plan=plan)

    def _replay_topology(self, spec: CompiledSpec, m: int,
                         counts: np.ndarray, update: bool) -> None:
        """Two-level replay: inner per-core regions per socket (each
        socket's pool advances by its own makespan), then the outer
        socket-level report with ``units=`` feedback — mirroring
        ``TopologyDispatcher._split`` for a plan fixed by the snapshot."""
        topo = self.dispatcher
        kspec = self._kernel_spec(spec, m)
        bpu = self._bytes_per_unit(spec)
        parts = np.split(counts, np.cumsum(self._socket_cores)[:-1])
        socket_counts = np.array([int(p.sum()) for p in parts],
                                 dtype=np.int64)
        placement = topo.placement_for(self._weights.get(spec.spec_id),
                                       spec.n)
        times = np.zeros(topo.n_sockets)
        lo = 0
        for s, c in enumerate(socket_counts):
            hi = lo + int(c)
            if c > 0:
                scale = topo._work_scale(spec.isa, s, (lo, hi), placement)
                st = topo.socket_dispatchers[s].dispatch(
                    kspec, int(c), None, bytes_per_unit=bpu,
                    work_scale=scale, update=update,
                    plan=Plan(counts=parts[s], key=kspec.table_key,
                              granularity=spec.granularity))
                times[s] = st.makespan
            lo = hi
        bal = topo._balancer(kspec)
        plan = Plan(counts=socket_counts, key=kspec.table_key,
                    granularity=spec.granularity)
        moved = float(spec.n) * bpu
        st = bal.report(plan, times, update=update and topo.dynamic,
                        label=f"{kspec.name}@{kspec.table_key}",
                        bytes_moved=moved)
        if moved > 0 and st.makespan > 0:
            topo._account(spec.isa, moved, st.makespan)
        if topo.keep_stats:
            topo.stats.append(st)
