"""Hybrid kernel dispatch: Balancer-planned per-core shards of real kernels.

This module closes the paper's loop at the layer it was written for.  The
Pallas kernels in this package execute as monolithic grids; the paper's
runtime instead splits every GEMM/GEMV along its N dimension into one
*contiguous* shard per core, sized by the per-ISA performance-ratio table
(Eq. 3), and feeds the measured shard times back into the table (Eq. 2):

    RatioTable["avx_vnni" | "membw"]  --Eq.3-->  per-core N shards
         ^                                           |
         |                                      worker pool runs the real
         +------------- Eq.2 + EMA <----------- Pallas shard (interpret on
                                                CPU, Mosaic on TPU)

:class:`HybridKernelDispatcher` owns that loop for any caller:

* ``dispatch(spec, total[, fn])`` — the low-level split/run/report cycle for
  an abstract kernel (used by the bandwidth benchmarks, ``fn=None`` runs the
  pure virtual-time model);
* ``q4_matmul(x, qw)`` / ``int8_gemm(a, w)`` — real sharded kernel
  execution: each worker's shard is a genuine ``pallas_call`` over that
  worker's weight rows, with per-shard block shapes chosen online by a
  :class:`~repro.core.tuner.KernelTuner`.

Primary-ISA keying follows the paper (kernels sharing a bottleneck share
ratios): compute-bound prefill GEMMs dispatch under ``"avx_vnni"``,
memory-bound decode GEMVs under ``"membw"``.  Every region reports its
bytes moved, so achieved-bandwidth fractions fall out of the uniform
:class:`~repro.runtime.RegionStats` telemetry.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.hybrid_sim import SimulatedHybridCPU, make_machine
from repro.core.pool import SubTask, ThreadWorkerPool, VirtualWorkerPool
from repro.core.tuner import KernelTuner, shape_class
from repro.quant.q4 import BYTES_PER_ELEM, QuantizedLinear
from repro.runtime import (
    Balancer,
    EvenPolicy,
    KernelSpec,
    ProportionalPolicy,
    RatioTable,
    RegionStats,
    StatsSink,
)

# The package re-exports functions named like the kernel modules
# (`repro.kernels.int8_gemm` is the ops wrapper once __init__ has run), so
# the candidate tables must be imported from the submodules by full path.
from repro.kernels.int8_gemm import CANDIDATE_BLOCKS as _I8_CANDIDATES
from repro.kernels.q4_matmul import CANDIDATE_BLOCKS as _Q4_CANDIDATES
from . import ops

__all__ = ["HybridKernelDispatcher", "GEMM_ISA", "GEMV_ISA"]

GEMM_ISA = "avx_vnni"   # compute-bound prefill GEMM
GEMV_ISA = "membw"      # memory-bound decode GEMV


class HybridKernelDispatcher:
    """Per-core balanced dispatch of kernel parallel regions.

    Construct via :meth:`virtual` (deterministic hybrid-CPU model, one
    :class:`VirtualWorkerPool` per ISA over a shared machine) or
    :meth:`threaded` (real OS threads with wall-clock shard times).  One
    dispatcher owns one :class:`RatioTable` keyed by primary ISA, one
    :class:`KernelTuner` for per-shard block shapes, and running
    bytes/busy-seconds accounting per ISA for achieved-bandwidth fractions.

    ``dynamic=False`` turns the dispatcher into the OpenMP-balanced static
    baseline (equal shards, no feedback) — same execution path, so dynamic
    vs. static comparisons isolate the paper's contribution.
    """

    def __init__(self, pool_factory: Callable[[str], object], n_workers: int,
                 *, machine: Optional[SimulatedHybridCPU] = None,
                 table: Optional[RatioTable] = None, alpha: float = 0.3,
                 tuner: Optional[KernelTuner] = None,
                 sink: Optional[StatsSink] = None, dynamic: bool = True,
                 interpret: bool = True, keep_stats: bool = True):
        self.n_workers = n_workers
        self.machine = machine
        self.table = table or RatioTable(n_workers, alpha=alpha)
        if self.table.n_workers != n_workers:
            raise ValueError("table size does not match worker count")
        self.tuner = tuner or KernelTuner()
        self.sink = sink
        self.dynamic = dynamic
        self.interpret = interpret
        self.keep_stats = keep_stats
        self.stats: list = []
        self._pool_factory = pool_factory
        self._pools: Dict[str, object] = {}
        self._balancers: Dict[tuple, Balancer] = {}
        self._bytes: Dict[str, float] = {}
        self._busy: Dict[str, float] = {}

    # ------------------------------------------------------- constructors --
    @classmethod
    def virtual(cls, machine: SimulatedHybridCPU | str, *,
                execute: bool = False, seed: int = 0, **kwargs):
        """Dispatcher over the simulated hybrid CPU: shard times come from
        the core model; ``execute=True`` additionally runs the real kernel
        shards (correctness under virtual timing)."""
        if isinstance(machine, str):
            machine = make_machine(machine, seed=seed)
        return cls(
            lambda isa: VirtualWorkerPool(machine, isa=isa, execute=execute),
            machine.n_cores, machine=machine, **kwargs)

    @classmethod
    def threaded(cls, n_workers: int, **kwargs):
        """Dispatcher over one persistent OS-thread pool (wall-clock shard
        times; the ISA only keys the ratio table)."""
        pool = ThreadWorkerPool(n_workers)
        return cls(lambda isa: pool, n_workers, **kwargs)

    def close(self) -> None:
        for pool in {id(p): p for p in self._pools.values()}.values():
            pool.close()

    # ------------------------------------------------------------ plumbing --
    def _pool(self, isa: str):
        if isa not in self._pools:
            self._pools[isa] = self._pool_factory(isa)
        return self._pools[isa]

    def _balancer(self, spec: KernelSpec) -> Balancer:
        key = (spec.isa, spec.granularity)
        if key not in self._balancers:
            if self.dynamic:
                policy = ProportionalPolicy(self.table, key=spec.isa,
                                            granularity=spec.granularity)
            else:
                policy = EvenPolicy(self.n_workers,
                                    granularity=spec.granularity)
            self._balancers[key] = Balancer(policy, sink=self.sink,
                                            keep_stats=False)
        return self._balancers[key]

    # ------------------------------------------------------------ dispatch --
    def dispatch(self, spec: KernelSpec, total: int,
                 fn: Optional[Callable[[int, int], None]] = None, *,
                 bytes_per_unit: float = 0.0,
                 update: bool = True) -> RegionStats:
        """One balanced parallel region of ``total`` units along the
        kernel's split dimension: plan per-core contiguous shards, run them
        on the ISA's pool, feed shard times back.  ``fn(start, size)``
        executes one shard (``None``: purely modelled)."""
        bal = self._balancer(spec)
        plan = bal.plan(total)
        subtasks = [
            SubTask(worker=w, start=lo, size=hi - lo,
                    work=float(hi - lo) * spec.work_per_unit, fn=fn)
            for w, (lo, hi) in enumerate(plan.ranges)
        ]
        times = self._pool(spec.isa).run(subtasks)
        moved = float(total) * bytes_per_unit
        st = bal.report(plan, times, update=update and self.dynamic,
                        label=spec.name, bytes_moved=moved)
        if moved > 0 and st.makespan > 0:
            self._bytes[spec.isa] = self._bytes.get(spec.isa, 0.0) + moved
            self._busy[spec.isa] = self._busy.get(spec.isa, 0.0) + st.makespan
        if self.keep_stats:
            self.stats.append(st)
        return st

    # ----------------------------------------------------------- telemetry --
    def achieved_bandwidth(self, isa: str = GEMV_ISA) -> float:
        """Bytes/s streamed by this dispatcher's ``isa`` regions so far
        (total bytes moved / total region makespan)."""
        busy = self._busy.get(isa, 0.0)
        if busy <= 0:
            return 0.0
        return self._bytes.get(isa, 0.0) / busy

    def achieved_bandwidth_fraction(self, isa: str = GEMV_ISA) -> float:
        """The paper's headline metric: achieved bandwidth as a fraction of
        the machine's streaming (MLC-analogue) bandwidth.  Requires a
        virtual machine (the denominator)."""
        if self.machine is None:
            raise ValueError("bandwidth fraction needs a simulated machine")
        return self.achieved_bandwidth(isa) / self.machine.socket_bandwidth

    # ------------------------------------------------------- real kernels --
    def _require_executing(self, isa: str) -> None:
        pool = self._pool(isa)
        if getattr(pool, "execute", True) is False:
            raise ValueError(
                "this dispatcher's virtual pool does not execute shard fns "
                "(construct with execute=True), so kernel outputs would be "
                "zeros; use dispatch() for purely modelled regions")

    def _select_blocks(self, kernel: str, m: int, size: int, k: int,
                       candidates) -> tuple:
        return self.tuner.select((kernel, shape_class(m, size, k)),
                                 candidates)

    def _shard_fn(self, kernel: str, m: int, k: int, candidates, blocks,
                  run_shard: Callable[[int, int, tuple], jnp.ndarray],
                  out: np.ndarray) -> Callable[[int, int], None]:
        """Wrap one shard execution: pick blocks (tuner unless pinned), run
        the real kernel over rows [start, start+size), time it for the
        tuner, write the rows into ``out``."""
        def fn(start: int, size: int) -> None:
            blk = blocks or self._select_blocks(kernel, m, size, k,
                                                candidates)
            t0 = time.perf_counter()
            y = run_shard(start, size, blk)
            y.block_until_ready()
            if blocks is None:
                self.tuner.report((kernel, shape_class(m, size, k)), blk,
                                  time.perf_counter() - t0)
            out[:, start:start + size] = np.asarray(y)
        return fn

    def q4_matmul(self, x, qw: QuantizedLinear, *, isa: str = GEMV_ISA,
                  blocks: Optional[tuple] = None, granularity: int = 8,
                  update: bool = True):
        """Fp32-Int4-Fp32 ``x (M,K) @ Q4_0 (N,K).T`` as balanced per-core
        N-row shards.  ``isa`` keys the ratio table ("membw" for decode
        GEMV, "avx_vnni" when the same kernel runs compute-bound prefill);
        the virtual work model follows the bottleneck."""
        self._require_executing(isa)
        m, k = x.shape
        n = qw.out_features
        out = np.zeros((m, n), dtype=x.dtype)

        def run_shard(start, size, blk):
            shard = QuantizedLinear(qw.packed[start:start + size],
                                    qw.scales[start:start + size])
            return ops.q4_matmul(x, shard, blocks=blk,
                                 interpret=self.interpret)

        fn = self._shard_fn("q4_matmul", m, k, _Q4_CANDIDATES, blocks,
                            run_shard, out)
        bytes_per_row = k * BYTES_PER_ELEM
        work = bytes_per_row if isa == GEMV_ISA else 2.0 * m * k
        spec = KernelSpec("q4_matmul", isa=isa, granularity=granularity,
                          work_per_unit=work)
        self.dispatch(spec, n, fn, bytes_per_unit=bytes_per_row,
                      update=update)
        return jnp.asarray(out)

    def int8_gemm(self, a_u8, w_s8, *, isa: str = GEMM_ISA,
                  blocks: Optional[tuple] = None, granularity: int = 16,
                  update: bool = True):
        """u8 (M,K) x s8 (N,K) -> s32 (M,N) as balanced per-core N-row
        shards (the paper's VNNI prefill GEMM; s32 accumulation makes shard
        outputs bit-identical to the monolithic grid)."""
        self._require_executing(isa)
        m, k = a_u8.shape
        n = w_s8.shape[0]
        out = np.zeros((m, n), dtype=np.int32)

        def run_shard(start, size, blk):
            return ops.int8_gemm(a_u8, w_s8[start:start + size], blocks=blk,
                                 interpret=self.interpret)

        fn = self._shard_fn("int8_gemm", m, k, _I8_CANDIDATES, blocks,
                            run_shard, out)
        work = 2.0 * m * k if isa != GEMV_ISA else float(k)
        spec = KernelSpec("int8_gemm", isa=isa, granularity=granularity,
                          work_per_unit=work)
        self.dispatch(spec, n, fn, bytes_per_unit=float(k), update=update)
        return jnp.asarray(out)
