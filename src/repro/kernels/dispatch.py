"""Hybrid kernel dispatch: Balancer-planned per-core shards of real kernels.

This module closes the paper's loop at the layer it was written for.  The
Pallas kernels in this package execute as monolithic grids; the paper's
runtime instead splits every GEMM/GEMV along its N dimension into one
*contiguous* shard per core, sized by the per-ISA performance-ratio table
(Eq. 3), and feeds the measured shard times back into the table (Eq. 2):

    RatioTable["avx_vnni" | "membw"]  --Eq.3-->  per-core N shards
         ^                                           |
         |                                      worker pool runs the real
         +------------- Eq.2 + EMA <----------- Pallas shard (interpret on
                                                CPU, Mosaic on TPU)

:class:`HybridKernelDispatcher` owns that loop for any caller:

* ``dispatch(spec, total[, fn])`` — the low-level split/run/report cycle for
  an abstract kernel (used by the bandwidth benchmarks, ``fn=None`` runs the
  pure virtual-time model);
* ``q4_matmul(x, qw)`` / ``int8_gemm(a, w)`` — real sharded kernel
  execution: each worker's shard is a genuine ``pallas_call`` over that
  worker's weight rows, with per-shard block shapes chosen online by a
  :class:`~repro.core.tuner.KernelTuner`.

Primary-ISA keying follows the paper (kernels sharing a bottleneck share
ratios): compute-bound prefill GEMMs dispatch under ``"avx_vnni"``,
memory-bound decode GEMVs under ``"membw"``.  Balanced-trunk callers
additionally split the *table* key per layer kind — ``kernel_key(isa,
kind)`` produces ``"membw/attn_proj"``-style keys so every projection
family converges its own ratio vector while executing under its phase's
ISA.  Every region reports its bytes moved, so achieved-bandwidth
fractions fall out of the uniform :class:`~repro.runtime.RegionStats`
telemetry.

:func:`bridged_linear` is the jit bridge: the model trunk is a jitted
``lax``-free unrolled loop whose projections must reach these host-side
shard dispatchers.  Inside a trace it routes the call through an ordered
``io_callback`` (the sharded per-core Pallas calls stay usable from the
jitted decode step); outside a trace — or when the caller disallows the
callback — it falls back to direct eager shard-wise execution.
"""

from __future__ import annotations

import functools
import os
import threading
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

# The jit bridge below runs ordered io_callbacks that themselves dispatch
# jitted Pallas shard programs.  jax's CPU client executes programs on a
# thread pool sized from the host CPU count; on a 1-2 CPU host the outer
# program can hold every execution thread while its callback waits on the
# nested shard program — a guaranteed deadlock.  Synchronous dispatch runs
# each program on the calling thread instead, which composes with nesting,
# so flip it where the pool is too small for the bridge to be safe.
if (os.cpu_count() or 1) <= 2:
    try:
        jax.config.update("jax_cpu_enable_async_dispatch", False)
    except (AttributeError, KeyError):  # jax without the flag
        pass

from repro.core import events as _ev
from repro.core.hybrid_sim import SimulatedHybridCPU, make_machine
from repro.core.pool import SubTask, ThreadWorkerPool, VirtualWorkerPool
from repro.core.tuner import KernelTuner, shape_class
from repro.quant.q4 import BYTES_PER_ELEM, QuantizedLinear
from repro.runtime import (
    Balancer,
    EvenPolicy,
    KernelSpec,
    Plan,
    ProportionalPolicy,
    RatioTable,
    RegionStats,
    StatsSink,
)

# The package re-exports functions named like the kernel modules
# (`repro.kernels.int8_gemm` is the ops wrapper once __init__ has run), so
# the candidate tables must be imported from the submodules by full path.
from repro.kernels.int8_gemm import CANDIDATE_BLOCKS as _I8_CANDIDATES
from repro.kernels.q4_matmul import CANDIDATE_BLOCKS as _Q4_CANDIDATES
from . import ops

__all__ = ["HybridKernelDispatcher", "GEMM_ISA", "GEMV_ISA",
           "TRUNK_KINDS", "kernel_key", "bridged_linear",
           "bridged_linear_fused"]

GEMM_ISA = "avx_vnni"   # compute-bound prefill GEMM
GEMV_ISA = "membw"      # memory-bound decode GEMV

# Layer kinds of the balanced trunk: every decode-step projection family
# gets its own ratio-table key per ISA (q/k/v/o share "attn_proj"; the MLP
# up/gate projections share "mlp_up"; the down projection and the LM head
# stand alone).  Kinds sharing a bottleneck could share a table — keeping
# them separate lets the loop see per-family shape effects (granularity
# rounding at small N) without polluting the big-GEMV entries.
TRUNK_KINDS = ("attn_proj", "mlp_up", "mlp_down", "head")


def kernel_key(isa: str, kind: Optional[str] = None) -> str:
    """Ratio-table key for a trunk projection: ``"<isa>/<kind>"`` (or the
    bare ISA when no kind is given — the PR-3 balanced-head convention)."""
    return isa if kind is None else f"{isa}/{kind}"


def _bridge_run(layer, isa: str, key: Optional[str], x) -> np.ndarray:
    """Host half of :func:`bridged_linear`: one balanced shard dispatch."""
    return np.asarray(layer(jnp.asarray(x, jnp.float32), isa=isa, key=key),
                      dtype=np.float32)


def bridged_linear(layer, x: jax.Array, *, isa: str,
                   key: Optional[str] = None,
                   allow_callback: bool = True) -> jax.Array:
    """Apply a host-side balanced linear (``layer(x, isa=, key=)`` with an
    ``out_features`` attribute) from either side of a jit boundary.

    * Inside a trace: the call becomes an *ordered* ``io_callback`` — the
      jitted decode step stays one compiled program while every projection
      still runs as real per-core shards through the dispatcher's worker
      pools, with shard times fed back to the ratio table in program order.
    * Outside a trace (or with ``allow_callback=False``, the
      tracing-disallowed mode): direct eager shard-wise execution.

    Always computes in f32 (the dispatchers' accumulation dtype) and casts
    back to the caller's dtype.
    """
    if isinstance(x, jax.core.Tracer):
        if not allow_callback:
            raise RuntimeError(
                "balanced trunk was built with jit_bridge=False but its "
                "projections are being traced; run the forward eagerly "
                "(the engine skips jax.jit for such trunks)")
        out_shape = jax.ShapeDtypeStruct(x.shape[:-1] + (layer.out_features,),
                                         jnp.float32)
        fn = functools.partial(_bridge_run, layer, isa, key)
        out = io_callback(fn, out_shape, x, ordered=True)
    else:
        out = layer(x, isa=isa, key=key)
    return out.astype(x.dtype)


def _bridge_run_multi(layers, isa: str, keys, x) -> np.ndarray:
    """Host half of :func:`bridged_linear_fused`: one round trip runs every
    layer's balanced shard dispatch back to back (program order preserved,
    so ratio-table updates are identical to separate bridged calls)."""
    xj = jnp.asarray(x, jnp.float32)
    return np.concatenate(
        [np.asarray(layer(xj, isa=isa, key=key), dtype=np.float32)
         for layer, key in zip(layers, keys)], axis=-1)


def bridged_linear_fused(layers, x: jax.Array, *, isa: str, keys,
                         allow_callback: bool = True) -> tuple:
    """Apply several host-side balanced linears that share the same input
    through ONE jit-bridge round trip (the fused-q/k/v optimization: an
    attention layer's three input projections become a single ordered
    ``io_callback`` instead of three).

    Each layer still runs as its own balanced shard-dispatch region with
    its own table ``key`` — in the same order a sequence of
    :func:`bridged_linear` calls would — so outputs, shard times, and
    ratio-table updates are bit-identical to the per-matmul path; only the
    number of host round trips changes.  Returns one array per layer.
    """
    keys = list(keys)
    if len(keys) != len(layers):
        raise ValueError("need one table key per fused layer")
    if not isinstance(x, jax.core.Tracer):
        # eager: no bridge to amortize, so no concat/split round trip
        return tuple(layer(x, isa=isa, key=key).astype(x.dtype)
                     for layer, key in zip(layers, keys))
    if not allow_callback:
        raise RuntimeError(
            "balanced trunk was built with jit_bridge=False but its "
            "projections are being traced; run the forward eagerly "
            "(the engine skips jax.jit for such trunks)")
    widths = [layer.out_features for layer in layers]
    out_shape = jax.ShapeDtypeStruct(x.shape[:-1] + (sum(widths),),
                                     jnp.float32)
    fn = functools.partial(_bridge_run_multi, layers, isa, keys)
    cat = io_callback(fn, out_shape, x, ordered=True)
    outs, lo = [], 0
    for w in widths:
        outs.append(jax.lax.slice_in_dim(cat, lo, lo + w, axis=-1)
                    .astype(x.dtype))
        lo += w
    return tuple(outs)


class HybridKernelDispatcher:
    """Per-core balanced dispatch of kernel parallel regions.

    Construct via :meth:`virtual` (deterministic hybrid-CPU model, one
    :class:`VirtualWorkerPool` per ISA over a shared machine) or
    :meth:`threaded` (real OS threads with wall-clock shard times).  One
    dispatcher owns one :class:`RatioTable` keyed by primary ISA, one
    :class:`KernelTuner` for per-shard block shapes, and running
    bytes/busy-seconds accounting per ISA for achieved-bandwidth fractions.

    ``dynamic=False`` turns the dispatcher into the OpenMP-balanced static
    baseline (equal shards, no feedback) — same execution path, so dynamic
    vs. static comparisons isolate the paper's contribution.
    """

    def __init__(self, pool_factory: Callable[[str], object], n_workers: int,
                 *, machine: Optional[SimulatedHybridCPU] = None,
                 table: Optional[RatioTable] = None, alpha: float = 0.3,
                 tuner: Optional[KernelTuner] = None,
                 sink: Optional[StatsSink] = None, dynamic: bool = True,
                 interpret: bool = True, keep_stats: bool = True):
        self.n_workers = n_workers
        self.machine = machine
        self.table = table or RatioTable(n_workers, alpha=alpha)
        if self.table.n_workers != n_workers:
            raise ValueError("table size does not match worker count")
        self.tuner = tuner or KernelTuner()
        self.sink = sink
        self.dynamic = dynamic
        self.interpret = interpret
        self.keep_stats = keep_stats
        self.stats: list = []
        self.last_stats: Optional[RegionStats] = None
        self._pool_factory = pool_factory
        self._pools: Dict[str, object] = {}
        self._balancers: Dict[tuple, Balancer] = {}
        # worker liveness the owner can flip directly (the replica-level
        # set_active idiom one level down); combined with the machine's
        # scheduled capacity events at plan time — see capacity_mask()
        self.active = np.ones(n_workers, dtype=bool)
        self._bytes: Dict[str, float] = {}
        self._busy: Dict[str, float] = {}
        # bytes/busy accounting is a read-modify-write on plain dicts;
        # shard reports may arrive from concurrent regions (threaded
        # pools, future async serving), so the accumulation is locked
        self._acct_lock = threading.Lock()

    # ------------------------------------------------------- constructors --
    @classmethod
    def virtual(cls, machine: SimulatedHybridCPU | str, *,
                execute: bool = False, seed: int = 0, **kwargs):
        """Dispatcher over the simulated hybrid CPU: shard times come from
        the core model; ``execute=True`` additionally runs the real kernel
        shards (correctness under virtual timing)."""
        if isinstance(machine, str):
            machine = make_machine(machine, seed=seed)
        if hasattr(machine, "sockets"):  # a MachineTopology, not a flat CPU
            raise ValueError(
                "multi-socket machines need repro.topology."
                "TopologyDispatcher (one flat dispatcher per bandwidth "
                "domain); HybridKernelDispatcher balances one socket")
        return cls(
            lambda isa: VirtualWorkerPool(machine, isa=isa, execute=execute),
            machine.n_cores, machine=machine, **kwargs)

    @classmethod
    def threaded(cls, n_workers: int, **kwargs):
        """Dispatcher over one persistent OS-thread pool (wall-clock shard
        times; the ISA only keys the ratio table)."""
        pool = ThreadWorkerPool(n_workers)
        return cls(lambda isa: pool, n_workers, **kwargs)

    def close(self) -> None:
        for pool in {id(p): p for p in self._pools.values()}.values():
            pool.close()

    # ------------------------------------------------------------ plumbing --
    def _pool(self, isa: str):
        if isa not in self._pools:
            self._pools[isa] = self._pool_factory(isa)
        return self._pools[isa]

    def set_active(self, i: int, active: bool = True) -> None:
        """Mark worker ``i`` parked (or returned).  Plans stop assigning
        to it; its ratio-table entry is untouched (zero-count workers are
        carried over by the ``units > 0`` rule), so it resumes at its last
        learned speed."""
        if not 0 <= i < self.n_workers:
            raise IndexError(f"worker {i} out of range")
        self.active[i] = bool(active)

    def capacity_mask(self, isa: str = GEMV_ISA) -> np.ndarray:
        """The plan-time active mask: explicit :meth:`set_active` state
        AND the machine's scheduled capacity events sampled at the ISA
        pool's clock (the time the next region will actually start) — so
        both eager dispatch and the compiled planner see fresh masks
        without extra wiring."""
        mask = self.active.copy()
        if self.machine is not None:
            pool = self._pools.get(isa)
            now = float(getattr(pool, "clock", 0.0)) if pool is not None else 0.0
            mask &= self.machine.active_mask(now)
        return mask

    def _balancer(self, spec: KernelSpec) -> Balancer:
        key = (spec.table_key, spec.granularity)
        if key not in self._balancers:
            if self.dynamic:
                policy = ProportionalPolicy(
                    self.table, key=spec.table_key,
                    granularity=spec.granularity,
                    active=lambda isa=spec.isa: self.capacity_mask(isa))
            else:
                # the static baseline stays capacity-blind on purpose:
                # that contrast is what bench_elastic measures
                policy = EvenPolicy(self.n_workers,
                                    granularity=spec.granularity)
            self._balancers[key] = Balancer(policy, sink=self.sink,
                                            keep_stats=False)
        return self._balancers[key]

    # ------------------------------------------------------------ dispatch --
    def dispatch(self, spec: KernelSpec, total: int,
                 fn: Optional[Callable[[int, int], None]] = None, *,
                 bytes_per_unit: float = 0.0, work_scale: float = 1.0,
                 update: bool = True,
                 plan: Optional[Plan] = None) -> RegionStats:
        """One balanced parallel region of ``total`` units along the
        kernel's split dimension: plan per-core contiguous shards, run them
        on the ISA's pool, feed shard times back.  ``fn(start, size)``
        executes one shard (``None``: purely modelled).  ``work_scale``
        inflates the modelled work per unit without changing the bytes
        accounting — the NUMA hook: a byte streamed from a remote socket
        costs ``cross_socket_penalty`` wall time but is still one byte.
        ``plan`` replays an externally realized split instead of planning
        afresh — the compiled-decode feedback path, where the per-core
        counts were fixed by the offset snapshot the device executed."""
        bal = self._balancer(spec)
        if plan is None:
            plan = bal.plan(total)
        elif int(np.asarray(plan.counts).sum()) != total:
            raise ValueError("replayed plan does not cover the region")
        work_per_unit = spec.work_per_unit * work_scale
        subtasks = [
            SubTask(worker=w, start=lo, size=hi - lo,
                    work=float(hi - lo) * work_per_unit, fn=fn)
            for w, (lo, hi) in enumerate(plan.ranges)
        ]
        pool = self._pool(spec.isa)
        tracing = _ev.TRACER is not None
        # virtual pools carry a deterministic clock; threaded pools don't,
        # so only virtual dispatch gets region spans (wall-clock spans
        # would break byte-identical traces)
        t0 = getattr(pool, "clock", None) if tracing else None
        times = pool.run(subtasks)
        moved = float(total) * bytes_per_unit
        st = bal.report(plan, times, update=update and self.dynamic,
                        label=f"{spec.name}@{spec.table_key}",
                        bytes_moved=moved)
        if moved > 0 and st.makespan > 0:
            self._account(spec.isa, moved, st.makespan)
        if t0 is not None:
            _ev.emit_span(
                f"dispatch:{spec.isa}", f"{spec.name}@{spec.table_key}",
                t0, pool.clock - t0, cat="dispatch",
                args=lambda: {"units": int(total),
                              "imbalance": round(st.imbalance, 4)})
            _ev.emit_counter(
                f"ratio:{spec.table_key}", pool.clock,
                lambda: {f"w{i}": round(float(r), 5) for i, r in
                         enumerate(self.table.ratios(spec.table_key))})
            _ev.emit_counter(
                f"capacity:{spec.isa}", pool.clock,
                lambda: {"active_workers": int(
                    self.capacity_mask(spec.isa).sum())})
            if moved > 0 and self.machine is not None:
                _ev.emit_counter(
                    f"bw:{spec.isa}", pool.clock,
                    lambda: {"achieved_bw_frac": round(
                        self.achieved_bandwidth_fraction(spec.isa), 5)})
        if self.keep_stats:
            self.stats.append(st)
        self.last_stats = st
        return st

    # ----------------------------------------------------------- telemetry --
    def _account(self, isa: str, moved: float, busy: float) -> None:
        """Accrue one region's bytes/busy under the accounting lock."""
        with self._acct_lock:
            if _ev.TRACER is not None:
                where = f"{type(self).__name__}._account"
                _ev.emit_acquire(self._acct_lock, where=where)
                _ev.emit_read(self, f"bytes[{isa}]", where=where)
                _ev.emit_write(self, f"bytes[{isa}]", where=where)
            self._bytes[isa] = self._bytes.get(isa, 0.0) + moved
            self._busy[isa] = self._busy.get(isa, 0.0) + busy
            if _ev.TRACER is not None:
                _ev.emit_release(self._acct_lock,
                                 where=f"{type(self).__name__}._account")

    def reset_bandwidth_accounting(self) -> None:
        """Zero the cumulative bytes/busy counters (steady-state windows:
        warm the ratio tables first, reset, then measure)."""
        self._bytes.clear()
        self._busy.clear()

    def achieved_bandwidth(self, isa: str = GEMV_ISA) -> float:
        """Bytes/s streamed by this dispatcher's ``isa`` regions so far
        (total bytes moved / total region makespan)."""
        busy = self._busy.get(isa, 0.0)
        if busy <= 0:
            return 0.0
        return self._bytes.get(isa, 0.0) / busy

    def achieved_bandwidth_fraction(self, isa: str = GEMV_ISA) -> float:
        """The paper's headline metric: achieved bandwidth as a fraction of
        the machine's streaming (MLC-analogue) bandwidth.  Requires a
        virtual machine (the denominator)."""
        if self.machine is None:
            raise ValueError("bandwidth fraction needs a simulated machine")
        return self.achieved_bandwidth(isa) / self.machine.socket_bandwidth

    # ------------------------------------------------------- real kernels --
    def _require_executing(self, isa: str) -> None:
        pool = self._pool(isa)
        if getattr(pool, "execute", True) is False:
            raise ValueError(
                "this dispatcher's virtual pool does not execute shard fns "
                "(construct with execute=True), so kernel outputs would be "
                "zeros; use dispatch() for purely modelled regions")

    def _select_blocks(self, kernel: str, m: int, size: int, k: int,
                       candidates) -> tuple:
        return self.tuner.select((kernel, shape_class(m, size, k)),
                                 candidates)

    def _shard_fn(self, kernel: str, m: int, k: int, candidates, blocks,
                  run_shard: Callable[[int, int, tuple], jnp.ndarray],
                  out: np.ndarray) -> Callable[[int, int], None]:
        """Wrap one shard execution: pick blocks (tuner unless pinned), run
        the real kernel over rows [start, start+size), time it for the
        tuner, write the rows into ``out``."""
        def fn(start: int, size: int) -> None:
            blk = blocks or self._select_blocks(kernel, m, size, k,
                                                candidates)
            t0 = time.perf_counter()
            y = run_shard(start, size, blk)
            y.block_until_ready()
            if blocks is None:
                self.tuner.report((kernel, shape_class(m, size, k)), blk,
                                  time.perf_counter() - t0)
            out[:, start:start + size] = np.asarray(y)
        return fn

    def q4_matmul(self, x, qw: QuantizedLinear, *, isa: str = GEMV_ISA,
                  key: Optional[str] = None,
                  blocks: Optional[tuple] = None, granularity: int = 8,
                  work_scale: float = 1.0, update: bool = True):
        """Fp32-Int4-Fp32 ``x (M,K) @ Q4_0 (N,K).T`` as balanced per-core
        N-row shards.  ``isa`` keys the ratio table ("membw" for decode
        GEMV, "avx_vnni" when the same kernel runs compute-bound prefill);
        ``key`` optionally refines the table key per layer kind (see
        :func:`kernel_key`); the virtual work model follows the
        bottleneck."""
        self._require_executing(isa)
        m, k = x.shape
        n = qw.out_features
        out = np.zeros((m, n), dtype=x.dtype)

        def run_shard(start, size, blk):
            shard = QuantizedLinear(qw.packed[start:start + size],
                                    qw.scales[start:start + size])
            return ops.q4_matmul(x, shard, blocks=blk,
                                 interpret=self.interpret)

        fn = self._shard_fn("q4_matmul", m, k, _Q4_CANDIDATES, blocks,
                            run_shard, out)
        bytes_per_row = k * BYTES_PER_ELEM
        work = bytes_per_row if isa == GEMV_ISA else 2.0 * m * k
        spec = KernelSpec("q4_matmul", isa=isa, granularity=granularity,
                          work_per_unit=work, key=key)
        self.dispatch(spec, n, fn, bytes_per_unit=bytes_per_row,
                      work_scale=work_scale, update=update)
        return jnp.asarray(out)

    def int8_gemm(self, a_u8, w_s8, *, isa: str = GEMM_ISA,
                  key: Optional[str] = None,
                  blocks: Optional[tuple] = None, granularity: int = 16,
                  work_scale: float = 1.0, update: bool = True):
        """u8 (M,K) x s8 (N,K) -> s32 (M,N) as balanced per-core N-row
        shards (the paper's VNNI prefill GEMM; s32 accumulation makes shard
        outputs bit-identical to the monolithic grid)."""
        self._require_executing(isa)
        m, k = a_u8.shape
        n = w_s8.shape[0]
        out = np.zeros((m, n), dtype=np.int32)

        def run_shard(start, size, blk):
            return ops.int8_gemm(a_u8, w_s8[start:start + size], blocks=blk,
                                 interpret=self.interpret)

        fn = self._shard_fn("int8_gemm", m, k, _I8_CANDIDATES, blocks,
                            run_shard, out)
        work = 2.0 * m * k if isa != GEMV_ISA else float(k)
        spec = KernelSpec("int8_gemm", isa=isa, granularity=granularity,
                          work_per_unit=work, key=key)
        self.dispatch(spec, n, fn, bytes_per_unit=float(k),
                      work_scale=work_scale, update=update)
        return jnp.asarray(out)

    def f32_matmul(self, x, w, *, isa: str = GEMV_ISA,
                   key: Optional[str] = None, granularity: int = 1,
                   work_scale: float = 1.0, update: bool = True):
        """f32 ``x (M,K) @ W (N,K).T`` as balanced per-core N-row shards of
        a plain host matmul — no quantization, no block constraints
        (``granularity=1``), so shard-wise output is exactly the monolithic
        product.  This is the trunk's precision-reference path: the bytes
        model streams the f32 weight rows (4K bytes each)."""
        self._require_executing(isa)
        x = np.asarray(x, dtype=np.float32)
        w = np.asarray(w, dtype=np.float32)
        m, k = x.shape
        n = w.shape[0]
        out = np.zeros((m, n), dtype=np.float32)

        def fn(start: int, size: int) -> None:
            out[:, start:start + size] = x @ w[start:start + size].T

        bytes_per_row = 4.0 * k
        work = bytes_per_row if isa == GEMV_ISA else 2.0 * m * k
        spec = KernelSpec("f32_matmul", isa=isa, granularity=granularity,
                          work_per_unit=work, key=key)
        self.dispatch(spec, n, fn, bytes_per_unit=bytes_per_row,
                      work_scale=work_scale, update=update)
        return jnp.asarray(out)
