"""Pure-jnp oracles for every Pallas kernel (ground truth for allclose)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.q4 import QuantizedLinear, dequantize_q4_0
from repro.quant.int8 import (
    QuantizedActivation,
    QuantizedWeightI8,
    u8s8_matmul_decompose,
)


def int8_gemm_ref(a_u8: jax.Array, w_s8: jax.Array) -> jax.Array:
    """u8 (M,K) x s8 (N,K) -> s32 (M,N): raw VNNI/MXU accumulation."""
    return jnp.dot(
        a_u8.astype(jnp.int32), w_s8.astype(jnp.int32).T,
        preferred_element_type=jnp.int32,
    )


def int8_gemm_f32_ref(a: QuantizedActivation, w: QuantizedWeightI8) -> jax.Array:
    """Full quantized linear: u8s8 accumulation + dequant to f32."""
    acc = int8_gemm_ref(a.q, w.q)
    return u8s8_matmul_decompose(a, w, acc)


def q4_matmul_ref(x: jax.Array, qw: QuantizedLinear) -> jax.Array:
    """f32/bf16 (M,K) x Q4_0 (N,K) -> (M,N): dequantize-then-matmul.

    This is the paper's "Fp32-Int4-Fp32" GEMV/GEMM path (weights dequantized
    group-wise; activations stay float).
    """
    w = dequantize_q4_0(qw, dtype=jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w.T,
                   preferred_element_type=jnp.float32).astype(x.dtype)
