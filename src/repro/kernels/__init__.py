"""Pallas TPU kernels for the paper's compute hot-spots.

``<name>.py`` holds the pallas_call + BlockSpec kernels, ``ops.py`` the jit'd
public wrappers (padding + tuner dispatch), ``ref.py`` the pure-jnp oracles.
"""

from .ops import int8_gemm, int8_linear, q4_matmul, TunedMatmul
from . import ref

__all__ = ["int8_gemm", "int8_linear", "q4_matmul", "TunedMatmul", "ref"]
