"""Pallas TPU kernels for the paper's compute hot-spots.

``<name>.py`` holds the pallas_call + BlockSpec kernels, ``ops.py`` the jit'd
public wrappers (padding + tuner dispatch), ``ref.py`` the pure-jnp oracles,
``dispatch.py`` the hybrid per-core balanced shard dispatcher (the paper's
runtime applied to these kernels), ``compiled.py`` the zero-callback
compiled lowering of balanced regions (offsets in, cost tape out).
"""

from .ops import int8_gemm, int8_linear, q4_matmul, TunedMatmul
from .dispatch import (
    GEMM_ISA,
    GEMV_ISA,
    TRUNK_KINDS,
    HybridKernelDispatcher,
    bridged_linear,
    kernel_key,
)
from .compiled import CompiledDispatcher, CompiledSpec
from . import ref

__all__ = [
    "int8_gemm",
    "int8_linear",
    "q4_matmul",
    "TunedMatmul",
    "ref",
    "HybridKernelDispatcher",
    "GEMM_ISA",
    "GEMV_ISA",
    "TRUNK_KINDS",
    "kernel_key",
    "bridged_linear",
    "CompiledDispatcher",
    "CompiledSpec",
]
