"""Public jit'd wrappers around the Pallas kernels.

Responsibilities:
  * shape hygiene — pad M/N/K up to block multiples, slice the result back;
  * config selection — candidate block shapes are chosen by the dynamic
    :class:`repro.core.tuner.KernelTuner` (the paper's per-ISA performance
    table, re-keyed by (kernel, shape-class)), falling back to defaults when
    no tuner is supplied;
  * backend selection — ``interpret=True`` runs the kernel body on CPU
    (validation); on TPU hardware the same call lowers to Mosaic.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.tuner import KernelTuner, shape_class
from repro.quant.q4 import GROUP, QuantizedLinear
from repro.quant.int8 import QuantizedActivation, QuantizedWeightI8, u8s8_matmul_decompose

from . import int8_gemm as _i8
from . import q4_matmul as _q4
from . import ref as _ref

__all__ = ["int8_gemm", "int8_linear", "q4_matmul", "TunedMatmul"]


def _pad_to(x: jax.Array, rows: int, cols: int, value=0) -> jax.Array:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)), constant_values=value)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def int8_gemm(
    a_u8: jax.Array,
    w_s8: jax.Array,
    *,
    blocks: tuple[int, int, int] = _i8.DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    """u8 (M,K) x s8 (N,K) -> s32 (M,N), padding to block multiples.

    Zero-padding is exact for the s32 accumulation (0*w == 0).
    """
    m, k = a_u8.shape
    n = w_s8.shape[0]
    bm, bn, bk = blocks
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    a_p = _pad_to(a_u8, mp, kp)
    w_p = _pad_to(w_s8, np_, kp)
    out = _i8.int8_gemm_pallas(a_p, w_p, blocks=blocks, interpret=interpret)
    return out[:m, :n]


def int8_linear(
    a: QuantizedActivation,
    w: QuantizedWeightI8,
    *,
    blocks: tuple[int, int, int] = _i8.DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    """Full quantized linear (u8s8 -> s32 -> dequant f32)."""
    acc = int8_gemm(a.q, w.q, blocks=blocks, interpret=interpret)
    return u8s8_matmul_decompose(a, w, acc)


def q4_matmul(
    x: jax.Array,
    qw: QuantizedLinear,
    *,
    blocks: tuple[int, int, int] = _q4.DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    """f32/bf16 (M,K) x Q4_0 (N,K) -> (M,N), padding M/N to block multiples.

    K padding would shift group boundaries, so K must already be a multiple
    of ``blocks[2]`` (all assigned configs satisfy this; the ops layer picks
    a compatible bk otherwise).
    """
    m, k = x.shape
    n = qw.packed.shape[0]
    bm, bn, bk = blocks
    if k % bk:
        # choose the largest group-multiple bk that divides K
        bk = GROUP
        for cand in (1024, 512, 256, 128, 64, 32):
            if k % cand == 0:
                bk = cand
                break
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    x_p = _pad_to(x, mp, k)
    packed_p = _pad_to(qw.packed, np_, k // 2)
    scales_p = _pad_to(qw.scales, np_, k // GROUP)
    out = _q4.q4_matmul_pallas(
        x_p, QuantizedLinear(packed_p, scales_p), blocks=(bm, bn, bk),
        interpret=interpret,
    )
    return out[:m, :n]


class TunedMatmul:
    """Dispatch wrapper that lets a :class:`KernelTuner` pick block configs
    online — per-(kernel, shape-class) EMA argmin, the paper's table re-keyed.
    """

    def __init__(self, tuner: Optional[KernelTuner] = None, interpret: bool = False):
        self.tuner = tuner or KernelTuner()
        self.interpret = interpret

    def q4(self, x: jax.Array, qw: QuantizedLinear) -> jax.Array:
        key = ("q4_matmul", shape_class(x.shape[0], qw.out_features, x.shape[1]))
        cfg = self.tuner.select(key, _q4.CANDIDATE_BLOCKS)
        t0 = time.perf_counter()
        out = q4_matmul(x, qw, blocks=cfg, interpret=self.interpret)
        out.block_until_ready()
        self.tuner.report(key, cfg, time.perf_counter() - t0)
        return out

    def int8(self, a: QuantizedActivation, w: QuantizedWeightI8) -> jax.Array:
        key = ("int8_gemm", shape_class(a.q.shape[0], w.q.shape[0], a.q.shape[1]))
        cfg = self.tuner.select(key, _i8.CANDIDATE_BLOCKS)
        t0 = time.perf_counter()
        out = int8_linear(a, w, blocks=cfg, interpret=self.interpret)
        out.block_until_ready()
        self.tuner.report(key, cfg, time.perf_counter() - t0)
        return out
