"""Pallas TPU kernel: fused Q4_0 dequant + matmul (the paper's INT4 GEMV).

The paper's decode hot-spot is "Fp32-Int4-Fp32" GEMV: weights stay packed in
memory (0.5625 bytes/element) and are dequantized group-wise on the fly.
This is memory-bandwidth bound, so the TPU kernel's objective is to stream
the *packed* bytes HBM->VMEM (the f32 dequantized form exists only in
VMEM/VREGs) — the same reason Neural Speed fuses dequant into the VNNI
micro-kernel instead of materializing f32 weights.

Layout note (TPU-native rethink): llama.cpp packs element j and j+16 of a
32-group into one byte.  We keep that storage layout bit-for-bit (checkpoint
compatible) and unpack with a reshape-free trick: a (bn, bk/2) byte tile is
viewed as (bn, groups, 16); low and high nibbles are dequantized separately
against a broadcast scale and contracted against the matching halves of the
activation tile, avoiding any minor-dimension interleave on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships this under the TPU-prefixed name
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

from repro.quant.q4 import GROUP, QuantizedLinear

__all__ = ["q4_matmul_pallas", "DEFAULT_BLOCKS", "CANDIDATE_BLOCKS"]

# (bm, bn, bk): bk must be a multiple of GROUP (=32).
DEFAULT_BLOCKS = (8, 256, 512)
CANDIDATE_BLOCKS = (
    (8, 256, 512),
    (8, 512, 256),
    (8, 128, 1024),
    (128, 128, 512),
    (256, 256, 256),
)


def _kernel(x_ref, p_ref, s_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bn, half_bk = p_ref.shape
    groups = half_bk * 2 // GROUP
    bm, bk = x_ref.shape

    packed = p_ref[...].reshape(bn, groups, GROUP // 2)
    scales = s_ref[...].astype(jnp.float32)[..., None]  # (bn, groups, 1)
    # Dequantize both nibble planes: plane 0 = elements 0..15 of each group,
    # plane 1 = elements 16..31 (llama.cpp block_q4_0 layout).
    lo = (packed & 0x0F).astype(jnp.float32)
    hi = (packed >> 4).astype(jnp.float32)
    w_lo = ((lo - 8.0) * scales).reshape(bn, half_bk)
    w_hi = ((hi - 8.0) * scales).reshape(bn, half_bk)

    # Matching activation halves: x viewed as (bm, groups, 32); first 16
    # columns of each group hit the low plane, last 16 the high plane.
    x = x_ref[...].astype(jnp.float32).reshape(bm, groups, GROUP)
    x_lo = x[:, :, : GROUP // 2].reshape(bm, half_bk)
    x_hi = x[:, :, GROUP // 2:].reshape(bm, half_bk)

    acc_ref[...] += jnp.dot(x_lo, w_lo.T, preferred_element_type=jnp.float32)
    acc_ref[...] += jnp.dot(x_hi, w_hi.T, preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def q4_matmul_pallas(
    x: jax.Array,
    qw: QuantizedLinear,
    *,
    blocks: tuple[int, int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    """``x`` (M, K) f32/bf16 x Q4_0 (N, K) -> (M, N) in x.dtype."""
    m, k = x.shape
    n = qw.packed.shape[0]
    if qw.packed.shape[1] * 2 != k:
        raise ValueError("K mismatch between x and packed weights")
    bm, bn, bk = blocks
    if bk % GROUP:
        raise ValueError(f"bk={bk} must be a multiple of {GROUP}")
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{n},{k}) not divisible by blocks {blocks}")
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // 2), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // GROUP), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, qw.packed, qw.scales)
