"""Pallas TPU kernel: fused Q4_0 dequant + matmul (the paper's INT4 GEMV).

The paper's decode hot-spot is "Fp32-Int4-Fp32" GEMV: weights stay packed in
memory (0.5625 bytes/element) and are dequantized group-wise on the fly.
This is memory-bandwidth bound, so the TPU kernel's objective is to stream
the *packed* bytes HBM->VMEM (the f32 dequantized form exists only in
VMEM/VREGs) — the same reason Neural Speed fuses dequant into the VNNI
micro-kernel instead of materializing f32 weights.

Layout note (TPU-native rethink): llama.cpp packs element j and j+16 of a
32-group into one byte.  We keep that storage layout bit-for-bit (checkpoint
compatible) and unpack with a reshape-free trick: a (bn, bk/2) byte tile is
viewed as (bn, groups, 16); low and high nibbles are dequantized separately
against a broadcast scale and contracted against the matching halves of the
activation tile, avoiding any minor-dimension interleave on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships this under the TPU-prefixed name
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

from repro.quant.q4 import GROUP, QuantizedLinear

__all__ = ["q4_matmul_pallas", "q4_matmul_pallas_db", "DEFAULT_BLOCKS",
           "CANDIDATE_BLOCKS"]

# (bm, bn, bk): bk must be a multiple of GROUP (=32).
DEFAULT_BLOCKS = (8, 256, 512)
CANDIDATE_BLOCKS = (
    (8, 256, 512),
    (8, 512, 256),
    (8, 128, 1024),
    (128, 128, 512),
    (256, 256, 256),
)


def _kernel(x_ref, p_ref, s_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bn, half_bk = p_ref.shape
    groups = half_bk * 2 // GROUP
    bm, bk = x_ref.shape

    packed = p_ref[...].reshape(bn, groups, GROUP // 2)
    scales = s_ref[...].astype(jnp.float32)[..., None]  # (bn, groups, 1)
    # Dequantize both nibble planes: plane 0 = elements 0..15 of each group,
    # plane 1 = elements 16..31 (llama.cpp block_q4_0 layout).
    lo = (packed & 0x0F).astype(jnp.float32)
    hi = (packed >> 4).astype(jnp.float32)
    w_lo = ((lo - 8.0) * scales).reshape(bn, half_bk)
    w_hi = ((hi - 8.0) * scales).reshape(bn, half_bk)

    # Matching activation halves: x viewed as (bm, groups, 32); first 16
    # columns of each group hit the low plane, last 16 the high plane.
    x = x_ref[...].astype(jnp.float32).reshape(bm, groups, GROUP)
    x_lo = x[:, :, : GROUP // 2].reshape(bm, half_bk)
    x_hi = x[:, :, GROUP // 2:].reshape(bm, half_bk)

    acc_ref[...] += jnp.dot(x_lo, w_lo.T, preferred_element_type=jnp.float32)
    acc_ref[...] += jnp.dot(x_hi, w_hi.T, preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _dequant_tile(packed, scales, bn, half_bk):
    """Shared dequant of one (bn, bk/2) packed tile into the two nibble
    planes (see :func:`_kernel`'s layout note)."""
    groups = half_bk * 2 // GROUP
    p = packed.reshape(bn, groups, GROUP // 2)
    s = scales.astype(jnp.float32)[..., None]  # (bn, groups, 1)
    lo = (p & 0x0F).astype(jnp.float32)
    hi = (p >> 4).astype(jnp.float32)
    w_lo = ((lo - 8.0) * s).reshape(bn, half_bk)
    w_hi = ((hi - 8.0) * s).reshape(bn, half_bk)
    return w_lo, w_hi


def _db_kernel(x_ref, p_hbm, s_hbm, o_ref,
               p_buf, s_buf, acc_ref, p_sem, s_sem, *, bk: int):
    """Double-buffered variant of :func:`_kernel`: the packed weight tiles
    stay in HBM/ANY and are streamed into a two-slot VMEM scratch with
    async copies — the next K tile's DMA is issued *before* the current
    tile's dot products run, so on hardware the stream overlaps compute
    (shard-level double buffering; the decode GEMV is bandwidth-bound, so
    hiding the fetch behind the dot is the whole win).  Identical
    accumulation order to the plain kernel — per K tile, low-plane dot
    then high-plane dot — so outputs are bit-identical."""
    j = pl.program_id(1)
    _, bn, half_bk = p_buf.shape
    groups = bk // GROUP
    bm = x_ref.shape[0]
    nk = x_ref.shape[1] // bk

    def p_dma(slot, kk):
        return pltpu.make_async_copy(
            p_hbm.at[pl.ds(j * bn, bn), pl.ds(kk * half_bk, half_bk)],
            p_buf.at[slot], p_sem.at[slot])

    def s_dma(slot, kk):
        return pltpu.make_async_copy(
            s_hbm.at[pl.ds(j * bn, bn), pl.ds(kk * groups, groups)],
            s_buf.at[slot], s_sem.at[slot])

    # Warm up: start streaming tile 0 into slot 0.
    p_dma(0, 0).start()
    s_dma(0, 0).start()
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(kk, carry):
        slot = jax.lax.rem(kk, 2)
        nxt = jax.lax.rem(kk + 1, 2)

        # Prefetch the next tile into the other slot while this one computes.
        @pl.when(kk + 1 < nk)
        def _prefetch():
            p_dma(nxt, kk + 1).start()
            s_dma(nxt, kk + 1).start()

        p_dma(slot, kk).wait()
        s_dma(slot, kk).wait()

        w_lo, w_hi = _dequant_tile(p_buf[slot], s_buf[slot], bn, half_bk)
        x = x_ref[pl.ds(0, bm), pl.ds(kk * bk, bk)]
        x = x.astype(jnp.float32).reshape(bm, groups, GROUP)
        x_lo = x[:, :, : GROUP // 2].reshape(bm, half_bk)
        x_hi = x[:, :, GROUP // 2:].reshape(bm, half_bk)
        acc_ref[...] += jnp.dot(x_lo, w_lo.T,
                                preferred_element_type=jnp.float32)
        acc_ref[...] += jnp.dot(x_hi, w_hi.T,
                                preferred_element_type=jnp.float32)
        return carry

    jax.lax.fori_loop(0, nk, body, 0)
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def q4_matmul_pallas_db(
    x: jax.Array,
    qw: QuantizedLinear,
    *,
    blocks: tuple[int, int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    """Double-buffered ``x (M, K) x Q4_0 (N, K) -> (M, N)``: one grid over
    (M, N) tiles with the K stream hand-pipelined inside the kernel (two
    VMEM slots, DMA-prefetch of tile ``k+1`` overlapping tile ``k``'s
    compute).  Bit-identical to :func:`q4_matmul_pallas` at equal ``bk``."""
    m, k = x.shape
    n = qw.packed.shape[0]
    if qw.packed.shape[1] * 2 != k:
        raise ValueError("K mismatch between x and packed weights")
    bm, bn, bk = blocks
    if bk % GROUP:
        raise ValueError(f"bk={bk} must be a multiple of {GROUP}")
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{n},{k}) not divisible by blocks {blocks}")
    return pl.pallas_call(
        functools.partial(_db_kernel, bk=bk),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # packed stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),  # scales stay in HBM
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, bn, bk // 2), jnp.uint8),      # two packed slots
            pltpu.VMEM((2, bn, bk // GROUP), jnp.float16),
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(x, qw.packed, qw.scales)


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def q4_matmul_pallas(
    x: jax.Array,
    qw: QuantizedLinear,
    *,
    blocks: tuple[int, int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    """``x`` (M, K) f32/bf16 x Q4_0 (N, K) -> (M, N) in x.dtype."""
    m, k = x.shape
    n = qw.packed.shape[0]
    if qw.packed.shape[1] * 2 != k:
        raise ValueError("K mismatch between x and packed weights")
    bm, bn, bk = blocks
    if bk % GROUP:
        raise ValueError(f"bk={bk} must be a multiple of {GROUP}")
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{n},{k}) not divisible by blocks {blocks}")
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // 2), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // GROUP), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, qw.packed, qw.scales)
