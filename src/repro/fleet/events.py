"""Fleet traffic and node lifecycle events (open loop, fully seeded).

Cluster-scale serving sees traffic the single-engine generator
(:func:`repro.serving.traffic.poisson_requests`) does not model:

* **Heavy-tailed prompt lengths** — most prompts are short, a few are
  very long (the classic production length distribution).  Lengths are
  drawn from a clipped Pareto tail over ``prompt_len=(lo, hi)``.
* **Diurnal rate swings** — the arrival rate is a seeded schedule
  ``rate(t) = base * (1 + swing * sin(2*pi*t/period))``, realized as a
  non-homogeneous Poisson process via thinning, so load crests and
  troughs sweep across the run.
* **Node failure / recovery** — :class:`NodeEvent` entries interleaved
  with arrivals drain a node mid-run and later return it, forcing the
  fleet router's ratio table to re-converge twice.

Everything is determined by ``seed`` — the property every CI assertion
in this repository leans on.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serving import Request

__all__ = ["diurnal_rate", "fleet_requests", "NodeEvent", "failure_window"]


def diurnal_rate(base_rate: float, swing: float = 0.5,
                 period: float = 60.0):
    """The seeded rate schedule ``rate(t)`` for :func:`fleet_requests`:
    a sinusoidal swing of amplitude ``swing * base_rate`` around
    ``base_rate`` with the given ``period`` (virtual seconds).  Returned
    as a plain callable so tests can probe it directly."""
    if base_rate <= 0:
        raise ValueError("base_rate must be > 0")
    if not 0 <= swing < 1:
        raise ValueError("swing must be in [0, 1)")

    def rate(t: float) -> float:
        return base_rate * (1.0 + swing * np.sin(2.0 * np.pi * t / period))

    return rate


def fleet_requests(n: int, *, base_rate: float, vocab_size: int,
                   prompt_len: Tuple[int, int],
                   max_new_tokens: int | Tuple[int, int],
                   swing: float = 0.5, period: float = 60.0,
                   tail: float = 2.0, seed: int = 0,
                   stop_token: Optional[int] = None) -> List[Request]:
    """``n`` open-loop requests under a diurnal rate schedule with
    heavy-tailed prompt lengths.

    Arrivals realize the non-homogeneous Poisson process of
    :func:`diurnal_rate` by thinning: candidate gaps are exponential at
    the peak rate ``base_rate * (1 + swing)`` and each candidate is
    accepted with probability ``rate(t) / peak`` — exact, and fully
    determined by ``seed``.

    Prompt lengths are ``lo + round(X * scale)`` clipped to ``hi`` where
    ``X ~ Pareto(tail)``: the bulk sits near ``lo`` with a tail reaching
    ``hi`` (smaller ``tail`` = heavier tail).  ``max_new_tokens`` may be
    a scalar or a uniform ``(lo, hi)`` range.
    """
    if n < 1:
        raise ValueError("need at least one request")
    lo, hi = prompt_len
    if not 1 <= lo <= hi:
        raise ValueError("prompt_len must satisfy 1 <= lo <= hi")
    rng = np.random.default_rng(seed)
    rate = diurnal_rate(base_rate, swing, period)
    peak = base_rate * (1.0 + swing)

    arrivals, t = [], 0.0
    while len(arrivals) < n:
        t += rng.exponential(1.0 / peak)
        if rng.uniform() <= rate(t) / peak:
            arrivals.append(t)

    # heavy-tailed lengths: Pareto tail scaled so the 8x-median ballpark
    # lands inside the range, then clipped to hi
    scale = max((hi - lo) / 8.0, 1.0)

    def draw_len() -> int:
        return min(hi, lo + int(round(rng.pareto(tail) * scale)))

    def draw_new() -> int:
        if isinstance(max_new_tokens, (int, np.integer)):
            return int(max_new_tokens)
        a, b = max_new_tokens
        return int(rng.integers(a, b + 1))

    out = []
    for i in range(n):
        s0 = draw_len()
        out.append(Request(
            prompt=rng.integers(0, vocab_size, size=s0, dtype=np.int32),
            max_new_tokens=draw_new(),
            arrival_time=float(arrivals[i]),
            stop_token=stop_token,
        ))
    return out


@dataclass(frozen=True)
class NodeEvent:
    """One node lifecycle event on the fleet timeline.

    ``kind="fail"`` drains the node: its queued (still-WAITING) requests
    are rerouted to surviving nodes, admitted work is aborted, and the
    node stops contributing feedback.  ``kind="recover"`` returns it to
    service (the router's table then re-learns its share).
    """

    time: float
    node: str
    kind: str  # "fail" | "recover"

    def __post_init__(self) -> None:
        if self.kind not in ("fail", "recover"):
            raise ValueError(f"unknown event kind {self.kind!r}")


def failure_window(node: str, fail_at: float,
                   recover_at: Optional[float] = None) -> List[NodeEvent]:
    """A fail event, plus the matching recovery when ``recover_at`` is
    given — the bench's mid-run outage in one call."""
    out = [NodeEvent(time=fail_at, node=node, kind="fail")]
    if recover_at is not None:
        if recover_at <= fail_at:
            raise ValueError("recover_at must be after fail_at")
        out.append(NodeEvent(time=recover_at, node=node, kind="recover"))
    return out
