"""Fleet-scale serving: the paper's balancing loop, applied recursively.

The single-machine story is a two-level hierarchy — per-core ratio
tables inside each socket's cost model, a per-socket
:class:`~repro.serving.InflightDispatcher` above them.  This package
adds the third level: a :class:`Cluster` of named heterogeneous nodes
(multi-socket, flat, throttled), a :class:`FleetRouter` whose policy is
a :class:`~repro.runtime.RecursivePolicy` — a node-level
:class:`~repro.runtime.RatioTable` whose workers are themselves
Balancer-backed dispatchers — and an :class:`AdmissionController`
shedding or degrading what the fleet cannot finish within its SLOs.

Everything runs on the shared virtual clock, so fleet runs (traffic,
failures, routing decisions) are exactly reproducible from a seed.
"""

from .admission import AdmissionController
from .cluster import Cluster, Node, NodeSpec
from .events import NodeEvent, diurnal_rate, failure_window, fleet_requests
from .router import FleetRouter, run_fleet

__all__ = [
    "AdmissionController",
    "Cluster",
    "Node",
    "NodeSpec",
    "NodeEvent",
    "diurnal_rate",
    "failure_window",
    "fleet_requests",
    "FleetRouter",
    "run_fleet",
]
