"""SLO-aware admission control at the fleet front door.

Routing alone cannot save an overloaded fleet: once every node's queue
is deep, spreading work merely spreads the lateness.  The
:class:`AdmissionController` sits in front of
:class:`~repro.fleet.router.FleetRouter.submit` and keeps the *admitted*
work finishable:

* **Queue-depth cap** (``queue_cap``) — shed arrivals outright when the
  fleet-wide queue depth (running + prefilling + waiting across active
  nodes) is already at the cap.  Classic load shedding: a request that
  would only wait is cheaper to reject at arrival than to time out
  after holding a slot.
* **Deadline shedding** — a request carrying ``deadline`` is shed when
  the controller's completion estimate (from the router's learned
  per-node tokens/s EWMAs) lands past it.  No estimate yet -> admit
  (cold start must not shed).
* **Graceful degradation** (``degrade_depth``) — between "fine" and
  "shed" there is "shorter": past this depth, ``max_new_tokens`` is
  scaled by ``degrade_factor`` (floor ``min_new_tokens``) and the
  request is marked ``degraded`` so
  :class:`~repro.serving.LatencyReport` accounts for it.

Shed requests are finished on the spot (``FinishReason.SHED``, zero
engine work) and land in the router's ``finished`` list, so goodput
reports see exactly what was sacrificed.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core import events as _ev
from repro.serving import DECODE, PREFILL, FinishReason, Request, RequestState

__all__ = ["AdmissionController"]


@dataclass
class AdmissionController:
    """Front-door policy: shed, degrade, or admit.  All thresholds are
    optional — the default-constructed controller admits everything."""

    queue_cap: Optional[int] = None       # fleet queue depth hard cap
    degrade_depth: Optional[int] = None   # start shrinking max_new_tokens
    degrade_factor: float = 0.5
    min_new_tokens: int = 1
    slack: float = 1.0                    # estimate multiplier for deadlines

    def __post_init__(self) -> None:
        if self.queue_cap is not None and self.queue_cap < 1:
            raise ValueError("queue_cap must be >= 1")
        if self.degrade_depth is not None and self.degrade_depth < 0:
            raise ValueError("degrade_depth must be >= 0")
        if not 0 < self.degrade_factor <= 1:
            raise ValueError("degrade_factor must be in (0, 1]")
        if self.min_new_tokens < 1:
            raise ValueError("min_new_tokens must be >= 1")
        self.n_shed = 0
        self.n_degraded = 0

    # ----------------------------------------------------------------- API --
    def consider(self, request: Request, router) -> bool:
        """Mutate-and-verdict: True to route ``request``, False when it was
        shed (already finished with ``FinishReason.SHED``)."""
        depth = self._fleet_depth(router)
        if self.queue_cap is not None and depth >= self.queue_cap:
            self._shed(request, router.now)
            self._note(router, "shed", depth, reason="queue_cap")
            return False
        if request.deadline is not None:
            est = self.estimate_finish(request, router)
            if est is not None and est > request.deadline:
                self._shed(request, router.now)
                self._note(router, "shed", depth, reason="deadline",
                           estimate=round(float(est), 6),
                           deadline=float(request.deadline))
                return False
        if (self.degrade_depth is not None and depth >= self.degrade_depth
                and request.max_new_tokens > self.min_new_tokens):
            request.max_new_tokens = max(
                self.min_new_tokens,
                int(request.max_new_tokens * self.degrade_factor))
            request.degraded = True
            self.n_degraded += 1
            self._note(router, "degrade", depth,
                       max_new_tokens=int(request.max_new_tokens))
        return True

    def estimate_finish(self, request: Request, router) -> Optional[float]:
        """Completion-time estimate against the *best* node's learned
        throughput: queued prefill work plus the new prompt at the node's
        prefill rate, then decode at its per-slot share of the decode
        rate.  ``None`` before the first feedback window (no basis)."""
        pf = router.node_tps(PREFILL)
        dec = router.node_tps(DECODE)
        best: Optional[float] = None
        for i, node in enumerate(router.cluster.nodes):
            if not node.active:
                continue
            if not (np.isfinite(pf[i]) and np.isfinite(dec[i])):
                continue
            ttft = (node.pending_prefill_tokens
                    + request.prompt_len) / max(pf[i], 1e-9)
            # Decode throughput is shared with everything already in the
            # node, but only while those requests still owe tokens: an
            # in-flight request contends for min(its remaining tokens,
            # this request's lifetime).  Degraded admissions (clamped
            # max_new_tokens) therefore shrink the estimate — backlog
            # equals queue_depth * max_new_tokens (the old flat-depth
            # model) only when every in-flight request outlives this one.
            probe = getattr(node, "remaining_decode_tokens", None)
            if callable(probe):
                backlog = probe(cap=request.max_new_tokens)
            else:
                backlog = node.queue_depth * request.max_new_tokens
            est = ttft + (request.max_new_tokens
                          + backlog) / max(dec[i], 1e-9)
            if best is None or est < best:
                best = est
        if best is None:
            return None
        return router.now + self.slack * best

    # ------------------------------------------------------------- helpers --
    @staticmethod
    def _fleet_depth(router) -> int:
        return sum(node.queue_depth for node in router.cluster.nodes
                   if node.active)

    @staticmethod
    def _note(router, decision: str, depth: int, **payload) -> None:
        """Telemetry for a non-default verdict: a trace instant plus a
        flight-recorder record (both no-ops when nothing is installed)."""
        _ev.emit_instant("fleet", f"admission:{decision}", router.now,
                         args=lambda: {"decision": decision,
                                       "depth": int(depth), **payload})
        if _ev.RECORDER is not None:
            _ev.record("admission", decision, t=router.now,
                       depth=int(depth), **payload)

    def _shed(self, request: Request, now: float) -> None:
        request.state = RequestState.FINISHED
        request.finish_reason = FinishReason.SHED
        request.finish_time = now
        self.n_shed += 1
