"""Cluster model: named heterogeneous nodes under one virtual clock.

A :class:`Node` is one machine of the fleet — a
:class:`~repro.topology.MachineTopology` (multi-socket, flat, or a
throttled box) running one
:class:`~repro.serving.ContinuousBatchingEngine` replica per socket,
each clocked by a :class:`~repro.serving.HybridPhaseCost` over that
socket's simulated cores, and routed internally by an
:class:`~repro.serving.InflightDispatcher`.  A node is therefore itself
a two-level balancing domain (socket -> core); the
:class:`~repro.fleet.router.FleetRouter` adds the third level on top.

The :class:`Cluster` clock is the slowest node's engine clock — nodes
run concurrently, so fleet time is ``max`` over node times, exactly the
dispatcher-over-replicas convention one level down.  All time is virtual
(deterministic), so fleet runs are exactly reproducible.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core import events as _ev
from repro.serving import (
    ContinuousBatchingEngine,
    HybridPhaseCost,
    InflightDispatcher,
    Request,
)
from repro.serving.scheduler import IterationStats
from repro.topology import MachineTopology, make_topology

__all__ = ["NodeSpec", "Node", "Cluster"]

_FOREVER = (0.0, 1e18)


@dataclass(frozen=True)
class NodeSpec:
    """Declarative description of one fleet node.

    ``topology`` is a topology/machine name (``"dual-125h"``,
    ``"2s-12900k"``, ``"ultra-125h"``, ...) or a ready
    :class:`MachineTopology`.  ``throttle > 1`` applies a permanent
    background slowdown to every core — the "throttled box" whose
    *nominal* capacity (what static partitioning sees) stays high while
    its real throughput is ``1/throttle`` of it.
    """

    name: str
    topology: Union[str, MachineTopology]
    max_slots: int = 4
    prefill_chunk: Optional[int] = 8
    prefill_lanes: int = 1
    throttle: float = 1.0

    def __post_init__(self) -> None:
        if self.throttle < 1.0:
            raise ValueError("throttle must be >= 1 (1 = unthrottled)")


class Node:
    """One cluster node: per-socket engine replicas behind an in-node
    dispatcher, plus the liveness switch the fleet's failure events flip."""

    def __init__(self, spec: NodeSpec, cfg, params, *, max_seq: int,
                 seed: int = 0, alpha: float = 0.3):
        self.spec = spec
        self.name = spec.name
        topo = (make_topology(spec.topology, seed=seed)
                if isinstance(spec.topology, str) else spec.topology)
        self.topology = topo
        if spec.throttle > 1.0:
            # the throttle is background load on the *simulated machines*:
            # both kernel timing and the virtual clock see it, nominal
            # bandwidth numbers do not
            for m in topo.machines:
                for core in range(m.n_cores):
                    m.background.append((*_FOREVER, core, spec.throttle))
        self.engines = [
            ContinuousBatchingEngine(
                cfg, params, max_slots=spec.max_slots, max_seq=max_seq,
                prefill_chunk=spec.prefill_chunk,
                prefill_lanes=spec.prefill_lanes,
                cost_model=HybridPhaseCost(machine))
            for machine in topo.machines
        ]
        self.dispatcher = InflightDispatcher(self.engines, alpha=alpha)
        self.active = True

    # ------------------------------------------------------------- probes --
    @property
    def now(self) -> float:
        return max(e.now for e in self.engines)

    @property
    def has_work(self) -> bool:
        return self.dispatcher.has_work

    @property
    def pending_prefill_tokens(self) -> int:
        return self.dispatcher.pending_prefill_tokens

    @property
    def queue_depth(self) -> int:
        return self.dispatcher.queue_depth

    def remaining_decode_tokens(self, cap: Optional[int] = None) -> int:
        """Decode tokens still owed to requests the node already owns
        (waiting + prefilling + running), optionally capping each
        request's remainder at ``cap``.  Degraded requests (clamped
        ``max_new_tokens``) owe less — the admission controller's
        deadline estimates read actual backlog instead of assuming every
        in-flight request contends forever."""
        total = 0
        for e in self.engines:
            for r in e.outstanding():
                rem = max(0, r.max_new_tokens - r.n_generated)
                total += min(rem, cap) if cap is not None else rem
        return total

    @property
    def nominal_capacity(self) -> float:
        """Aggregate streaming bandwidth on paper — what a static
        capacity-share partition weights by.  Deliberately blind to
        ``throttle``: nominal numbers don't know about background load
        (that asymmetry is the fleet study's point).  Capacity events are
        different: core parking is *observable* (the OS publishes it), so
        parked cores' bandwidth is subtracted — this is the number
        :meth:`replan_capacity` re-plans when an event fires mid-serve."""
        return self.topology.active_bandwidth(self.now)

    # ------------------------------------------------------------ serving --
    def submit(self, request: Request) -> tuple:
        if not self.active:
            raise ValueError(f"node {self.name!r} is failed")
        return self.dispatcher.submit(request)

    def step(self) -> List[IterationStats]:
        if not self.active:
            return []
        if _ev.TRACER is not None:
            # node scope: one trace process per node (replicas nest inside)
            _ev.push_scope(f"node:{self.name}")
            try:
                return self.dispatcher.step()
            finally:
                _ev.pop_scope()
        return self.dispatcher.step()

    def poll_finished(self) -> List[Request]:
        return self.dispatcher.poll_finished()

    # ------------------------------------------------------------ failure --
    def fail(self) -> List[Request]:
        """Drain the node: still-WAITING requests are extracted (they never
        executed — the retry-able half, returned for resubmission
        elsewhere), admitted requests are aborted (their cache state dies
        with the node).  The node stops stepping and reporting."""
        self.active = False
        requeued: List[Request] = []
        for e in self.engines:
            requeued.extend(e.steal_waiting())
            for r in e.outstanding():   # lanes + decode batch
                e.abort(r)
        requeued.sort(key=lambda r: r.arrival_time)
        return requeued

    def recover(self) -> None:
        self.active = True

    # ----------------------------------------------------------- capacity --
    def replan_capacity(self, now: Optional[float] = None) -> None:
        """Re-plan the node after a capacity event: sample each socket's
        active mask and adjust what the serving stack asks of it.

        * **Partially parked socket** — shrink that engine's soft
          ``slot_budget`` proportionally (floored at 1): fewer concurrent
          requests are admitted while the remaining cores absorb the
          in-flight ones.  No state is evicted, nothing retraces.
        * **Fully parked socket** — deactivate its replica in the
          dispatcher (``set_active``): *admitted* work freezes in place
          and resumes on unpark (deliberately unlike :meth:`fail`, which
          aborts — parked state survives), while still-waiting requests
          are stolen back and resubmitted through routing so live sockets
          pick them up.  If every socket is parked they wait in the
          dispatcher's ``pending`` queue.
        * **Returned socket** — restore the budget and reactivate (which
          also flushes any pending queue).

        ``now`` defaults to the node clock; capacity events applied with
        the from-now-on ``[0, inf)`` idiom are visible on every timeline
        regardless of clock skew.
        """
        t = self.now if now is None else now
        for s, (machine, engine) in enumerate(
                zip(self.topology.machines, self.engines)):
            mask = machine.active_mask(t)
            if not mask.any():
                if self.dispatcher.active[s]:
                    requeued = engine.steal_waiting()
                    self.dispatcher.set_active(s, False)
                    for r in requeued:
                        self.dispatcher.submit(r)
                continue
            frac = float(mask.mean())
            engine.set_slot_budget(int(round(engine.max_slots * frac)))
            if not self.dispatcher.active[s]:
                self.dispatcher.set_active(s, True)


class Cluster:
    """Named nodes under one fleet clock."""

    def __init__(self, nodes: Sequence[Node]):
        if not nodes:
            raise ValueError("need at least one node")
        names = [n.name for n in nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate node names: {names}")
        self.nodes = list(nodes)
        self.by_name: Dict[str, Node] = {n.name: n for n in self.nodes}

    @classmethod
    def build(cls, specs: Sequence[NodeSpec], cfg, params, *, max_seq: int,
              seed: int = 0, alpha: float = 0.3) -> "Cluster":
        """One shared model (cfg, params) across all nodes — engines with
        identical shapes share jit caches, so a 6-socket fleet compiles
        once.  Node ``i`` seeds its topology ``seed + i`` (distinct jitter
        streams)."""
        return cls([Node(spec, cfg, params, max_seq=max_seq, seed=seed + i,
                         alpha=alpha)
                    for i, spec in enumerate(specs)])

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def now(self) -> float:
        """Fleet clock: slowest node (nodes run concurrently)."""
        return max(n.now for n in self.nodes)

    @property
    def has_work(self) -> bool:
        return any(n.active and n.has_work for n in self.nodes)

    def nominal_shares(self) -> np.ndarray:
        caps = np.array([n.nominal_capacity for n in self.nodes],
                        dtype=np.float64)
        return caps / caps.sum()
