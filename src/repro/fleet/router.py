"""Fleet-level request routing: the paper's loop, third level.

:class:`FleetRouter` closes the measure -> normalize -> EMA -> split
loop over *nodes*: per-phase (tokens, seconds) windows aggregated from
every node's iteration stats feed a node-level
:class:`~repro.runtime.RatioTable` via ``units=``, and each arriving
request is routed to the node with the least ratio-normalized backlog,
discounted by that node's TTFT/TPOT headroom against the SLOs.

The balancer is *recursive*: its policy is a
:class:`~repro.runtime.RecursivePolicy` whose children are the nodes'
own :class:`~repro.serving.InflightDispatcher` balancing domains, so
every fleet-level report carries the per-node per-phase
:class:`~repro.runtime.RegionStats` underneath it
(``RegionStats.children``) — one telemetry tree spanning
cluster -> machine -> socket (and, inside each engine's cost model,
-> core).

Round-robin and static-capacity baselines run on the *same* code path
(same stepping, same feedback accounting, same failure handling); only
the argmin differs — so a goodput comparison isolates the routing
decision itself.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import events as _ev
from repro.runtime import Balancer, Plan, RatioTable, RecursivePolicy, StatsSink
from repro.serving import DECODE, PREFILL, Request

from .cluster import Cluster
from .events import NodeEvent

__all__ = ["FleetRouter", "run_fleet"]

PHASES = (PREFILL, DECODE)
EPS = 1e-9


class FleetRouter:
    """Route requests across cluster nodes by learned per-phase throughput
    ratios, backlog, and SLO headroom.

    ``policy`` selects the routing rule:

    * ``"learned"`` — ratio-normalized backlog (Eq. 3 over the node
      table) scaled by per-phase SLO headroom;
    * ``"round_robin"`` — cycle over active nodes;
    * ``"static"`` — weighted round-robin proportional to fixed shares
      (``static_shares``, default the nodes' *nominal* capacities).

    All policies skip failed nodes and share the feedback plumbing, so
    the learned table keeps converging even under a baseline policy (it
    is simply ignored by the argmin).
    """

    POLICIES = ("learned", "round_robin", "static")

    def __init__(self, cluster: Cluster, *, policy: str = "learned",
                 table: Optional[RatioTable] = None, alpha: float = 0.3,
                 static_shares: Optional[Sequence[float]] = None,
                 slo_ttft: Optional[float] = None,
                 slo_tpot: Optional[float] = None,
                 admission=None, sink: Optional[StatsSink] = None):
        if policy not in self.POLICIES:
            raise ValueError(f"policy must be one of {self.POLICIES}")
        self.cluster = cluster
        self.policy = policy
        n = cluster.n_nodes
        self.table = table or RatioTable(n, alpha=alpha)
        if self.table.n_workers != n:
            raise ValueError("table size does not match node count")
        self.slo_ttft = slo_ttft
        self.slo_tpot = slo_tpot
        self.admission = admission
        # the recursive balancer: each phase's policy plans/reports over
        # the node table while snapshotting every node dispatcher's own
        # latest per-phase RegionStats as children
        self._balancers = {
            phase: Balancer(
                RecursivePolicy(
                    self.table, key=phase, feedback="units",
                    children=[
                        (lambda d=node.dispatcher, p=phase:
                         d.last_stats.get(p))
                        for node in cluster.nodes
                    ]),
                sink=sink, keep_stats=False)
            for phase in PHASES
        }
        self.last_stats: Dict[str, object] = {}
        # windowed per-phase (units, seconds) over nodes — same >=2-nodes
        # rule as the replica dispatcher one level down
        self._acc = {phase: (np.zeros(n, dtype=np.int64), np.zeros(n))
                     for phase in PHASES}
        # tokens/s EWMA per node per phase (admission's wait estimator)
        self._tps = {phase: np.full(n, np.nan) for phase in PHASES}
        self._tps_alpha = alpha
        # per-node latency EWMAs (headroom feedback)
        self._ttft_ewma = np.full(n, np.nan)
        self._tpot_ewma = np.full(n, np.nan)
        self._lat_alpha = alpha
        if static_shares is None:
            shares = cluster.nominal_shares()
        else:
            shares = np.asarray(static_shares, dtype=np.float64)
            if shares.shape != (n,) or (shares <= 0).any():
                raise ValueError("static_shares must be n positive weights")
            shares = shares / shares.sum()
        self.static_shares = shares
        self.routed = np.zeros(n, dtype=np.int64)
        self._rr = 0
        self.finished: List[Request] = []
        self.n_requeued = 0
        # requests that arrived while *every* node was failed — held at
        # the router (not crashed on) and flushed on the first recovery
        self._parked: List[Request] = []
        self.n_parked = 0

    # ------------------------------------------------------------- probes --
    @property
    def now(self) -> float:
        return self.cluster.now

    @property
    def has_work(self) -> bool:
        return self.cluster.has_work

    def node_tps(self, phase: str) -> np.ndarray:
        """Per-node observed tokens/s EWMA for ``phase`` (NaN before the
        first window lands)."""
        return self._tps[phase].copy()

    def headroom(self, i: int, phase: str) -> float:
        """SLO headroom of node ``i`` in ``phase``: 1 with full margin,
        shrinking toward the floor as the node's latency EWMA approaches
        (or passes) the SLO.  1.0 when no SLO is set or nothing finished
        on the node yet."""
        slo, ewma = ((self.slo_ttft, self._ttft_ewma) if phase == PREFILL
                     else (self.slo_tpot, self._tpot_ewma))
        if slo is None or not np.isfinite(ewma[i]):
            return 1.0
        return float(np.clip(1.0 - ewma[i] / slo, 0.05, 1.0))

    # ------------------------------------------------------------ routing --
    def route(self, request: Request) -> int:
        active = [i for i, node in enumerate(self.cluster.nodes)
                  if node.active]
        if not active:
            raise ValueError("no active node to route to")
        if self.policy == "round_robin":
            for _ in range(self.cluster.n_nodes):
                i = self._rr % self.cluster.n_nodes
                self._rr += 1
                if self.cluster.nodes[i].active:
                    return i
        if self.policy == "static":
            # deterministic weighted round-robin: the active node furthest
            # behind its share
            lag = [(self.routed[i] + 1) / self.static_shares[i]
                   for i in active]
            return active[int(np.argmin(lag))]
        # learned: ratio-normalized backlog / headroom, per phase (Eq. 3
        # with the node table's learned per-phase speeds)
        pf = np.maximum(self.table.ratios(PREFILL), EPS)
        dec = np.maximum(self.table.ratios(DECODE), EPS)
        scores = []
        for i in active:
            node = self.cluster.nodes[i]
            prefill_backlog = ((node.pending_prefill_tokens
                                + request.prompt_len) / pf[i])
            decode_backlog = ((node.queue_depth + 1)
                              * request.max_new_tokens / dec[i])
            scores.append(
                prefill_backlog / self.headroom(i, PREFILL)
                + decode_backlog / self.headroom(i, DECODE))
        return active[int(np.argmin(scores))]

    def submit(self, request: Request) -> Optional[int]:
        """Admission-check (when configured) then route and enqueue.
        Returns the node index, or None when the request was shed — or
        deferred: a request arriving during a fleet-wide failure window
        (every node down) parks at the router and is resubmitted through
        the full admission + routing path by the first recovery event,
        instead of aborting the run (``route`` keeps its raise for direct
        callers)."""
        if self.admission is not None:
            if not self.admission.consider(request, self):
                self.finished.append(request)
                return None
        if not any(node.active for node in self.cluster.nodes):
            self._parked.append(request)
            self.n_parked += 1
            if _ev.RECORDER is not None:
                _ev.record("admission", "parked", t=self.now,
                           decision="parked",
                           arrival=float(request.arrival_time))
            return None
        i = self.route(request)
        node = self.cluster.nodes[i]
        node.submit(request)
        self.routed[i] += 1
        _ev.emit_instant(
            "fleet", f"route:{node.name}", self.now,
            args=lambda: {"rid": int(request.request_id),
                          "node": node.name, "policy": self.policy,
                          "prompt_len": int(request.prompt_len)})
        if _ev.RECORDER is not None:
            _ev.record("route", node.name, t=self.now,
                       rid=int(request.request_id), policy=self.policy,
                       queue_depth=int(node.queue_depth))
        return i

    # ------------------------------------------------------------ driving --
    def step(self) -> None:
        """One iteration on every active node + fleet-level feedback."""
        cluster = self.cluster
        n = cluster.n_nodes
        units = {phase: np.zeros(n, dtype=np.int64) for phase in PHASES}
        times = {phase: np.zeros(n) for phase in PHASES}
        for i, node in enumerate(cluster.nodes):
            stats = node.step()
            if not stats:
                continue
            # node throughput = aggregate tokens over the slowest
            # replica's wall time (replicas run concurrently)
            units[PREFILL][i] = sum(s.prefill_tokens for s in stats)
            times[PREFILL][i] = max(s.prefill_seconds for s in stats)
            units[DECODE][i] = sum(s.decode_tokens for s in stats)
            times[DECODE][i] = max(s.decode_seconds for s in stats)
        for phase in PHASES:
            acc_u, acc_t = self._acc[phase]
            acc_u += units[phase]
            acc_t += times[phase]
            if (np.count_nonzero(acc_u) >= 2
                    or (n == 1 and acc_u.any())):
                self.last_stats[phase] = self._balancers[phase].report(
                    Plan(counts=acc_u.copy(), key=phase), acc_t.copy())
                self._update_tps(phase, acc_u, acc_t)
                acc_u[:] = 0
                acc_t[:] = 0.0
                _ev.emit_counter(
                    f"ratio:fleet:{phase}", self.now,
                    lambda phase=phase: {
                        f"n{i}": round(float(r), 5)
                        for i, r in enumerate(self.table.ratios(phase))})
        for i, node in enumerate(cluster.nodes):
            for r in node.poll_finished():
                self._observe_latency(i, r)
                self.finished.append(r)

    def _update_tps(self, phase: str, units: np.ndarray,
                    seconds: np.ndarray) -> None:
        tps = self._tps[phase]
        a = self._tps_alpha
        for i in range(len(tps)):
            if units[i] <= 0 or seconds[i] <= 0:
                continue  # absence of measurement, not a measurement
            sample = units[i] / seconds[i]
            tps[i] = sample if not np.isfinite(tps[i]) else (
                (1 - a) * tps[i] + a * sample)

    def _observe_latency(self, i: int, r: Request) -> None:
        if _ev.RECORDER is not None and (r.ttft is not None
                                         or r.tpot is not None):
            _ev.record("latency", self.cluster.nodes[i].name, t=self.now,
                       rid=int(r.request_id),
                       ttft=(None if r.ttft is None else float(r.ttft)),
                       tpot=(None if r.tpot is None else float(r.tpot)))
        a = self._lat_alpha
        if r.ttft is not None:
            e = self._ttft_ewma
            e[i] = r.ttft if not np.isfinite(e[i]) else (
                (1 - a) * e[i] + a * r.ttft)
        if r.tpot is not None:
            e = self._tpot_ewma
            e[i] = r.tpot if not np.isfinite(e[i]) else (
                (1 - a) * e[i] + a * r.tpot)

    # ------------------------------------------------------------- events --
    def apply_event(self, event: NodeEvent) -> None:
        node = self.cluster.by_name[event.node]
        i = self.cluster.nodes.index(node)
        _ev.emit_instant("fleet", f"{event.kind}:{event.node}", self.now,
                         args=lambda: {"node": event.node,
                                       "kind": event.kind})
        if _ev.RECORDER is not None:
            _ev.record("node_event", event.node, t=self.now,
                       event=event.kind)
        if event.kind == "fail":
            requeued = node.fail()
            # mask the dead node out of the feedback window: its partial
            # (units, seconds) sums are stale measurements that would
            # EMA-drag its ratio on the next report (the fleet-level twin
            # of InflightDispatcher.set_active)
            for acc_u, acc_t in self._acc.values():
                acc_u[i] = 0
                acc_t[i] = 0.0
            # collect the aborted ones now so their latency never pollutes
            # the headroom EWMAs of a node that is gone
            self.finished.extend(node.poll_finished())
            self.n_requeued += len(requeued)
            for r in requeued:  # reroute the never-executed queue
                self.submit(r)
        else:
            node.recover()
            if self._parked:
                # first node back: flush requests parked during the
                # fleet-wide outage, in arrival order, through the full
                # admission + routing path
                parked, self._parked = self._parked, []
                for r in parked:
                    self.submit(r)

    def run(self, requests: Sequence[Request],
            events: Sequence[NodeEvent] = ()) -> List[Request]:
        """Open-loop replay of ``requests`` interleaved with ``events`` on
        the fleet timeline; drives the cluster to completion and returns
        every finished request (including shed / aborted)."""
        return run_fleet(self, requests, events)


def run_fleet(router: FleetRouter, requests: Sequence[Request],
              events: Sequence[NodeEvent] = ()) -> List[Request]:
    """Drive a fleet run: progress in-flight work up to each arrival or
    event (so feedback from earlier requests steers later routing — the
    open-loop replay idiom), apply it, then drain."""
    timeline = sorted(
        [(r.arrival_time, 0, r) for r in requests]
        + [(e.time, 1, e) for e in events],
        key=lambda item: (item[0], item[1]))
    for t, kind, item in timeline:
        while router.has_work and router.now < t:
            router.step()
        if kind == 0:
            router.submit(item)
        else:
            router.apply_event(item)
    while router.has_work:
        router.step()
    for i, node in enumerate(router.cluster.nodes):
        for r in node.poll_finished():
            router._observe_latency(i, r)
            router.finished.append(r)
    return router.finished
