"""NUMA-aware weight placement for balanced trunks.

The decode step streams every trunk weight once per token, so *where* each
weight's bytes are resident decides which socket can stream them locally.
:func:`place_trunk` walks a :class:`~repro.models.balanced.BalancedTrunk`
and pins every banked projection's column (N-row) range to sockets —
contiguous ranges proportional to each socket's streaming bandwidth, the
placement that lets every domain's pool saturate on local traffic — and
registers the pinning with the trunk's :class:`~repro.topology.dispatch.
TopologyDispatcher`, which from then on charges the fabric penalty for any
dispatch outside the resident range.

Per-domain byte accounting comes with it: :class:`TrunkPlacement` records
the resident weight bytes per socket (packed Q4 bytes, s8 bytes, or f32
bytes — what the decode step actually streams), so the placement itself is
auditable next to the per-domain achieved-bandwidth fractions.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import numpy as np

from repro.quant.q4 import BYTES_PER_ELEM

from .dispatch import TopologyDispatcher
from .machine import place_rows

__all__ = ["place_rows", "place_trunk", "TrunkPlacement"]


def _weight_handle(layer) -> Tuple[object, int, float]:
    """(registry object, n rows, streamed bytes per row) for one balanced
    layer — the registry object must be the exact array the layer hands
    its dispatcher's kernel entry point."""
    from repro.models.layers import (
        BalancedFp32Linear,
        BalancedLinear,
        BalancedQuantLinear,
    )

    if isinstance(layer, BalancedQuantLinear):
        return layer.qw, layer.out_features, layer.qw.in_features * BYTES_PER_ELEM
    if isinstance(layer, BalancedLinear):
        return layer.w.q, layer.out_features, float(layer.w.q.shape[1])
    if isinstance(layer, BalancedFp32Linear):
        return layer.w, layer.out_features, 4.0 * layer.w.shape[1]
    raise TypeError(f"not a balanced linear: {type(layer).__name__}")


@dataclass
class TrunkPlacement:
    """The resident map of one placed trunk: per-layer socket ranges plus
    per-socket resident-byte totals."""

    shares: np.ndarray
    entries: List[tuple] = field(default_factory=list)  # (label, ranges)
    socket_bytes: np.ndarray = None

    @property
    def n_layers(self) -> int:
        return len(self.entries)

    @property
    def total_bytes(self) -> float:
        return float(self.socket_bytes.sum())

    def lines(self) -> List[str]:
        total = max(self.total_bytes, 1.0)
        frac = ", ".join(f"{b / total:.1%}" for b in self.socket_bytes)
        return [
            f"[placement] {self.n_layers} weights, "
            f"{self.total_bytes / 1e6:.2f} MB resident",
            f"[placement] per-socket bytes: [{frac}] "
            f"(bandwidth shares: {np.round(self.shares, 3).tolist()})",
        ]


def place_trunk(trunk, granularity: int = 1) -> TrunkPlacement:
    """Pin every banked projection (and the head) of ``trunk`` to the
    sockets of its dispatcher's topology.  Idempotent — re-placing simply
    overwrites the same registrations."""
    disp = trunk.dispatcher
    if not isinstance(disp, TopologyDispatcher):
        raise ValueError(
            "place_trunk needs a trunk bound to a repro.topology."
            "TopologyDispatcher; this trunk's dispatcher is "
            f"{type(disp).__name__}")
    if not disp.socket_local:
        raise ValueError("the socket-oblivious baseline interleaves pages "
                         "by construction; there is nothing to place")
    shares = disp.topology.bandwidth_shares()
    placement = TrunkPlacement(
        shares=shares,
        socket_bytes=np.zeros(disp.n_sockets, dtype=np.float64))
    layers = [(f"{group}.{name}[{j}][{r}]", layer)
              for (j, group, name), stack in sorted(trunk.bank.items())
              for r, layer in enumerate(stack)]
    if trunk.head is not None:
        layers.append(("head", trunk.head))
    for label, layer in layers:
        obj, n, bytes_per_row = _weight_handle(layer)
        ranges = place_rows(n, shares, granularity)
        disp.register_placement(obj, ranges)
        placement.entries.append((label, ranges))
        for s, (lo, hi) in enumerate(ranges):
            placement.socket_bytes[s] += (hi - lo) * bytes_per_row
    return placement
