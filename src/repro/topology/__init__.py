"""repro.topology — NUMA/multi-socket machine model and two-level dispatch.

Layers (bottom up):

* :mod:`machine` — :class:`SocketSpec`/:class:`BandwidthDomain`/
  :class:`MachineTopology`: N sockets, each its own bandwidth pool and
  seeded jitter stream, plus the cross-socket transfer penalty and the
  socket-oblivious flattened view.  The flat hybrid CPU is the 1-socket
  special case.
* :mod:`dispatch` — :class:`TopologyDispatcher`: the paper's Eq. 2/3 loop
  per socket (one flat dispatcher per bandwidth domain) under a
  socket-level proportional split learned with ``units=`` feedback; or
  the socket-oblivious baseline over the flattened machine.
* :mod:`placement` — NUMA-aware weight placement for balanced trunks:
  column ranges pinned to the socket that streams them, with per-domain
  resident-byte accounting.
"""

from .machine import (
    BandwidthDomain,
    MachineTopology,
    SocketSpec,
    TOPOLOGIES,
    make_2s_12900k,
    make_dual_125h,
    make_topology,
)
from .dispatch import TopologyDispatcher
from .placement import TrunkPlacement, place_rows, place_trunk

__all__ = [
    "BandwidthDomain",
    "SocketSpec",
    "MachineTopology",
    "TOPOLOGIES",
    "make_dual_125h",
    "make_2s_12900k",
    "make_topology",
    "TopologyDispatcher",
    "TrunkPlacement",
    "place_rows",
    "place_trunk",
]
