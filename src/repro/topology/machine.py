"""NUMA / multi-socket machine model: per-socket bandwidth domains.

The flat :class:`~repro.core.hybrid_sim.SimulatedHybridCPU` models one
socket whose cores share one memory-bandwidth pool — the machine the
paper's dynamic ratio loop was written for.  Real AIPC-class deployments
increasingly span multiple sockets (or tiles/clusters) where bandwidth
contention is *per-socket*: a core streams its local DRAM at full speed
but pays a fabric transfer penalty (UPI/IF-style) for bytes resident on
another socket, and each socket's pool is contended only by the work
assigned to *that* socket.

:class:`MachineTopology` composes one :class:`SimulatedHybridCPU` per
socket (each with its own seeded jitter stream and background-load list),
so the existing virtual-time pools, ratio tables, and dispatchers all
apply unchanged *within* a socket.  What the topology adds:

* :class:`BandwidthDomain` views — name, cores, streaming bandwidth — the
  per-domain denominators of the achieved-bandwidth fraction;
* ``cross_socket_penalty`` — the multiplicative wall-time cost of
  streaming one remote byte relative to a local one (typical 2-socket
  boards: remote sustained bandwidth ~55-65% of local, so ~1.8);
* ``flattened()`` — the socket-oblivious view: every core in one flat
  machine, which is what a NUMA-unaware dispatcher sees.  With
  interleaved (first-touch-oblivious) page placement each core streams
  ``(S-1)/S`` of its bytes remotely, captured by ``oblivious_blend``.

The flat machine is exactly the 1-socket special case: a
``MachineTopology`` with one socket has blend 1.0, zero remote traffic,
and ``aggregate_bandwidth == socket_bandwidth``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List

import numpy as np

from repro.core.hybrid_sim import (
    CoreSpec,
    SimulatedHybridCPU,
    make_12900k,
    make_ultra_125h,
)
from repro.core.ratio import proportional_partition

__all__ = [
    "BandwidthDomain",
    "SocketSpec",
    "MachineTopology",
    "make_dual_125h",
    "make_2s_12900k",
    "TOPOLOGIES",
    "make_topology",
    "place_rows",
]

MEMBW = "membw"


def place_rows(n: int, shares, granularity: int = 1) -> tuple:
    """Contiguous per-socket ``(lo, hi)`` ranges of ``n`` rows proportional
    to ``shares`` — the single counts-to-ranges conversion both the
    dispatch-side default placement and :func:`~repro.topology.placement.
    place_trunk` pin weights with (one implementation, so the fabric
    penalty can never see two different notions of "resident")."""
    counts = proportional_partition(n, np.asarray(shares, dtype=np.float64),
                                    granularity)
    out, cursor = [], 0
    for c in counts:
        out.append((cursor, cursor + int(c)))
        cursor += int(c)
    return tuple(out)


@dataclass(frozen=True)
class SocketSpec:
    """One socket (bandwidth domain) of a multi-socket machine: a name and
    the cores that contend for its local memory pool."""

    name: str
    cores: List[CoreSpec]

    @property
    def n_cores(self) -> int:
        return len(self.cores)

    @property
    def bandwidth(self) -> float:
        """Streaming bandwidth of this socket's pool (sum of its cores'
        sustainable draws — the per-socket MLC analogue)."""
        return float(sum(c.throughput[MEMBW] for c in self.cores))


@dataclass(frozen=True)
class BandwidthDomain:
    """Read-only view of one socket as a bandwidth domain: the unit the
    two-level balancer's outer split operates over."""

    index: int
    name: str
    bandwidth: float       # bytes/s, local streaming
    core_start: int        # global core index range [core_start, core_end)
    core_end: int

    @property
    def n_cores(self) -> int:
        return self.core_end - self.core_start


@dataclass
class MachineTopology:
    """N sockets, each its own bandwidth pool; cross-socket transfer pays a
    multiplicative penalty.

    Each socket is materialized as a flat :class:`SimulatedHybridCPU` (its
    cores, its jitter stream seeded ``seed + socket_index``, its own
    ``background`` throttle list), available via :attr:`machines` — the
    object per-socket worker pools and dispatchers run on.
    """

    sockets: List[SocketSpec]
    cross_socket_penalty: float = 1.8
    seed: int = 0
    name: str = ""
    machines: List[SimulatedHybridCPU] = field(init=False)

    def __post_init__(self) -> None:
        if not self.sockets:
            raise ValueError("topology needs at least one socket")
        if self.cross_socket_penalty < 1.0:
            raise ValueError("cross_socket_penalty must be >= 1")
        self.machines = [
            SimulatedHybridCPU(cores=list(s.cores), seed=self.seed + i)
            for i, s in enumerate(self.sockets)
        ]

    # ------------------------------------------------------------- shape ---
    @property
    def n_sockets(self) -> int:
        return len(self.sockets)

    @property
    def n_cores(self) -> int:
        return sum(s.n_cores for s in self.sockets)

    def socket_of(self, core: int) -> int:
        """Socket index owning global core index ``core``."""
        for d in self.domains():
            if d.core_start <= core < d.core_end:
                return d.index
        raise IndexError(f"core {core} out of range for {self.n_cores} cores")

    def domains(self) -> List[BandwidthDomain]:
        out, start = [], 0
        for i, s in enumerate(self.sockets):
            out.append(BandwidthDomain(
                index=i, name=s.name, bandwidth=s.bandwidth,
                core_start=start, core_end=start + s.n_cores))
            start += s.n_cores
        return out

    # --------------------------------------------------------- bandwidth ---
    def socket_bandwidth(self, socket: int) -> float:
        return self.sockets[socket].bandwidth

    @property
    def aggregate_bandwidth(self) -> float:
        """Sum of per-socket streaming bandwidths — the denominator of the
        *aggregate* achieved-bandwidth fraction (every pool saturated by
        local traffic; no machine can exceed it)."""
        return float(sum(s.bandwidth for s in self.sockets))

    def bandwidth_shares(self) -> np.ndarray:
        """Per-socket fraction of aggregate bandwidth — the NUMA placement
        prior (bytes live where they can be streamed fastest)."""
        bw = np.array([s.bandwidth for s in self.sockets], dtype=np.float64)
        return bw / bw.sum()

    # ---------------------------------------------------------- capacity ---
    def park_core(self, core: int, t_start: float = 0.0,
                  t_end: float = float("inf")) -> None:
        """Park global core index ``core`` (routed to its socket machine)."""
        s = self.socket_of(core)
        local = core - self.domains()[s].core_start
        self.machines[s].park(local, t_start, t_end)

    def unpark_core(self, core: int) -> None:
        s = self.socket_of(core)
        local = core - self.domains()[s].core_start
        self.machines[s].unpark(local)

    def park_socket(self, socket: int, t_start: float = 0.0,
                    t_end: float = float("inf")) -> None:
        """Park every core of ``socket`` — a socket's worth of capacity
        gone (thermal trip, foreground app pinned to one tile)."""
        m = self.machines[socket]
        for local in range(m.n_cores):
            m.park(local, t_start, t_end)

    def unpark_socket(self, socket: int) -> None:
        m = self.machines[socket]
        for local in range(m.n_cores):
            m.unpark(local)

    def active_mask(self, now: float = 0.0) -> np.ndarray:
        """Global-core boolean mask: concatenation of per-socket masks."""
        return np.concatenate([m.active_mask(now) for m in self.machines])

    def active_bandwidth(self, now: float = 0.0) -> float:
        """Aggregate streaming bandwidth of *active* cores only — what
        ``Node.nominal_capacity`` re-plans to when a capacity event fires."""
        total = 0.0
        for m in self.machines:
            mask = m.active_mask(now)
            total += float(m.true_throughput(MEMBW)[mask].sum())
        return total

    # ------------------------------------------------- oblivious baseline --
    @property
    def oblivious_blend(self) -> float:
        """Effective per-byte wall-time multiplier of socket-oblivious
        streaming: with interleaved (NUMA-unaware) page placement a core
        finds ``(S-1)/S`` of its bytes on remote sockets, each costing
        ``cross_socket_penalty`` relative to a local byte."""
        s = self.n_sockets
        if s <= 1:
            return 1.0
        remote = (s - 1) / s
        return 1.0 + (self.cross_socket_penalty - 1.0) * remote

    def flattened(self, seed_offset: int = 0) -> SimulatedHybridCPU:
        """All cores as one flat machine — the socket-oblivious view (also
        the clock source for phase cost models that only need total
        compute).  Bandwidth pools are *not* merged: the flat machine's
        ``socket_bandwidth`` equals :attr:`aggregate_bandwidth`, and
        NUMA-oblivious callers must additionally pay
        :attr:`oblivious_blend` per streamed byte."""
        cores: List[CoreSpec] = []
        for s in self.sockets:
            cores.extend(s.cores)
        return SimulatedHybridCPU(cores=cores, seed=self.seed + seed_offset)


# ----------------------------------------------------------- constructors --
def _renamed(cores: List[CoreSpec], socket: int) -> List[CoreSpec]:
    return [CoreSpec(name=f"s{socket}.{c.name}", kind=c.kind,
                     throughput=dict(c.throughput), jitter=c.jitter)
            for c in cores]


def _dual(flat_factory: Callable[..., SimulatedHybridCPU], name: str,
          seed: int, penalty: float) -> MachineTopology:
    sockets = [
        SocketSpec(name=f"socket{i}",
                   cores=_renamed(flat_factory(seed=0).cores, i))
        for i in range(2)
    ]
    return MachineTopology(sockets=sockets, cross_socket_penalty=penalty,
                           seed=seed, name=name)


def make_dual_125h(seed: int = 0) -> MachineTopology:
    """Two Ultra-7-125H clusters behind a fabric: the AIPC scale-out
    configuration — each cluster keeps its own LPDDR5x pool (~89.6 GB/s),
    remote streaming sustains ~55% of local (penalty 1.8)."""
    return _dual(make_ultra_125h, "dual-125h", seed, penalty=1.8)


def make_2s_12900k(seed: int = 0) -> MachineTopology:
    """Dual-socket 12900K-class board: per-socket DDR5-4800 dual channel
    (~76.8 GB/s each), UPI-style interconnect (penalty 1.8)."""
    return _dual(make_12900k, "2s-12900k", seed, penalty=1.8)


TOPOLOGIES: Dict[str, Callable[..., MachineTopology]] = {
    "dual-125h": make_dual_125h,
    "2s-12900k": make_2s_12900k,
}


def make_topology(name: str, seed: int = 0) -> MachineTopology:
    """Resolve ``name`` to a :class:`MachineTopology`.  Flat machine names
    (see :data:`repro.core.hybrid_sim.MACHINES`) are wrapped as their
    1-socket special case, so every machine in the repository is a valid
    topology."""
    from repro.core.hybrid_sim import MACHINES

    if name in TOPOLOGIES:
        return TOPOLOGIES[name](seed=seed)
    if name in MACHINES:
        flat = MACHINES[name](seed)
        return MachineTopology(
            sockets=[SocketSpec(name="socket0", cores=list(flat.cores))],
            cross_socket_penalty=1.0, seed=seed, name=name)
    raise KeyError(
        f"unknown machine {name!r}; known: {sorted(MACHINES)}; "
        f"topology machines: {sorted(TOPOLOGIES)}")
