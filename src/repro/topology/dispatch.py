"""Two-level balanced dispatch over a NUMA topology.

:class:`TopologyDispatcher` generalizes the flat
:class:`~repro.kernels.dispatch.HybridKernelDispatcher` to a
:class:`~repro.topology.machine.MachineTopology`:

* **inner level** — one flat dispatcher per socket, each owning its own
  per-core :class:`~repro.runtime.RatioTable` and virtual worker pools
  over that socket's :class:`~repro.core.hybrid_sim.SimulatedHybridCPU`.
  The paper's Eq. 2/3 loop runs unchanged *within* each bandwidth domain,
  which is exactly where its shared-pool assumption holds.
* **outer level** — a socket-level :class:`~repro.runtime.RatioTable`
  (one entry per socket, ``units=`` feedback since granularity rounding
  makes realized counts differ from the proportional plan) splits every
  GEMM/GEMV's N dimension into one contiguous column range per socket.
  Sockets execute concurrently: the region's wall time is the max of the
  per-socket makespans, and the feedback converges the split to the point
  where all domains finish together.

NUMA placement closes the loop: each weight's column ranges are pinned to
sockets (see :mod:`repro.topology.placement`; default: proportional to
socket bandwidth).  A socket assigned columns outside its resident range
streams them across the fabric at ``cross_socket_penalty`` wall time per
byte — modelled by inflating the region's work (never its bytes: a remote
byte is still one byte of traffic, it just takes longer), so the learned
split is pulled toward the placement and the achieved-bandwidth fraction
honestly reflects any mismatch.

``socket_local=False`` is the **socket-oblivious baseline**: one flat
dispatcher over all cores with interleaved (NUMA-unaware) page placement,
paying :attr:`~repro.topology.machine.MachineTopology.oblivious_blend`
per streamed byte.  Same execution path, so socket-local vs oblivious
comparisons isolate exactly the topology contribution — the dual-socket
analogue of the dispatcher's ``dynamic=False`` OpenMP baseline.

Kernel entry points (``q4_matmul`` / ``int8_gemm`` / ``f32_matmul``)
keep the flat dispatcher's signatures, so
:class:`~repro.models.balanced.BalancedTrunk` and the balanced layers
bind to a :class:`TopologyDispatcher` unchanged.
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.analysis import invariants as _contracts
from repro.core import events as _ev
from repro.core.tuner import KernelTuner
from repro.kernels.dispatch import GEMV_ISA, HybridKernelDispatcher
from repro.quant.q4 import BYTES_PER_ELEM, QuantizedLinear
from repro.runtime import (
    Balancer,
    EvenPolicy,
    KernelSpec,
    ProportionalPolicy,
    RatioTable,
    RegionStats,
    StatsSink,
)

from .machine import MachineTopology, make_topology, place_rows

__all__ = ["TopologyDispatcher"]

Ranges = Tuple[Tuple[int, int], ...]


class TopologyDispatcher:
    """Socket-local balanced dispatch (or its socket-oblivious baseline)
    over a multi-socket machine.

    One instance owns one socket-level ratio table, one flat
    :class:`HybridKernelDispatcher` per socket (sharing one
    :class:`KernelTuner`), a placement registry pinning weights' column
    ranges to sockets, and aggregate bytes/busy accounting on top of the
    per-socket accounting the inner dispatchers already keep.
    """

    def __init__(self, topology: MachineTopology | str, *,
                 dynamic: bool = True, socket_local: bool = True,
                 execute: bool = False, alpha: float = 0.3, seed: int = 0,
                 table: Optional[RatioTable] = None,
                 tuner: Optional[KernelTuner] = None,
                 sink: Optional[StatsSink] = None, interpret: bool = True,
                 keep_stats: bool = True):
        if isinstance(topology, str):
            topology = make_topology(topology, seed=seed)
        self.topology = topology
        self.dynamic = dynamic
        self.socket_local = socket_local
        self.sink = sink
        self.keep_stats = keep_stats
        self.stats: list = []
        self.tuner = tuner or KernelTuner()
        sub_kwargs = dict(dynamic=dynamic, execute=execute, alpha=alpha,
                          tuner=self.tuner, sink=sink, interpret=interpret,
                          keep_stats=False)
        if socket_local:
            self.socket_dispatchers = [
                HybridKernelDispatcher.virtual(m, **sub_kwargs)
                for m in topology.machines
            ]
            self.flat = None
            self.table = table or RatioTable(topology.n_sockets, alpha=alpha)
            if self.table.n_workers != topology.n_sockets:
                raise ValueError("table size does not match socket count")
        else:
            self.socket_dispatchers = []
            self.flat = HybridKernelDispatcher.virtual(
                topology.flattened(), **sub_kwargs)
            self.table = None
        self._balancers: Dict[tuple, Balancer] = {}
        self._bytes: Dict[str, float] = {}
        self._busy: Dict[str, float] = {}
        # concurrent shard reports (per-socket regions finishing together,
        # future async serving) must not interleave the aggregate
        # read-modify-write — the race the analysis pass flags as RC001
        self._acct_lock = threading.Lock()
        # id(weight) -> (weight kept alive, per-socket contiguous ranges)
        self._placement: Dict[int, Tuple[object, Ranges]] = {}
        self._default_ranges: Dict[int, Ranges] = {}

    # ------------------------------------------------------------- shape ---
    @property
    def n_sockets(self) -> int:
        return self.topology.n_sockets

    def close(self) -> None:
        for d in self.socket_dispatchers:
            d.close()
        if self.flat is not None:
            self.flat.close()

    # ---------------------------------------------------------- placement --
    def register_placement(self, weight, ranges) -> None:
        """Pin ``weight``'s N rows to sockets: ``ranges`` is one contiguous
        ``(lo, hi)`` per socket, in socket order, covering ``[0, N)``.  The
        weight object itself is the registry key (and is kept alive by the
        registry, so its ``id`` cannot be recycled)."""
        ranges = tuple((int(lo), int(hi)) for lo, hi in ranges)
        if len(ranges) != self.n_sockets and self.socket_local:
            raise ValueError("need one range per socket")
        cursor = 0
        for lo, hi in ranges:
            if lo != cursor or hi < lo:
                raise ValueError("placement ranges must be contiguous "
                                 "ascending from 0")
            cursor = hi
        self._placement[id(weight)] = (weight, ranges)

    def placement_for(self, weight, total: int) -> Ranges:
        """The resident column ranges for ``weight`` (its registered
        placement, or the default bandwidth-proportional split of
        ``total``)."""
        if weight is not None and id(weight) in self._placement:
            return self._placement[id(weight)][1]
        if total not in self._default_ranges:
            self._default_ranges[total] = place_rows(
                total, self.topology.bandwidth_shares())
        return self._default_ranges[total]

    def _work_scale(self, isa: str, socket: int, rng: Tuple[int, int],
                    placement: Ranges) -> float:
        """Wall-time multiplier for socket ``socket`` executing columns
        ``rng``: the fraction resident on other sockets pays the fabric
        penalty.  Compute-bound ISAs stream comparatively few bytes, so
        only memory-bound regions are penalized."""
        penalty = self.topology.cross_socket_penalty
        if isa != GEMV_ISA or penalty <= 1.0:
            return 1.0
        lo, hi = rng
        plo, phi = placement[socket]
        local = max(0, min(hi, phi) - max(lo, plo))
        remote_frac = 1.0 - local / (hi - lo)
        return 1.0 + (penalty - 1.0) * remote_frac

    # ------------------------------------------------------------ plumbing --
    def socket_mask(self, isa: str = GEMV_ISA) -> np.ndarray:
        """Per-socket active mask: a socket stays plannable while *any* of
        its cores is active (the inner dispatcher masks the parked ones);
        a fully-parked socket gets a zero-width outer range."""
        return np.array([d.capacity_mask(isa).any()
                         for d in self.socket_dispatchers], dtype=bool)

    def _balancer(self, spec: KernelSpec) -> Balancer:
        key = (spec.table_key, spec.granularity)
        if key not in self._balancers:
            if self.dynamic:
                policy = ProportionalPolicy(
                    self.table, key=spec.table_key,
                    granularity=spec.granularity, feedback="units",
                    active=lambda isa=spec.isa: self.socket_mask(isa))
            else:
                policy = EvenPolicy(self.n_sockets,
                                    granularity=spec.granularity)
            self._balancers[key] = Balancer(policy, sink=self.sink,
                                            keep_stats=False)
        return self._balancers[key]

    def _oblivious_scale(self, isa: str) -> float:
        return (self.topology.oblivious_blend if isa == GEMV_ISA else 1.0)

    def _split(self, spec: KernelSpec, total: int, weight,
               run_socket: Callable[[int, int, int, float], float], *,
               bytes_per_unit: float, update: bool) -> RegionStats:
        """The outer loop: plan the socket split, run each socket's range
        (``run_socket(socket, lo, hi, work_scale) -> makespan seconds``),
        feed socket makespans back with ``units=`` counts, account
        aggregate bytes/busy over the concurrent region."""
        bal = self._balancer(spec)
        plan = bal.plan(total)
        placement = self.placement_for(weight, total)
        check = _contracts.contracts_enabled()
        inner_before = sum(d._bytes.get(spec.isa, 0.0)
                           for d in self.socket_dispatchers) if check else 0.0
        tracing = _ev.TRACER is not None
        times = np.zeros(self.n_sockets)
        for s, (lo, hi) in enumerate(plan.ranges):
            if hi <= lo:
                continue
            scale = self._work_scale(spec.isa, s, (lo, hi), placement)
            if tracing:
                pool = self.socket_dispatchers[s]._pools.get(spec.isa)
                t0 = float(getattr(pool, "clock", 0.0)) if pool else 0.0
            times[s] = run_socket(s, lo, hi, scale)
            if tracing:
                _ev.emit_span(
                    f"socket{s}", f"{spec.name}@{spec.table_key}",
                    t0, times[s], cat="socket",
                    args=lambda s=s, lo=lo, hi=hi: {"socket": s,
                                                    "units": hi - lo})
        moved = float(total) * bytes_per_unit
        st = bal.report(plan, times, update=update and self.dynamic,
                        label=f"{spec.name}@{spec.table_key}",
                        bytes_moved=moved)
        if tracing and self.table is not None:
            now = max((float(getattr(d._pools.get(spec.isa), "clock", 0.0))
                       if d._pools.get(spec.isa) else 0.0
                       for d in self.socket_dispatchers), default=0.0)
            _ev.emit_counter(
                f"ratio:socket:{spec.table_key}", now,
                lambda: {f"s{i}": round(float(r), 5) for i, r in
                         enumerate(self.table.ratios(spec.table_key))})
        # Sockets run concurrently: the region occupies max(times) wall
        # seconds while moving the sum of the per-socket traffic.
        if moved > 0 and st.makespan > 0:
            self._account(spec.isa, moved, st.makespan)
            if check:
                inner_after = sum(d._bytes.get(spec.isa, 0.0)
                                  for d in self.socket_dispatchers)
                _contracts.check_bytes_conserved(
                    moved, inner_after - inner_before,
                    where=f"TopologyDispatcher._split[{spec.name}]")
        if self.keep_stats:
            self.stats.append(st)
        return st

    def _account(self, isa: str, moved: float, busy: float) -> None:
        """Accrue one region's aggregate bytes/busy under the lock."""
        with self._acct_lock:
            if _ev.TRACER is not None:
                where = "TopologyDispatcher._account"
                _ev.emit_acquire(self._acct_lock, where=where)
                _ev.emit_read(self, f"bytes[{isa}]", where=where)
                _ev.emit_write(self, f"bytes[{isa}]", where=where)
            self._bytes[isa] = self._bytes.get(isa, 0.0) + moved
            self._busy[isa] = self._busy.get(isa, 0.0) + busy
            if _ev.TRACER is not None:
                _ev.emit_release(self._acct_lock,
                                 where="TopologyDispatcher._account")

    # ------------------------------------------------------------ dispatch --
    def dispatch(self, spec: KernelSpec, total: int,
                 fn: Optional[Callable[[int, int], None]] = None, *,
                 bytes_per_unit: float = 0.0, update: bool = True,
                 weight=None) -> RegionStats:
        """One balanced region of ``total`` units split socket-first, then
        per-core within each socket (both levels learn).  ``fn(start,
        size)`` receives *global* offsets.  ``weight`` selects a registered
        placement (default: bandwidth-proportional)."""
        if not self.socket_local:
            st = self.flat.dispatch(
                spec, total, fn, bytes_per_unit=bytes_per_unit,
                work_scale=self._oblivious_scale(spec.isa), update=update)
            if self.keep_stats:
                self.stats.append(st)
            return st

        def run_socket(s: int, lo: int, hi: int, scale: float) -> float:
            sub_fn = None if fn is None else (
                lambda start, size, lo=lo: fn(lo + start, size))
            st = self.socket_dispatchers[s].dispatch(
                spec, hi - lo, sub_fn, bytes_per_unit=bytes_per_unit,
                work_scale=scale, update=update)
            return st.makespan

        return self._split(spec, total, weight, run_socket,
                           bytes_per_unit=bytes_per_unit, update=update)

    # ------------------------------------------------------- real kernels --
    def _kernel(self, spec: KernelSpec, n: int, weight,
                run_sub: Callable[[int, int, int, float], jnp.ndarray], *,
                bytes_per_unit: float, update: bool):
        """Shared kernel path: socket split, per-socket sub-kernel on the
        sliced weight rows, outputs concatenated in column order (identity
        with the monolithic kernel — N-row shards never touch a reduction)."""
        if not self.socket_local:
            raise RuntimeError("_kernel is a socket-local path")
        outs: Dict[int, jnp.ndarray] = {}

        def run_socket(s: int, lo: int, hi: int, scale: float) -> float:
            outs[s] = run_sub(s, lo, hi, scale)
            return self.socket_dispatchers[s].last_stats.makespan

        self._split(spec, n, weight, run_socket,
                    bytes_per_unit=bytes_per_unit, update=update)
        return jnp.concatenate([outs[s] for s in sorted(outs)], axis=-1)

    def q4_matmul(self, x, qw: QuantizedLinear, *, isa: str = GEMV_ISA,
                  key: Optional[str] = None,
                  blocks: Optional[tuple] = None, granularity: int = 8,
                  update: bool = True):
        """Fp32-Int4-Fp32 ``x (M,K) @ Q4_0 (N,K).T``: columns sharded
        socket-first by the outer table, then per-core Pallas shards within
        each socket (see :meth:`HybridKernelDispatcher.q4_matmul`)."""
        if not self.socket_local:
            return self.flat.q4_matmul(
                x, qw, isa=isa, key=key, blocks=blocks,
                granularity=granularity,
                work_scale=self._oblivious_scale(isa), update=update)
        m, k = x.shape
        bytes_per_row = k * BYTES_PER_ELEM
        work = bytes_per_row if isa == GEMV_ISA else 2.0 * m * k
        spec = KernelSpec("q4_matmul", isa=isa, granularity=granularity,
                          work_per_unit=work, key=key)

        def run_sub(s, lo, hi, scale):
            shard = QuantizedLinear(qw.packed[lo:hi], qw.scales[lo:hi])
            return self.socket_dispatchers[s].q4_matmul(
                x, shard, isa=isa, key=key, blocks=blocks,
                granularity=granularity, work_scale=scale, update=update)

        return self._kernel(spec, qw.out_features, qw, run_sub,
                            bytes_per_unit=bytes_per_row, update=update)

    def int8_gemm(self, a_u8, w_s8, *, isa: str = "avx_vnni",
                  key: Optional[str] = None,
                  blocks: Optional[tuple] = None, granularity: int = 16,
                  update: bool = True):
        """u8 x s8 -> s32 GEMM, socket-sharded then core-sharded (s32
        accumulation keeps shard outputs bit-identical)."""
        if not self.socket_local:
            return self.flat.int8_gemm(
                a_u8, w_s8, isa=isa, key=key, blocks=blocks,
                granularity=granularity,
                work_scale=self._oblivious_scale(isa), update=update)
        m, k = a_u8.shape
        work = 2.0 * m * k if isa != GEMV_ISA else float(k)
        spec = KernelSpec("int8_gemm", isa=isa, granularity=granularity,
                          work_per_unit=work, key=key)

        def run_sub(s, lo, hi, scale):
            return self.socket_dispatchers[s].int8_gemm(
                a_u8, w_s8[lo:hi], isa=isa, key=key, blocks=blocks,
                granularity=granularity, work_scale=scale, update=update)

        return self._kernel(spec, int(w_s8.shape[0]), w_s8, run_sub,
                            bytes_per_unit=float(k), update=update)

    def f32_matmul(self, x, w, *, isa: str = GEMV_ISA,
                   key: Optional[str] = None, granularity: int = 1,
                   update: bool = True):
        """f32 ``x @ W.T``, socket-sharded then core-sharded; shard-exact
        like the flat dispatcher's precision-reference path."""
        if not self.socket_local:
            return self.flat.f32_matmul(
                x, w, isa=isa, key=key, granularity=granularity,
                work_scale=self._oblivious_scale(isa), update=update)
        w = np.asarray(w, dtype=np.float32)
        m, k = np.asarray(x).shape
        bytes_per_row = 4.0 * k
        work = bytes_per_row if isa == GEMV_ISA else 2.0 * m * k
        spec = KernelSpec("f32_matmul", isa=isa, granularity=granularity,
                          work_per_unit=work, key=key)

        def run_sub(s, lo, hi, scale):
            return self.socket_dispatchers[s].f32_matmul(
                x, w[lo:hi], isa=isa, key=key, granularity=granularity,
                work_scale=scale, update=update)

        return self._kernel(spec, int(w.shape[0]), w, run_sub,
                            bytes_per_unit=bytes_per_row, update=update)

    # ----------------------------------------------------------- telemetry --
    def reset_bandwidth_accounting(self) -> None:
        """Zero aggregate and per-socket bytes/busy counters (steady-state
        measurement windows)."""
        self._bytes.clear()
        self._busy.clear()
        for d in self.socket_dispatchers:
            d.reset_bandwidth_accounting()
        if self.flat is not None:
            self.flat.reset_bandwidth_accounting()

    def achieved_bandwidth(self, isa: str = GEMV_ISA,
                           socket: Optional[int] = None) -> float:
        """Aggregate bytes/s of this dispatcher's ``isa`` regions (total
        bytes over concurrent-region wall time), or one socket's."""
        if socket is not None:
            if not self.socket_local:
                raise ValueError("per-socket bandwidth is undefined for "
                                 "the socket-oblivious baseline")
            return self.socket_dispatchers[socket].achieved_bandwidth(isa)
        if not self.socket_local:
            return self.flat.achieved_bandwidth(isa)
        busy = self._busy.get(isa, 0.0)
        if busy <= 0:
            return 0.0
        return self._bytes.get(isa, 0.0) / busy

    def achieved_bandwidth_fraction(self, isa: str = GEMV_ISA,
                                    socket: Optional[int] = None) -> float:
        """The paper's headline metric at topology scale: aggregate
        achieved bandwidth over the sum of per-socket streaming bandwidths
        (or, with ``socket=``, one domain's fraction of its own pool)."""
        if socket is not None:
            return (self.achieved_bandwidth(isa, socket=socket)
                    / self.topology.socket_bandwidth(socket))
        return self.achieved_bandwidth(isa) / self.topology.aggregate_bandwidth

    def socket_ratios(self, key: str) -> np.ndarray:
        """The outer (socket-level) ratio table for ``key``."""
        if self.table is None:
            raise ValueError("the socket-oblivious baseline has no "
                             "socket-level table")
        return self.table.ratios(key)
