"""Synthetic tokenized data pipeline with per-host sharding and prefetch.

Production shape: each host builds the *same* deterministic stream and takes
its own slice of the global batch (``host_id``/``n_hosts``), so no data
service is needed for the dry-run scale; a real corpus would replace
``SyntheticLM`` behind the same iterator contract.

``SyntheticLM`` emits sequences with a learnable 2-gram structure
(``x_{t+1} = (a * x_t + c) mod V`` on a per-sequence (a, c)), so example
drivers show real loss decrease rather than noise-floor churn.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    microbatch: int
    host_id: int = 0
    n_hosts: int = 1
    seed: int = 0
    structured: bool = True  # learnable 2-gram stream vs uniform noise


class SyntheticLM:
    """Deterministic, restartable synthetic LM stream."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.host_batch = cfg.global_batch // cfg.n_hosts
        if self.host_batch % cfg.microbatch:
            raise ValueError("host batch must be a multiple of microbatch")
        self.step = 0

    def seek(self, step: int) -> None:
        """Restart-from-checkpoint support: position the stream."""
        self.step = step

    def _gen(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id])
        )
        b, s, v = self.host_batch, cfg.seq_len, cfg.vocab_size
        if cfg.structured:
            # stream-global (a, c): a deterministic 2-gram the model can learn
            g = np.random.default_rng(cfg.seed)
            a = int(g.integers(2, 8))
            c = int(g.integers(1, v))
            x0 = rng.integers(0, v, size=(b, 1))
            toks = np.empty((b, s), dtype=np.int32)
            toks[:, :1] = x0
            for t in range(1, s):
                toks[:, t: t + 1] = (a * toks[:, t - 1: t] + c) % v
        else:
            toks = rng.integers(0, v, size=(b, s), dtype=np.int32)
        labels = np.concatenate(
            [toks[:, 1:], np.full((b, 1), -100, np.int32)], axis=1
        )
        n_micro = b // cfg.microbatch
        return {
            "tokens": toks.reshape(n_micro, cfg.microbatch, s),
            "labels": labels.reshape(n_micro, cfg.microbatch, s),
        }

    def __iter__(self) -> Iterator[dict]:
        while True:
            out = self._gen(self.step)
            self.step += 1
            yield out


class Prefetcher:
    """Background-thread prefetch (depth-bounded) around any iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._stop = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        for item in self._it:
            if self._stop:
                return
            self._q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop = True
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
