"""Data pipeline substrate."""

from .pipeline import DataConfig, SyntheticLM, Prefetcher

__all__ = ["DataConfig", "SyntheticLM", "Prefetcher"]
