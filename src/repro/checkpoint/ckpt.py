"""Fault-tolerant checkpointing: atomic, versioned, elastic-reshardable.

* Atomicity: write into ``step_XXXX.tmp`` then ``os.replace`` — a crash
  mid-write never corrupts the latest valid checkpoint.
* Fault tolerance: ``latest_step``/``restore`` let a relaunched job resume
  (see ``launch/train.py``); ``keep_last`` bounds disk.
* Elasticity: arrays are stored unsharded (device_get), so a restore may
  target a *different* mesh — pass ``shardings`` and each leaf is
  device_put to its new layout.  At real pod scale this becomes one file
  per host plus a reshard step; the interface is unchanged.
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p).strip("[].'") for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save(ckpt_dir: str, step: int, tree: Any, *, extra: Optional[dict] = None,
         keep_last: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "extra": extra or {},
                   "keys": sorted(flat)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _cleanup(ckpt_dir, keep_last)
    return final


def _cleanup(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d{8})", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, template: Any,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``template`` (values ignored).

    ``shardings``: optional pytree of jax.sharding.Sharding matching
    ``template`` — enables elastic restore onto a different mesh.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)

    leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
                    if shardings is not None else [None] * len(leaves_t))
    out = []
    for (path_t, leaf), shard in zip(leaves_t, shard_leaves):
        key = "/".join(str(p).strip("[].'") for p in path_t)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)
    return tree, meta
