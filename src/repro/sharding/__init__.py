"""Sharding rules (FSDP+TP/EP PartitionSpecs)."""

from .specs import (
    param_shardings,
    state_shardings,
    batch_shardings,
    opt_shardings,
    fsdp_axes,
    data_axes,
    activation_sharding,
    constrain,
    constrain_tree,
    current_mesh,
)

__all__ = [
    "param_shardings", "state_shardings", "batch_shardings",
    "opt_shardings", "fsdp_axes", "data_axes",
    "activation_sharding", "constrain", "constrain_tree", "current_mesh",
]
