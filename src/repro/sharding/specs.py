"""Sharding rules: FSDP(+pod) x TP/EP PartitionSpecs for every param/state.

Layout summary (mesh axes ``("pod",)? + ("data", "model")``):

* FSDP: the non-TP dim of every matrix is sharded over ``fsdp_axes`` =
  ("pod","data") on the multi-pod mesh, ("data",) on one pod — weights,
  moments and grad accumulators all scale 1/(pod*data).
* TP: attention heads / MLP hidden / vocab shard over "model".
* EP: MoE expert dim shards over "model" (expert compute is local;
  GSPMD inserts the dispatch/combine collectives).
* Mamba/xLSTM: channel dim (d_inner / heads) shards over "model" — these
  mixers are channel-parallel, the time recurrence stays local.
* Stacked-period params carry a leading (n_periods) axis -> prepend None.

GSPMD handles non-divisible cases (40 heads over 16, kv=2 over 16) by
implicit padding, which keeps every (arch x shape) cell compiling; the
divisible-by-design cells take the fast path.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fsdp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# --------------------------------------------------------------- params ---
def _param_spec(path: str, leaf, fsdp) -> P:
    """PartitionSpec for one parameter, from its tree path."""
    f = fsdp
    rules: list[tuple[str, P]] = [
        # embeddings
        (r"embed/tok$", P("model", f)),
        (r"embed/out$", P(f, "model")),
        # attention
        (r"mixer/w[qkv]$", P(f, "model")),
        (r"mixer/wo$", P("model", f)),
        (r"mixer/b[qkv]$", P("model")),
        # dense mlp
        (r"ffn/w[ig]$", P(f, "model")),
        (r"ffn/wo$", P("model", f)),
        # moe
        (r"ffn/router$", P(f, None)),
        (r"ffn/w[ig]$", P("model", f, None)),      # (E, d, ff) — EP
        (r"ffn/swo$", P("model", f)),
        (r"ffn/sw[ig]$", P(f, "model")),
        # mamba
        (r"mixer/in_proj$", P(f, "model")),
        (r"mixer/conv_w$", P(None, "model")),
        (r"mixer/conv_b$", P("model")),
        (r"mixer/x_proj$", P("model", None)),
        (r"mixer/dt_proj$", P(None, "model")),
        (r"mixer/dt_bias$", P("model")),
        (r"mixer/A_log$", P("model", None)),
        (r"mixer/D$", P("model")),
        (r"mixer/out_proj$", P("model", f)),
        # mlstm / slstm: TP over 'model' on the inner dim like the other
        # mixers.  Known limitation (see EXPERIMENTS §Perf): the per-head
        # block-diagonal projections and head-interleaved reshapes make
        # xLSTM resharding-heavy under GSPMD whatever the placement we
        # tried (model-TP 15.7s / fsdp-only 27.8s / replicated 134s
        # collective seconds for xlstm-1.3b train_4k); a hand-written
        # shard_map mixer is the proper fix.
        (r"mixer/w_(up|z)$", P(f, "model")),
        (r"mixer/w[qkv]$", P("model", None, None)),  # per-head blockdiag
        (r"mixer/w_if$", P("model", None)),
        (r"mixer/b_if$", P(None)),
        (r"mixer/w_down$", P("model", f)),
        # slstm
        (r"mixer/w_x$", P(f, "model")),
        (r"mixer/r_h$", P("model", None, None)),
        (r"mixer/bias$", P(None)),
        (r"mixer/w_out$", P(f, "model")),
    ]
    for pat, spec in rules:
        if re.search(pat, path):
            if re.search(r"ffn/w[ig]$", path):
                rank = leaf.ndim - (1 if path.startswith("period") else 0)
                spec = P("model", f, None) if rank == 3 else P(f, "model")
            if path.startswith("period"):
                spec = P(None, *spec)
            return spec
    # norms / scalars / anything small: replicate
    return P(None) if not path.startswith("period") else P(None, None)


def _path_str(path) -> str:
    return "/".join(str(p).strip("[].'") for p in path)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _fit_spec(mesh: Mesh, spec: P, shape) -> P:
    """Explicit in_shardings require exact divisibility; drop (replicate)
    any axis that does not divide its dimension (e.g. kv=8 heads or 4
    xLSTM heads against model=16)."""
    out = []
    for i, axis in enumerate((list(spec) + [None] * len(shape))[: len(shape)]):
        n = _axis_size(mesh, axis)
        out.append(axis if n > 1 and shape[i] % n == 0 else
                   (axis if n == 1 else None))
    return P(*out)


def _serve_spec(path: str, leaf, base: P) -> P:
    """Inference placement: weights stay stationary (no FSDP gathers —
    decode is weight-bandwidth bound, the paper's own regime).  MoE expert
    tensors shard over BOTH axes (E on 'model', ff on 'data'); everything
    else drops its fsdp axis (replicated across 'data', TP over 'model').
    """
    if re.search(r"ffn/w[ig]$", path) and leaf.ndim - (1 if path.startswith("period") else 0) == 3:
        spec = P("model", None, "data")
    elif re.search(r"ffn/wo$", path) and leaf.ndim - (1 if path.startswith("period") else 0) == 3:
        spec = P("model", "data", None)
    else:
        # drop fsdp axes from the train spec
        cleaned = []
        for ax in base:
            if ax is None:
                cleaned.append(None)
            elif isinstance(ax, (tuple, list)):
                kept = tuple(a for a in ax if a == "model")
                cleaned.append(kept[0] if kept else None)
            else:
                cleaned.append(ax if ax == "model" else None)
        return P(*cleaned)
    if path.startswith("period"):
        spec = P(None, *spec)
    return spec


def param_shardings(mesh: Mesh, abstract_params, mode: str = "train") -> Any:
    f = fsdp_axes(mesh)

    def one(path, leaf):
        ps = _path_str(path)
        spec = _param_spec(ps, leaf, f)
        if mode == "serve":
            spec = _serve_spec(ps, leaf, spec)
        spec = _fit_spec(mesh, spec, leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, abstract_params)


# ---------------------------------------------------------------- states --
def _state_spec(path: str, leaf, dp, batch_sharded: bool,
                phase: str = "decode") -> P:
    """Decode/prefill state sharding.  Leading axis is n_periods (stacked).

    KV caches (P, B, Hkv, S, hd):
      * decode: batch over data + HEAD_DIM over model — hd=64..128 divides
        every assigned arch, the softmax stays local (psum of tiny (B,H,1,S)
        partial scores), and the cache write at a traced index lands on an
        unsharded dim.  Fully shards the cache (e.g. llama4 decode_32k:
        824 GB global -> 3.2 GB/device).
      * prefill: heads over model when divisible, else sequence — hd
        sharding would psum (B,H,S,S) score tensors there.
    Mamba h: (P, B, di, n) -> di over model.  conv: (P, B, k-1, di).
    mLSTM c: (P, B, H, dv, dk) -> heads over model; n,m similar.
    sLSTM c/n/m/h: (P, B, d) -> d over model.
    """
    b_ax = dp if batch_sharded else None
    if re.search(r"(k|v)$", path) and leaf.ndim == 5:
        if phase == "decode":
            # sequence over 'model': local partial scores + tiny softmax
            # psum; the head axis rarely divides (kv=2..24) and hd-sharding
            # makes GSPMD gather the cache (measured).  Fully shards the
            # cache: batch x seq.
            return P(None, b_ax, None, "model", None)
        if leaf.shape[2] % 16 == 0:
            return P(None, b_ax, "model", None, None)
        return P(None, b_ax, None, "model", None)     # KVCache.k/.v
    if re.search(r"idx$", path):
        return P(None)
    if re.search(r"conv$", path):
        return P(None, b_ax, None, "model")
    if re.search(r"/h$", path) and leaf.ndim == 4:
        return P(None, b_ax, "model", None)            # mamba h
    if leaf.ndim == 5:
        return P(None, b_ax, "model", None, None)      # mlstm c
    if leaf.ndim == 4:
        return P(None, b_ax, "model", None)            # mlstm n
    if leaf.ndim == 3:
        return P(None, b_ax, "model")                  # mlstm m / slstm vecs
    return P(None)


def state_shardings(mesh: Mesh, abstract_state, batch: int,
                    phase: str = "decode") -> Any:
    dp = data_axes(mesh)
    import math
    dp_size = math.prod(mesh.shape[a] for a in dp)
    batch_sharded = batch % dp_size == 0 and batch >= dp_size

    def one(path, leaf):
        spec = _state_spec(_path_str(path), leaf, dp, batch_sharded, phase)
        spec = _fit_spec(mesh, spec, leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, abstract_state)


# ---------------------------------------------------------------- batch ---
def batch_shardings(mesh: Mesh, abstract_batch, batch_dim: int = 0) -> Any:
    """Token/label/embed inputs: batch over ("pod","data"); for microbatched
    train inputs (n_micro leading axis) the batch dim is 1."""
    dp = data_axes(mesh)
    import math
    dp_size = math.prod(mesh.shape[a] for a in dp)

    def one(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) > batch_dim and shape[batch_dim] % dp_size == 0 and \
                shape[batch_dim] >= dp_size:
            spec[batch_dim] = dp
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, abstract_batch)


def opt_shardings(mesh: Mesh, abstract_opt, params_shardings) -> Any:
    """Optimizer state follows param sharding; factored row/col stats drop
    the last/second-last dim's axis respectively; step is replicated."""
    def spec_of(s: NamedSharding) -> P:
        return s.spec

    import repro.training.optimizer as O  # noqa

    def one(path, leaf):
        ps = _path_str(path)
        m = re.match(r"(mu|nu)/(.*?)(/row|/col)?$", ps)
        if not m:
            return NamedSharding(mesh, P())  # step
        base_path = m.group(2)
        tail = m.group(3)
        # find the matching param sharding by path
        flat = jax.tree_util.tree_flatten_with_path(params_shardings)[0]
        target = None
        for p_path, shard in flat:
            if _path_str(p_path) == base_path:
                target = shard
                break
        if target is None:
            return NamedSharding(mesh, P(*( [None] * leaf.ndim )))
        spec = list(spec_of(target))
        spec = (spec + [None] * leaf.ndim)[: max(leaf.ndim, len(spec))]
        if tail == "/row":
            spec = spec[:-1]
        elif tail == "/col":
            spec = spec[:-2] + spec[-1:]
        spec = (spec + [None] * leaf.ndim)[: leaf.ndim]
        return NamedSharding(mesh, _fit_spec(mesh, P(*spec), leaf.shape))

    return jax.tree_util.tree_map_with_path(one, abstract_opt)


# ------------------------------------------------ activation constraints --
# GSPMD sharding propagation is weak through while loops (scan-over-periods
# + remat): without explicit constraints the carry/activations fall back to
# replicated-batch layouts, turning every TP psum into a full-activation
# all-reduce (measured: 2.6 TB wire per train step for granite-8b).  Model
# code calls ``constrain(x, ("dp", None, "tp"))``; a driver installs the
# mesh via ``activation_sharding(mesh)`` — with no context installed the
# helpers are no-ops, so single-device tests/examples are untouched.

import contextlib as _contextlib
import contextvars as _contextvars

_ACT_MESH: "_contextvars.ContextVar" = _contextvars.ContextVar(
    "activation_mesh", default=None)


@_contextlib.contextmanager
def activation_sharding(mesh: Mesh):
    token = _ACT_MESH.set(mesh)
    try:
        yield
    finally:
        _ACT_MESH.reset(token)


def constrain(x, dims) -> Any:
    """dims: per-axis entries of {"dp", "tp", None} (trailing Nones may be
    omitted).  No-op outside an activation_sharding context."""
    mesh = _ACT_MESH.get()
    if mesh is None or x is None:
        return x
    dp = data_axes(mesh)
    spec = []
    for d in dims:
        spec.append(dp if d == "dp" else ("model" if d == "tp" else None))
    spec = _fit_spec(mesh, P(*spec), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_tree(tree, shardings) -> Any:
    """Constrain a pytree (e.g. grad accumulators) to given NamedShardings;
    no-op when no mesh context is installed."""
    if _ACT_MESH.get() is None or shardings is None:
        return tree
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, shardings)


def current_mesh():
    """The mesh installed by :func:`activation_sharding` (or None)."""
    return _ACT_MESH.get()
