"""repro: dynamic parallel method for hybrid compute, at framework scale.

Faithful reproduction of "A dynamic parallel method for performance
optimization on hybrid CPUs" (CS.DC 2024) plus its TPU-pod-scale adaptation:
workload-balancing schedulers, Q4_0/INT8 quantized kernels (Pallas), a
10-architecture model zoo, pjit/shard_map distribution, serving and training
stacks, and a multi-pod dry-run + roofline harness.
"""

__version__ = "0.1.0"
