"""Training steps: microbatch gradient accumulation, even and uneven.

The *uneven* path is the paper's method at pod scale: each data-parallel
slice runs ``k_i`` local accumulation steps (k_i from
:class:`repro.runtime.UnevenBatchPlanner`, proportional to measured
throughput).  Local accumulation contains **no collectives**, so unequal
trip counts cannot deadlock SPMD; a single weighted combine
(sum_i w_i g_i, w_i = k_i/sum k) equals the plain average over all
microbatches — proved by ``tests/test_training.py::test_uneven_equals_even``.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import loss_fn
from repro.sharding.specs import constrain_tree
from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state


def microbatch_grads(cfg: ModelConfig, params, batch: dict, *,
                     capacity: Optional[int] = None, remat: bool = False,
                     acc_dtype=jnp.float32, grad_shardings=None):
    """Average loss+grads over the leading microbatch axis of ``batch``
    (scan — activations for only one microbatch live at a time).

    ``acc_dtype``: f32 by default; bf16 halves the accumulator footprint
    for >=50B models (the f32 accumulator alone is ~6.25 GB/device for a
    400B model on 256 chips)."""
    n_micro = jax.tree.leaves(batch)[0].shape[0]

    def one(p, mb):
        (l, metrics), g = jax.value_and_grad(
            lambda pp: loss_fn(cfg, pp, mb, capacity=capacity, remat=remat),
            has_aux=True
        )(p)
        return l, metrics, g

    def body(carry, mb):
        g_acc, l_acc = carry
        l, metrics, g = one(params, mb)
        # Constrain the *addend*: forces the partitioner to reduce-scatter
        # each microbatch's weight grads straight into the FSDP layout
        # instead of all-reducing the full tensor and slicing (measured
        # ~16x on the grad-reduction wire term).
        g = constrain_tree(g, grad_shardings)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(acc_dtype), g_acc, g)
        g_acc = constrain_tree(g_acc, grad_shardings)
        return (g_acc, l_acc + l), metrics

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
    (g_sum, l_sum), metrics = jax.lax.scan(body, (g0, jnp.zeros(())), batch)
    grads = jax.tree.map(lambda g: g / n_micro, g_sum)
    last_metrics = jax.tree.map(lambda m: m[-1], metrics)
    return l_sum / n_micro, grads, last_metrics


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    capacity: Optional[int] = None,
                    remat: bool = False,
                    acc_dtype=jnp.float32,
                    grad_shardings=None) -> Callable:
    """jit-able train step: (params, opt_state, batch) -> (params, opt_state,
    metrics).  ``batch`` leaves have shape (n_micro, mb, ...)."""

    def step(params, opt_state: OptState, batch: dict):
        loss, grads, metrics = microbatch_grads(cfg, params, batch,
                                                capacity=capacity, remat=remat,
                                                acc_dtype=acc_dtype,
                                                grad_shardings=grad_shardings)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return params, opt_state, metrics

    return step


# ------------------------------------------------------ uneven DP (paper) --
def local_accum(cfg: ModelConfig, params, microbatches: dict, *,
                capacity: Optional[int] = None):
    """One pod's local pass: average grads over its own k_i microbatches.
    Contains no cross-pod collectives (safe for unequal k_i)."""
    loss, grads, _ = microbatch_grads(cfg, params, microbatches,
                                      capacity=capacity)
    return loss, grads


def weighted_combine(grads_list: Sequence, counts: np.ndarray):
    """sum_i (k_i / sum k) * g_i — equals the global microbatch average.

    On hardware this is the single cross-pod all-reduce (optionally through
    :mod:`repro.training.grad_compress` for the pod axis).
    """
    counts = np.asarray(counts, dtype=np.float64)
    w = counts / counts.sum()
    out = jax.tree.map(lambda g: g * w[0], grads_list[0])
    for wi, gi in zip(w[1:], grads_list[1:]):
        out = jax.tree.map(lambda a, b: a + b * wi, out, gi)
    return out


def uneven_data_parallel_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    params,
    opt_state: OptState,
    pod_batches: Sequence[dict],
    counts: np.ndarray,
    *,
    local_fn: Optional[Callable] = None,
):
    """Reference driver for the paper's uneven-DP step (one step).

    ``pod_batches[i]`` has leading dim ``counts[i]`` (that pod's
    microbatches).  In deployment each pod runs ``local_fn`` concurrently;
    here they run sequentially (single process) — numerics are identical.
    Returns (params, opt_state, mean_loss).
    """
    local_fn = local_fn or (lambda p, b: local_accum(cfg, p, b))
    losses, grads_list = [], []
    for b in pod_batches:
        l, g = local_fn(params, b)
        losses.append(l)
        grads_list.append(g)
    grads = weighted_combine(grads_list, counts)
    params, opt_state, _ = adamw_update(opt_cfg, params, grads, opt_state)
    w = np.asarray(counts) / np.asarray(counts).sum()
    mean_loss = sum(float(l) * wi for l, wi in zip(losses, w))
    return params, opt_state, mean_loss
