"""Training substrate: optimizer, train steps (even/uneven), compression."""

from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state, lr_at
from .train_step import (
    make_train_step,
    microbatch_grads,
    local_accum,
    weighted_combine,
    uneven_data_parallel_step,
)
from . import grad_compress

__all__ = [
    "AdamWConfig", "OptState", "adamw_update", "init_opt_state", "lr_at",
    "make_train_step", "microbatch_grads", "local_accum",
    "weighted_combine", "uneven_data_parallel_step", "grad_compress",
]
