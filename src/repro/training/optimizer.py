"""AdamW + schedules, built from scratch (no optax dependency).

Master weights and moments are float32 regardless of param dtype (bf16
params are cast on apply) — standard mixed-precision discipline.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # Memory regime for >=100B models on 16GB/chip: factored second moment
    # (Adafactor-style row/col stats for ndim>=2 tensors) + bf16 first
    # moment.  Full f32 AdamW moments for llama4/jamba at 256 chips need
    # ~12.5 GB/device — they do not fit next to params + activations.
    factored: bool = False


class OptState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 *
                    (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def _factorable(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def init_opt_state(params, cfg: Optional[AdamWConfig] = None) -> OptState:
    factored = bool(cfg and cfg.factored)

    def mu_init(p):
        return jnp.zeros(p.shape, jnp.bfloat16 if factored else jnp.float32)

    def nu_init(p):
        if factored and _factorable(p):
            # row/col second-moment statistics (Adafactor)
            return {
                "row": jnp.zeros(p.shape[:-1], jnp.float32),
                "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return jnp.zeros(p.shape, jnp.float32)

    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(mu_init, params),
                    nu=jax.tree.map(nu_init, params))


def global_norm(grads) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def adamw_update(
    cfg: AdamWConfig, params, grads, state: OptState
) -> tuple[dict, OptState, dict]:
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu2 = (cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g).astype(mu.dtype)
        if isinstance(nu, dict):  # factored second moment
            g2 = g * g + 1e-30
            row = cfg.b2 * nu["row"] + (1 - cfg.b2) * g2.mean(-1)
            col = cfg.b2 * nu["col"] + (1 - cfg.b2) * g2.mean(-2)
            nu2 = {"row": row, "col": col}
            vhat = (row[..., None] * col[..., None, :]
                    / jnp.maximum(row.mean(-1)[..., None, None], 1e-30)) / b2c
        else:
            nu2 = cfg.b2 * nu + (1 - cfg.b2) * g * g
            vhat = nu2 / b2c
        mhat = mu2.astype(jnp.float32) / b1c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/biases exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu2, nu2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step=step, mu=new_mu, nu=new_nu), metrics
