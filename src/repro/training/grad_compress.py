"""INT8 gradient compression with error feedback (distributed-optimization
trick for cross-pod all-reduce).

Cross-pod links (DCN between pods) are ~10x slower than in-pod ICI; gradient
bytes dominate the pod-boundary collective term.  Per-tensor symmetric int8
quantization cuts those bytes 4x (vs f32 grads) / 2x (vs bf16); the residual
(quantization error) is carried to the next step (error feedback), which
keeps SGD/Adam convergence — standard 1-bit/8-bit Adam practice.

``compress -> (all-reduce int8-as-int32 sums...) -> decompress`` —— in this
framework we quantize before the *pod-axis* psum only (in-pod reduction
stays full precision), see ``training/train_step.py``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    q: jax.Array      # int8 payload
    scale: jax.Array  # () f32 per tensor


def compress(g: jax.Array, err: jax.Array) -> tuple[Compressed, jax.Array]:
    """Quantize g + carried error; returns (payload, new_error)."""
    x = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return Compressed(q=q, scale=scale), x - deq


def decompress(c: Compressed) -> jax.Array:
    return c.q.astype(jnp.float32) * c.scale


def compress_tree(grads, errors):
    """Tree-mapped compress; errors pytree matches grads."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [compress(g, e) for g, e in zip(flat_g, flat_e)]
    comp = treedef.unflatten([o[0] for o in out])
    new_err = treedef.unflatten([o[1] for o in out])
    return comp, new_err


def decompress_tree(comp):
    return jax.tree.map(
        decompress, comp, is_leaf=lambda x: isinstance(x, Compressed)
    )


def init_errors(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
