"""Dynamic INT8 activation quantization (the paper's GEMM operands).

The paper's INT8 GEMM uses unsigned-INT8 activations against signed-INT8
weights (the AVX-VNNI ``vpdpbusd`` contract, which maps to the TPU MXU's
s8xs8 path with a zero-point correction term).

* Activations: per-row asymmetric u8 — scale + zero-point.
* Weights: per-channel symmetric s8.

``u8s8_matmul_decompose`` shows the standard zero-point algebra used by both
the reference and the Pallas kernel:
  y = (a_u8 - zp) @ w_s8^T * (sa * sw)
    = (a_u8 @ w_s8^T - zp * colsum(w_s8)) * (sa * sw)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QuantizedActivation(NamedTuple):
    q: jax.Array      # uint8 (M, K)
    scale: jax.Array  # float32 (M, 1)
    zero: jax.Array   # int32 (M, 1) zero-point in u8 domain


class QuantizedWeightI8(NamedTuple):
    q: jax.Array      # int8 (N, K)
    scale: jax.Array  # float32 (N,) per-output-channel


def quantize_u8_dynamic(x: jax.Array) -> QuantizedActivation:
    """Per-row asymmetric quantization to u8 (llama.cpp-style dynamic)."""
    x = x.astype(jnp.float32)
    xmin = jnp.min(x, axis=-1, keepdims=True)
    xmax = jnp.max(x, axis=-1, keepdims=True)
    scale = (xmax - xmin) / 255.0
    scale = jnp.where(scale == 0, 1.0, scale)
    zero = jnp.round(-xmin / scale)
    q = jnp.clip(jnp.round(x / scale) + zero, 0, 255).astype(jnp.uint8)
    return QuantizedActivation(q=q, scale=scale, zero=zero.astype(jnp.int32))


def dequantize_u8(qa: QuantizedActivation) -> jax.Array:
    return (qa.q.astype(jnp.float32) - qa.zero.astype(jnp.float32)) * qa.scale


def quantize_s8_symmetric(w: jax.Array) -> QuantizedWeightI8:
    """Per-channel symmetric s8 for weights (N, K)."""
    w = w.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(w), axis=-1)
    scale = jnp.where(absmax == 0, 1.0, absmax / 127.0)
    q = jnp.clip(jnp.round(w / scale[:, None]), -127, 127).astype(jnp.int8)
    return QuantizedWeightI8(q=q, scale=scale)


def dequantize_s8(qw: QuantizedWeightI8) -> jax.Array:
    return qw.q.astype(jnp.float32) * qw.scale[:, None]


def u8s8_matmul_decompose(
    a: QuantizedActivation, w: QuantizedWeightI8, acc_s32: jax.Array
) -> jax.Array:
    """Turn a raw u8*s8 s32 accumulation into the f32 result.

    ``acc_s32`` is ``a.q @ w.q.T`` accumulated in int32 (what the MXU /
    VNNI unit produces); the zero-point correction uses the weight column
    sums.
    """
    colsum = jnp.sum(w.q.astype(jnp.int32), axis=-1)  # (N,)
    corrected = acc_s32.astype(jnp.float32) - (
        a.zero.astype(jnp.float32) * colsum[None, :].astype(jnp.float32)
    )
    return corrected * a.scale * w.scale[None, :]
