"""Quantization substrate: Q4_0 weights (llama.cpp layout) + dynamic INT8."""

from .q4 import (
    GROUP,
    BYTES_PER_ELEM,
    QuantizedLinear,
    quantize_q4_0,
    dequantize_q4_0,
    q4_0_abstract,
)
from .int8 import (
    QuantizedActivation,
    QuantizedWeightI8,
    quantize_u8_dynamic,
    dequantize_u8,
    quantize_s8_symmetric,
    dequantize_s8,
    u8s8_matmul_decompose,
)

__all__ = [
    "GROUP",
    "BYTES_PER_ELEM",
    "QuantizedLinear",
    "quantize_q4_0",
    "dequantize_q4_0",
    "q4_0_abstract",
    "QuantizedActivation",
    "QuantizedWeightI8",
    "quantize_u8_dynamic",
    "dequantize_u8",
    "quantize_s8_symmetric",
    "dequantize_s8",
    "u8s8_matmul_decompose",
]
