"""Q4_0 weight-only quantization (llama.cpp-compatible layout).

The paper evaluates llama2-7B in 4-bit weight-only quantization, "equivalent
data type in llama.cpp is Q4_0 ... group size of 32, each group has 32 INT4
data and a FLOAT16 scale".

Faithful format, per group of 32 consecutive K elements:
  * scale  d = max|x| / -8   (sign chosen so the max maps to -8, llama.cpp's
    convention — keeps the code-point -8 in use)
  * codes  q = clamp(round(x/d) + 8, 0, 15), 4 bits each
  * packing: byte j of the group holds element j in its LOW nibble and
    element j+16 in its HIGH nibble (llama.cpp block_q4_0).

A weight matrix W of shape (N, K) is stored as
  packed : uint8 (N, K/2)    — K/32 groups of 16 bytes each
  scales : float16 (N, K/32)

Bytes per K element: 0.5 (int4) + 2/32 (scale) = 0.5625 — the factor used
throughout the bandwidth math.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

GROUP = 32
BYTES_PER_ELEM = 0.5 + 2.0 / GROUP  # 0.5625


class QuantizedLinear(NamedTuple):
    """Q4_0 weights for ``y = x @ W.T`` with W logically (N, K)."""

    packed: jax.Array  # uint8 (N, K // 2)
    scales: jax.Array  # float16 (N, K // GROUP)

    @property
    def out_features(self) -> int:
        return self.packed.shape[0]

    @property
    def in_features(self) -> int:
        return self.packed.shape[1] * 2

    @property
    def nbytes(self) -> int:
        return self.packed.size + 2 * self.scales.size


def quantize_q4_0(w: jax.Array) -> QuantizedLinear:
    """Quantize W (N, K) to Q4_0.  K must be a multiple of 32."""
    n, k = w.shape
    if k % GROUP:
        raise ValueError(f"K={k} must be a multiple of {GROUP}")
    g = w.reshape(n, k // GROUP, GROUP).astype(jnp.float32)
    # llama.cpp: d = max-by-|.| / -8 (keeps the sign of the absmax element).
    idx = jnp.argmax(jnp.abs(g), axis=-1, keepdims=True)
    maxval = jnp.take_along_axis(g, idx, axis=-1)  # signed absmax
    d = maxval / -8.0
    inv = jnp.where(d == 0, 0.0, 1.0 / d)
    q = jnp.clip(jnp.round(g * inv) + 8.0, 0.0, 15.0).astype(jnp.uint8)
    # byte j: elem j low nibble, elem j+16 high nibble
    lo = q[..., :GROUP // 2]
    hi = q[..., GROUP // 2:]
    packed = (lo | (hi << 4)).reshape(n, k // 2)
    return QuantizedLinear(packed=packed, scales=d[..., 0].astype(jnp.float16))


def dequantize_q4_0(qw: QuantizedLinear, dtype=jnp.float32) -> jax.Array:
    """Exact inverse of the packing (not of the rounding)."""
    n, half_k = qw.packed.shape
    k = half_k * 2
    b = qw.packed.reshape(n, k // GROUP, GROUP // 2)
    lo = (b & 0x0F).astype(jnp.int8)
    hi = (b >> 4).astype(jnp.int8)
    q = jnp.concatenate([lo, hi], axis=-1)  # (n, groups, 32)
    d = qw.scales.astype(jnp.float32)[..., None]
    return ((q.astype(jnp.float32) - 8.0) * d).reshape(n, k).astype(dtype)


def q4_0_abstract(n: int, k: int) -> QuantizedLinear:
    """ShapeDtypeStruct stand-in (for dry-runs / eval_shape)."""
    if k % GROUP:
        raise ValueError(f"K={k} must be a multiple of {GROUP}")
    return QuantizedLinear(
        packed=jax.ShapeDtypeStruct((n, k // 2), jnp.uint8),
        scales=jax.ShapeDtypeStruct((n, k // GROUP), jnp.float16),
    )
