"""Model trunk: composes mixers (attn/mamba/mlstm/slstm) + FFNs (dense/moe)
into the per-architecture layer plan, scanning over repeated periods.

Compile-time discipline: layers are grouped into the smallest repeating
(mixer, ffn) *period* (see ``ModelConfig.period``); parameters of each
period position are stacked over repeats and the trunk is a single
``lax.scan`` whose body applies one period.  A 72-layer jamba therefore
lowers as one 8-layer body — HLO size and compile time stay bounded across
the whole zoo.

States (KV caches / SSM / xLSTM states) follow the same stacking so that
prefill/decode scan over the same structure.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.specs import constrain, constrain_tree
from . import attention as A
from . import moe as M
from . import ssm as S
from . import xlstm as X
from .layers import (
    _norm_init,
    embed_fwd,
    init_embedding,
    init_mlp,
    logits_fwd,
    mlp_fwd,
    norm_fwd,
)

MIXER_INIT = {
    "attn": A.init_attn,
    "mamba": S.init_mamba,
    "mlstm": X.init_mlstm,
    "slstm": X.init_slstm,
}


def _init_layer(cfg: ModelConfig, key, mixer: str, ffn: str) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: dict[str, Any] = {
        "norm1": _norm_init(cfg, k1),
        "mixer": MIXER_INIT[mixer](cfg, k2),
    }
    if ffn != "none":
        p["norm2"] = _norm_init(cfg, k3)
        p["ffn"] = M.init_moe(cfg, k4) if ffn == "moe" else init_mlp(cfg, k4)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    """Returns {"embed": ..., "period": [stacked per-position params],
    "final_norm": ...}."""
    period = cfg.period()
    n_rep = cfg.n_periods
    keys = jax.random.split(key, n_rep * len(period) + 2)
    stacked = []
    for j, (mixer, ffn) in enumerate(period):
        per_rep = [
            _init_layer(cfg, keys[i * len(period) + j], mixer, ffn)
            for i in range(n_rep)
        ]
        stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep))
    return {
        "embed": init_embedding(cfg, keys[-2]),
        "period": stacked,
        "final_norm": _norm_init(cfg, keys[-1]),
    }


def abstract_params(cfg: ModelConfig, key=None) -> dict:
    """ShapeDtypeStruct pytree (no allocation) — dry-run weights."""
    k = jax.random.key(0) if key is None else key
    return jax.eval_shape(lambda: init_params(cfg, k))


# --------------------------------------------------------------- states ---
def init_state(cfg: ModelConfig, batch: int, max_seq: int):
    """Per-period-position stacked decoding state."""
    period = cfg.period()
    n_rep = cfg.n_periods
    out = []
    for mixer, _ in period:
        if mixer == "attn":
            one = lambda: A.KVCache(
                k=jnp.zeros((batch, cfg.n_kv_heads, max_seq, cfg.hd), cfg.cdtype),
                v=jnp.zeros((batch, cfg.n_kv_heads, max_seq, cfg.hd), cfg.cdtype),
                idx=jnp.zeros((), jnp.int32),
            )
        elif mixer == "mamba":
            one = lambda: S.init_mamba_state(cfg, batch)
        elif mixer == "mlstm":
            one = lambda: X.init_mlstm_state(cfg, batch)
        elif mixer == "slstm":
            one = lambda: X.init_slstm_state(cfg, batch)
        else:
            raise ValueError(mixer)
        reps = [one() for _ in range(n_rep)]
        out.append(jax.tree.map(lambda *xs: jnp.stack(xs), *reps))
    return out


def abstract_state(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.eval_shape(lambda: init_state(cfg, batch, max_seq))


def init_slot_state(cfg: ModelConfig, n_slots: int, max_seq: int):
    """Like :func:`init_state` but with per-row KV-cache indices: each of the
    ``n_slots`` batch rows advances through its cache independently, which is
    what a continuous-batching decode batch needs (rows are unrelated
    requests at different positions)."""
    state = init_state(cfg, n_slots, max_seq)

    def widen(leaf):
        if not isinstance(leaf, A.KVCache):
            return leaf  # SSM/xLSTM states already carry a batch axis
        # stacked over period repeats: k (n_rep, B, ...), idx (n_rep,)
        return A.KVCache(
            k=leaf.k, v=leaf.v,
            idx=jnp.zeros((leaf.k.shape[0], n_slots), jnp.int32))

    return jax.tree.map(widen, state,
                        is_leaf=lambda x: isinstance(x, A.KVCache))


# -------------------------------------------------------------- forward ---
class ForwardOut(NamedTuple):
    logits: jax.Array
    state: Any
    aux: dict


def _apply_layer(cfg, mixer, ffn, p, x, positions, state, capacity,
                 proj_attn=None, proj_ffn=None):
    h = norm_fwd(cfg, p["norm1"], x)
    if mixer == "attn":
        mix, new_state = A.attn_fwd(cfg, p["mixer"], h, positions, state,
                                    proj=proj_attn)
    elif mixer == "mamba":
        mix, new_state = S.mamba_fwd(cfg, p["mixer"], h, state)
    elif mixer == "mlstm":
        mix, new_state = X.mlstm_fwd(cfg, p["mixer"], h, state)
    elif mixer == "slstm":
        mix, new_state = X.slstm_fwd(cfg, p["mixer"], h, state)
    else:
        raise ValueError(mixer)
    x = x + mix
    aux = None
    if ffn != "none":
        h2 = norm_fwd(cfg, p["norm2"], x)
        if ffn == "moe":
            y, aux = M.moe_fwd(cfg, p["ffn"], h2, capacity)
        else:
            y = mlp_fwd(cfg, p["ffn"], h2, proj=proj_ffn)
        x = x + y
    return x, new_state, aux


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: Optional[jax.Array] = None,
    *,
    embeds: Optional[jax.Array] = None,
    prefix_embeds: Optional[jax.Array] = None,
    state: Optional[list] = None,
    pos_offset: jax.Array | int = 0,
    capacity: Optional[int] = None,
    logits_mode: str = "all",
    apply_head: bool = True,
    remat: bool = False,
    trunk=None,
    trunk_isa: str = "membw",
    trunk_offsets=None,
) -> ForwardOut:
    """Trunk forward.

    tokens: (B, S) int32 — or ``embeds`` (B, S, d) for embed-input archs
    (musicgen stub).  ``prefix_embeds`` (B, P, d) is prepended (internvl2
    stub).  ``state`` enables prefill/decode (returned updated).
    ``apply_head=False`` skips the LM-head matmul and returns the final-
    normed hidden states in the ``logits`` slot — for callers that run the
    head outside the jitted trunk (balanced hybrid kernel dispatch).

    ``trunk`` (a :class:`~repro.models.balanced.BalancedTrunk`) reroutes
    every supported projection through balanced per-core shard dispatch
    under the ``trunk_isa`` execution ISA (the caller's phase: "membw"
    decode / "avx_vnni" prefill).  The period loop is then unrolled in
    Python instead of ``lax.scan`` — each (position, repeat) needs its own
    host-side weight bank, whether the callbacks are traced into a jitted
    step or executed eagerly.  ``trunk_offsets`` (compiled trunks only) is
    the device offset snapshot forwarded to every projection.
    """
    if embeds is not None:
        x = embeds.astype(cfg.cdtype)
    else:
        x = embed_fwd(cfg, params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = constrain(x, ("dp", None, None))

    b, s, _ = x.shape
    offset = jnp.asarray(pos_offset)
    if offset.ndim == 1:  # per-row offsets (slot-batched serving)
        positions = offset[:, None] + jnp.arange(s)[None, :]
    else:
        positions = offset + jnp.arange(s)[None, :]
    positions = jnp.broadcast_to(positions, (b, s))

    period = cfg.period()
    have_state = state is not None
    moe_cfg = cfg.moe

    if trunk is not None:
        # Balanced-trunk path: unrolled Python loop over period repeats so
        # each (position, repeat) projection reaches its own host-side
        # balanced layer (static at trace time — the io_callback bridge
        # closes over the concrete weight bank).
        lb = jnp.zeros((), jnp.float32)
        dropped = jnp.zeros((), jnp.float32)
        per_pos_states: list = [[] for _ in period]
        for r in range(cfg.n_periods):
            for j, (mixer, ffn) in enumerate(period):
                p_j = jax.tree.map(lambda a, r=r: a[r], params["period"][j])
                st_j = (jax.tree.map(lambda s, r=r: s[r], state[j])
                        if have_state else None)
                x, new_st, aux = _apply_layer(
                    cfg, mixer, ffn, p_j, x, positions, st_j, capacity,
                    proj_attn=trunk.projector(j, r, "attn", trunk_isa,
                                              offsets=trunk_offsets),
                    proj_ffn=trunk.projector(j, r, "ffn", trunk_isa,
                                             offsets=trunk_offsets),
                )
                x = constrain(x, ("dp", None, None))
                if have_state:
                    per_pos_states[j].append(new_st)
                if aux is not None:
                    lb = lb + aux["lb_loss"]
                    dropped = dropped + aux["dropped"]
        new_state = ([jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
                      for reps in per_pos_states] if have_state else 0)
    else:
        def period_body(carry, xs):
            x, lb, dropped = carry
            p_stack, st_stack = xs
            new_states = []
            for j, (mixer, ffn) in enumerate(period):
                st_j = st_stack[j] if have_state else None
                x, new_st, aux = _apply_layer(
                    cfg, mixer, ffn, p_stack[j], x, positions, st_j, capacity
                )
                # anchor sharding propagation inside the while body (GSPMD
                # does not reliably propagate through scan+remat)
                x = constrain(x, ("dp", None, None))
                new_states.append(new_st if have_state else st_j)
                if aux is not None:
                    lb = lb + aux["lb_loss"]
                    dropped = dropped + aux["dropped"]
            return (x, lb, dropped), (new_states if have_state else 0)

        carry0 = (x, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
        xs = (params["period"],
              state if have_state else jnp.zeros((cfg.n_periods,)))
        body = jax.checkpoint(period_body) if remat else period_body
        (x, lb, dropped), new_state = jax.lax.scan(body, carry0, xs)

    if logits_mode == "last":
        # Serving prefill: only the last position's logits are consumed;
        # slicing *before* the (d x vocab) matmul avoids materializing a
        # (B, S, V) tensor (53 GB/device for llama4 at prefill_32k).
        x = x[:, -1:, :]
    x = norm_fwd(cfg, params["final_norm"], x)
    if apply_head:
        logits = logits_fwd(cfg, params["embed"], x)
        logits = constrain(logits, ("dp", None, "tp"))
    else:
        logits = x.astype(jnp.float32)
    n_moe = max(1, sum(1 for _, f in cfg.layer_plan() if f == "moe"))
    aux = {"lb_loss": lb / n_moe, "dropped": dropped / n_moe}
    return ForwardOut(logits=logits, state=new_state if have_state else None, aux=aux)


def balanced_lm_head(cfg: ModelConfig, params: dict, dispatcher):
    """Bind the model's LM head to a hybrid kernel dispatcher: the (vocab,
    d_model) head matrix is Q4_0-quantized and every call runs as balanced
    per-core Pallas shards (see
    :class:`~repro.models.layers.BalancedQuantLinear`).  Use with
    ``forward(..., apply_head=False)``: the decode-step Fp32-Int4-Fp32 GEMV
    — the paper's hot path — then executes through the ratio-table loop
    instead of inside the jitted trunk."""
    from .layers import BalancedQuantLinear

    w = (params["embed"]["tok"] if cfg.tie_embeddings
         else params["embed"]["out"].T)  # (vocab, d_model) = (N, K)
    return BalancedQuantLinear.from_dense(w, dispatcher)


def loss_fn(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    *,
    lb_coef: float = 0.01,
    capacity: Optional[int] = None,
    remat: bool = False,
) -> tuple[jax.Array, dict]:
    """Next-token cross-entropy (+ MoE load-balance loss).

    batch: {"tokens": (B,S), "labels": (B,S) with -100 = ignore} and
    optionally "embeds"/"prefix_embeds" for stub-frontend archs.
    """
    out = forward(
        cfg,
        params,
        batch.get("tokens"),
        embeds=batch.get("embeds"),
        prefix_embeds=batch.get("prefix_embeds"),
        capacity=capacity,
        remat=remat,
    )
    labels = batch["labels"]
    logits = out.logits
    if logits.shape[1] != labels.shape[1]:  # prefix positions carry no loss
        logits = logits[:, logits.shape[1] - labels.shape[1]:, :]
    valid = labels != -100
    safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(valid.sum(), 1)
    ce = jnp.where(valid, nll, 0.0).sum() / denom
    total = ce + lb_coef * out.aux["lb_loss"]
    metrics = {"loss": total, "ce": ce, "lb": out.aux["lb_loss"],
               "dropped": out.aux["dropped"]}
    return total, metrics
