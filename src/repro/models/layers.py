"""Shared building blocks: norms, MLPs, embeddings, rotary embeddings.

Parameters are plain dict pytrees; every ``init_*`` has matching
``fwd_*`` so stages can be stacked and scanned.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _norm_init(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"w": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}
    if cfg.norm == "nonparam_ln":  # olmo: no affine parameters
        return {}
    raise ValueError(cfg.norm)


def norm_fwd(cfg: ModelConfig, p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (xf * p["w"]).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    if cfg.norm == "layernorm":
        xf = xf * p["w"] + p["b"]
    return xf.astype(x.dtype)


def _dense(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def init_mlp(cfg: ModelConfig, key) -> dict:
    dt = cfg.cdtype
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp == "swiglu":
        return {
            "wi": _dense(k1, d, f, dt),
            "wg": _dense(k2, d, f, dt),
            "wo": _dense(k3, f, d, dt),
        }
    if cfg.mlp == "gelu":
        return {"wi": _dense(k1, d, f, dt), "wo": _dense(k3, f, d, dt)}
    raise ValueError(cfg.mlp)


def mlp_fwd(cfg: ModelConfig, p: dict, x: jax.Array,
            proj: Optional[callable] = None) -> jax.Array:
    """``proj(name, x, w)`` overrides each projection matmul (balanced
    hybrid dispatch of the trunk); default is the in-graph ``x @ w``."""
    mm = proj or (lambda name, x, w: x @ w)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(mm("wg", x, p["wg"])) * mm("wi", x, p["wi"])
    else:  # gelu
        h = jax.nn.gelu(mm("wi", x, p["wi"]))
    return mm("wo", h, p["wo"])


def init_embedding(cfg: ModelConfig, key) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model)) * 0.02
                 ).astype(cfg.cdtype)}
    if not cfg.tie_embeddings:
        p["out"] = _dense(k2, cfg.d_model, cfg.vocab_size, cfg.cdtype)
    return p


def embed_fwd(cfg: ModelConfig, p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def logits_fwd(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["out"]
    return (x @ w).astype(jnp.float32)


# ------------------------------------------------------- balanced linears --
class BalancedQuantLinear:
    """Host-side Fp32-Int4-Fp32 linear ``y = x @ W.T`` executed as balanced
    per-core shards of the Q4 Pallas kernel (the paper's decode hot path).

    The weight stays packed (Q4_0); each call plans one contiguous N-row
    shard per core from the dispatcher's per-ISA ratio table, runs the real
    kernel shard-wise, and feeds shard times back — the model hot path *is*
    the control loop.  ``isa`` selects the table key per phase:
    ``"membw"`` for memory-bound decode GEMV, ``"avx_vnni"`` when the same
    weight runs a compute-bound prefill GEMM.
    """

    def __init__(self, qw, dispatcher, *, blocks=None):
        self.qw = qw
        self.dispatcher = dispatcher
        # Optional pinned (bm, bn, bk): the compiled lowering pins a
        # deterministic block config, so comparison trunks pin the same one
        # here to make bridged-vs-compiled Q4 outputs bit-identical
        # (Q4 float accumulation order depends on bk).
        self.blocks = blocks

    @classmethod
    def from_dense(cls, w: jax.Array, dispatcher, *,
                   blocks=None) -> "BalancedQuantLinear":
        """Quantize a dense (N, K) weight to Q4_0 and bind the dispatcher."""
        from repro.quant.q4 import quantize_q4_0

        return cls(quantize_q4_0(jnp.asarray(w, jnp.float32)), dispatcher,
                   blocks=blocks)

    @property
    def out_features(self) -> int:
        return self.qw.out_features

    def __call__(self, x: jax.Array, *, isa: str = "membw",
                 key: Optional[str] = None) -> jax.Array:
        unflatten = x.ndim == 3
        if unflatten:  # (B, S, d) hidden states -> one (B*S, d) GEMM/GEMV
            b, s, d = x.shape
            x = x.reshape(b * s, d)
        y = self.dispatcher.q4_matmul(x.astype(jnp.float32), self.qw,
                                      isa=isa, key=key, blocks=self.blocks)
        return y.reshape(b, s, -1) if unflatten else y


class BalancedLinear:
    """Dense linear executed as the paper's prefill path: dynamic u8
    activation quantization + s8 weights through balanced per-core INT8
    GEMM shards (``avx_vnni`` table key), dequantized back to f32."""

    def __init__(self, w_s8, dispatcher):
        self.w = w_s8
        self.dispatcher = dispatcher

    @classmethod
    def from_dense(cls, w: jax.Array, dispatcher) -> "BalancedLinear":
        from repro.quant.int8 import quantize_s8_symmetric

        return cls(quantize_s8_symmetric(jnp.asarray(w, jnp.float32)),
                   dispatcher)

    @property
    def out_features(self) -> int:
        return self.w.q.shape[0]

    def __call__(self, x: jax.Array, *, isa: str = "avx_vnni",
                 key: Optional[str] = None) -> jax.Array:
        from repro.quant.int8 import quantize_u8_dynamic, u8s8_matmul_decompose

        unflatten = x.ndim == 3
        if unflatten:
            b, s, d = x.shape
            x = x.reshape(b * s, d)
        qa = quantize_u8_dynamic(x.astype(jnp.float32))
        acc = self.dispatcher.int8_gemm(qa.q, self.w.q, isa=isa, key=key)
        y = u8s8_matmul_decompose(qa, self.w, acc)
        return y.reshape(b, s, -1) if unflatten else y


class BalancedFp32Linear:
    """Full-precision linear sharded per core through the dispatcher's
    plain host matmul — the trunk's precision-reference path: identical to
    the monolithic ``x @ W.T`` (N-row shards don't change any output
    element's reduction), but every call still exercises the ratio-table
    loop and bytes accounting like the quantized paths."""

    def __init__(self, w, dispatcher):
        import numpy as np

        self.w = np.asarray(w, dtype=np.float32)  # (N, K)
        self.dispatcher = dispatcher

    @classmethod
    def from_dense(cls, w: jax.Array, dispatcher) -> "BalancedFp32Linear":
        return cls(w, dispatcher)

    @property
    def out_features(self) -> int:
        return self.w.shape[0]

    def __call__(self, x: jax.Array, *, isa: str = "membw",
                 key: Optional[str] = None) -> jax.Array:
        unflatten = x.ndim == 3
        if unflatten:
            b, s, d = x.shape
            x = x.reshape(b * s, d)
        y = self.dispatcher.f32_matmul(x, self.w, isa=isa, key=key)
        return y.reshape(b, s, -1) if unflatten else y


# ----------------------------------------------------------------- rotary --
def rope_angles(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin (..., dim//2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float,
               fraction: float = 1.0) -> jax.Array:
    """x (B, H, S, hd); positions (B, S) or (S,).

    ``fraction < 1`` rotates only the first ``fraction * hd`` dims
    (ChatGLM-style 2D rope: half the head is positional, half is not).
    """
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    if positions.ndim == 1:
        positions = positions[None, :]
    cos, sin = rope_angles(positions, rot, theta)  # (B, S, rot/2)
    cos = cos[:, None, :, :]
    sin = sin[:, None, :, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1).astype(x.dtype) if rot < hd else yr.astype(x.dtype)
