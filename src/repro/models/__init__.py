"""Model zoo: composable mixers + trunk covering all assigned families."""

from .transformer import (
    init_params,
    abstract_params,
    init_state,
    abstract_state,
    init_slot_state,
    balanced_lm_head,
    forward,
    loss_fn,
    ForwardOut,
)
from .layers import BalancedLinear, BalancedQuantLinear

__all__ = [
    "init_params",
    "abstract_params",
    "init_state",
    "abstract_state",
    "init_slot_state",
    "balanced_lm_head",
    "forward",
    "loss_fn",
    "ForwardOut",
    "BalancedLinear",
    "BalancedQuantLinear",
]
