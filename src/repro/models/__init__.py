"""Model zoo: composable mixers + trunk covering all assigned families."""

from .transformer import (
    init_params,
    abstract_params,
    init_state,
    abstract_state,
    init_slot_state,
    balanced_lm_head,
    forward,
    loss_fn,
    ForwardOut,
)
from .layers import BalancedFp32Linear, BalancedLinear, BalancedQuantLinear
from .balanced import BalancedTrunk

__all__ = [
    "BalancedTrunk",
    "BalancedFp32Linear",
    "init_params",
    "abstract_params",
    "init_state",
    "abstract_state",
    "init_slot_state",
    "balanced_lm_head",
    "forward",
    "loss_fn",
    "ForwardOut",
    "BalancedLinear",
    "BalancedQuantLinear",
]
