"""Model zoo: composable mixers + trunk covering all assigned families."""

from .transformer import (
    init_params,
    abstract_params,
    init_state,
    abstract_state,
    init_slot_state,
    forward,
    loss_fn,
    ForwardOut,
)

__all__ = [
    "init_params",
    "abstract_params",
    "init_state",
    "abstract_state",
    "init_slot_state",
    "forward",
    "loss_fn",
    "ForwardOut",
]
