"""Mixture-of-Experts layer with capacity-bounded sort-based dispatch.

Dispatch is the sort/scatter formulation (no (T, E, C) one-hot einsum — that
tensor is ~5e12 elements for llama4-maverick at train_4k): token->expert
assignments are sorted by expert id, positions within each expert segment
become buffer offsets, and overflow beyond the expert's capacity is dropped.
Expert compute is a static (E, C, d) x (E, d, f) einsum, shardable with E on
the 'model' axis (expert parallelism); GSPMD inserts the dispatch/combine
collectives.

Paper integration (first-class): expert load imbalance is the MoE
incarnation of the paper's hybrid-core imbalance.  Two Eq.-3 mechanisms:

* :class:`repro.runtime.ExpertCapacityPlanner` retunes the static
  capacity between recompiles from the load EMA (slow loop);
* :func:`balanced_expert_assignment` (here) computes an LPT expert->shard
  permutation from the load EMA so each EP shard carries equal expected
  load (fast loop, a pure weight/router-column permutation).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from .layers import _dense


def default_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = math.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor)
    return max(8, (c + 7) // 8 * 8)  # MXU-friendly multiple of 8


def init_moe(cfg: ModelConfig, key) -> dict:
    m = cfg.moe
    dff = m.d_ff or cfg.d_ff
    d, e = cfg.d_model, m.n_experts
    dt = cfg.cdtype
    ks = jax.random.split(key, 7)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(jnp.float32),
        "wi": (jax.random.normal(ks[1], (e, d, dff)) * d ** -0.5).astype(dt),
        "wg": (jax.random.normal(ks[2], (e, d, dff)) * d ** -0.5).astype(dt),
        "wo": (jax.random.normal(ks[3], (e, dff, d)) * dff ** -0.5).astype(dt),
    }
    if m.shared_expert:
        p["swi"] = _dense(ks[4], d, dff, dt)
        p["swg"] = _dense(ks[5], d, dff, dt)
        p["swo"] = _dense(ks[6], dff, d, dt)
    return p


def _dispatch(cfg: ModelConfig, xf: jax.Array, probs: jax.Array, c: int):
    """Sort-based dispatch of ``xf`` (T, d) into an (E, C, d) buffer.

    Returns (buf, dest, st, swk, counts) — all index arrays are local to
    this token shard (the combine must use the same shard).
    """
    m = cfg.moe
    t, d = xf.shape
    e, k = m.n_experts, m.top_k
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                       # (T*k,)
    flat_w = top_p.reshape(-1)
    tok_of = jnp.arange(t * k, dtype=jnp.int32) // k

    order = jnp.argsort(flat_e, stable=True)
    se, sw, st = flat_e[order], flat_w[order], tok_of[order]
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    seg_start = jnp.cumsum(counts) - counts          # (E,)
    seg_pos = jnp.arange(t * k, dtype=jnp.int32) - seg_start[se]
    keep = seg_pos < c
    dest = jnp.where(keep, se * c + seg_pos, e * c - 1)

    gathered = xf[st] * keep[:, None].astype(xf.dtype)
    buf = jnp.zeros((e * c, d), xf.dtype).at[dest].add(gathered)
    return buf.reshape(e, c, d), dest, st, (sw * keep).astype(xf.dtype), counts


def _combine(out_buf: jax.Array, dest, st, swk, t: int, dtype) -> jax.Array:
    e, c, d = out_buf.shape
    contrib = out_buf.reshape(e * c, d)[dest] * swk[:, None].astype(out_buf.dtype)
    return jnp.zeros((t, d), dtype).at[st].add(contrib.astype(dtype))


def _expert_ffn(p: dict, buf: jax.Array) -> jax.Array:
    """Expert SwiGLU on the (E, C, d) buffer.  With E sharded on 'model'
    and C sharded on the data axes this is a pure block-local einsum."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["wi"]
    )
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])


def moe_fwd(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    capacity: Optional[int] = None,
) -> tuple[jax.Array, dict]:
    """x: (B, S, d) -> (y, aux) with aux = {lb_loss, load, dropped}.

    Distribution: when an activation-sharding mesh is installed and the
    token count divides the data axes, dispatch/combine run *per data
    shard* under shard_map (local argsort/scatter — no global token
    gather; measured ~100x wire reduction on llama4 train vs the naive
    GSPMD lowering of a global sort).  Expert compute stays a GSPMD einsum
    with E on 'model' and C on the data axes (block-local).
    """
    from repro.sharding.specs import current_mesh, data_axes
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k

    xf = x.reshape(t, d)
    logits = (xf.astype(jnp.float32)) @ p["router"]  # (T, E) f32
    probs = jax.nn.softmax(logits, axis=-1)

    mesh = current_mesh()
    import math as _math
    dp = data_axes(mesh) if mesh is not None else ()
    dp_size = _math.prod(mesh.shape[a] for a in dp) if mesh is not None else 1
    local_path = mesh is not None and dp_size > 1 and t % dp_size == 0 \
        and (t // dp_size) >= 1

    tp_size = mesh.shape.get("model", 1) if mesh is not None else 1
    # EP all-to-all moves token buffers but requires the (FSDP-sharded)
    # expert weights gathered per layer — worth it only when the token
    # volume is large (train/prefill).  Decode (a handful of tokens) must
    # keep weights stationary: the GSPMD einsum path reshard's the tiny
    # buffer instead.
    tokens_per_expert = (t // dp_size) * k / e if dp_size else t * k / e
    ep_path = (local_path and tp_size > 1 and e % tp_size == 0
               and tokens_per_expert >= 8)

    if ep_path:
        # Full expert parallelism: dispatch locally per data shard, exchange
        # expert chunks with all-to-all over 'model', run the e/tp local
        # experts, reverse the exchange, combine locally.  Wire per trip =
        # 2 x buffer bytes (fwd) [+ same bwd] — no buffer-sized gathers.
        t_l = t // dp_size
        c = capacity if capacity is not None else default_capacity(cfg, t_l)
        c = max(8, min(c, t_l * k))

        def moe_local(xf_l, probs_l, wg_l, wi_l, wo_l):
            buf, dest, st, swk, counts = _dispatch(cfg, xf_l, probs_l, c)
            # (E, c, d) -> (E/tp, c*tp, d): expert chunks to their owners
            bufx = jax.lax.all_to_all(buf, "model", split_axis=0,
                                      concat_axis=1, tiled=True)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", bufx, wg_l)) * \
                jnp.einsum("ecd,edf->ecf", bufx, wi_l)
            outx = jnp.einsum("ecf,efd->ecd", h, wo_l)
            out = jax.lax.all_to_all(outx, "model", split_axis=1,
                                     concat_axis=0, tiled=True)
            y_l = _combine(out, dest, st, swk, t_l, x.dtype)
            return y_l, counts[None, :]

        y, counts_g = shard_map(
            moe_local,
            mesh=mesh,
            in_specs=(P(dp, None), P(dp, None),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=(P(dp, None), P(dp, None)),
            check_rep=False,
        )(xf, probs, p["wg"], p["wi"], p["wo"])
        counts = counts_g.sum(0)
        dropped = 1.0 - jnp.minimum(counts, c).sum() / jnp.maximum(
            counts.sum(), 1).astype(jnp.float32)
    elif local_path:
        t_l = t // dp_size
        c = capacity if capacity is not None else default_capacity(cfg, t_l)
        c = max(8, min(c, t_l * k))

        def dispatch_local(xf_l, probs_l):
            buf, dest, st, swk, counts = _dispatch(cfg, xf_l, probs_l, c)
            return buf, dest, st, swk, counts[None, :]

        buf, dest, st, swk, counts_g = shard_map(
            dispatch_local,
            mesh=mesh,
            in_specs=(P(dp, None), P(dp, None)),
            out_specs=(P(None, dp, None), P(dp), P(dp), P(dp), P(dp, None)),
        )(xf, probs)

        out_buf = _expert_ffn(p, buf)

        def combine_local(out_buf_l, dest_l, st_l, swk_l):
            return _combine(out_buf_l, dest_l, st_l, swk_l, t_l, x.dtype)

        y = shard_map(
            combine_local,
            mesh=mesh,
            in_specs=(P(None, dp, None), P(dp), P(dp), P(dp)),
            out_specs=P(dp, None),
        )(out_buf, dest, st, swk)
        counts = counts_g.sum(0)
        dropped = 1.0 - jnp.minimum(counts, c).sum() / jnp.maximum(
            counts.sum(), 1).astype(jnp.float32)
    else:
        c = capacity if capacity is not None else default_capacity(cfg, t)
        buf, dest, st, swk, counts = _dispatch(cfg, xf, probs, c)
        if mesh is not None:
            from repro.sharding.specs import constrain
            # move the (small) buffer to the experts, not the other way
            buf = constrain(buf, ("tp", None, None))
        out_buf = _expert_ffn(p, buf)
        y = _combine(out_buf, dest, st, swk, t, x.dtype)
        dropped = 1.0 - jnp.minimum(counts, c).sum() / jnp.maximum(
            counts.sum(), 1).astype(jnp.float32)

    if m.shared_expert:
        sh = jax.nn.silu(xf @ p["swg"]) * (xf @ p["swi"])
        y = y + (sh @ p["swo"]).astype(x.dtype)

    # Switch-style load-balance loss + telemetry for the capacity planner.
    frac = counts.astype(jnp.float32) / jnp.maximum(counts.sum(), 1)
    mean_prob = probs.mean(axis=0)
    aux = {
        "lb_loss": e * jnp.sum(frac * mean_prob),
        "load": counts.astype(jnp.float32),
        "dropped": dropped,
    }
    return y.reshape(b, s, d), aux


# ------------------------------------------------------- expert placement --
def balanced_expert_assignment(load: np.ndarray, n_shards: int) -> np.ndarray:
    """LPT (longest-processing-time) expert->shard placement.

    Returns a permutation ``perm`` of expert ids such that slicing
    ``perm`` into ``n_shards`` contiguous blocks yields near-equal summed
    load per block — Eq. 3 applied to EP shards, realized as placement
    because per-shard *capacity* must stay static for XLA.
    """
    load = np.asarray(load, dtype=np.float64)
    e = len(load)
    if e % n_shards:
        raise ValueError(f"{e} experts not divisible by {n_shards} shards")
    per = e // n_shards
    shard_load = np.zeros(n_shards)
    shard_members: list[list[int]] = [[] for _ in range(n_shards)]
    for idx in np.argsort(-load):
        open_shards = [s for s in range(n_shards) if len(shard_members[s]) < per]
        s = min(open_shards, key=lambda s: shard_load[s])
        shard_members[s].append(int(idx))
        shard_load[s] += load[idx]
    return np.concatenate([np.array(ms, dtype=np.int64) for ms in shard_members])


def apply_expert_permutation(p: dict, perm: np.ndarray) -> dict:
    """Permute expert-stacked params (and router columns) so that logical
    expert ``perm[i]`` lives at position ``i``.  Forward output is invariant.
    """
    perm = jnp.asarray(perm)
    q = dict(p)
    q["router"] = p["router"][:, perm]
    for name in ("wi", "wg", "wo"):
        q[name] = p[name][perm]
    return q
