"""Balanced trunk: every projection of the decode step through the paper's
per-core shard dispatch.

PR 3 put the LM-head GEMV on the :class:`~repro.kernels.dispatch.
HybridKernelDispatcher`; the rest of the decode step (q/k/v/o attention
projections, MLP up/gate/down) still executed as monolithic jitted
matmuls, so the per-ISA ratio loop saw a fraction of the bytes moved per
token.  :class:`BalancedTrunk` extracts *all* of those weights into
host-side balanced linears — :class:`~repro.models.layers.
BalancedQuantLinear` (Q4_0 decode GEMV), :class:`~repro.models.layers.
BalancedLinear` (dynamic-u8 x s8 INT8 GEMM) or :class:`~repro.models.
layers.BalancedFp32Linear` (precision reference, shard-exact) — and hands
the trunk forward a per-layer projection hook.  Three execution modes:

* ``mode="bridge"`` (the ``jit_bridge=True`` legacy spelling): under jit
  every projection becomes an ordered ``io_callback`` into the
  dispatcher's worker pools — the host re-plans *inside* the step;
* ``mode="eager"`` (``jit_bridge=False``): tracing disallowed, direct
  shard-wise execution;
* ``mode="compiled"``: zero host callbacks — projections lower through a
  :class:`~repro.kernels.compiled.CompiledDispatcher` as single Pallas
  grids whose per-core boundaries are device offset arrays planned
  *between* engine steps, with a traced cost tape feeding the same Eq. 2
  EMA updates after the step (see :mod:`repro.kernels.compiled`).

Table keys are per (ISA x layer kind): ``"membw/attn_proj"``,
``"avx_vnni/mlp_up"``, ... (see :data:`~repro.kernels.dispatch.
TRUNK_KINDS`), so each projection family converges its own ratio vector
per phase while the dispatcher's bytes accounting aggregates the whole
decode step per ISA — the trunk-level achieved-bandwidth fraction.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import jax

from repro.configs.base import ModelConfig
from repro.kernels.dispatch import (
    bridged_linear,
    bridged_linear_fused,
    kernel_key,
)

from .layers import BalancedFp32Linear, BalancedLinear, BalancedQuantLinear

__all__ = ["BalancedTrunk", "QUANT_MODES"]

QUANT_MODES = ("q4", "int8", "fp32")

_LAYER_CLS = {
    "q4": BalancedQuantLinear,
    "int8": BalancedLinear,
    "fp32": BalancedFp32Linear,
}

# (group, param name) -> ratio-table layer kind
_KIND = {
    ("attn", "wq"): "attn_proj",
    ("attn", "wk"): "attn_proj",
    ("attn", "wv"): "attn_proj",
    ("attn", "wo"): "attn_proj",
    ("ffn", "wi"): "mlp_up",
    ("ffn", "wg"): "mlp_up",
    ("ffn", "wo"): "mlp_down",
}


class BalancedTrunk:
    """Host-side balanced projection bank for a model's whole trunk.

    ``bank[(j, group, name)]`` holds one balanced linear per period repeat
    for period position ``j`` and parameter ``name`` of ``group`` ("attn"
    mixer or dense "ffn"); unsupported layers (SSM/xLSTM mixers, MoE ffns)
    are simply not banked and keep their in-graph matmuls.  ``head`` is the
    optional balanced LM head (kind ``"head"``).
    """

    MODES = ("eager", "bridge", "compiled")

    def __init__(self, cfg: ModelConfig, dispatcher, *,
                 bank: Dict[Tuple[int, str, str], List],
                 head=None, quant: str = "q4", jit_bridge: bool = True,
                 fused: bool = True, mode: Optional[str] = None,
                 double_buffer: bool = True):
        self.cfg = cfg
        self.dispatcher = dispatcher
        self.bank = bank
        self.head = head
        self.quant = quant
        # ``jit_bridge=`` is the legacy two-mode spelling; ``mode=`` wins
        # when given.
        mode = mode or ("bridge" if jit_bridge else "eager")
        if mode not in self.MODES:
            raise ValueError(f"mode must be one of {self.MODES}")
        self.mode = mode
        self.double_buffer = double_buffer
        # Fused q/k/v: the three input projections of an attention layer
        # share one jit-bridge round trip (a single ordered io_callback)
        # instead of three.  Token-identical to the per-matmul path — the
        # host side still runs three separate balanced regions in the same
        # program order — so False exists only as the identity reference.
        # (Compiled mode has no round trips to fuse; the flag is ignored.)
        self.fused = fused
        self._ctx = None  # lazy CompiledDispatcher (mode="compiled" only)

    @property
    def jit_bridge(self) -> bool:
        """Whether the trunk's projections may be traced (legacy name: in
        ``"compiled"`` mode they trace without any bridge)."""
        return self.mode != "eager"

    # -------------------------------------------------------- construction --
    @classmethod
    def from_params(cls, cfg: ModelConfig, params: dict, dispatcher, *,
                    quant: str = "q4", include_head: bool = True,
                    jit_bridge: bool = True, fused: bool = True,
                    mode: Optional[str] = None, double_buffer: bool = True,
                    pin_q4_blocks: bool = False) -> "BalancedTrunk":
        """Quantize (or copy, for fp32) every supported trunk projection of
        ``params`` into dispatcher-bound balanced linears.

        Weights are stored transposed relative to the forward's ``x @ w``
        convention: a (d_in, d_out) parameter becomes an (N, K) = (d_out,
        d_in) balanced linear computing ``x @ W.T``.

        ``pin_q4_blocks`` pins every Q4 layer to the deterministic block
        config the compiled lowering uses for its K
        (:func:`~repro.kernels.compiled.q4_blocks`), making a bridged
        trunk's Q4 outputs bit-identical to the compiled one's.
        """
        if quant not in QUANT_MODES:
            raise ValueError(f"quant must be one of {QUANT_MODES}")
        layer_cls = _LAYER_CLS[quant]

        def make_layer(w):  # w is dense (N, K)
            if quant == "q4" and pin_q4_blocks:
                from repro.kernels.compiled import q4_blocks

                return layer_cls.from_dense(w, dispatcher,
                                            blocks=q4_blocks(w.shape[1]))
            return layer_cls.from_dense(w, dispatcher)
        period = cfg.period()
        bank: Dict[Tuple[int, str, str], List] = {}
        for j, (mixer, ffn) in enumerate(period):
            groups = []
            if mixer == "attn":
                groups.append(("attn", ("wq", "wk", "wv", "wo")))
            if ffn == "dense":
                names = ("wi", "wg", "wo") if cfg.mlp == "swiglu" else ("wi", "wo")
                groups.append(("ffn", names))
            for group, names in groups:
                stack = params["period"][j]["mixer" if group == "attn" else "ffn"]
                for name in names:
                    w_stack = stack[name]  # (n_rep, d_in, d_out)
                    bank[(j, group, name)] = [
                        make_layer(w_stack[r].T)
                        for r in range(cfg.n_periods)
                    ]
        head = None
        if include_head:
            w = (params["embed"]["tok"] if cfg.tie_embeddings
                 else params["embed"]["out"].T)  # (vocab, d_model)
            head = make_layer(w)
        return cls(cfg, dispatcher, bank=bank, head=head, quant=quant,
                   jit_bridge=jit_bridge, fused=fused, mode=mode,
                   double_buffer=double_buffer)

    # ------------------------------------------------------------ compiled --
    def _compiled(self):
        """The lazily-built :class:`~repro.kernels.compiled.
        CompiledDispatcher` for this trunk, with every banked call site
        (both phase ISAs, plus the head) pre-registered so the offset
        snapshot's pytree keyset is complete before the first trace."""
        if self.mode != "compiled":
            raise ValueError(f"trunk mode is {self.mode!r}, not 'compiled'")
        if self._ctx is None:
            from repro.kernels.compiled import CompiledDispatcher

            ctx = CompiledDispatcher(self.dispatcher,
                                     double_buffer=self.double_buffer)
            for (j, group, name), layers in self.bank.items():
                for isa in ("membw", "avx_vnni"):
                    ctx.spec_for(layers[0], isa, _KIND[(group, name)])
            if self.head is not None:
                for isa in ("membw", "avx_vnni"):
                    ctx.spec_for(self.head, isa, "head")
            self._ctx = ctx
        return self._ctx

    def compiled_refresh(self):
        """Re-plan all call sites from the current ratio tables; returns
        the device offset snapshot to pass into the next jitted step."""
        return self._compiled().refresh()

    def compiled_tape_begin(self):
        return self._compiled().tape_begin()

    def compiled_tape_end(self, tape):
        return self._compiled().tape_end(tape)

    def compiled_feedback(self, records, update: bool = True):
        """Replay one step's cost-tape records through the dispatcher
        (Eq. 2 EMA updates + bandwidth accounting) and return the
        refreshed offset snapshot."""
        return self._compiled().feedback(records, update=update)

    # ----------------------------------------------------------- dispatch --
    def supports(self, j: int, group: str) -> bool:
        return any(k[0] == j and k[1] == group for k in self.bank)

    def projector(self, j: int, rep: int, group: str, isa: str,
                  offsets=None) -> Optional[Callable]:
        """The ``proj(name, x, w)`` hook for one (period position, repeat,
        group): balanced layers where banked, in-graph matmul otherwise.
        Returns ``None`` when nothing at this position is banked (the
        forward then skips hook plumbing entirely).  ``offsets`` (compiled
        mode only) is the device offset snapshot the step was called with."""
        if not self.supports(j, group):
            return None

        if self.mode == "compiled":
            ctx = self._compiled()

            def proj(name: str, x: jax.Array, w: jax.Array) -> jax.Array:
                layers = self.bank.get((j, group, name))
                if layers is None:
                    return x @ w
                return ctx.apply(layers[rep], x, isa=isa,
                                 kind=_KIND[(group, name)], offsets=offsets)

            return proj

        def proj(name: str, x: jax.Array, w: jax.Array) -> jax.Array:
            layers = self.bank.get((j, group, name))
            if layers is None:
                return x @ w
            kind = _KIND[(group, name)]
            return bridged_linear(layers[rep], x, isa=isa,
                                  key=kernel_key(isa, kind),
                                  allow_callback=self.jit_bridge)

        if (self.fused and group == "attn"
                and all((j, "attn", n) in self.bank
                        for n in ("wq", "wk", "wv"))):
            qkv_layers = [self.bank[(j, "attn", n)][rep]
                          for n in ("wq", "wk", "wv")]
            qkv_keys = [kernel_key(isa, _KIND[("attn", n)])
                        for n in ("wq", "wk", "wv")]

            def qkv(x: jax.Array, wq, wk, wv) -> tuple:
                # one jit-bridge round trip for all three projections;
                # wq/wk/wv are ignored (the banked weights are the truth)
                return bridged_linear_fused(
                    qkv_layers, x, isa=isa, keys=qkv_keys,
                    allow_callback=self.jit_bridge)

            proj.qkv = qkv

        return proj

    def apply_head(self, x: jax.Array, *, isa: str,
                   offsets=None) -> jax.Array:
        """Balanced LM head with the per-phase ``"<isa>/head"`` table key.
        Bridge/eager modes run it host-side (the engine applies the head
        outside the jitted trunk); compiled mode lowers it in-graph like
        every other projection."""
        if self.head is None:
            raise ValueError("trunk was built with include_head=False")
        if self.mode == "compiled":
            return self._compiled().apply(self.head, x, isa=isa,
                                          kind="head", offsets=offsets)
        return bridged_linear(self.head, x, isa=isa,
                              key=kernel_key(isa, "head"),
                              allow_callback=self.jit_bridge)
