"""Modality-frontend STUBS for backbone-only assigned architectures.

Per the assignment, [vlm]/[audio] entries specify the transformer backbone
only; the frontend (InternViT vision tower, EnCodec audio codec) is a stub:
``input_specs()`` provides precomputed patch/frame embeddings with the right
shapes/dtypes, and the helpers here generate concrete stand-ins for smoke
tests and examples.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def vlm_prefix_spec(cfg: ModelConfig, batch: int) -> jax.ShapeDtypeStruct:
    """Precomputed vision-patch embeddings (InternViT output, projected)."""
    return jax.ShapeDtypeStruct((batch, cfg.n_prefix, cfg.d_model), cfg.cdtype)


def vlm_prefix_stub(cfg: ModelConfig, batch: int, key=None) -> jax.Array:
    key = jax.random.key(0) if key is None else key
    return (jax.random.normal(key, (batch, cfg.n_prefix, cfg.d_model)) * 0.02
            ).astype(cfg.cdtype)


def audio_frame_spec(cfg: ModelConfig, batch: int, seq: int) -> jax.ShapeDtypeStruct:
    """Precomputed EnCodec frame embeddings (sum of codebook embeddings)."""
    return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cfg.cdtype)


def audio_frame_stub(cfg: ModelConfig, batch: int, seq: int, key=None) -> jax.Array:
    key = jax.random.key(1) if key is None else key
    return (jax.random.normal(key, (batch, seq, cfg.d_model)) * 0.02
            ).astype(cfg.cdtype)
