"""Mamba (selective SSM) mixer for the hybrid architectures (Jamba).

Training/prefill uses a *chunked* associative scan: the sequence is split
into ``cfg.ssm.chunk``-length chunks; within a chunk the diagonal linear
recurrence is solved with ``jax.lax.associative_scan`` (log-depth), and a
plain ``lax.scan`` carries the (B, d_inner, d_state) state across chunks.
Hidden states for the whole sequence are never materialized — transient
memory is O(B * chunk * d_inner * d_state) per chunk, which is what makes
jamba-1.5-large's d_inner=16384 trainable at seq 4096.

Decode keeps a recurrent state (h, conv window) and advances one token in
O(1) — the reason this family runs the ``long_500k`` cell.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


class MambaState(NamedTuple):
    h: jax.Array     # (B, d_inner, d_state) f32
    conv: jax.Array  # (B, d_conv-1, d_inner) last inputs for causal conv


def dt_rank(cfg: ModelConfig) -> int:
    return math.ceil(cfg.d_model / 16)


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def init_mamba(cfg: ModelConfig, key) -> dict:
    s = cfg.ssm
    d, di, dr, n = cfg.d_model, d_inner(cfg), dt_rank(cfg), s.d_state
    dt = cfg.cdtype
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A; dt bias s.t. softplus(bias) in [1e-3, 0.1]
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_init = jnp.exp(
        jax.random.uniform(ks[0], (di,)) * (math.log(0.1) - math.log(1e-3))
        + math.log(1e-3)
    )
    inv_softplus = dt_init + jnp.log1p(-jnp.exp(-dt_init))
    return {
        "in_proj": (jax.random.normal(ks[1], (d, 2 * di)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (s.d_conv, di)) * s.d_conv ** -0.5).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": (jax.random.normal(ks[3], (di, dr + 2 * n)) * di ** -0.5).astype(dt),
        "dt_proj": (jax.random.normal(ks[4], (dr, di)) * dr ** -0.5).astype(dt),
        "dt_bias": inv_softplus.astype(jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (di, d)) * di ** -0.5).astype(dt),
    }


def init_mamba_state(cfg: ModelConfig, batch: int) -> MambaState:
    s = cfg.ssm
    return MambaState(
        h=jnp.zeros((batch, d_inner(cfg), s.d_state), jnp.float32),
        conv=jnp.zeros((batch, s.d_conv - 1, d_inner(cfg)), cfg.cdtype),
    )


def _causal_conv(cfg: ModelConfig, p: dict, u: jax.Array,
                 prev: Optional[jax.Array]) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv along time. u: (B, S, di).  ``prev`` is the
    (B, d_conv-1, di) tail from the previous step (decode) or zeros."""
    kkernel = cfg.ssm.d_conv
    if prev is None:
        prev = jnp.zeros((u.shape[0], kkernel - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([prev, u], axis=1)  # (B, S+k-1, di)
    out = sum(
        ext[:, i: i + u.shape[1], :] * p["conv_w"][i][None, None, :]
        for i in range(kkernel)
    ) + p["conv_b"]
    new_prev = ext[:, -(kkernel - 1):, :]
    return jax.nn.silu(out), new_prev


def _ssm_inputs(cfg: ModelConfig, p: dict, u: jax.Array):
    """u: (B, L, di) -> dt (B,L,di) f32, B_ssm/C_ssm (B,L,n) f32."""
    n = cfg.ssm.d_state
    dr = p["dt_proj"].shape[0]
    xdb = u @ p["x_proj"]  # (B, L, dr + 2n)
    dt_in, b_in, c_in = jnp.split(xdb, [dr, dr + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"]).astype(jnp.float32)
    return dt, b_in.astype(jnp.float32), c_in.astype(jnp.float32)


def _chunk_scan(a: jax.Array, b: jax.Array, h0: jax.Array):
    """Solve h_t = a_t * h_{t-1} + b_t within a chunk.

    a, b: (B, L, di, n); h0: (B, di, n).  Returns (h_all (B,L,di,n), h_last).
    """
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_all = a_cum * h0[:, None] + b_cum
    return h_all, h_all[:, -1]


def mamba_fwd(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    state: Optional[MambaState] = None,
) -> tuple[jax.Array, Optional[MambaState]]:
    """x: (B, S, d).  With ``state``, continues from it (prefill/decode)."""
    s_cfg = cfg.ssm
    b_sz, s_len, _ = x.shape
    di, n = d_inner(cfg), s_cfg.d_state

    ud = x @ p["in_proj"]               # (B, S, 2di)
    u, z = jnp.split(ud, 2, axis=-1)
    conv_prev = state.conv if state is not None else None
    u, new_conv = _causal_conv(cfg, p, u, conv_prev)

    a_mat = -jnp.exp(p["A_log"])        # (di, n) f32
    h0 = state.h if state is not None else jnp.zeros((b_sz, di, n), jnp.float32)

    chunk = min(s_cfg.chunk, s_len)
    if s_len % chunk:
        chunk = s_len  # fall back to single chunk for odd lengths

    # Per-token projections are computed for the WHOLE sequence before the
    # chunk scan.  Computing them per chunk puts x_proj/dt_proj weight-grad
    # reductions inside the scan body (trip count = microbatches x periods
    # x S/chunk = 9216 for jamba train_4k — measured as the dominant wire
    # term); hoisted, they reduce once per microbatch.
    dt, b_in, c_in = _ssm_inputs(cfg, p, u)                # (B,S,di) (B,S,n)

    def process_chunk(h_prev, xs_c):
        u_c, dt_c, b_c, c_c = xs_c
        da = jnp.exp(dt_c[..., None] * a_mat[None, None])   # (B,L,di,n)
        db = (dt_c * u_c.astype(jnp.float32))[..., None] * b_c[:, :, None, :]
        h_all, h_last = _chunk_scan(da, db, h_prev)
        y = jnp.einsum("blin,bln->bli", h_all, c_c)
        y = y + p["D"] * u_c.astype(jnp.float32)
        return h_last, y.astype(x.dtype)

    if s_len == chunk:
        h_last, y = process_chunk(h0, (u, dt, b_in, c_in))
    else:
        n_chunks = s_len // chunk

        def chunked(a):
            return jnp.moveaxis(
                a.reshape(b_sz, n_chunks, chunk, *a.shape[2:]), 1, 0)

        xs = (chunked(u), chunked(dt), chunked(b_in), chunked(c_in))
        h_last, ys = jax.lax.scan(jax.checkpoint(process_chunk), h0, xs)
        y = ys.transpose(1, 0, 2, 3).reshape(b_sz, s_len, di)

    out = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype) @ p["out_proj"]
    new_state = MambaState(h=h_last, conv=new_conv) if state is not None else None
    return out, new_state
