"""GQA attention with rotary embeddings, KV cache, and query-chunking.

Memory discipline: scores are never materialized for more than one query
chunk at a time (``cfg.attn_chunk``) — a pure-JAX flash-attention analogue
(the online-softmax Pallas kernel is a hillclimb candidate, see §Perf).
GQA is computed in grouped form (no KV head repetition is materialized).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import _dense, apply_rope

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array    # (B, Hkv, S_max, hd)
    v: jax.Array    # (B, Hkv, S_max, hd)
    idx: jax.Array  # () int32 — number of valid positions; or (B,) int32
                    # for slot-batched serving where every row advances
                    # independently (continuous batching)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, n_layers: int) -> list:
    """Per-layer KV caches (only for layers whose mixer is 'attn';
    non-attention layers get their own state objects)."""
    shape = (batch, cfg.n_kv_heads, max_seq, cfg.hd)
    return [
        KVCache(
            k=jnp.zeros(shape, cfg.cdtype),
            v=jnp.zeros(shape, cfg.cdtype),
            idx=jnp.zeros((), jnp.int32),
        )
        for _ in range(n_layers)
    ]


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int) -> KVCache:
    shape = (batch, cfg.n_kv_heads, max_seq, cfg.hd)
    return KVCache(
        k=jax.ShapeDtypeStruct(shape, cfg.cdtype),
        v=jax.ShapeDtypeStruct(shape, cfg.cdtype),
        idx=jax.ShapeDtypeStruct((), jnp.int32),
    )


def init_attn(cfg: ModelConfig, key) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    dt = cfg.cdtype
    p = {
        "wq": _dense(k1, d, cfg.n_heads * hd, dt),
        "wk": _dense(k2, d, cfg.n_kv_heads * hd, dt),
        "wv": _dense(k3, d, cfg.n_kv_heads * hd, dt),
        "wo": _dense(k4, cfg.n_heads * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
    return p


def _sdpa_grouped(q, k, v, q_pos, kv_pos, kv_len) -> jax.Array:
    """Grouped scaled-dot-product attention on one query chunk.

    q: (B, Hkv, G, Sq, hd);  k, v: (B, Hkv, Skv, hd)
    q_pos: (B, Sq) global query positions; kv_pos: (Skv,);
    kv_len: () number of valid kv entries (cache may be partially filled),
    or (B,) when each row's cache fill differs (slot-batched decode).
    """
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum(
        "bhgqd,bhsd->bhgqs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    kv_len = jnp.asarray(kv_len)
    if kv_len.ndim == 1:
        kv_len = kv_len[:, None, None]  # (B, 1, 1) against (B, Sq, Skv)
    allowed = (kv_pos[None, :] <= q_pos[..., None]) & (kv_pos < kv_len)
    scores = jnp.where(allowed[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqs,bhsd->bhgqd", probs, v.astype(jnp.float32))
    return out.astype(v.dtype)


def attn_fwd(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[KVCache] = None,
    proj: Optional[callable] = None,
) -> tuple[jax.Array, Optional[KVCache]]:
    """x: (B, S, d); positions: (B, S) global positions of these tokens.

    Without cache: plain causal self-attention (training).
    With cache: appends this chunk's K/V at ``cache.idx`` (prefill writes a
    block, decode writes one token) and attends over everything valid.
    ``proj(name, x, w)`` overrides each projection matmul (balanced hybrid
    dispatch of the trunk); default is the in-graph ``x @ w``.  A ``proj``
    carrying a ``qkv`` attribute fuses the three input projections into
    one call (one jit-bridge round trip per layer instead of three).
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = hq // hkv

    mm = proj or (lambda name, x, w: x @ w)
    fused_qkv = getattr(mm, "qkv", None)
    if fused_qkv is not None:
        q, k, v = fused_qkv(x, p["wq"], p["wk"], p["wv"])
    else:
        q = mm("wq", x, p["wq"])
        k = mm("wk", x, p["wk"])
        v = mm("wv", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)

    q = apply_rope(q, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    k = apply_rope(k, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)

    if cache is not None:
        if cache.idx.ndim == 1:
            # Slot-batched cache: every row appends at its own offset
            # (continuous batching — rows are independent requests).
            row_upd = jax.vmap(
                lambda buf, new, at: jax.lax.dynamic_update_slice(
                    buf, new, (0, at, 0)))
            k_all = row_upd(cache.k, k.astype(cache.k.dtype), cache.idx)
            v_all = row_upd(cache.v, v.astype(cache.v.dtype), cache.idx)
        else:
            k_all = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, 0, cache.idx, 0))
            v_all = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, 0, cache.idx, 0))
        new_cache = KVCache(k=k_all, v=v_all, idx=cache.idx + s)
        kv_pos = jnp.arange(k_all.shape[2])
        kv_len = cache.idx + s
    else:
        k_all, v_all = k, v
        new_cache = None
        kv_pos = jnp.arange(s)
        kv_len = jnp.asarray(s)

    qg = q.reshape(b, hkv, g, s, hd)
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None, :], (b, s))

    chunk = cfg.attn_chunk
    if s <= chunk or s % chunk:
        out = _sdpa_grouped(qg, k_all, v_all, positions, kv_pos, kv_len)
    else:
        n_chunks = s // chunk
        qc = qg.reshape(b, hkv, g, n_chunks, chunk, hd).transpose(3, 0, 1, 2, 4, 5)
        pc = positions.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

        def body(carry, inp):
            qi, pi = inp
            return carry, _sdpa_grouped(qi, k_all, v_all, pi, kv_pos, kv_len)

        _, outs = jax.lax.scan(body, None, (qc, pc))
        out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, s, hd)

    out = out.reshape(b, hq, s, hd).transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return mm("wo", out, p["wo"]).astype(x.dtype), new_cache
