"""xLSTM mixers: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM uses the stabilized exponential-gating recurrence of arXiv:2405.04517:

    m_t = max(f~_t + m_{t-1}, i~_t)
    C_t = e^{f~+m_{t-1}-m_t} C_{t-1} + e^{i~-m_t} v_t k_t^T
    n_t = e^{f~+m_{t-1}-m_t} n_{t-1} + e^{i~-m_t} k_t
    h_t = C_t q_t / max(|n_t . q_t|, e^{-m_t})

Training/prefill runs the *chunkwise-parallel* form (intra-chunk attention-
like matrix + inter-chunk state carry), scanned over chunks with remat —
O(B * L^2) transients instead of a length-T serial scan, which is what makes
seq-4096 training of xlstm-1.3b feasible.  ``mlstm_recurrent_reference``
is the exact step recurrence used by unit tests and by decode.

sLSTM has true recurrent weights (block-diagonal per head) and cannot be
parallelized over time; it scans with chunk-level checkpointing.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

NEG = -1e30


# =============================================================== mLSTM ====
class MLSTMState(NamedTuple):
    c: jax.Array   # (B, H, dv, dk) stabilized matrix memory
    n: jax.Array   # (B, H, dk)
    m: jax.Array   # (B, H)
    conv: jax.Array  # (B, d_conv-1, di) causal-conv tail


def mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    x = cfg.xlstm
    di = int(x.proj_factor * cfg.d_model)
    h = cfg.n_heads
    dv = di // h
    dk = max(8, int(x.qk_dim_factor * dv))
    return di, h, dv, dk


def init_mlstm(cfg: ModelConfig, key) -> dict:
    di, h, dv, dk = mlstm_dims(cfg)
    d = cfg.d_model
    dt = cfg.cdtype
    ks = jax.random.split(key, 9)
    x = cfg.xlstm
    return {
        "w_up": (jax.random.normal(ks[0], (d, di)) * d ** -0.5).astype(dt),
        "w_z": (jax.random.normal(ks[1], (d, di)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (x.conv_kernel, di)) * x.conv_kernel ** -0.5).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        # per-head block-diagonal projections (official xLSTM layout)
        "wq": (jax.random.normal(ks[3], (h, dv, dk)) * dv ** -0.5).astype(dt),
        "wk": (jax.random.normal(ks[4], (h, dv, dk)) * dv ** -0.5).astype(dt),
        "wv": (jax.random.normal(ks[5], (h, dv, dv)) * dv ** -0.5).astype(dt),
        "w_if": (jax.random.normal(ks[6], (di, 2 * h)) * di ** -0.5).astype(jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]).astype(jnp.float32),
        "w_down": (jax.random.normal(ks[7], (di, d)) * di ** -0.5).astype(dt),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    di, h, dv, dk = mlstm_dims(cfg)
    return MLSTMState(
        c=jnp.zeros((batch, h, dv, dk), jnp.float32),
        n=jnp.zeros((batch, h, dk), jnp.float32),
        m=jnp.full((batch, h), NEG, jnp.float32),
        conv=jnp.zeros((batch, cfg.xlstm.conv_kernel - 1, di), cfg.cdtype),
    )


def _headwise_rms(h: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Non-parametric per-head RMS norm (stand-in for HeadwiseLayerNorm)."""
    return h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + eps)


def _mlstm_chunk(q, k, v, ig, fg, state):
    """One chunk of the chunkwise-parallel mLSTM.

    q,k: (B,H,L,dk) (q pre-scaled); v: (B,H,L,dv); ig,fg: (B,H,L) f32.
    state: (c (B,H,dv,dk), n (B,H,dk), m (B,H)).
    Returns h (B,H,L,dv) and the end-of-chunk state.
    """
    c0, n0, m0 = state
    b = jnp.cumsum(fg, axis=-1)                       # (B,H,L) log forget cum
    # D_ts = ig_s + b_t - b_s  (s <= t)
    dmat = ig[:, :, None, :] + b[:, :, :, None] - b[:, :, None, :]
    l = q.shape[2]
    causal = jnp.tril(jnp.ones((l, l), bool))
    dmat = jnp.where(causal, dmat, NEG)
    m_intra = dmat.max(-1)                            # (B,H,L)
    m_t = jnp.maximum(m0[:, :, None] + b, m_intra)    # (B,H,L)

    w = jnp.exp(dmat - m_t[..., None])                # (B,H,L,L)
    s = jnp.einsum("bhld,bhsd->bhls", q, k)           # (B,H,L,L) f32
    intra = jnp.einsum("bhls,bhsv->bhlv", w * s, v)
    inter_coef = jnp.exp(m0[:, :, None] + b - m_t)    # (B,H,L)
    inter = jnp.einsum("bhld,bhvd->bhlv", q, c0) * inter_coef[..., None]
    num = inter + intra

    den_intra = jnp.einsum("bhls,bhls->bhl", w, s)
    den_inter = jnp.einsum("bhld,bhd->bhl", q, n0) * inter_coef
    den = den_inter + den_intra
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

    # end-of-chunk state
    bl = b[:, :, -1]                                  # (B,H)
    m_end = jnp.maximum(m0 + bl, (ig + bl[..., None] - b).max(-1))
    wk_end = jnp.exp(ig + bl[..., None] - b - m_end[..., None])  # (B,H,L)
    c_new = jnp.exp(m0 + bl - m_end)[..., None, None] * c0 + jnp.einsum(
        "bhl,bhlv,bhld->bhvd", wk_end, v, k
    )
    n_new = jnp.exp(m0 + bl - m_end)[..., None] * n0 + jnp.einsum(
        "bhl,bhld->bhd", wk_end, k
    )
    return h, (c_new, n_new, m_end)


def mlstm_step(q, k, v, ig, fg, state):
    """Exact stabilized recurrence for ONE step (decode + reference).

    q,k: (B,H,dk) (q pre-scaled); v: (B,H,dv); ig,fg: (B,H).
    """
    c0, n0, m0 = state
    m_t = jnp.maximum(fg + m0, ig)
    f_p = jnp.exp(fg + m0 - m_t)
    i_p = jnp.exp(ig - m_t)
    c_t = f_p[..., None, None] * c0 + i_p[..., None, None] * jnp.einsum(
        "bhv,bhd->bhvd", v, k
    )
    n_t = f_p[..., None] * n0 + i_p[..., None] * k
    num = jnp.einsum("bhvd,bhd->bhv", c_t, q)
    den = jnp.einsum("bhd,bhd->bh", n_t, q)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
    return h, (c_t, n_t, m_t)


def mlstm_recurrent_reference(q, k, v, ig, fg, state):
    """Step-by-step over time (oracle for the chunkwise form).

    q,k: (B,H,L,dk); returns (h (B,H,L,dv), final state).
    """
    def body(st, inp):
        qt, kt, vt, it_, ft = inp
        h, st2 = mlstm_step(qt, kt, vt, it_, ft, st)
        return st2, h

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (q, k, v, ig, fg))
    st, hs = jax.lax.scan(body, state, xs)
    return jnp.moveaxis(hs, 0, 2), st


def _mlstm_causal_conv(cfg, p, u, prev):
    kk = cfg.xlstm.conv_kernel
    if prev is None:
        prev = jnp.zeros((u.shape[0], kk - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([prev, u], axis=1)
    out = sum(
        ext[:, i: i + u.shape[1], :] * p["conv_w"][i][None, None, :]
        for i in range(kk)
    ) + p["conv_b"]
    return jax.nn.silu(out), ext[:, -(kk - 1):, :]


def mlstm_fwd(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    state: Optional[MLSTMState] = None,
) -> tuple[jax.Array, Optional[MLSTMState]]:
    """x: (B, S, d) -> (out, new_state)."""
    di, nh, dv, dk = mlstm_dims(cfg)
    b_sz, s_len, _ = x.shape

    u = x @ p["w_up"]
    z = x @ p["w_z"]
    uc, new_conv = _mlstm_causal_conv(cfg, p, u, state.conv if state else None)

    uc_h = uc.reshape(b_sz, s_len, nh, dv)
    u_h = u.reshape(b_sz, s_len, nh, dv)
    q = jnp.einsum("bshd,hdk->bhsk", uc_h, p["wq"]).astype(jnp.float32) * dk ** -0.5
    k = jnp.einsum("bshd,hdk->bhsk", uc_h, p["wk"]).astype(jnp.float32)
    v = jnp.einsum("bshd,hdk->bhsk", u_h, p["wv"]).astype(jnp.float32)
    gates = uc.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    ig = gates[..., :nh].transpose(0, 2, 1)               # (B,H,S)
    fg = jax.nn.log_sigmoid(gates[..., nh:]).transpose(0, 2, 1)

    if state is not None:
        st = (state.c, state.n, state.m)
    else:
        st = (
            jnp.zeros((b_sz, nh, dv, dk), jnp.float32),
            jnp.zeros((b_sz, nh, dk), jnp.float32),
            jnp.full((b_sz, nh), NEG, jnp.float32),
        )

    chunk = min(cfg.xlstm.chunk, s_len)
    if s_len % chunk:
        chunk = s_len
    if s_len == chunk:
        h, st_out = _mlstm_chunk(q, k, v, ig, fg, st)
    else:
        nc = s_len // chunk

        def reshape_chunks(a):  # (B,H,S,...) -> (nc, B,H,L,...)
            return jnp.moveaxis(
                a.reshape(a.shape[0], a.shape[1], nc, chunk, *a.shape[3:]), 2, 0
            )

        xs = tuple(reshape_chunks(a) for a in (q, k, v, ig, fg))

        def body(carry, inp):
            h_c, carry2 = _mlstm_chunk(*inp, carry)
            return carry2, h_c

        st_out, hs = jax.lax.scan(jax.checkpoint(body), st, xs)
        h = jnp.moveaxis(hs, 0, 2).reshape(b_sz, nh, s_len, dv)

    h = _headwise_rms(h)
    h = h.transpose(0, 2, 1, 3).reshape(b_sz, s_len, di).astype(x.dtype)
    out = (h * jax.nn.silu(z)) @ p["w_down"]
    new_state = (
        MLSTMState(c=st_out[0], n=st_out[1], m=st_out[2], conv=new_conv)
        if state is not None
        else None
    )
    return out, new_state


# =============================================================== sLSTM ====
class SLSTMState(NamedTuple):
    c: jax.Array  # (B, d)
    n: jax.Array  # (B, d)
    m: jax.Array  # (B, d)
    h: jax.Array  # (B, d)


def init_slstm(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    dt = cfg.cdtype
    ks = jax.random.split(key, 4)
    return {
        # fused gate projections: z, i, f, o
        "w_x": (jax.random.normal(ks[0], (d, 4 * d)) * d ** -0.5).astype(dt),
        "r_h": (jax.random.normal(ks[1], (nh, dh, 4 * dh)) * dh ** -0.5).astype(jnp.float32),
        "bias": jnp.concatenate(
            [jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)), jnp.zeros((d,))]
        ).astype(jnp.float32),
        "w_out": (jax.random.normal(ks[2], (d, d)) * d ** -0.5).astype(dt),
    }


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z + 1e-6, m=jnp.full((batch, d), NEG), h=z)


def slstm_step(cfg: ModelConfig, p: dict, xt: jax.Array, st: SLSTMState):
    """One recurrent step. xt: (B, d) pre-projected gate input (B, 4d)."""
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    b = xt.shape[0]
    hh = st.h.reshape(b, nh, dh)
    rec = jnp.einsum("bhd,hde->bhe", hh, p["r_h"]).reshape(b, 4 * d)
    g = xt.astype(jnp.float32) + rec + p["bias"]
    zg, ig, fg, og = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(zg)
    fg = jax.nn.log_sigmoid(fg)
    m_t = jnp.maximum(fg + st.m, ig)
    i_p = jnp.exp(ig - m_t)
    f_p = jnp.exp(fg + st.m - m_t)
    c_t = f_p * st.c + i_p * z
    n_t = jnp.maximum(f_p * st.n + i_p, 1e-6)
    h_t = jax.nn.sigmoid(og) * (c_t / n_t)
    return SLSTMState(c=c_t, n=n_t, m=m_t, h=h_t)


def slstm_fwd(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    state: Optional[SLSTMState] = None,
) -> tuple[jax.Array, Optional[SLSTMState]]:
    """x: (B, S, d) -> (out, new_state); chunk-checkpointed time scan."""
    b_sz, s_len, d = x.shape
    st0 = state if state is not None else init_slstm_state(cfg, b_sz)
    xg = x @ p["w_x"]  # (B, S, 4d)

    chunk = min(cfg.xlstm.chunk, s_len)
    if s_len % chunk:
        chunk = s_len

    def step(st, xt):
        st2 = slstm_step(cfg, p, xt, st)
        return st2, st2.h

    def chunk_body(st, xc):
        return jax.lax.scan(step, st, xc)

    if s_len == chunk:
        st_out, hs = chunk_body(st0, jnp.moveaxis(xg, 1, 0))
        h = jnp.moveaxis(hs, 0, 1)
    else:
        nc = s_len // chunk
        xc = jnp.moveaxis(xg.reshape(b_sz, nc, chunk, 4 * d), 1, 0)  # (nc,B,L,4d)
        xc = jnp.moveaxis(xc, 2, 1)  # (nc, L, B, 4d)
        st_out, hs = jax.lax.scan(jax.checkpoint(chunk_body), st0, xc)
        h = hs.reshape(s_len, b_sz, d).transpose(1, 0, 2)

    out = (h.astype(x.dtype)) @ p["w_out"]
    return out, (st_out if state is not None else None)
