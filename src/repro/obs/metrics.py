"""Metrics registry: counters, gauges, explicit-bucket histograms.

Deliberately tiny and dependency-free — the Prometheus *text exposition
format* without the client library.  All layers publish into one
:class:`MetricsRegistry` at report time (``LatencyReport.publish``, the
serve driver, the benchmarks), so the hot paths never see a metric object.

TTFT/TPOT get explicit buckets matched to the repo's SLOs (2.0 s TTFT,
0.25 s TPOT in the fleet driver): enough resolution below the SLO to see a
burn coming, a few buckets above it to size the violation.
"""

from __future__ import annotations

import json
import re
from bisect import bisect_left
from typing import Dict, Iterable, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "TTFT_BUCKETS",
    "TPOT_BUCKETS",
    "lint_exposition",
]

# Upper bounds in seconds; +Inf is implicit.
TTFT_BUCKETS = (0.1, 0.25, 0.5, 1.0, 1.5, 2.0, 3.0, 5.0, 10.0)
TPOT_BUCKETS = (0.025, 0.05, 0.1, 0.15, 0.2, 0.25, 0.4, 0.8, 1.6)


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class Counter:
    """Monotone counter; one series per label set."""

    kind = "counter"

    def __init__(self, name: str, help: str):
        self.name = name
        self.help = help
        self._series: Dict[Tuple[Tuple[str, str], ...], float] = {}

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} decreased by {amount}")
        key = tuple(sorted(labels.items()))
        self._series[key] = self._series.get(key, 0.0) + float(amount)

    def value(self, **labels: str) -> float:
        return self._series.get(tuple(sorted(labels.items())), 0.0)

    def samples(self):
        for key, v in self._series.items():
            yield self.name, dict(key), v

    def to_json(self):
        return [{"labels": dict(k), "value": v}
                for k, v in self._series.items()]


class Gauge(Counter):
    """A value that can go either way (queue depth, ratio weight)."""

    kind = "gauge"

    def set(self, value: float, **labels: str) -> None:
        self._series[tuple(sorted(labels.items()))] = float(value)

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        key = tuple(sorted(labels.items()))
        self._series[key] = self._series.get(key, 0.0) + float(amount)


class Histogram:
    """Explicit-bucket cumulative histogram (Prometheus semantics)."""

    kind = "histogram"

    def __init__(self, name: str, help: str, buckets: Iterable[float]):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError(f"histogram {self.name} needs >= 1 bucket")
        self._series: Dict[Tuple[Tuple[str, str], ...], list] = {}

    def _cell(self, labels: Dict[str, str]):
        key = tuple(sorted(labels.items()))
        cell = self._series.get(key)
        if cell is None:
            # [per-bucket counts..., +Inf count], total count, sum
            cell = [[0] * (len(self.buckets) + 1), 0, 0.0]
            self._series[key] = cell
        return cell

    def observe(self, value: float, **labels: str) -> None:
        cell = self._cell(labels)
        cell[0][bisect_left(self.buckets, float(value))] += 1
        cell[1] += 1
        cell[2] += float(value)

    def observe_many(self, values: Iterable[float], **labels: str) -> None:
        for v in values:
            self.observe(v, **labels)

    def count(self, **labels: str) -> int:
        key = tuple(sorted(labels.items()))
        return self._series[key][1] if key in self._series else 0

    def samples(self):
        for key, (counts, n, total) in self._series.items():
            labels = dict(key)
            acc = 0
            for b, c in zip(self.buckets, counts):
                acc += c
                yield (f"{self.name}_bucket",
                       {**labels, "le": _fmt_value(b)}, acc)
            yield f"{self.name}_bucket", {**labels, "le": "+Inf"}, n
            yield f"{self.name}_sum", labels, total
            yield f"{self.name}_count", labels, n

    def to_json(self):
        out = []
        for key, (counts, n, total) in self._series.items():
            out.append({
                "labels": dict(key),
                "buckets": {_fmt_value(b): c
                            for b, c in zip(self.buckets, counts)},
                "inf": counts[-1], "count": n, "sum": total,
            })
        return out


class MetricsRegistry:
    """Named metrics with Prometheus text exposition and a JSON dump."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _register(self, metric):
        prev = self._metrics.get(metric.name)
        if prev is not None:
            if type(prev) is not type(metric):
                raise ValueError(
                    f"metric {metric.name!r} re-registered as a different "
                    f"kind ({prev.kind} vs {metric.kind})")
            return prev
        self._metrics[metric.name] = metric
        return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter(name, help))

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge(name, help))

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None) -> Histogram:
        return self._register(
            Histogram(name, help, buckets if buckets is not None
                      else TTFT_BUCKETS))

    def get(self, name: str):
        return self._metrics.get(name)

    def prometheus_text(self) -> str:
        """Prometheus text exposition format, version 0.0.4."""
        lines = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for sample_name, labels, value in m.samples():
                lines.append(
                    f"{sample_name}{_fmt_labels(labels)} {_fmt_value(value)}")
        return "\n".join(lines) + ("\n" if lines else "")

    def to_json(self) -> dict:
        return {
            name: {"kind": m.kind, "help": m.help, "series": m.to_json()}
            for name, m in sorted(self._metrics.items())
        }

    def write_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")


# --------------------------------------------------------- exposition lint --
_NAME_OK = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SAMPLE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r" (?P<value>[^ ]+)$")


def lint_exposition(text: str) -> list:
    """Check Prometheus text-format exposition; returns problem strings.

    This is the CI "metrics exposition lint": every sample parses, every
    TYPE is known, histograms carry ``_bucket``/``_sum``/``_count`` with an
    ``+Inf`` bucket and non-decreasing cumulative counts.
    """
    problems: list = []
    types: Dict[str, str] = {}
    buckets: Dict[str, list] = {}
    seen_suffix: Dict[str, set] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                problems.append(f"line {lineno}: malformed TYPE line")
            elif not _NAME_OK.match(parts[2]):
                problems.append(f"line {lineno}: bad metric name {parts[2]!r}")
            else:
                types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            problems.append(f"line {lineno}: unknown comment directive")
            continue
        m = _SAMPLE.match(line)
        if not m:
            problems.append(f"line {lineno}: unparseable sample: {line!r}")
            continue
        try:
            float(m.group("value"))
        except ValueError:
            problems.append(f"line {lineno}: non-numeric value "
                            f"{m.group('value')!r}")
        name = m.group("name")
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[:-len(suffix)] in types:
                base = name[:-len(suffix)]
                seen_suffix.setdefault(base, set()).add(suffix)
                break
        if base not in types:
            problems.append(f"line {lineno}: sample {name!r} has no TYPE")
            continue
        if types.get(base) == "histogram" and name.endswith("_bucket"):
            labels = m.group("labels") or ""
            le = None
            rest = []
            for pair in labels.split(","):
                if pair.startswith('le="'):
                    le = pair[4:].rstrip('"')
                elif pair:
                    rest.append(pair)
            if le is None:
                problems.append(f"line {lineno}: histogram bucket without le")
            else:
                bound = float("inf") if le == "+Inf" else float(le)
                buckets.setdefault((base, ",".join(sorted(rest))), []).append(
                    (lineno, bound, float(m.group("value"))))
    for base, kind in types.items():
        if kind != "histogram":
            continue
        missing = {"_bucket", "_sum", "_count"} - seen_suffix.get(base, set())
        if missing:
            problems.append(f"histogram {base}: missing series "
                            f"{sorted(missing)}")
    for (base, _rest), series in buckets.items():
        if not any(b == float("inf") for _, b, _ in series):
            problems.append(f"histogram {base}: no +Inf bucket")
        prev = None
        for lineno, bound, value in series:
            if prev is not None and bound > prev[0] and value < prev[1]:
                problems.append(
                    f"line {lineno}: histogram {base} cumulative count "
                    f"decreases at le={bound}")
            prev = (bound, value)
    return problems
