"""Observability substrate: virtual-clock tracing, metrics, flight recorder.

Three independent parts, all publishing through the :mod:`repro.core.events`
shim so instrumented call sites stay a single global load when disabled:

* :class:`SpanTracer` (:mod:`repro.obs.trace`) — spans and counter tracks on
  the shared virtual clock, exported as Chrome/Perfetto ``trace_event`` JSON.
* :class:`MetricsRegistry` (:mod:`repro.obs.metrics`) — counters, gauges and
  explicit-bucket histograms with Prometheus text exposition and a one-shot
  JSON dump.
* :class:`FlightRecorder` (:mod:`repro.obs.recorder`) — a bounded ring of
  recent balancer decisions dumped to disk when an SLO burn or an invariant
  contract (IV00x) trips.
"""

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               TPOT_BUCKETS, TTFT_BUCKETS, lint_exposition)
from repro.obs.recorder import DecisionRecord, FlightRecorder
from repro.obs.trace import SpanTracer, validate_trace

__all__ = [
    "SpanTracer",
    "validate_trace",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "TTFT_BUCKETS",
    "TPOT_BUCKETS",
    "lint_exposition",
    "FlightRecorder",
    "DecisionRecord",
]
