# lint: virtual-clock-module
"""Anomaly flight recorder: a bounded ring of recent balancer decisions.

Instrumented call sites publish decisions through
``repro.core.events.record`` — ratio-table updates, offset refreshes,
capacity (park/DVFS) windows, admission verdicts, node fail/recover, and
per-request latency observations.  The recorder keeps the last ``capacity``
of them; when an SLO burn (``burn_window`` consecutive violating latency
records) or an invariant contract (IV00x, see
:mod:`repro.analysis.invariants`) trips, the ring is dumped to disk so
"goodput dipped at t=41s" becomes a replayable decision log instead of a
shrug.

The recorder never raises out of ``record``/``trip`` — observability must
not take down the serve loop it is observing.
"""

from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

__all__ = ["DecisionRecord", "FlightRecorder"]


@dataclass(frozen=True)
class DecisionRecord:
    """One recorded decision on the virtual clock."""

    seq: int
    t: float
    kind: str      # "ratio" | "offsets" | "capacity" | "admission" |
    #                "node_event" | "latency" | ...
    key: str       # ratio-table key, offset spec name, node/core name, ...
    payload: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"seq": self.seq, "t": self.t, "kind": self.kind,
                "key": self.key, **({"payload": self.payload}
                                    if self.payload else {})}


class FlightRecorder:
    """Bounded decision ring with SLO-burn self-trip.

    ``slo_ttft``/``slo_tpot`` arm the burn detector: a ``latency`` record
    whose payload violates either SLO increments a streak, any compliant
    one resets it, and ``burn_window`` consecutive violations trip the
    recorder.  ``path`` is where :meth:`trip` dumps the ring (one JSON
    object); without a path the dump is kept on ``last_dump``.
    """

    def __init__(self, capacity: int = 256, *, path: Optional[str] = None,
                 slo_ttft: Optional[float] = None,
                 slo_tpot: Optional[float] = None,
                 burn_window: int = 8):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = int(capacity)
        self.path = path
        self.slo_ttft = slo_ttft
        self.slo_tpot = slo_tpot
        self.burn_window = int(burn_window)
        self._ring: deque = deque(maxlen=self.capacity)
        self._seq = 0
        self._streak = 0
        self.trips: list[dict] = []
        self.last_dump: Optional[dict] = None

    def __len__(self) -> int:
        return len(self._ring)

    def record(self, kind: str, key: str, t: float, payload: dict) -> None:
        self._seq += 1
        self._ring.append(DecisionRecord(
            seq=self._seq, t=float(t), kind=str(kind), key=str(key),
            payload=dict(payload) if payload else {}))
        if kind == "latency" and (self.slo_ttft is not None
                                  or self.slo_tpot is not None):
            self._observe_slo(payload, float(t))

    def _observe_slo(self, payload: dict, t: float) -> None:
        ttft = payload.get("ttft")
        tpot = payload.get("tpot")
        bad = ((self.slo_ttft is not None and ttft is not None
                and ttft > self.slo_ttft)
               or (self.slo_tpot is not None and tpot is not None
                   and tpot > self.slo_tpot))
        if not bad:
            self._streak = 0
            return
        self._streak += 1
        if self._streak >= self.burn_window:
            self._streak = 0
            self.trip(f"slo_burn: {self.burn_window} consecutive "
                      f"SLO-violating requests", t=t)

    def records(self) -> list:
        return list(self._ring)

    def snapshot(self, reason: str, t: Optional[float] = None) -> dict:
        return {
            "schema": "repro.obs.flight_recorder/1",
            "reason": reason,
            "t": t,
            "n_records": len(self._ring),
            "n_dropped": max(0, self._seq - len(self._ring)),
            "records": [r.to_dict() for r in self._ring],
        }

    def trip(self, reason: str, t: Optional[float] = None) -> dict:
        """Dump the ring (to ``path`` when set); never raises."""
        dump = self.snapshot(reason, t)
        self.trips.append({"reason": reason, "t": t, "seq": self._seq})
        self.last_dump = dump
        if self.path:
            try:
                with open(self.path, "w") as f:
                    json.dump(dump, f, indent=2, sort_keys=True)
                    f.write("\n")
            except OSError:
                pass
        return dump
