# lint: virtual-clock-module
"""Chrome/Perfetto ``trace_event`` tracer on the shared virtual clock.

The tracer receives spans, counters and instants through the hooks in
:mod:`repro.core.events` (``emit_span``/``emit_counter``/``emit_instant``)
and groups them into Perfetto processes and threads:

* **process** = the current *scope* — a stack pushed by :meth:`push_scope` /
  :meth:`pop_scope` from :class:`~repro.fleet.cluster.Node` ("node:big") and
  :class:`~repro.serving.dispatch.InflightDispatcher` ("replica0"), joined
  with "/".  Single-machine runs land in the implicit process ``"main"``.
* **thread (track)** = one core, socket, dispatch region or counter series
  within the process ("core3", "socket1", "engine", "dispatch:membw").

All timestamps are *virtual* seconds converted to microseconds at export,
so a fixed-seed run produces a byte-identical trace: virtual execution is
single-threaded, ids are assigned in first-seen order, and the JSON is
dumped with sorted keys and canonical separators.

Export with :meth:`write` and open the file at https://ui.perfetto.dev (or
``chrome://tracing``).  :func:`validate_trace` checks the schema the way the
CI smoke job does.
"""

from __future__ import annotations

import json
from typing import Optional

__all__ = ["SpanTracer", "validate_trace"]

_ALLOWED_PH = {"X", "C", "i", "M"}


def _us(t: float) -> float:
    """Virtual seconds -> trace microseconds, rounded so float noise cannot
    break byte-determinism across same-seed runs."""
    return round(float(t) * 1e6, 3)


class SpanTracer:
    """Collects trace events; install via ``repro.core.events.install``.

    Also implements the race-tracer ``emit`` hook as a no-op so the access
    events the pools/dispatchers emit while a span tracer is installed are
    accepted and discarded rather than raising.
    """

    def __init__(self):
        self._scope: list[str] = []
        self._pids: dict[str, int] = {}       # proc name -> pid (first-seen)
        self._tids: dict[tuple, int] = {}     # (pid, track) -> tid
        self._events: list[dict] = []         # ph M metadata, emission order
        self._body: list[dict] = []           # ph X/C/i, emission order
        self.n_spans = 0
        self.n_counters = 0
        self.n_instants = 0

    # ------------------------------------------------------------- scoping --
    def push_scope(self, name: str) -> None:
        self._scope.append(str(name))

    def pop_scope(self) -> None:
        self._scope.pop()

    def _proc(self) -> str:
        return "/".join(self._scope) if self._scope else "main"

    def _ids(self, track: str) -> tuple[int, int]:
        proc = self._proc()
        pid = self._pids.get(proc)
        if pid is None:
            pid = len(self._pids) + 1
            self._pids[proc] = pid
            self._events.append({
                "ph": "M", "pid": pid, "tid": 0,
                "name": "process_name", "args": {"name": proc},
            })
        key = (pid, track)
        tid = self._tids.get(key)
        if tid is None:
            tid = sum(1 for k in self._tids if k[0] == pid) + 1
            self._tids[key] = tid
            self._events.append({
                "ph": "M", "pid": pid, "tid": tid,
                "name": "thread_name", "args": {"name": track},
            })
        return pid, tid

    # --------------------------------------------------------------- hooks --
    def span(self, track: str, name: str, start: float, dur: float,
             cat: str = "", args: Optional[dict] = None) -> None:
        pid, tid = self._ids(track)
        ev = {"ph": "X", "pid": pid, "tid": tid, "name": name,
              "ts": _us(start), "dur": _us(dur)}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self._body.append(ev)
        self.n_spans += 1

    def counter(self, track: str, t_now: float, values: dict) -> None:
        pid, tid = self._ids(track)
        self._body.append({
            "ph": "C", "pid": pid, "tid": tid, "name": track,
            "ts": _us(t_now),
            "args": {k: float(v) for k, v in values.items()},
        })
        self.n_counters += 1

    def instant(self, track: str, name: str, t_now: float,
                args: Optional[dict] = None) -> None:
        pid, tid = self._ids(track)
        ev = {"ph": "i", "pid": pid, "tid": tid, "name": name,
              "ts": _us(t_now), "s": "t"}
        if args:
            ev["args"] = args
        self._body.append(ev)
        self.n_instants += 1

    def emit(self, event) -> None:  # race-detector hook: accept and discard
        pass

    # -------------------------------------------------------------- export --
    def chrome_events(self) -> list[dict]:
        """Metadata first (Perfetto names tracks before events reference
        them), then spans/counters/instants in emission order."""
        return self._events + self._body

    def to_chrome(self) -> dict:
        return {"displayTimeUnit": "ms", "traceEvents": self.chrome_events()}

    def write(self, path: str) -> None:
        """Deterministic dump: canonical separators + sorted keys means a
        fixed-seed run writes a byte-identical file."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f,
                      separators=(",", ":"), sort_keys=True)
            f.write("\n")


def validate_trace(trace) -> list[str]:
    """Schema-check a Chrome ``trace_event`` dict (or a path to one); returns
    a list of problems, empty when the trace is Perfetto-loadable."""
    if isinstance(trace, str):
        with open(trace) as f:
            trace = json.load(f)
    problems: list[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["top level must be an object with a 'traceEvents' list"]
    events = trace["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    named: set = set()
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _ALLOWED_PH:
            problems.append(f"{where}: unknown ph {ph!r}")
            continue
        for field in ("pid", "tid"):
            if not isinstance(ev.get(field), int):
                problems.append(f"{where}: missing int {field!r}")
        if not isinstance(ev.get("name"), str) or not ev.get("name"):
            problems.append(f"{where}: missing name")
        if ph == "M":
            if ev.get("name") not in ("process_name", "thread_name"):
                problems.append(f"{where}: metadata name {ev.get('name')!r}")
            elif not isinstance(ev.get("args", {}).get("name"), str):
                problems.append(f"{where}: metadata without args.name")
            else:
                named.add((ev["name"], ev.get("pid"), ev.get("tid")))
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: complete event with bad dur {dur!r}")
        if ph == "C" and not isinstance(ev.get("args"), dict):
            problems.append(f"{where}: counter without args")
        if ("process_name", ev.get("pid"), 0) not in named:
            problems.append(f"{where}: pid {ev.get('pid')} has no "
                            f"process_name metadata before first use")
    return problems
