"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax init; smoke tests see
1 device).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_debug_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod'
    axis (DCN-connected).  Axis meanings: 'pod' + 'data' carry FSDP/DP,
    'model' carries TP/EP."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU integration tests (uses however many host
    devices XLA_FLAGS provided)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
