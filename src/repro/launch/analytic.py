"""Analytic per-device FLOP / HBM-byte accounting for the roofline.

Why analytic: XLA's ``cost_analysis`` counts a while-loop body ONCE, not
times its trip count (verified: a 10-iteration scanned matmul reports the
flops of one matmul).  Our trunk is scan-over-periods and
scan-over-microbatches, with further chunk scans inside Mamba/xLSTM, so
HLO-reported flops/bytes understate real work by the product of trip
counts, with mixed attribution that cannot be recovered from the aggregate
scalar.  Collectives ARE recovered from HLO (with while-trip attribution,
see roofline.py); flops/bytes use the standard accounting below and the raw
HLO numbers are reported alongside as a lower-bound cross-check.

All results are per device: global work / mesh size.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.configs import ShapeSpec


@dataclass
class AnalyticCost:
    flops: float       # per device
    hbm_bytes: float   # per device


def _layer_fwd_flops_per_token(cfg: ModelConfig, mixer: str, ffn: str,
                               kv_len: float) -> float:
    """Forward matmul+mixer FLOPs for one token of one layer.

    ``kv_len``: average attention span (S/2 causal for train/prefill; the
    full cache length for decode)."""
    d, hd = cfg.d_model, cfg.hd
    f = 0.0
    if mixer == "attn":
        f += 2 * d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)   # qkv proj
        f += 2 * cfg.n_heads * hd * d                          # out proj
        f += 2 * cfg.n_heads * hd * kv_len * 2                 # scores + AV
    elif mixer == "mamba":
        s = cfg.ssm
        di, n = s.expand * d, s.d_state
        dtr = math.ceil(d / 16)
        f += 2 * d * 2 * di                     # in_proj
        f += 2 * s.d_conv * di                  # depthwise conv
        f += 2 * di * (dtr + 2 * n)             # x_proj
        f += 2 * dtr * di                       # dt_proj
        f += 10 * di * n                        # discretize + scan + gather
        f += 2 * di * n                         # y = h . C
        f += 2 * di * d + 4 * di                # out proj + gate
    elif mixer == "mlstm":
        x = cfg.xlstm
        di = int(x.proj_factor * d)
        dv = di // cfg.n_heads
        dk = max(8, int(x.qk_dim_factor * dv))
        l = x.chunk
        f += 2 * d * di * 2                     # up + z
        f += 2 * x.conv_kernel * di             # conv
        f += 2 * di * (2 * dk + dv)             # blockdiag qkv
        f += 2 * cfg.n_heads * l * (dk + dv)    # intra-chunk scores + AV
        f += 4 * cfg.n_heads * dv * dk          # state update + inter read
        f += 2 * di * d + 4 * di                # down + gating
    elif mixer == "slstm":
        dh = d // cfg.n_heads
        f += 2 * d * 4 * d                      # w_x
        f += 2 * d * 4 * dh                     # recurrent blockdiag
        f += 30 * d                             # pointwise cell math
        f += 2 * d * d                          # out proj
    if ffn == "dense":
        f += (6 if cfg.mlp == "swiglu" else 4) * d * cfg.d_ff
    elif ffn == "moe":
        m = cfg.moe
        dff = m.d_ff or cfg.d_ff
        f += 2 * d * m.n_experts                # router
        f += m.top_k * 6 * d * dff              # routed experts (swiglu)
        if m.shared_expert:
            f += 6 * d * dff
    return f


def forward_flops(cfg: ModelConfig, tokens: float, kv_len: float,
                  logits_positions: float) -> float:
    """Global forward FLOPs for ``tokens`` processed tokens."""
    per_tok = sum(
        _layer_fwd_flops_per_token(cfg, mixer, ffn, kv_len)
        for mixer, ffn in cfg.layer_plan()
    )
    f = tokens * per_tok
    f += logits_positions * 2 * cfg.d_model * cfg.vocab_size  # lm head
    return f


def param_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * 2.0  # bf16


def active_param_bytes(cfg: ModelConfig) -> float:
    return cfg.active_param_count() * 2.0


def state_bytes_per_seq(cfg: ModelConfig, seq: int) -> float:
    """KV cache + recurrent state bytes for one sequence of length seq."""
    total = 0.0
    d = cfg.d_model
    for mixer, _ in cfg.layer_plan():
        if mixer == "attn":
            total += 2 * cfg.n_kv_heads * seq * cfg.hd * 2          # bf16 KV
        elif mixer == "mamba":
            s = cfg.ssm
            total += s.expand * d * s.d_state * 4 + (s.d_conv - 1) * s.expand * d * 2
        elif mixer == "mlstm":
            x = cfg.xlstm
            di = int(x.proj_factor * d)
            dv = di // cfg.n_heads
            dk = max(8, int(x.qk_dim_factor * dv))
            total += cfg.n_heads * (dv * dk + dk + 1) * 4
        elif mixer == "slstm":
            total += 4 * d * 4
    return total


def analyze_cell(cfg: ModelConfig, shape: ShapeSpec, n_devices: int,
                 *, remat: bool = True) -> AnalyticCost:
    d = cfg.d_model
    n_layers = cfg.n_layers
    p_dev = param_bytes(cfg) / n_devices

    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        fwd = forward_flops(cfg, tokens, kv_len=shape.seq / 2,
                            logits_positions=tokens)
        # fwd(1x) + bwd(2x) + remat re-forward(1x)
        flops = fwd * (4.0 if remat else 3.0)
        # params re-read per microbatch pass (fwd+bwd+remat ~ 3) + grads +
        # optimizer state traffic + activation carries (bf16 rw per layer)
        n_micro = 8
        act_rw = tokens * d * n_layers * 2 * 2 * 2   # save+read, bf16, x2 safety
        hbm = (3 * n_micro * p_dev * n_devices        # param reads
               + 8 * param_bytes(cfg)                 # grad f32 rw
               + 12 * param_bytes(cfg)                # adam moments rw (f32)
               + act_rw) / n_devices
        return AnalyticCost(flops=flops / n_devices, hbm_bytes=hbm)

    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        fwd = forward_flops(cfg, tokens, kv_len=shape.seq / 2,
                            logits_positions=shape.batch)
        act_rw = tokens * d * n_layers * 2 * 2
        kv_w = shape.batch * state_bytes_per_seq(cfg, shape.seq)
        hbm = (param_bytes(cfg) + act_rw + kv_w) / n_devices
        return AnalyticCost(flops=fwd / n_devices, hbm_bytes=hbm)

    # decode: one token per sequence; reads active params + the whole state
    tokens = shape.batch
    fwd = forward_flops(cfg, tokens, kv_len=shape.seq,
                        logits_positions=shape.batch)
    kv_r = shape.batch * state_bytes_per_seq(cfg, shape.seq)
    act = tokens * d * n_layers * 2 * 4
    hbm = (active_param_bytes(cfg) + kv_r + act) / n_devices
    return AnalyticCost(flops=fwd / n_devices, hbm_bytes=hbm)
