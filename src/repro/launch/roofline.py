"""Roofline-term derivation from compiled dry-run artifacts.

Three terms, in seconds, per device (the compiled module after SPMD
partitioning IS the per-device program):

  compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw                (819 GB/s)
  collective = wire_bytes / ICI_bw               (~50 GB/s per link; we
               conservatively charge a single link direction)

``cost_analysis`` provides flops/bytes.  Collective bytes are NOT in
cost_analysis: we parse the post-SPMD HLO text, find every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, take its
output tensor bytes and apply the ring-algorithm wire factor per op kind
and participant-group size.
"""

from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, field
from typing import Optional

# v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12     # bf16
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of possibly-tuple HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    """Participants per replica group.

    Handles ``replica_groups={{0,1,2,3},{...}}`` and the iota form
    ``replica_groups=[8,32]<=[256]`` (8 groups of 32).
    """
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(1, len(ids))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return max(1, int(m.group(2)))
    return default


def _wire_factor(kind: str, n: int) -> float:
    """Ring-algorithm wire bytes per device, as a multiple of the op's
    output bytes."""
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind == "all-gather":
        return (n - 1) / n
    if kind == "reduce-scatter":
        return (n - 1)  # output is 1/n of the input that moves
    if kind == "all-to-all":
        return (n - 1) / n
    if kind == "collective-permute":
        return 1.0
    return 1.0


@dataclass
class CollectiveStats:
    ops: dict = field(default_factory=dict)       # kind -> count
    raw_bytes: dict = field(default_factory=dict)  # kind -> output bytes
    wire_bytes: float = 0.0
    # TPU-adjusted wire: the CPU backend computes bf16 dots in f32, so SPMD
    # all-reduces of dot partials appear as f32 even though the pre-SPMD
    # StableHLO is bf16 (verified) — a TPU backend moves those bytes in
    # bf16.  f32 dot-produced ARs are therefore halved in this metric.
    wire_bytes_tpu: float = 0.0

    def add(self, kind: str, nbytes: int, n: int, mult: float = 1.0,
            f32_dot_artifact: bool = False) -> None:
        self.ops[kind] = self.ops.get(kind, 0) + mult
        self.raw_bytes[kind] = self.raw_bytes.get(kind, 0) + nbytes * mult
        wire = nbytes * _wire_factor(kind, n) * mult
        self.wire_bytes += wire
        self.wire_bytes_tpu += wire * (0.5 if f32_dot_artifact else 1.0)


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """computation name -> its body lines (post-SPMD HLO text)."""
    comps: dict[str, list[str]] = {}
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        clean = re.sub(r"/\*.*?\*/", "", line)  # strip /*index=N*/ comments
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", clean)
        is_header = (
            m is not None
            and clean.rstrip().endswith("{")
            and "=" not in clean.split("(", 1)[0]
        )
        if is_header:
            cur = m.group(1)
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line.strip())
    return comps


def _entry_name(hlo_text: str, comps: dict) -> Optional[str]:
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
    if m and m.group(1) in comps:
        return m.group(1)
    # fall back: computation named like main
    for name in comps:
        if "main" in name:
            return name
    return next(iter(comps), None)


def _trip_count(cond_lines: list[str]) -> int:
    """Scan-lowered loop conditions compare the induction var against a
    constant; take the largest integer constant in the condition body."""
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def parse_collectives(hlo_text: str, default_group: int) -> CollectiveStats:
    """Collective wire bytes with while-loop trip attribution.

    XLA's cost analysis (and a naive text scan) counts a while body ONCE;
    scan-over-layers/microbatches would undercount collectives by the trip
    count.  We walk the call graph from ENTRY, multiplying by parsed trip
    counts at each ``while``.
    """
    comps = _split_computations(hlo_text)
    entry = _entry_name(hlo_text, comps)
    stats = CollectiveStats()
    if entry is None:
        return stats

    def walk(name: str, mult: float, depth: int = 0) -> None:
        if depth > 12 or name not in comps:
            return
        for line in comps[name]:
            m = re.search(r"=\s*(\([^)]*\)|[\w\[\],{}\/]+)\s+([\w\-]+)", line)
            if m:
                kind = m.group(2)
                base = kind.replace("-start", "")
                if base in _COLLECTIVES and not kind.endswith("-done"):
                    nbytes = _shape_bytes(m.group(1))
                    n = _group_size(line, default_group)
                    artifact = (base in ("all-reduce", "all-gather")
                                and "f32[" in m.group(1)
                                and "dot" in line)
                    stats.add(base, nbytes, n, mult, artifact)
                    continue
            wm = re.search(r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)",
                           line)
            if not wm:
                wm2 = re.search(r"body=%?([\w.\-]+).*?condition=%?([\w.\-]+)", line)
                if wm2 and "while(" in line:
                    cond_name, body_name = wm2.group(2), wm2.group(1)
                else:
                    cond_name = body_name = None
            else:
                cond_name, body_name = wm.group(1), wm.group(2)
            if body_name:
                trips = _trip_count(comps.get(cond_name, []))
                walk(body_name, mult * trips, depth + 1)
                continue
            cm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", line)
            if cm and "fused" not in cm.group(1):
                walk(cm.group(1), mult, depth + 1)

    walk(entry, 1.0)
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float              # analytic, per device (primary)
    hbm_bytes: float          # analytic, per device (primary)
    wire_bytes: float         # HLO-parsed with while-trip attribution
    per_device_output_bytes: float
    model_flops: float
    wire_bytes_tpu: float = 0.0  # f32-dot-AR artifact halved (see parse)
    collective_ops: dict = field(default_factory=dict)
    hlo_flops_raw: float = 0.0   # body-once HLO numbers (lower bound)
    hlo_bytes_raw: float = 0.0
    peak_mem_bytes: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / ICI_BW

    @property
    def t_collective_tpu(self) -> float:
        return self.wire_bytes_tpu / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per device): remat/dispatch overhead."""
        if self.flops <= 0:
            return 0.0
        return self.model_flops / self.flops

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of the compute roofline if the dominant term
        were perfectly overlapped: t_compute / t_bound."""
        if self.t_bound <= 0:
            return 0.0
        return self.t_compute / self.t_bound

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes,
            "wire_bytes_tpu": self.wire_bytes_tpu,
            "t_collective_tpu": self.t_collective_tpu,
            "collective_ops": self.collective_ops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
            "hlo_flops_raw": self.hlo_flops_raw,
            "hlo_bytes_raw": self.hlo_bytes_raw,
            "peak_mem_bytes": self.peak_mem_bytes,
            "per_device_output_bytes": self.per_device_output_bytes,
        }


def model_flops_per_device(cfg, shape_spec, n_devices: int) -> float:
    """MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D for inference
    forward (D = tokens processed), divided across devices."""
    n_active = cfg.active_param_count()
    if shape_spec.kind == "train":
        tokens = shape_spec.batch * shape_spec.seq
        total = 6.0 * n_active * tokens
    elif shape_spec.kind == "prefill":
        tokens = shape_spec.batch * shape_spec.seq
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape_spec.batch
    return total / n_devices


def analyze(compiled, *, arch: str, shape, mesh, cfg) -> Roofline:
    from .analytic import analyze_cell

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    n_dev = math.prod(mesh.devices.shape)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    stats = parse_collectives(hlo, default_group=n_dev)
    mem = None
    out_bytes = 0.0
    try:
        ma = compiled.memory_analysis()
        mem = float(ma.temp_size_in_bytes + ma.argument_size_in_bytes +
                    ma.output_size_in_bytes)
        out_bytes = float(ma.output_size_in_bytes)
    except Exception:
        pass
    ana = analyze_cell(cfg, shape, n_dev)
    return Roofline(
        arch=arch,
        shape=shape.name,
        mesh="x".join(str(s) for s in mesh.devices.shape),
        flops=ana.flops,
        hbm_bytes=ana.hbm_bytes,
        wire_bytes=stats.wire_bytes,
        wire_bytes_tpu=stats.wire_bytes_tpu,
        collective_ops=stats.ops,
        per_device_output_bytes=out_bytes,
        model_flops=model_flops_per_device(cfg, shape, n_dev),
        hlo_flops_raw=hlo_flops,
        hlo_bytes_raw=hlo_bytes,
        peak_mem_bytes=mem,
    )
