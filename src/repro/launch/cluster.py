"""Multi-host bootstrap for pod-scale runs.

On real hardware every host runs the SAME program (multi-controller SPMD):

  1. ``init_cluster()`` wires the hosts together (coordinator address from
     the scheduler's env: TPU_WORKER_HOSTNAMES / MEGASCALE_COORDINATOR /
     SLURM, or explicit flags);
  2. ``make_production_mesh(multi_pod=...)`` then sees the global device
     set and builds the (pod, data, model) mesh;
  3. the training loop is identical to launch/train.py — per-host data
     slices come from DataConfig(host_id=jax.process_index(),
     n_hosts=jax.process_count()).

Fault tolerance at this layer:
  * a failed host exits non-zero; the wrapper script (scripts/launch_pod.sh)
    relaunches the job, and launch/train.py auto-resumes from the last
    atomic checkpoint;
  * elastic restarts with a different host count reshard the checkpoint on
    restore (repro.checkpoint supports cross-mesh restore);
  * straggler mitigation is the paper's method: per-pod step times ->
    repro.runtime.RatioTable -> UnevenBatchPlanner microbatch counts; pods
    accumulate locally (no collectives) and join in one weighted
    all-reduce, so a slow pod never blocks lockstep collectives
    mid-accumulation.  The table persists via repro.runtime.RatioStore, so
    an elastic restart warm-starts from the last measured ratios.
"""

from __future__ import annotations

import os
from typing import Optional


def init_cluster(coordinator: Optional[str] = None,
                 num_processes: Optional[int] = None,
                 process_id: Optional[int] = None) -> bool:
    """Initialize jax.distributed if a multi-host environment is detected.

    Returns True when distributed mode is active.  Safe to call on a
    single host (no-op).
    """
    import jax

    coordinator = coordinator or os.environ.get("REPRO_COORDINATOR")
    num_processes = num_processes or _env_int("REPRO_NUM_PROCESSES")
    process_id = process_id or _env_int("REPRO_PROCESS_ID")

    # Scheduler-native autodetection (TPU pods, SLURM) works with no args.
    auto = any(v in os.environ for v in
               ("TPU_WORKER_HOSTNAMES", "MEGASCALE_COORDINATOR_ADDRESS",
                "SLURM_JOB_ID"))
    if coordinator is None and not auto:
        return False
    try:
        jax.distributed.initialize(
            coordinator_address=coordinator,
            num_processes=num_processes,
            process_id=process_id,
        )
        return True
    except Exception as e:  # pragma: no cover - depends on environment
        print(f"[cluster] distributed init failed ({e}); single-host mode")
        return False


def _env_int(name: str) -> Optional[int]:
    v = os.environ.get(name)
    return int(v) if v else None


def host_data_slice():
    """(host_id, n_hosts) for DataConfig."""
    import jax

    return jax.process_index(), jax.process_count()
