import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""HLO inspection for §Perf iteration: top collectives (trip-multiplied)
and largest tensors in a cell's compiled module.

  python -m repro.launch.hloscan --arch granite-8b --shape train_4k
"""

import argparse
import re
import sys

import jax

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_production_mesh
from repro.sharding.specs import activation_sharding
from repro.launch.roofline import (
    _COLLECTIVES,
    _entry_name,
    _group_size,
    _shape_bytes,
    _split_computations,
    _trip_count,
    _wire_factor,
)


def scan(hlo: str, default_group: int, top: int = 15):
    comps = _split_computations(hlo)
    entry = _entry_name(hlo, comps)
    colls = []
    big = []

    def walk(name, mult, depth=0):
        if depth > 12 or name not in comps:
            return
        for line in comps[name]:
            m = re.search(r"=\s*(\([^)]*\)|[\w\[\],{}\/]+)\s+([\w\-]+)", line)
            if m:
                kind = m.group(2)
                base = kind.replace("-start", "")
                nbytes = _shape_bytes(m.group(1))
                if base in _COLLECTIVES and not kind.endswith("-done"):
                    n = _group_size(line, default_group)
                    wire = nbytes * _wire_factor(base, n) * mult
                    colls.append((wire, base, n, mult, m.group(1)[:90],
                                  line[:60]))
                elif nbytes > 256 * 1024 * 1024:
                    big.append((nbytes, kind, m.group(1)[:90]))
            wm = re.search(
                r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)",
                line)
            if wm:
                trips = _trip_count(comps.get(wm.group(1), []))
                walk(wm.group(2), mult * trips, depth + 1)

    walk(entry, 1.0)
    colls.sort(reverse=True)
    big.sort(reverse=True)
    print("== top collectives (wire bytes x trips, per device) ==")
    for wire, base, n, mult, t, line in colls[:top]:
        print(f"{wire/1e9:10.2f} GB  {base:18} group={n:3} trips={mult:6.0f} {t}")
    print("== largest single tensors ==")
    seen = set()
    for nbytes, kind, t in big[:top]:
        key = (kind, t)
        if key in seen:
            continue
        seen.add(key)
        print(f"{nbytes/1e9:10.2f} GB  {kind:22} {t}")
    total = sum(c[0] for c in colls)
    print(f"total wire: {total/1e9:.1f} GB -> t_coll={total/50e9:.3f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    fn, fargs, in_sh = build_cell(cfg, shape, mesh)
    with mesh, activation_sharding(mesh):
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*fargs).compile()
    import math
    scan(compiled.as_text(), math.prod(mesh.devices.shape), args.top)


if __name__ == "__main__":
    main()
