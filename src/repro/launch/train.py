"""End-to-end training driver with fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --preset tiny \
      --steps 200 --ckpt-dir /tmp/ckpt

Features exercised here (the same code paths the dry-run lowers at pod
scale):
  * config-driven model construction (any assigned arch, or its reduced
    preset for CPU),
  * microbatched train step (remat + optional factored moments),
  * sharded lowering when >1 device is available (data x model mesh),
  * atomic checkpointing + automatic resume (kill the process mid-run and
    relaunch: it continues from the last step, data stream repositioned),
  * straggler telemetry: per-step wall times feed a repro.runtime
    RatioTable persisted next to the checkpoints (RatioStore), so ratios
    warm-start across restarts; at pod scale the UnevenBatchPlanner turns
    this table into per-pod microbatch counts — see examples/train_100m.py.
"""

from __future__ import annotations

import argparse
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.configs import get_config, reduced_config
from repro.runtime import RatioStore, RatioTable
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.models import init_params
from repro.training import AdamWConfig, init_opt_state, make_train_step


def build_mesh_if_useful():
    n = len(jax.devices())
    if n < 2:
        return None
    model = 2 if n % 2 == 0 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", choices=["tiny", "full"], default="tiny")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.preset == "full" else reduced_config(args.arch)
    if cfg.embed_input or cfg.n_prefix:
        raise SystemExit("use examples/ for stub-frontend archs")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps,
                          factored=cfg.param_count() > 50e9)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.global_batch,
                          microbatch=args.microbatch)
    data = SyntheticLM(data_cfg)

    params = init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params, opt_cfg)
    start_step = 0

    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            template = jax.eval_shape(lambda: {"params": params, "opt": opt})
            tree, meta = restore(args.ckpt_dir, last, template)
            params, opt = tree["params"], tree["opt"]
            start_step = last
            data.seek(meta["extra"]["data_step"])
            print(f"[train] resumed from step {last}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=True))
    table = RatioTable(n_workers=1)  # per-pod table at scale
    store = (RatioStore(os.path.join(args.ckpt_dir, "ratios.json"))
             if args.ckpt_dir else None)
    if store is not None:
        try:
            if store.load_into(table):
                print("[train] warm-started performance ratios from",
                      store.path)
        except Exception as e:  # corrupt sidecar must not block training
            print(f"[train] ignoring unreadable ratio store ({e})")
    it = Prefetcher(iter(data), depth=2)

    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch)
        metrics["loss"].block_until_ready()
        dt = time.perf_counter() - t0
        table.update("train_step", np.array([dt]))
        if (step + 1) % args.log_every == 0:
            toks = args.global_batch * args.seq_len / dt
            print(f"[train] step {step + 1} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} tok/s={toks:.0f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, step + 1, {"params": params, "opt": opt},
                 extra={"data_step": data.step})
            store.save(table)
    if args.ckpt_dir:
        save(args.ckpt_dir, args.steps, {"params": params, "opt": opt},
             extra={"data_step": data.step})
        store.save(table)
    print(f"[train] done in {time.time() - t_start:.1f}s")
    it.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
