import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks device count at first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (SPMD partitioner succeeds),
  * per-device memory fits (memory_analysis),
  * and it yields the roofline terms (cost_analysis + HLO collective parse).

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all            # every assigned cell
  python -m repro.launch.dryrun --all --multi-pod
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (
    ARCHS,
    SHAPES,
    ShapeSpec,
    cells,
    get_config,
    shape_supported,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze
from repro.models import abstract_params, abstract_state, forward
from repro.models.moe import default_capacity
from repro.sharding.specs import (
    activation_sharding,
    batch_shardings,
    opt_shardings,
    param_shardings,
    state_shardings,
)
from repro.training import AdamWConfig, init_opt_state, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# >=50B params: factored moments + bf16 mu (see training/optimizer.py).
FACTORED_THRESHOLD = 50e9


def _abstract(tree):
    return jax.tree.map(
        lambda x: x if isinstance(x, jax.ShapeDtypeStruct)
        else jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(cfg, shape: ShapeSpec, *, n_micro: int = 8) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.batch, shape.seq
    dt = cfg.cdtype
    if shape.kind == "train":
        mb = b // n_micro
        batch = {}
        if cfg.embed_input:
            batch["embeds"] = jax.ShapeDtypeStruct((n_micro, mb, s, cfg.d_model), dt)
            batch["labels"] = jax.ShapeDtypeStruct((n_micro, mb, s), jnp.int32)
        elif cfg.n_prefix:
            s_txt = s - cfg.n_prefix
            batch["tokens"] = jax.ShapeDtypeStruct((n_micro, mb, s_txt), jnp.int32)
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (n_micro, mb, cfg.n_prefix, cfg.d_model), dt)
            batch["labels"] = jax.ShapeDtypeStruct((n_micro, mb, s_txt), jnp.int32)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((n_micro, mb, s), jnp.int32)
            batch["labels"] = jax.ShapeDtypeStruct((n_micro, mb, s), jnp.int32)
        return {"batch": batch}
    if shape.kind == "prefill":
        if cfg.embed_input:
            return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)}
        if cfg.n_prefix:
            return {
                "tokens": jax.ShapeDtypeStruct((b, s - cfg.n_prefix), jnp.int32),
                "prefix_embeds": jax.ShapeDtypeStruct(
                    (b, cfg.n_prefix, cfg.d_model), dt),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    # decode: one new token against a state of seq_len
    if cfg.embed_input:
        return {"embeds": jax.ShapeDtypeStruct((b, 1, cfg.d_model), dt)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def build_cell(cfg, shape: ShapeSpec, mesh, n_micro: int = 8):
    """Returns (fn, args, in_shardings) ready for jit().lower()."""
    params = _abstract(abstract_params(cfg))
    # decode is weight-bandwidth bound: serve-mode placement keeps weights
    # stationary (no FSDP gathers); train/prefill amortize FSDP gathers
    # over a large token volume.
    p_sh = param_shardings(mesh, params,
                           mode="serve" if shape.kind == "decode" else "train")
    specs = input_specs(cfg, shape, n_micro=n_micro)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(
            factored=cfg.param_count() > FACTORED_THRESHOLD,
            total_steps=10_000,
        )
        opt = _abstract(jax.eval_shape(
            lambda p: init_opt_state(p, opt_cfg), params))
        o_sh = opt_shardings(mesh, opt, p_sh)
        b_sh = batch_shardings(mesh, specs["batch"], batch_dim=1)
        # capacity=None: moe_fwd derives the static per-dispatch-group
        # capacity from its local token count (global/16 under shard_map)
        big = cfg.param_count() > FACTORED_THRESHOLD
        step = make_train_step(cfg, opt_cfg, capacity=None, remat=True,
                               acc_dtype=jnp.bfloat16 if big else jnp.float32,
                               grad_shardings=p_sh)
        return step, (params, opt, specs["batch"]), (p_sh, o_sh, b_sh)

    if shape.kind == "prefill":
        state = _abstract(abstract_state(cfg, shape.batch, shape.seq))
        s_sh = state_shardings(mesh, state, shape.batch, phase="prefill")
        in_sh = [p_sh]
        args = [params]
        for k in ("tokens", "embeds", "prefix_embeds"):
            if k in specs:
                args.append(specs[k])
                in_sh.append(batch_shardings(mesh, specs[k], batch_dim=0))
        args.append(state)
        in_sh.append(s_sh)
        has_prefix = "prefix_embeds" in specs
        has_embeds = "embeds" in specs

        def prefill(params, *rest):
            i = 0
            tokens = embeds = prefix = None
            if not has_embeds:
                tokens = rest[i]; i += 1
            if has_embeds:
                embeds = rest[i]; i += 1
            if has_prefix:
                prefix = rest[i]; i += 1
            state = rest[i]
            out = forward(cfg, params, tokens, embeds=embeds,
                          prefix_embeds=prefix, state=state,
                          logits_mode="last")
            return out.logits, out.state

        return prefill, tuple(args), tuple(in_sh)

    # decode
    state = _abstract(abstract_state(cfg, shape.batch, shape.seq))
    s_sh = state_shardings(mesh, state, shape.batch, phase="decode")
    tok_key = "embeds" if cfg.embed_input else "tokens"
    tok_spec = specs[tok_key]
    t_sh = batch_shardings(mesh, tok_spec, batch_dim=0)
    from jax.sharding import NamedSharding, PartitionSpec as P
    off_sh = NamedSharding(mesh, P())
    offset = jax.ShapeDtypeStruct((), jnp.int32)
    use_embeds = cfg.embed_input

    def decode(params, tok, state, offset):
        out = forward(cfg, params,
                      None if use_embeds else tok,
                      embeds=tok if use_embeds else None,
                      state=state, pos_offset=offset, logits_mode="last")
        return out.logits, out.state

    return decode, (params, tok_spec, state, offset), (p_sh, t_sh, s_sh, off_sh)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = OUT_DIR) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_supported(cfg, shape_name):
        return {"arch": arch, "shape": shape_name, "status": "SKIP",
                "reason": "long_500k requires sub-quadratic attention"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, in_sh = build_cell(cfg, shape, mesh)

    t0 = time.time()
    with mesh, activation_sharding(mesh):
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem_txt = ""
    try:
        mem_txt = str(compiled.memory_analysis())
    except Exception:
        pass
    roof = analyze(compiled, arch=arch, shape=shape, mesh=mesh, cfg=cfg)
    result = {
        "status": "OK",
        "mesh_shape": list(mesh.devices.shape),
        "multi_pod": multi_pod,
        "lower_seconds": round(t_lower, 2),
        "compile_seconds": round(t_compile, 2),
        "memory_analysis": mem_txt,
        **roof.to_dict(),
    }
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every assigned cell in subprocesses")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    if args.all:
        failures = []
        for arch, shape, ok in cells(include_skipped=True):
            mesh_tag = "2x16x16" if args.multi_pod else "16x16"
            tag = f"{arch} x {shape} x {mesh_tag}"
            if not ok:
                print(f"[dryrun] SKIP {tag} (long_500k needs sub-quadratic)")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", args.out]
            if args.multi_pod:
                cmd.append("--multi-pod")
            print(f"[dryrun] {tag} ...", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                failures.append(tag)
                print(f"[dryrun] FAIL {tag}\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}")
            else:
                print(r.stdout.strip().splitlines()[-1])
        print(f"[dryrun] done; {len(failures)} failures")
        for f in failures:
            print("  FAIL", f)
        return 1 if failures else 0

    res = run_cell(args.arch, args.shape, args.multi_pod, args.out)
    if res["status"] == "OK":
        print(json.dumps({k: res[k] for k in (
            "arch", "shape", "mesh_shape", "compile_seconds", "flops",
            "hbm_bytes", "wire_bytes", "bottleneck", "t_compute", "t_memory",
            "t_collective", "peak_mem_bytes")}, default=str))
    else:
        print(json.dumps(res))
    return 0


if __name__ == "__main__":
    sys.exit(main())
