"""Serving driver: batched greedy generation on any assigned arch (reduced
preset on CPU), with the paper's dynamic replica routing when more than one
replica is requested.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --preset tiny \
      --batch 4 --prompt-len 16 --steps 32
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import init_params
from repro.serving import RoutedServer, ServeEngine


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--preset", choices=["tiny", "full"], default="tiny")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--replicas", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.preset == "full" else reduced_config(args.arch)
    if cfg.embed_input:
        raise SystemExit("use examples/ for stub-frontend archs")
    params = init_params(cfg, jax.random.key(0))
    max_seq = args.prompt_len + args.steps + 8
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.batch, args.prompt_len), dtype=np.int32)

    if args.replicas > 1:
        per = max(1, args.batch // args.replicas)
        engines = [ServeEngine(cfg, params, batch_size=args.batch, max_seq=max_seq)
                   for _ in range(args.replicas)]
        srv = RoutedServer(engines)
        t0 = time.time()
        out, counts, times = srv.serve_batch(prompts, args.steps)
        print(f"[serve] routed counts={counts.tolist()} times={times.round(3).tolist()}")
        print(f"[serve] {out.shape[0] * args.steps / (time.time() - t0):.1f} tok/s")
        return 0

    eng = ServeEngine(cfg, params, batch_size=args.batch, max_seq=max_seq)
    r = eng.generate(jax.numpy.asarray(prompts), args.steps)
    print(f"[serve] prefill={r.prefill_seconds * 1e3:.1f} ms "
          f"decode={r.decode_seconds * 1e3:.1f} ms "
          f"({r.tokens_per_second:.1f} tok/s)")
    print("[serve] sample:", r.tokens[0, -min(16, args.steps):].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
