"""Serving driver: request-level continuous batching with open-loop
(seeded Poisson) traffic, phase-aware ratio learning, and dynamic replica
routing.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --preset tiny \
      --replicas 2 --requests 8 --prompt-len 16 --steps 8 --rate 20

Modes:
* default — continuous batching: requests arrive open-loop and are routed
  to replicas by measured per-phase throughput; each replica interleaves
  chunked prefill with its running decode batch.  ``--machine`` drives a
  deterministic virtual clock from the paper's hybrid-CPU model (per-phase
  core dispatch); ``--machine wall`` uses real wall time.
* ``--legacy-batch`` — the seed-era whole-batch path (one
  ``RoutedServer.serve_batch`` round), kept for migration comparisons.
* ``--fleet`` — cluster-scale serving: a default heterogeneous fleet
  (NUMA flagship + NUMA desktop + flat box + throttled box) behind the
  recursive :class:`repro.fleet.FleetRouter`, driven by diurnal
  heavy-tailed traffic with a mid-run node failure window.
  ``--fleet-policy`` selects learned / round_robin / static routing and
  ``--fleet-admission`` adds the SLO-aware front door.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import events as _ev
from repro.core.hybrid_sim import MACHINES
from repro.core.tuner import KernelTuner, TunerStore
from repro.kernels import (
    GEMV_ISA,
    TRUNK_KINDS,
    HybridKernelDispatcher,
    kernel_key,
)
from repro.models import BalancedTrunk, balanced_lm_head, init_params
from repro.runtime import RatioStore, RatioTable
from repro.topology import TOPOLOGIES, TopologyDispatcher
from repro.serving import (
    DECODE,
    PREFILL,
    ContinuousBatchingEngine,
    HybridPhaseCost,
    InflightDispatcher,
    LatencyReport,
    RoutedServer,
    ServeEngine,
    poisson_requests,
)


def replica_slot_counts(batch: int, replicas: int) -> list:
    """Split a total concurrent-request budget across replicas: ``per``
    slots each plus the remainder spread over the first replicas (every
    replica gets at least one slot)."""
    if replicas < 1:
        raise ValueError("need at least one replica")
    base, rem = divmod(batch, replicas)
    return [max(1, base + (1 if i < rem else 0)) for i in range(replicas)]


def run_fleet_mode(args, cfg, params, max_seq: int, registry=None) -> int:
    """``--fleet``: the default heterogeneous 4-node cluster behind the
    recursive FleetRouter, under diurnal heavy-tailed traffic with a
    mid-run failure window on the largest node."""
    from repro.fleet import (
        AdmissionController,
        Cluster,
        FleetRouter,
        NodeSpec,
        failure_window,
        fleet_requests,
    )

    specs = (
        NodeSpec("big", "dual-125h", max_slots=args.batch, prefill_lanes=2),
        NodeSpec("mid", "2s-12900k", max_slots=args.batch, prefill_lanes=2),
        NodeSpec("flat", "ultra-125h", max_slots=args.batch),
        NodeSpec("slow", "ultra-125h", max_slots=args.batch, throttle=3.0),
    )
    cluster = Cluster.build(specs, cfg, params, max_seq=max_seq,
                            seed=args.seed)
    admission = None
    if args.fleet_admission:
        admission = AdmissionController(queue_cap=6 * len(specs),
                                        degrade_depth=3 * len(specs))
    # --ratios warm-starts/persists the *node-level* fleet table here
    # (same store format the replica path uses): a restarted router skips
    # the cold-start rounds where every node looks identical.
    table = RatioTable(len(specs), alpha=0.3)
    store = RatioStore(args.ratios) if args.ratios else None
    if store is not None and store.load_into(table):
        print(f"[serve] warm-started fleet node ratios from {args.ratios}")
    router = FleetRouter(cluster, policy=args.fleet_policy, table=table,
                         slo_ttft=2.0, slo_tpot=0.25, admission=admission)
    requests = fleet_requests(
        args.requests, base_rate=args.rate, vocab_size=cfg.vocab_size,
        prompt_len=(4, args.prompt_len), max_new_tokens=args.steps,
        seed=args.seed)
    # fail the flagship a quarter of the way through the expected span,
    # bring it back past the halfway crest
    span = args.requests / args.rate
    events = failure_window("big", fail_at=0.25 * span,
                            recover_at=0.6 * span)
    t_wall = time.perf_counter()
    done = router.run(requests, events)
    report = LatencyReport.from_requests(
        done, slo_ttft=2.0, slo_tpot=0.25,
        wall_duration=time.perf_counter() - t_wall)
    if registry is not None:
        report.publish(registry)
    names = [n.name for n in cluster.nodes]
    print(f"[serve] fleet {names} policy={args.fleet_policy} "
          f"routed={router.routed.tolist()} requeued={router.n_requeued}")
    for line in report.lines():
        print(line)
    print(f"[serve] node prefill ratios: "
          f"{np.round(router.table.ratios(PREFILL), 3).tolist()}")
    print(f"[serve] node decode  ratios: "
          f"{np.round(router.table.ratios(DECODE), 3).tolist()}")
    st = router.last_stats.get(DECODE)
    if st is not None:
        print(f"[serve] recursive decode stats: {len(st.children)} node "
              f"domains under the fleet table")
    if store is not None:
        store.save(router.table)
        print(f"[serve] saved fleet node ratios to {args.ratios}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--preset", choices=["tiny", "full"], default="tiny")
    ap.add_argument("--batch", type=int, default=4,
                    help="total concurrent-request slots across replicas")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32,
                    help="max new tokens per request")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="open-loop Poisson arrival rate, req/s (0: all at t=0)")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="prompt tokens prefilled per iteration (0: one-shot)")
    ap.add_argument("--machine", default=None,
                    choices=sorted(MACHINES) + ["wall"],
                    help="virtual hybrid-CPU clock (default ultra-125h), "
                         "or 'wall' for real time")
    ap.add_argument("--topology", default=None,
                    choices=sorted(TOPOLOGIES) + sorted(MACHINES),
                    help="serve on a NUMA topology: the balanced trunk "
                         "dispatches socket-local (two-level ratio split, "
                         "NUMA-placed weights) and the virtual clock runs "
                         "on the flattened machine; implies "
                         "--balanced-trunk (flat machine names are the "
                         "1-socket special case)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ratios", default=None,
                    help="JSON path to warm-start/persist replica ratios")
    ap.add_argument("--legacy-batch", action="store_true",
                    help="run the seed-era whole-batch serve_batch path")
    ap.add_argument("--fleet", action="store_true",
                    help="serve on the default heterogeneous 4-node fleet "
                         "through the recursive FleetRouter (diurnal "
                         "traffic + mid-run failure window)")
    ap.add_argument("--fleet-policy", default="learned",
                    choices=["learned", "round_robin", "static"],
                    help="fleet routing policy (with --fleet)")
    ap.add_argument("--fleet-admission", action="store_true",
                    help="enable SLO-aware admission control (queue cap, "
                         "graceful degradation) in front of the fleet")
    ap.add_argument("--balanced-head", action="store_true",
                    help="run the LM head as balanced per-core Q4 Pallas "
                         "shards (hybrid kernel dispatch) instead of inside "
                         "the jitted trunk")
    ap.add_argument("--balanced-trunk", action="store_true",
                    help="run EVERY trunk projection (q/k/v/o, MLP "
                         "up/gate/down, head) as balanced per-core shards "
                         "through the io_callback bridge, with per-phase x "
                         "per-layer-kind ratio keys")
    ap.add_argument("--trunk-quant", choices=["q4", "int8", "fp32"],
                    default="q4",
                    help="balanced-trunk weight path: Q4_0 Pallas GEMV, "
                         "dynamic-u8xs8 INT8 GEMM, or shard-exact fp32")
    ap.add_argument("--tuner-cache", default=None,
                    help="JSON path to warm-start/persist the kernel "
                         "tuner's block-shape tables (shared across "
                         "replicas, like --ratios for ratio tables)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace_event JSON of the "
                         "run: spans on the virtual clock at every "
                         "balancing level plus ratio / bandwidth / "
                         "capacity counter tracks")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write run metrics (TTFT/TPOT histograms, "
                         "goodput): Prometheus text exposition, or a JSON "
                         "dump when PATH ends in .json")
    ap.add_argument("--flight-recorder", default=None, metavar="PATH",
                    help="record balancer decisions (ratio reports, offset "
                         "refreshes, capacity/admission events) in a "
                         "bounded ring dumped to PATH; auto-dumps on SLO "
                         "burn or contract trip")
    args = ap.parse_args()
    if args.topology:
        if args.balanced_head:
            raise SystemExit("--topology dispatches the whole trunk; "
                             "drop --balanced-head")
        if args.machine is not None:
            raise SystemExit(
                "--topology provides the virtual clock (the topology's "
                "flattened machine); drop --machine")
        args.balanced_trunk = True
    args.machine = args.machine or "ultra-125h"
    if args.balanced_head and args.balanced_trunk:
        raise SystemExit("--balanced-trunk already includes the head; "
                         "drop --balanced-head")

    cfg = get_config(args.arch) if args.preset == "full" else reduced_config(args.arch)
    if cfg.embed_input:
        raise SystemExit("use examples/ for stub-frontend archs")
    params = init_params(cfg, jax.random.key(0))
    max_seq = args.prompt_len + args.steps + 8
    slot_counts = replica_slot_counts(args.batch, args.replicas)

    # observability: install the tracer / flight recorder before any mode
    # runs, write the artifacts after it returns (or raises)
    tracer = recorder = registry = None
    prev_tracer = prev_recorder = None
    if args.trace:
        from repro.obs import SpanTracer
        tracer = SpanTracer()
        prev_tracer = _ev.install(tracer)
    if args.flight_recorder:
        from repro.obs import FlightRecorder
        recorder = FlightRecorder(
            path=args.flight_recorder,
            slo_ttft=2.0 if args.fleet else None,
            slo_tpot=0.25 if args.fleet else None)
        prev_recorder = _ev.install_recorder(recorder)
    if args.metrics:
        from repro.obs import MetricsRegistry
        registry = MetricsRegistry()
    try:
        return run_mode(args, cfg, params, max_seq, slot_counts, registry)
    finally:
        if tracer is not None:
            _ev.install(prev_tracer)
            tracer.write(args.trace)
            print(f"[serve] wrote trace to {args.trace} "
                  f"({tracer.n_spans} spans, {tracer.n_counters} counter "
                  f"samples, {tracer.n_instants} instants)")
        if recorder is not None:
            _ev.install_recorder(prev_recorder)
            if recorder.last_dump is None:
                recorder.trip("exit")
            print(f"[serve] flight recorder: {len(recorder.records())} "
                  f"records, {len(recorder.trips)} trip(s) -> "
                  f"{args.flight_recorder}")
        if registry is not None:
            if args.metrics.endswith(".json"):
                registry.write_json(args.metrics)
            else:
                with open(args.metrics, "w", encoding="utf-8") as fh:
                    fh.write(registry.prometheus_text())
            print(f"[serve] wrote metrics to {args.metrics}")


def run_mode(args, cfg, params, max_seq, slot_counts, registry=None) -> int:
    """Dispatch to the selected serving mode (fleet / legacy / default)."""
    if args.fleet:
        if (args.legacy_batch or args.balanced_head or args.balanced_trunk
                or args.topology):
            raise SystemExit("--fleet is a standalone mode: the fleet owns "
                             "its topologies and cost models")
        return run_fleet_mode(args, cfg, params, max_seq, registry)

    if args.legacy_batch:
        rng = np.random.default_rng(args.seed)
        prompts = rng.integers(0, cfg.vocab_size,
                               size=(args.batch, args.prompt_len),
                               dtype=np.int32)
        engines = [ServeEngine(cfg, params, batch_size=n, max_seq=max_seq)
                   for n in slot_counts]
        srv = RoutedServer(engines)
        out, counts, times = srv.serve_batch(prompts, args.steps)
        print(f"[serve] legacy routed counts={counts.tolist()} "
              f"times={times.round(3).tolist()}")
        print(f"[serve] generated shape={out.shape}")
        return 0

    chunk = args.prefill_chunk if args.prefill_chunk > 0 else None
    engines, dispatchers = [], []
    # One kernel tuner shared by every replica dispatcher so a single
    # --tuner-cache file accumulates all block-shape measurements.
    tuner = KernelTuner()
    tuner_store = TunerStore(args.tuner_cache) if args.tuner_cache else None
    if tuner_store is not None and tuner_store.load_into(tuner):
        print(f"[serve] warm-started kernel tuner from {args.tuner_cache}")
    for i, n_slots in enumerate(slot_counts):
        clock = args.topology or args.machine
        cost = (None if args.machine == "wall"
                else HybridPhaseCost(clock, seed=args.seed + i))
        head, trunk = None, None
        if args.balanced_head or args.balanced_trunk:
            if args.topology:
                disp = TopologyDispatcher(args.topology,
                                          seed=args.seed + i, execute=True,
                                          keep_stats=False, tuner=tuner)
            elif args.machine == "wall":
                disp = HybridKernelDispatcher.threaded(4, keep_stats=False,
                                                       tuner=tuner)
            else:
                disp = HybridKernelDispatcher.virtual(
                    args.machine, seed=args.seed + i, execute=True,
                    keep_stats=False, tuner=tuner)
            dispatchers.append(disp)
            if args.balanced_trunk:
                trunk = BalancedTrunk.from_params(cfg, params, disp,
                                                  quant=args.trunk_quant)
            else:
                head = balanced_lm_head(cfg, params, disp)
        engines.append(ContinuousBatchingEngine(
            cfg, params, max_slots=n_slots, max_seq=max_seq,
            prefill_chunk=chunk, cost_model=cost, balanced_head=head,
            balanced_trunk=trunk))

    table = RatioTable(args.replicas, alpha=0.3)
    store = RatioStore(args.ratios) if args.ratios else None
    if store is not None and store.load_into(table):
        print(f"[serve] warm-started replica ratios from {args.ratios}")
    disp = InflightDispatcher(engines, table=table)

    requests = poisson_requests(
        args.requests, rate=args.rate, vocab_size=cfg.vocab_size,
        prompt_len=args.prompt_len, max_new_tokens=args.steps,
        seed=args.seed)
    routed = np.zeros(args.replicas, dtype=np.int64)
    t_wall = time.perf_counter()
    for r in requests:
        # Let in-flight work progress up to this arrival so per-phase
        # throughput feedback from earlier requests steers the routing of
        # later ones (open loop: arrivals never wait on service).
        while disp.has_work and disp.now < r.arrival_time:
            disp.step()
        i, _ = disp.submit(r)
        routed[i] += 1
    disp.run_until_idle()

    clock = "virtual" if args.machine != "wall" else "wall"
    report = LatencyReport.from_requests(
        requests, clock=clock,
        wall_duration=time.perf_counter() - t_wall)
    if registry is not None:
        report.publish(registry)
    print(f"[serve] {args.replicas} replica(s), slots={slot_counts}, "
          f"routed={routed.tolist()} ({clock} clock)")
    for line in report.lines():
        print(line)
    print(f"[serve] replica prefill ratios: "
          f"{np.round(disp.table.ratios(PREFILL), 3).tolist()}")
    print(f"[serve] replica decode  ratios: "
          f"{np.round(disp.table.ratios(DECODE), 3).tolist()}")
    if args.machine != "wall":
        core = engines[0].cost_model.table
        print(f"[serve] core ratio spread (replica 0): "
              f"prefill={core.ratios(PREFILL).max() / core.ratios(PREFILL).min():.2f}x "
              f"decode={core.ratios(DECODE).max() / core.ratios(DECODE).min():.2f}x")
        print(f"[serve] decode achieved-bandwidth fraction (replica 0): "
              f"{engines[0].cost_model.achieved_bandwidth_fraction():.2f}")
    if args.balanced_head and args.machine != "wall":
        d0 = dispatchers[0]
        kt = d0.table.ratios(GEMV_ISA)
        print(f"[serve] balanced-head kernel table (replica 0): "
              f"membw spread={kt.max() / kt.min():.2f}x "
              f"achieved_bw_frac={d0.achieved_bandwidth_fraction():.2f}")
    if args.topology:
        d0 = dispatchers[0]
        print(f"[serve] topology {args.topology}: "
              f"{d0.topology.n_sockets} socket(s), "
              f"aggregate {d0.topology.aggregate_bandwidth / 1e9:.1f} GB/s")
        if engines[0].placement is not None:
            for line in engines[0].placement.lines():
                print(line)
        for kind in TRUNK_KINDS:
            key = kernel_key(GEMV_ISA, kind)
            if key in d0.table.keys():
                print(f"[serve] socket split {key}: "
                      f"{np.round(d0.socket_ratios(key), 3).tolist()}")
        fracs = [d0.achieved_bandwidth_fraction(socket=s)
                 for s in range(d0.topology.n_sockets)]
        print(f"[serve] per-socket decode achieved_bw_frac (replica 0): "
              f"{[round(f, 2) for f in fracs]}")
        print(f"[serve] aggregate decode achieved_bw_frac (replica 0): "
              f"{d0.achieved_bandwidth_fraction():.2f}")
    elif args.balanced_trunk and args.machine != "wall":
        d0 = dispatchers[0]
        for kind in TRUNK_KINDS:
            key = kernel_key(GEMV_ISA, kind)
            if key in d0.table.keys():
                kt = d0.table.ratios(key)
                print(f"[serve] trunk {key} spread: "
                      f"{kt.max() / kt.min():.2f}x")
        print(f"[serve] trunk decode achieved_bw_frac (replica 0): "
              f"{d0.achieved_bandwidth_fraction():.2f}")
    sample = requests[0].tokens
    print("[serve] sample:", sample[-min(16, args.steps):].tolist())
    if store is not None:
        store.save(table)
        print(f"[serve] saved replica ratios to {args.ratios}")
    if tuner_store is not None:
        tuner_store.save(tuner)
        print(f"[serve] saved kernel tuner tables to {args.tuner_cache}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
