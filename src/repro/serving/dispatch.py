"""In-flight request dispatch across replicas by per-phase throughput.

Replaces the seed's whole-batch barrier (``RoutedServer.serve_batch``):
requests are routed *individually* the moment they arrive, and every
replica keeps decoding while others prefill — the serving analogue of the
paper's proportional core dispatch, but with the ratio table keyed by
execution phase ("prefill" / "decode") because the two phases expose
different relative replica speeds (compute-bound vs memory-bound, paper
Fig. 4).

Routing is load-aware Eq. 3: a new request goes to the replica with the
smallest estimated backlog in ratio-normalized time::

    score_i = (pending_prefill_tokens_i + prompt_len) / pr_i^prefill
            + (running_i + 1) * expected_new / pr_i^decode

Feedback is iteration-level: each :meth:`step` runs one iteration on every
replica and reports (tokens, seconds) per phase through two
:class:`~repro.runtime.Balancer` instances over one shared
:class:`~repro.runtime.RatioTable`, with zero-work replicas masked out of
the EMA (``units=`` feedback).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import events as _ev
from repro.runtime import Plan, RatioTable, RegionStats, StatsSink

from .engine import ContinuousBatchingEngine
from .phases import DECODE, PREFILL, phase_balancers
from .request import Request
from .scheduler import IterationStats

__all__ = ["InflightDispatcher"]


class InflightDispatcher:
    """Route requests across :class:`ContinuousBatchingEngine` replicas by
    measured per-phase throughput; no batch barrier anywhere."""

    def __init__(self, engines: Sequence[ContinuousBatchingEngine], *,
                 table: Optional[RatioTable] = None, alpha: float = 0.3,
                 sink: Optional[StatsSink] = None):
        if not engines:
            raise ValueError("need at least one engine")
        self.engines = list(engines)
        n = len(self.engines)
        self.table = table or RatioTable(n, alpha=alpha)
        if self.table.n_workers != n:
            raise ValueError("table size does not match replica count")
        self._balancers = phase_balancers(self.table, sink)
        # windowed feedback accumulators: (units, seconds) per phase, held
        # until at least two replicas have measurements (see step())
        self._acc = {phase: (np.zeros(n, dtype=np.int64), np.zeros(n))
                     for phase in (PREFILL, DECODE)}
        # replica liveness: deactivated replicas are skipped by routing and
        # stepping and masked out of EMA feedback (see set_active)
        self.active = np.ones(n, dtype=bool)
        # requests that arrived while *every* replica was inactive; held
        # here and flushed the moment one reactivates (see submit)
        self.pending: List[Request] = []
        # latest emitted per-phase RegionStats — the child-telemetry probe
        # a recursive parent balancer snapshots (RegionStats.children)
        self.last_stats: Dict[str, RegionStats] = {}

    # ----------------------------------------------------------- liveness --
    def set_active(self, i: int, active: bool = True) -> None:
        """Mark replica ``i`` failed (or recovered).  Deactivation clears
        the replica's windowed feedback accumulators: a replica that shed
        or died mid-window has *partial* (units, seconds) sums that would
        otherwise ride into a later multi-replica report and EMA-drag its
        ratio via a stale ``units=`` measurement — the same
        absence-of-measurement rule :attr:`~repro.runtime.RegionStats.
        measured` applies to zero-count workers (its entries then sit at
        (0, 0.0) and the table's ``units > 0`` mask carries its ratio
        over unchanged)."""
        if not 0 <= i < len(self.engines):
            raise IndexError(f"replica {i} out of range")
        self.active[i] = bool(active)
        if not active:
            if _ev.TRACER is not None:
                for phase in self._acc:
                    _ev.emit_write(self, f"acc[{phase}]",
                                   where="InflightDispatcher.set_active")
            for acc_u, acc_t in self._acc.values():
                acc_u[i] = 0
                acc_t[i] = 0.0
        elif self.pending:
            # first replica back: flush requests deferred while every
            # replica was down (arrival order preserved)
            pending, self.pending = self.pending, []
            for r in pending:
                self.submit(r)

    # ------------------------------------------------------------ routing --
    def route(self, request: Request) -> int:
        """Pick the replica with the least ratio-normalized backlog, among
        those whose cache can serve the whole request (replicas may be
        heterogeneous in ``max_seq`` too); when no cache fits
        prompt + max_new_tokens, fall back to replicas that at least hold
        the prompt (generation then ends early at the cache edge, the
        engine's LENGTH semantics)."""
        if not self.active.any():
            raise ValueError("no active replica to route to")
        need = request.prompt_len + request.max_new_tokens
        full = [e.max_seq >= need and self.active[i]
                for i, e in enumerate(self.engines)]
        if not any(full):
            full = [e.max_seq >= request.prompt_len + 1 and self.active[i]
                    for i, e in enumerate(self.engines)]
        if not any(full):
            raise ValueError(
                f"prompt of {request.prompt_len} tokens fits no replica "
                f"(max_seq: {[e.max_seq for e in self.engines]})")
        pf = np.maximum(self.table.ratios(PREFILL), 1e-9)
        dec = np.maximum(self.table.ratios(DECODE), 1e-9)
        scores = []
        for i, e in enumerate(self.engines):
            if not full[i]:
                scores.append(np.inf)
                continue
            prefill_backlog = (e.pending_prefill_tokens + request.prompt_len) / pf[i]
            # every outstanding request will decode, whatever lifecycle
            # stage it is in right now (waiting, prefilling, or running)
            outstanding = e.n_running + e.n_prefilling + e.n_waiting + 1
            decode_backlog = outstanding * request.max_new_tokens / dec[i]
            scores.append(prefill_backlog + decode_backlog)
        return int(np.argmin(scores))  # ties -> lowest replica id

    def submit(self, request: Request) -> tuple:
        """Route and enqueue; returns (replica index, request id).

        A request arriving while *every* replica is inactive (a node-wide
        failure or capacity window) is deferred, not crashed on: it waits
        in :attr:`pending` and is resubmitted by the first
        :meth:`set_active` reactivation.  Returns ``(-1, None)`` for a
        deferred request.  :meth:`route` keeps its raise — calling it
        directly with no active replica is a programming error."""
        if not self.active.any():
            self.pending.append(request)
            return -1, None
        i = self.route(request)
        rid = self.engines[i].submit(request)
        return i, rid

    # ------------------------------------------------------------ probes --
    @property
    def pending_prefill_tokens(self) -> int:
        """Aggregate prompt tokens queued across active replicas (the
        fleet router's prefill-pressure signal for this dispatcher)."""
        return sum(e.pending_prefill_tokens
                   for i, e in enumerate(self.engines) if self.active[i])

    @property
    def queue_depth(self) -> int:
        """Outstanding (waiting + prefilling + running) requests across
        active replicas."""
        return sum(e.queue_depth
                   for i, e in enumerate(self.engines) if self.active[i])

    # ------------------------------------------------------------ driving --
    @property
    def has_work(self) -> bool:
        # pending requests are deliberately excluded: they only exist while
        # every replica is inactive, when stepping cannot make progress —
        # the driver must apply the recovery event (set_active) to proceed
        return any(e.has_work
                   for i, e in enumerate(self.engines) if self.active[i])

    @property
    def now(self) -> float:
        """Dispatcher clock = slowest replica clock (replicas run
        concurrently; the fleet is done when the last one is)."""
        return max(e.now for e in self.engines)

    def step(self) -> List[IterationStats]:
        """One iteration on every replica + per-phase ratio feedback.

        Feedback is *windowed*: per-phase (tokens, seconds) accumulate
        across iterations and are reported once at least two replicas have
        measurements — a single replica running alone carries no relative
        information (the table would carry it over anyway), but its solo
        rounds still count toward the next multi-replica comparison, so
        ratios keep learning even when replicas never work in the same
        iteration.  Deactivated replicas are not stepped and contribute
        empty stats (units 0 -> masked out of the update)."""
        tracing = _ev.TRACER is not None
        stats = []
        for i, e in enumerate(self.engines):
            if not self.active[i]:
                stats.append(IterationStats(now=e.now))
                continue
            if tracing:
                # replica scope: the engine's spans (and everything its
                # cost model dispatches) land in this replica's process
                _ev.push_scope(f"replica{i}")
                try:
                    stats.append(e.step())
                finally:
                    _ev.pop_scope()
            else:
                stats.append(e.step())
        for phase, units, times in (
            (PREFILL,
             np.array([s.prefill_tokens for s in stats], dtype=np.int64),
             np.array([s.prefill_seconds for s in stats])),
            (DECODE,
             np.array([s.decode_tokens for s in stats], dtype=np.int64),
             np.array([s.decode_seconds for s in stats])),
        ):
            acc_u, acc_t = self._acc[phase]
            if _ev.TRACER is not None:
                # the windowed accumulators are the dispatcher's shared
                # mutable state: a failure monitor calling set_active()
                # concurrently with step() would race this read-modify-write
                _ev.emit_read(self, f"acc[{phase}]",
                              where="InflightDispatcher.step")
                _ev.emit_write(self, f"acc[{phase}]",
                               where="InflightDispatcher.step")
            acc_u += units
            acc_t += times
            if (np.count_nonzero(acc_u) >= 2
                    or (len(self.engines) == 1 and acc_u.any())):
                self.last_stats[phase] = self._balancers[phase].report(
                    Plan(counts=acc_u.copy(), key=phase), acc_t.copy())
                acc_u[:] = 0
                acc_t[:] = 0.0
        return stats

    def run_until_idle(self, max_steps: Optional[int] = None
                       ) -> List[List[IterationStats]]:
        out = []
        while self.has_work:
            if max_steps is not None and len(out) >= max_steps:
                break
            out.append(self.step())
        return out

    def poll_finished(self) -> List[Request]:
        done: List[Request] = []
        for e in self.engines:
            done.extend(e.poll_finished())
        done.sort(key=lambda r: (r.finish_time, r.arrival_time))
        return done
