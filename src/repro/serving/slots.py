"""Slot-based KV/SSM cache manager for the continuous-batching engine.

The decode batch is *persistent*: one pytree of model state with
``n_slots`` batch rows (see :func:`repro.models.init_slot_state` — KV cache
indices are per-row so every slot advances independently).  Requests are
prefilled on a detached batch-1 state and then *adopted* into a free slot
(a jitted per-row scatter); finished requests release their slot, which is
immediately reusable.  The jitted decode step therefore always sees the
same static shape — admission and eviction never trigger recompilation.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_slot_state
from repro.models.attention import KVCache

__all__ = ["SlotCacheManager"]


@functools.partial(jax.jit, donate_argnums=(0,))
def _adopt(big, small, slot):
    """Scatter a batch-1 state pytree into row ``slot`` of the slot-batched
    state.  KV-cache ``idx`` leaves are (n_rep,) in ``small`` (scalar per
    repeat) but (n_rep, n_slots) in ``big``; every other leaf carries the
    batch axis at position 1."""

    def put(b, s):
        if s.ndim == b.ndim:
            return b.at[:, slot].set(s[:, 0])
        return b.at[:, slot].set(s)

    return jax.tree.map(put, big, small)


@functools.partial(jax.jit, donate_argnums=(0,))
def _reset_slot(big, slot):
    """Zero a released slot's cache index.  While the slot stays free its
    idx still drifts (+1 per decode step, like every row); that is
    harmless — cache writes clamp at the buffer edge and the next adopt
    overwrites the whole row — but resetting here keeps the drift from
    accumulating across occupancies."""

    def fix(leaf):
        if isinstance(leaf, KVCache):
            return KVCache(k=leaf.k, v=leaf.v,
                           idx=leaf.idx.at[:, slot].set(0))
        return leaf

    return jax.tree.map(fix, big, is_leaf=lambda x: isinstance(x, KVCache))


class SlotCacheManager:
    """Owns the persistent decode-batch state plus per-slot host mirrors.

    ``pos[slot]`` is the number of valid context tokens in the slot (the
    rope/cache offset of the *next* token); ``last_token[slot]`` is the most
    recently sampled token, i.e. the next decode-step input.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, max_seq: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.state = init_slot_state(cfg, n_slots, max_seq)
        self.pos = np.zeros(n_slots, dtype=np.int32)
        self.last_token = np.zeros(n_slots, dtype=np.int32)
        self._free = list(range(n_slots - 1, -1, -1))  # pop() -> lowest id

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - self.n_free

    def allocate(self) -> Optional[int]:
        """Reserve a slot (lowest id first, deterministic); None when full."""
        if not self._free:
            return None
        return self._free.pop()

    def adopt(self, slot: int, small_state, n_context: int,
              last_token: int) -> None:
        """Install a prefilled batch-1 state into ``slot`` and arm the row
        for decoding (``n_context`` prompt tokens consumed, ``last_token``
        already sampled from the prefill logits)."""
        if not 0 <= slot < self.n_slots:
            raise IndexError(f"slot {slot} out of range")
        if n_context + 1 > self.max_seq:
            raise ValueError(
                f"context {n_context} leaves no room in max_seq {self.max_seq}")
        self.state = _adopt(self.state, small_state,
                            jnp.asarray(slot, jnp.int32))
        self.pos[slot] = n_context
        self.last_token[slot] = last_token

    def release(self, slot: int) -> None:
        """Return a slot to the free list (its cache rows become dead)."""
        if slot in self._free:
            raise ValueError(f"slot {slot} is already free")
        self.state = _reset_slot(self.state, jnp.asarray(slot, jnp.int32))
        self.pos[slot] = 0
        self.last_token[slot] = 0
        self._free.append(slot)
        self._free.sort(reverse=True)  # keep lowest-id-first determinism
