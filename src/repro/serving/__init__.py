"""Serving substrate: request-level continuous batching on the unified
Balancer, with per-phase ("prefill"/"decode") ratio learning.

Layering::

    Request / RequestState / FinishReason      (request.py)
        |
    IterationScheduler  +  SlotCacheManager    (scheduler.py, slots.py)
        |
    ContinuousBatchingEngine                   (engine.py)
        |
    InflightDispatcher  --- per-phase RatioTable ---  HybridPhaseCost
    (replica routing, dispatch.py)                    (core dispatch, phases.py)

``ServeEngine`` / ``RoutedServer`` remain as the seed-era whole-batch API;
``RoutedServer.serve_batch`` now executes through the new engine.
"""

from .engine import (
    ContinuousBatchingEngine,
    GenerationResult,
    RoutedServer,
    ServeEngine,
)
from .dispatch import InflightDispatcher
from .phases import (
    DECODE,
    HybridPhaseCost,
    LinearPhaseCost,
    PhaseCostModel,
    PHASE_ISA,
    PREFILL,
    TRUNK_KINDS,
    phase_kernel_key,
)
from .metrics import LatencyReport, percentiles, slo_met
from .request import FinishReason, Request, RequestState
from .scheduler import IterationScheduler, IterationStats, PrefillChunk
from .slots import SlotCacheManager
from .traffic import poisson_requests

__all__ = [
    "ServeEngine",
    "RoutedServer",
    "GenerationResult",
    "ContinuousBatchingEngine",
    "InflightDispatcher",
    "Request",
    "RequestState",
    "FinishReason",
    "IterationScheduler",
    "IterationStats",
    "PrefillChunk",
    "SlotCacheManager",
    "LatencyReport",
    "percentiles",
    "slo_met",
    "poisson_requests",
    "PREFILL",
    "DECODE",
    "PHASE_ISA",
    "TRUNK_KINDS",
    "phase_kernel_key",
    "PhaseCostModel",
    "HybridPhaseCost",
    "LinearPhaseCost",
]
