"""Serving substrate: batched engine + proportional replica routing."""

from .engine import ServeEngine, RoutedServer, GenerationResult

__all__ = ["ServeEngine", "RoutedServer", "GenerationResult"]
