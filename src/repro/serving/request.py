"""Request-level serving primitives.

A :class:`Request` is one generation job moving through the
continuous-batching engine's lifecycle::

    WAITING --admit--> PREFILL --last chunk--> RUNNING --finish--> FINISHED
                 (slot allocated)     (joins the persistent decode batch)

Timestamps are recorded in the engine's clock domain (wall seconds, or
virtual seconds when a phase cost model drives the clock), so latency
metrics (TTFT / TPOT) are deterministic under the simulator.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

__all__ = ["RequestState", "FinishReason", "Request"]


class RequestState(enum.Enum):
    """Lifecycle of a request inside the engine."""

    WAITING = "waiting"    # submitted, not yet admitted (queue)
    PREFILL = "prefill"    # slot reserved; prompt being consumed (chunked)
    RUNNING = "running"    # in the persistent decode batch
    FINISHED = "finished"  # left the engine; slot released


class FinishReason(enum.Enum):
    LENGTH = "length"      # hit max_new_tokens
    STOP = "stop"          # sampled the stop token
    ABORTED = "aborted"    # cancelled / engine shut down before completion
    SHED = "shed"          # rejected by admission control, never executed


@dataclass(eq=False)  # identity semantics: prompts are arrays, ids are per-engine
class Request:
    """One generation request plus its per-request runtime record.

    The engine mutates the bookkeeping fields; callers create requests with
    just ``prompt`` / ``max_new_tokens`` (and optionally ``arrival_time``
    for open-loop traffic replay).
    """

    prompt: np.ndarray                   # (S0,) int32 token ids
    max_new_tokens: int
    request_id: int = -1                 # assigned by the engine at submit()
    arrival_time: float = 0.0            # engine-clock arrival (open loop)
    stop_token: Optional[int] = None
    deadline: Optional[float] = None     # absolute clock bound for admission
    degraded: bool = False               # max_new_tokens shrunk by admission

    # --- engine bookkeeping -------------------------------------------------
    state: RequestState = RequestState.WAITING
    finish_reason: Optional[FinishReason] = None
    slot: Optional[int] = None           # decode-batch row while admitted
    prefill_done: int = 0                # prompt tokens consumed so far
    generated: List[int] = field(default_factory=list)

    # --- latency record (engine clock) --------------------------------------
    admit_time: Optional[float] = None   # prefill started
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    def __post_init__(self) -> None:
        self.prompt = np.asarray(self.prompt, dtype=np.int32).reshape(-1)
        if len(self.prompt) == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def n_generated(self) -> int:
        return len(self.generated)

    @property
    def tokens(self) -> np.ndarray:
        """prompt + generated tokens, the shape callers consume."""
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, dtype=np.int32)])

    # --- serving metrics ----------------------------------------------------
    @property
    def ttft(self) -> Optional[float]:
        """Time to first token: arrival -> first generated token."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def tpot(self) -> Optional[float]:
        """Time per output token over the decode phase (excludes TTFT).
        ``None`` for single-token completions — with no decode interval
        there is no sample, and a 0.0 placeholder would drag TPOT
        percentiles toward zero."""
        if self.finish_time is None or self.first_token_time is None:
            return None
        if self.n_generated <= 1:
            return None
        return ((self.finish_time - self.first_token_time)
                / (self.n_generated - 1))
