"""Batched serving engine: prefill + decode with KV/SSM state, plus the
paper's dynamic replica routing.

``ServeEngine`` drives one model replica (jit'd prefill + decode-step).
``RoutedServer`` composes several replicas behind the paper's Eq.-3 router
(:class:`repro.runtime.ReplicaRouter` driven through a
:class:`repro.runtime.Balancer`): each batch of requests is split across
replicas proportionally to their measured decode throughput — the serving
analogue of proportional core dispatch (useful when replicas live on
heterogeneous pods or are co-tenanted).  Splits are clamped to per-replica
batch capacity with the overflow redistributed to replicas with headroom.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import forward, init_state
from repro.runtime import (
    Balancer,
    DeviceRuntime,
    Plan,
    ReplicaRouter,
    StatsSink,
    clamp_to_capacity,
)


@dataclass
class GenerationResult:
    tokens: np.ndarray        # (B, prompt+new)
    prefill_seconds: float
    decode_seconds: float
    steps: int

    @property
    def tokens_per_second(self) -> float:
        new = self.tokens.shape[0] * self.steps
        return new / max(self.decode_seconds, 1e-9)


class ServeEngine:
    """One replica: static-shape batched greedy decoding."""

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int,
                 max_seq: int, donate_state: bool = True):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq

        @jax.jit
        def _prefill(params, tokens, state):
            out = forward(cfg, params, tokens, state=state, pos_offset=0,
                          logits_mode="last")
            return out.logits[:, -1, :], out.state

        donate = (2,) if donate_state else ()

        @functools.partial(jax.jit, donate_argnums=donate)
        def _decode(params, tok, state, offset):
            out = forward(cfg, params, tok, state=state, pos_offset=offset)
            return out.logits[:, -1, :], out.state

        self._prefill = _prefill
        self._decode = _decode

    def fresh_state(self):
        return init_state(self.cfg, self.batch_size, self.max_seq)

    def generate(self, prompts: jax.Array, n_steps: int,
                 sampler: Optional[Callable] = None) -> GenerationResult:
        """prompts: (B, S0) int32.  Greedy unless ``sampler(logits)->tok``."""
        b, s0 = prompts.shape
        assert b == self.batch_size
        state = self.fresh_state()

        t0 = time.perf_counter()
        logits, state = self._prefill(self.params, prompts, state)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        pick = sampler or (lambda lg: jnp.argmax(lg, -1)[:, None])
        toks = [np.asarray(prompts)]
        tok = pick(logits)
        t1 = time.perf_counter()
        for i in range(n_steps):
            toks.append(np.asarray(tok))
            logits, state = self._decode(self.params, tok, state,
                                         jnp.asarray(s0 + i, jnp.int32))
            tok = pick(logits)
        tok.block_until_ready()
        t_decode = time.perf_counter() - t1
        return GenerationResult(
            tokens=np.concatenate(toks, axis=1),
            prefill_seconds=t_prefill,
            decode_seconds=t_decode,
            steps=n_steps,
        )


class RoutedServer:
    """Paper Eq. 3 at the serving layer: proportional request routing
    across replicas with measured-throughput feedback."""

    def __init__(self, engines: Sequence[ServeEngine],
                 sink: Optional[StatsSink] = None):
        self.engines = list(engines)
        self.runtime = DeviceRuntime(n_slices=len(engines), alpha=0.3)
        self.router = ReplicaRouter(self.runtime)
        # keep_stats=False: a serving process is long-lived; per-batch
        # telemetry goes to the sink, not an unbounded list.
        self.balancer = Balancer(self.router, sink=sink, keep_stats=False)

    @property
    def capacities(self) -> np.ndarray:
        return np.array([e.batch_size for e in self.engines], dtype=np.int64)

    def serve_batch(self, prompts: np.ndarray, n_steps: int,
                    times_override: Optional[np.ndarray] = None):
        """Split ``prompts`` across replicas ∝ current ratios; run; feed
        times back.  ``times_override`` lets tests/benchmarks inject
        simulated heterogeneous replica speeds."""
        if len(prompts) == 0:
            return (np.zeros((0, prompts.shape[1] + n_steps),
                             dtype=prompts.dtype),
                    np.zeros(len(self.engines), dtype=np.int64),
                    np.zeros(len(self.engines)))
        # The proportional split can exceed a fast replica's static batch
        # size; clamp to capacity and hand the overflow to other replicas.
        planned = self.balancer.plan(len(prompts))
        counts = clamp_to_capacity(planned.counts, self.capacities)
        plan = Plan(counts=counts, key=planned.key)
        with self.balancer.balanced_region(plan=plan) as region:
            results, start = [], 0
            for i, (eng, c) in enumerate(zip(self.engines, counts)):
                if c == 0:
                    continue
                chunk = prompts[start:start + c]
                start += c
                pad = eng.batch_size - len(chunk)
                padded = np.pad(chunk, ((0, pad), (0, 0))) if pad else chunk
                with region.timed(i):
                    r = eng.generate(jnp.asarray(padded), n_steps)
                results.append(r.tokens[: len(chunk)])
            if times_override is not None:
                region.times[:] = np.asarray(times_override, dtype=np.float64)
        return np.concatenate(results, axis=0), counts, region.times
