"""Serving engines: request-level continuous batching plus the legacy
static-batch engine.

``ContinuousBatchingEngine`` is the serving core: a persistent decode
batch of ``max_slots`` rows (slot-based KV/SSM state, per-row cache
indices), an iteration-level scheduler that interleaves (optionally
chunked) prefill with running decode steps, and request
admission/eviction with no full-batch barrier.  Time comes either from
wall-clock measurement or from a per-phase hybrid-CPU cost model
(:class:`~repro.serving.phases.HybridPhaseCost`), which also drives the
paper's control loop with separate "prefill" / "decode" ratio keys.

``ServeEngine`` (static shapes, whole-batch generate) remains for
benchmarks and as the building block the compatibility layer is
constructed from.  ``RoutedServer.serve_batch`` is now a thin wrapper
over per-replica continuous-batching engines: it keeps the seed-era
signature (proportional split, capacity clamp, ``times_override``) while
executing through the new request path.  New callers should use
:class:`~repro.serving.dispatch.InflightDispatcher` instead, which routes
individual requests by measured per-phase replica throughput.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import events as _ev
from repro.models import forward, init_state
from repro.models.attention import KVCache
from repro.runtime import (
    Balancer,
    DeviceRuntime,
    Plan,
    ReplicaRouter,
    StatsSink,
    clamp_to_capacity,
)

from .phases import DECODE, PHASE_ISA, PREFILL, phase_kernel_key
from .request import FinishReason, Request, RequestState
from .scheduler import IterationScheduler, IterationStats
from .slots import SlotCacheManager


def _stack_lane_states(states):
    """Stack per-lane batch-1 states into one B-row state pytree.

    Every leaf carries the period-repeat axis first and the batch axis
    second, so generic leaves concatenate along axis 1; KV caches need the
    per-row index form — ``idx`` goes from (n_rep,) scalar-per-repeat to
    (n_rep, B), the slot-batched convention ``attn_fwd`` already supports
    (each lane appends at its own offset)."""

    def comb(*leaves):
        if isinstance(leaves[0], KVCache):
            return KVCache(
                k=jnp.concatenate([l.k for l in leaves], axis=1),
                v=jnp.concatenate([l.v for l in leaves], axis=1),
                idx=jnp.stack([l.idx for l in leaves], axis=1))
        return jnp.concatenate(leaves, axis=1)

    return jax.tree.map(comb, *states,
                        is_leaf=lambda x: isinstance(x, KVCache))


def _slice_lane_state(stacked, i: int):
    """Row ``i`` of a lane-stacked state, back in batch-1 form (KV ``idx``
    returns to its (n_rep,) scalar-per-repeat shape, so the row is adopt-
    and restack-compatible with states from :func:`init_state`)."""

    def pick(leaf):
        if isinstance(leaf, KVCache):
            return KVCache(k=leaf.k[:, i:i + 1], v=leaf.v[:, i:i + 1],
                           idx=leaf.idx[:, i])
        return leaf[:, i:i + 1]

    return jax.tree.map(pick, stacked,
                        is_leaf=lambda x: isinstance(x, KVCache))


@dataclass
class GenerationResult:
    tokens: np.ndarray        # (B, prompt+new) — B may include padding rows
    prefill_seconds: float
    decode_seconds: float
    steps: int
    n_requests: Optional[int] = None   # real (unpadded) request count

    @property
    def tokens_per_second(self) -> float:
        b = self.n_requests if self.n_requests is not None else self.tokens.shape[0]
        new = b * self.steps
        return new / max(self.decode_seconds, 1e-9)


class ServeEngine:
    """One replica: static-shape batched greedy decoding."""

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int,
                 max_seq: int, donate_state: bool = True):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq

        @jax.jit
        def _prefill(params, tokens, state):
            out = forward(cfg, params, tokens, state=state, pos_offset=0,
                          logits_mode="last")
            return out.logits[:, -1, :], out.state

        donate = (2,) if donate_state else ()

        @functools.partial(jax.jit, donate_argnums=donate)
        def _decode(params, tok, state, offset):
            out = forward(cfg, params, tok, state=state, pos_offset=offset)
            return out.logits[:, -1, :], out.state

        self._prefill = _prefill
        self._decode = _decode

    def fresh_state(self):
        return init_state(self.cfg, self.batch_size, self.max_seq)

    def generate(self, prompts: jax.Array, n_steps: int,
                 sampler: Optional[Callable] = None,
                 n_requests: Optional[int] = None) -> GenerationResult:
        """prompts: (B, S0) int32.  Greedy unless ``sampler(logits)->tok``.
        ``n_requests`` is the real request count when rows are padding."""
        b, s0 = prompts.shape
        assert b == self.batch_size
        state = self.fresh_state()

        t0 = time.perf_counter()
        logits, state = self._prefill(self.params, prompts, state)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        pick = sampler or (lambda lg: jnp.argmax(lg, -1)[:, None])
        toks = [np.asarray(prompts)]
        tok = pick(logits)
        t1 = time.perf_counter()
        for i in range(n_steps):
            toks.append(np.asarray(tok))
            logits, state = self._decode(self.params, tok, state,
                                         jnp.asarray(s0 + i, jnp.int32))
            tok = pick(logits)
        tok.block_until_ready()
        t_decode = time.perf_counter() - t1
        return GenerationResult(
            tokens=np.concatenate(toks, axis=1),
            prefill_seconds=t_prefill,
            decode_seconds=t_decode,
            steps=n_steps,
            n_requests=n_requests,
        )


class ContinuousBatchingEngine:
    """Request-level engine: persistent decode batch + interleaved prefill.

    One :meth:`step` is one scheduler iteration:

    1. *(idle fast-forward)* with nothing admitted and nothing running, the
       clock jumps to the next arrival (open-loop traffic replay).
    2. *Prefill lane*: at most one prompt chunk (``prefill_chunk`` tokens,
       or the whole prompt) runs on a detached batch-1 state; on the last
       chunk the first token is sampled and the state is adopted into a
       free decode slot.
    3. *Decode lane*: one greedy step for the whole persistent batch;
       finished requests release their slots immediately (reused by the
       next admission — no barrier, late requests join mid-flight).

    ``cost_model`` (see :class:`~repro.serving.phases.PhaseCostModel`)
    replaces wall timing with deterministic virtual seconds; the jitted
    model still produces the real tokens.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int,
                 max_seq: int, prefill_chunk: Optional[int] = None,
                 prefill_lanes: int = 1,
                 sampler: Optional[Callable] = None, cost_model=None,
                 balanced_head=None, balanced_trunk=None, topology=None,
                 donate_state: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.cost_model = cost_model
        if prefill_lanes < 1:
            raise ValueError("prefill_lanes must be >= 1")
        self.prefill_lanes = prefill_lanes
        # Optional hybrid kernel dispatch of the LM head (see
        # models.balanced_lm_head): the jitted trunk stops before the head
        # and the decode-step Fp32-Int4-Fp32 GEMV runs as balanced per-core
        # Pallas shards with per-phase ISA table keys.  ``balanced_trunk``
        # (a models.BalancedTrunk) extends the same loop to *every*
        # projection of the step — q/k/v/o and MLP up/gate/down run as
        # per-core shards through the io_callback bridge, eagerly when
        # the trunk disallows tracing, or (mode="compiled") as offset-
        # driven single-grid lowerings with zero host callbacks — under
        # (phase ISA x layer kind) table keys; its optional head replaces
        # ``balanced_head``.
        if balanced_head is not None and balanced_trunk is not None \
                and balanced_trunk.head is not None:
            raise ValueError(
                "pass either balanced_head or a balanced_trunk with a head, "
                "not both")
        self.balanced_trunk = balanced_trunk
        self.balanced_head = balanced_head
        # NUMA wiring: a balanced trunk bound to a repro.topology.
        # TopologyDispatcher is adopted automatically — its weights are
        # placed (column ranges pinned to the socket that streams them)
        # and the topology is exposed for telemetry.  Passing ``topology=``
        # explicitly asserts which machine the trunk must be balanced over.
        self.topology, self.placement = self._adopt_topology(
            balanced_trunk, topology)
        apply_head = (balanced_head is None
                      and (balanced_trunk is None
                           or balanced_trunk.head is None))
        self.manager = SlotCacheManager(cfg, max_slots, max_seq)
        self.scheduler = IterationScheduler(prefill_chunk,
                                            prefill_lanes=prefill_lanes)
        # soft concurrency cap (<= max_slots): admission headroom only, so
        # a capacity event can shrink the effective batch without touching
        # allocated slot state or recompiling (shapes stay max_slots)
        self.slot_budget = max_slots
        self.now = 0.0
        self.finished: List[Request] = []
        self._running: List[Request] = []
        # One prefill lane -> one partial state.  The fresh template is
        # allocated once and reused for every admission (_prefill never
        # donates its state argument, so the template stays intact).
        self._fresh_prefill_state = init_state(cfg, 1, max_seq)
        self._partial = None           # in-flight batch-1 prefill state
        self._partials = {}            # request_id -> state (multi-lane)
        self._next_id = 0
        # (B,) greedy rows by default; a sampler sees (B, V) logits.
        self._pick = sampler or (lambda lg: jnp.argmax(lg, -1))

        trunk = balanced_trunk
        # Tracing-disallowed fallback: a trunk built with jit_bridge=False
        # runs its shard dispatches eagerly, so the step functions must
        # not be jitted (the io_callback bridge would otherwise trace).
        use_jit = trunk is None or trunk.jit_bridge
        # Compiled trunk: the step functions take the device offset
        # snapshot as an extra argument, apply the balanced head in-graph,
        # and return the traced cost tape as an extra output — zero host
        # callbacks inside the step; ratio feedback + offset refresh run
        # between steps (see repro.kernels.compiled).
        compiled = trunk is not None and getattr(trunk, "mode",
                                                 None) == "compiled"
        self._compiled_trunk = compiled

        donate = (2,) if donate_state and use_jit else ()

        if compiled:
            def _head_in_graph(logits, phase, offsets):
                if trunk.head is None:
                    return logits
                return trunk.apply_head(logits, isa=PHASE_ISA[phase],
                                        offsets=offsets)

            def _prefill(params, tokens, state, offset, offsets):
                tape = trunk.compiled_tape_begin()
                out = forward(cfg, params, tokens, state=state,
                              pos_offset=offset, logits_mode="last",
                              apply_head=apply_head, trunk=trunk,
                              trunk_isa=PHASE_ISA[PREFILL],
                              trunk_offsets=offsets)
                logits = _head_in_graph(out.logits[:, -1, :], PREFILL,
                                        offsets)
                return logits, out.state, trunk.compiled_tape_end(tape)

            def _decode(params, tok, state, pos, offsets):
                tape = trunk.compiled_tape_begin()
                out = forward(cfg, params, tok, state=state, pos_offset=pos,
                              apply_head=apply_head, trunk=trunk,
                              trunk_isa=PHASE_ISA[DECODE],
                              trunk_offsets=offsets)
                logits = _head_in_graph(out.logits[:, -1, :], DECODE,
                                        offsets)
                return logits, out.state, trunk.compiled_tape_end(tape)

            def _prefill_lanes_fn(params, tokens, states, offsets, snap):
                tape = trunk.compiled_tape_begin()
                stacked = _stack_lane_states(states)
                out = forward(cfg, params, tokens, state=stacked,
                              pos_offset=offsets, logits_mode="last",
                              apply_head=apply_head, trunk=trunk,
                              trunk_isa=PHASE_ISA[PREFILL],
                              trunk_offsets=snap)
                rows = [_slice_lane_state(out.state, i)
                        for i in range(len(states))]
                logits = _head_in_graph(out.logits[:, -1, :], PREFILL, snap)
                return logits, rows, trunk.compiled_tape_end(tape)
        else:
            def _prefill(params, tokens, state, offset):
                out = forward(cfg, params, tokens, state=state,
                              pos_offset=offset, logits_mode="last",
                              apply_head=apply_head,
                              trunk=trunk, trunk_isa=PHASE_ISA[PREFILL])
                return out.logits[:, -1, :], out.state

            def _decode(params, tok, state, pos):
                out = forward(cfg, params, tok, state=state, pos_offset=pos,
                              apply_head=apply_head,
                              trunk=trunk, trunk_isa=PHASE_ISA[DECODE])
                return out.logits[:, -1, :], out.state

            def _prefill_lanes_fn(params, tokens, states, offsets):
                # One batched trunk call over all active lanes: per-row
                # cache offsets (each lane appends at its own position),
                # then the rows split back into batch-1 partial states.
                stacked = _stack_lane_states(states)
                out = forward(cfg, params, tokens, state=stacked,
                              pos_offset=offsets, logits_mode="last",
                              apply_head=apply_head, trunk=trunk,
                              trunk_isa=PHASE_ISA[PREFILL])
                rows = [_slice_lane_state(out.state, i)
                        for i in range(len(states))]
                return out.logits[:, -1, :], rows

        if use_jit:
            _prefill = jax.jit(_prefill)
            _prefill_lanes_fn = jax.jit(_prefill_lanes_fn)
            _decode = functools.partial(jax.jit, donate_argnums=donate)(_decode)

        self._prefill = _prefill
        self._prefill_lanes = _prefill_lanes_fn
        self._decode = _decode
        # Initial offset snapshot (compiled mode): planned from whatever
        # the ratio tables currently hold, refreshed after every step.
        self._offsets = trunk.compiled_refresh() if compiled else None

    @staticmethod
    def _adopt_topology(trunk, topology):
        """Resolve the engine's machine topology from the balanced trunk's
        dispatcher (placing the trunk's weights NUMA-aware when the
        dispatcher is socket-local) and validate an explicit ``topology=``
        against it.  Returns (topology, TrunkPlacement) — (None, None)
        for flat dispatch."""
        from repro.topology import TopologyDispatcher, place_trunk

        disp = getattr(trunk, "dispatcher", None)
        if not isinstance(disp, TopologyDispatcher):
            if topology is not None:
                raise ValueError(
                    "topology= requires a balanced_trunk bound to a "
                    "repro.topology.TopologyDispatcher (the trunk decides "
                    "where its weights execute)")
            return None, None
        adopted = disp.topology
        if topology is not None:
            name = topology if isinstance(topology, str) else topology.name
            if (topology is not adopted and name != adopted.name):
                raise ValueError(
                    f"topology= names {name!r} but the balanced trunk is "
                    f"balanced over {adopted.name!r}")
        placement = place_trunk(trunk) if disp.socket_local else None
        return adopted, placement

    def _head(self, hidden: jax.Array, phase: str) -> jax.Array:
        """Apply the (possibly balanced) LM head to (B, d) hidden states."""
        if self._compiled_trunk and self.balanced_head is None:
            # Compiled trunk: its head (if any) already ran in-graph.
            return hidden
        if self.balanced_head is not None or (
                self.balanced_trunk is not None
                and self.balanced_trunk.head is not None):
            # The trunk step is dispatched asynchronously and its ordered
            # io_callbacks run on a jax runtime thread; the eager balanced
            # head launches its own shard programs from this thread.  On
            # the CPU client the two can starve each other out of
            # execution threads (the head's program holds one while
            # data-waiting on ``hidden``, the callback's inner shards
            # can't get one, the trunk can't finish without the callback)
            # — so drain the in-flight step before dispatching host work.
            jax.block_until_ready(hidden)
        if self.balanced_head is not None:
            return self.balanced_head(hidden, isa=PHASE_ISA[phase])
        if self.balanced_trunk is not None and self.balanced_trunk.head is not None:
            return self.balanced_trunk.apply_head(
                hidden, isa=PHASE_ISA[phase])
        return hidden  # jitted trunk already produced logits

    # ------------------------------------------------------------- intake --
    def submit(self, request: Request) -> int:
        """Queue a request; returns its engine-assigned id."""
        if request.prompt_len + 1 > self.max_seq:
            raise ValueError(
                f"prompt of {request.prompt_len} tokens cannot decode within "
                f"max_seq={self.max_seq}")
        request.request_id = self._next_id
        self._next_id += 1
        self.scheduler.submit(request)
        return request.request_id

    def set_slot_budget(self, budget: int) -> int:
        """Re-plan the soft concurrency cap (a capacity event fired):
        admission stops above the budget while already-admitted requests
        run to completion — no slot state is evicted and no shape changes,
        so nothing retraces.  Clamped to ``[1, max_slots]`` (budget 0 with
        waiting work would wedge ``run_until_idle``; full drain is the
        dispatcher's ``set_active`` job).  Returns the applied budget."""
        self.slot_budget = int(np.clip(budget, 1, self.max_slots))
        return self.slot_budget

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work or bool(self._running)

    @property
    def n_running(self) -> int:
        return len(self._running)

    @property
    def n_waiting(self) -> int:
        return self.scheduler.n_waiting()

    @property
    def n_prefilling(self) -> int:
        return len(self.scheduler.lanes)

    @property
    def pending_prefill_tokens(self) -> int:
        """Prompt tokens queued ahead of a newly routed request (the
        dispatcher's prefill-pressure signal)."""
        pending = sum(r.prompt_len for r in self.scheduler.waiting)
        pending += sum(r.prompt_len - r.prefill_done
                       for r in self.scheduler.lanes)
        return pending

    @property
    def queue_depth(self) -> int:
        """Outstanding requests at every pre-finish stage (waiting +
        prefilling + running) — the admission controller's load probe."""
        return self.n_running + self.n_prefilling + self.n_waiting

    def outstanding(self) -> List[Request]:
        """Every request currently owned by the engine (queue, prefill
        lane(s), decode batch) — what a failing node must drain."""
        out = list(self.scheduler.waiting)
        out.extend(self.scheduler.lanes)
        out.extend(self._running)
        return out

    def steal_waiting(self) -> List[Request]:
        """Remove and return all still-WAITING requests (they never
        executed, so they can be resubmitted elsewhere verbatim — the
        retry-able half of a node drain; admitted requests have cache
        state here and must be aborted instead)."""
        out = list(self.scheduler.waiting)
        self.scheduler.waiting.clear()
        return out

    def poll_finished(self) -> List[Request]:
        """Drain and return requests finished since the last poll."""
        out, self.finished = self.finished, []
        return out

    def abort(self, request: Request) -> bool:
        """Cancel a request at any pre-finish stage (queue, prefill lane,
        or decode batch), releasing whatever it holds.  Returns False when
        it already finished."""
        if request.state is RequestState.FINISHED:
            return False
        man, sched = self.manager, self.scheduler
        if request.state is RequestState.WAITING:
            try:
                sched.waiting.remove(request)
            except ValueError:
                raise ValueError("request is not queued in this engine")
        elif request.state is RequestState.PREFILL:
            sched.remove_lane(request)  # raises when not prefilling here
            self._partial = None
            self._partials.pop(request.request_id, None)
            man.release(request.slot)
            request.slot = None
        elif request.state is RequestState.RUNNING:
            if request not in self._running:
                raise ValueError("request is not running in this engine")
            self._running.remove(request)
            man.release(request.slot)
            request.slot = None
        request.state = RequestState.FINISHED
        request.finish_reason = FinishReason.ABORTED
        request.finish_time = self.now
        self.finished.append(request)
        return True

    # -------------------------------------------------------------- step ---
    def step(self) -> IterationStats:
        """Run one scheduler iteration; returns what it did (the per-phase
        feedback record)."""
        st = IterationStats()
        man, sched = self.manager, self.scheduler

        # Idle fast-forward: nothing to run until the next arrival.
        if (not self._running and not sched.lanes
                and sched.waiting and not sched.n_waiting(self.now)):
            self.now = max(self.now, sched.waiting[0].arrival_time)

        # admission headroom: free slots, clamped by the soft slot budget
        # (a capacity event may have shrunk the sustainable concurrency)
        budget_free = max(0, min(man.n_free,
                                 self.slot_budget - man.n_active))
        chunks = sched.next_prefill(self.now, budget_free)
        if chunks and self.prefill_lanes == 1:
            chunk = chunks[0]
            req = chunk.request
            if req.slot is None:  # newly admitted: reserve the slot now
                req.slot = man.allocate()
                req.state = RequestState.PREFILL
                req.admit_time = self.now
                self._partial = self._fresh_prefill_state
            tokens = jnp.asarray(
                req.prompt[chunk.start:chunk.start + chunk.length][None, :])
            t0 = time.perf_counter()
            if self._compiled_trunk:
                logits, small, recs = self._prefill(
                    self.params, tokens, self._partial,
                    jnp.asarray(chunk.start, jnp.int32), self._offsets)
            else:
                logits, small = self._prefill(
                    self.params, tokens, self._partial,
                    jnp.asarray(chunk.start, jnp.int32))
            tok = None
            if chunk.is_last:
                # head + sampling inside the timed window, matching the
                # decode lane — with a balanced head the host-side GEMV is
                # part of the step, so TTFT must include it
                tok = int(np.asarray(
                    self._pick(self._head(logits, PREFILL))).reshape(-1)[0])
            if self.cost_model is None:
                logits.block_until_ready()
                dt = time.perf_counter() - t0
            else:
                dt = self.cost_model.prefill_seconds(
                    chunk.length, ctx=chunk.start + chunk.length)
            if self._compiled_trunk:
                # Between-step feedback: replay the step's cost tape into
                # the ratio tables and refresh the offset snapshot.
                self._offsets = self.balanced_trunk.compiled_feedback(
                    jax.device_get(recs))
            req.prefill_done += chunk.length
            sched.prefill_advanced(chunk)
            if self.cost_model is not None:
                # span on the engine's virtual clock (wall-timed engines
                # stay untraced: their timestamps are not deterministic)
                _ev.emit_span("engine", PREFILL, self.now, dt, cat="engine",
                              args=lambda: {"tokens": int(chunk.length)})
            self.now += dt
            st.prefill_tokens = chunk.length
            st.prefill_seconds = dt
            if chunk.is_last:
                self._partial = None
                req.generated.append(tok)
                req.first_token_time = self.now
                man.adopt(req.slot, small, req.prompt_len, tok)
                req.state = RequestState.RUNNING
                self._running.append(req)
                st.admitted.append(req.request_id)
                self._maybe_finish(req, tok, st)
            else:
                self._partial = small
        elif chunks:
            self._step_prefill_lanes(chunks, st)

        if self._running:
            tok = jnp.asarray(man.last_token[:, None])
            pos = jnp.asarray(man.pos)
            t0 = time.perf_counter()
            if self._compiled_trunk:
                logits, man.state, recs = self._decode(
                    self.params, tok, man.state, pos, self._offsets)
            else:
                logits, man.state = self._decode(self.params, tok,
                                                 man.state, pos)
            next_tok = np.asarray(
                self._pick(self._head(logits, DECODE))).reshape(-1)
            if self.cost_model is None:
                dt = time.perf_counter() - t0
            else:
                dt = self.cost_model.decode_seconds(
                    len(self._running), ctx=int(man.pos.max()))
            if self._compiled_trunk:
                self._offsets = self.balanced_trunk.compiled_feedback(
                    jax.device_get(recs))
            if self.cost_model is not None:
                _ev.emit_span(
                    "engine", DECODE, self.now, dt, cat="engine",
                    args=lambda: {"batch": len(self._running)})
            self.now += dt
            st.decode_tokens = len(self._running)
            st.decode_seconds = dt
            for req in list(self._running):
                t = int(next_tok[req.slot])
                req.generated.append(t)
                man.last_token[req.slot] = t
                man.pos[req.slot] += 1
                self._maybe_finish(req, t, st)

        st.n_running = len(self._running)
        st.n_waiting = self.scheduler.n_waiting()
        st.now = self.now
        if self.cost_model is not None:
            _ev.emit_counter("queue", self.now,
                             lambda: {"depth": float(self.queue_depth)})
        return st

    def _step_prefill_lanes(self, chunks, st: IterationStats) -> None:
        """Multi-lane prefill: all active lanes advance by one shared-length
        chunk through a *single* batched trunk call (per-row cache offsets),
        instead of one batch-1 call per prompt — the GEMM over B*L rows is
        what the balanced per-core split wants to see.  Token-identical to
        the batch-1 path: rows of a matmul are independent and each lane's
        cache rows are its own."""
        man, sched = self.manager, self.scheduler
        for c in chunks:
            req = c.request
            if req.slot is None:  # newly admitted: reserve the slot now
                req.slot = man.allocate()
                req.state = RequestState.PREFILL
                req.admit_time = self.now
                self._partials[req.request_id] = self._fresh_prefill_state
        length = chunks[0].length
        tokens = jnp.asarray(np.stack(
            [np.asarray(c.request.prompt[c.start:c.start + length])
             for c in chunks]))
        offsets = jnp.asarray(
            np.array([c.start for c in chunks], dtype=np.int32))
        states = [self._partials[c.request.request_id] for c in chunks]
        t0 = time.perf_counter()
        if self._compiled_trunk:
            logits, rows, recs = self._prefill_lanes(
                self.params, tokens, states, offsets, self._offsets)
        else:
            logits, rows = self._prefill_lanes(self.params, tokens, states,
                                               offsets)
        finishing = [i for i, c in enumerate(chunks) if c.is_last]
        picked = None
        if finishing:  # head + sampling inside the timed window (TTFT)
            picked = np.asarray(
                self._pick(self._head(logits, PREFILL))).reshape(-1)
        if self.cost_model is None:
            logits.block_until_ready()
            dt = time.perf_counter() - t0
        else:
            # one parallel region over all lanes' tokens: the batched call
            # is what splits across cores, so it is timed as one chunk
            dt = self.cost_model.prefill_seconds(
                length * len(chunks),
                ctx=max(c.start + length for c in chunks))
        if self._compiled_trunk:
            self._offsets = self.balanced_trunk.compiled_feedback(
                jax.device_get(recs))
        if self.cost_model is not None:
            _ev.emit_span(
                "engine", PREFILL, self.now, dt, cat="engine",
                args=lambda: {"tokens": int(length * len(chunks)),
                              "lanes": len(chunks)})
        self.now += dt
        st.prefill_tokens = length * len(chunks)
        st.prefill_seconds = dt
        for i, c in enumerate(chunks):
            req = c.request
            req.prefill_done += length
            sched.prefill_advanced(c)
            if c.is_last:
                tok = int(picked[i])
                self._partials.pop(req.request_id, None)
                req.generated.append(tok)
                req.first_token_time = self.now
                man.adopt(req.slot, rows[i], req.prompt_len, tok)
                req.state = RequestState.RUNNING
                self._running.append(req)
                st.admitted.append(req.request_id)
                self._maybe_finish(req, tok, st)
            else:
                self._partials[req.request_id] = rows[i]

    def _maybe_finish(self, req: Request, tok: int, st: IterationStats) -> None:
        stopped = req.stop_token is not None and tok == req.stop_token
        out_of_room = req.prompt_len + req.n_generated + 1 > self.max_seq
        if not (stopped or out_of_room
                or req.n_generated >= req.max_new_tokens):
            return
        req.finish_reason = (FinishReason.STOP if stopped
                             else FinishReason.LENGTH)
        req.finish_time = self.now
        req.state = RequestState.FINISHED
        self.manager.release(req.slot)
        req.slot = None
        self._running.remove(req)
        self.finished.append(req)
        st.finished.append(req.request_id)

    def run_until_idle(self, max_steps: Optional[int] = None) -> List[IterationStats]:
        """Step until every submitted request has finished."""
        stats = []
        while self.has_work:
            if max_steps is not None and len(stats) >= max_steps:
                break
            stats.append(self.step())
        return stats


class RoutedServer:
    """Seed-era batch API (paper Eq. 3 at the serving layer), now a thin
    compatibility wrapper over per-replica continuous-batching engines.

    The whole-batch contract is preserved — proportional split across
    replicas by the "serve_step" ratio entry, capacity clamp with overflow
    redistribution, per-replica measured (or injected) times fed back —
    but each replica's share executes through a
    :class:`ContinuousBatchingEngine` rather than a padded static batch.
    Note the engine admits through a single prefill lane, so a replica's
    ``c`` prompts prefill as ``c`` batch-1 calls instead of the seed's one
    batched call; on real hardware callers that want maximal prefill
    batching for a fixed, fully-arrived batch should keep using
    :meth:`ServeEngine.generate`.  Request-level callers should use
    :class:`~repro.serving.dispatch.InflightDispatcher` directly.
    """

    def __init__(self, engines: Sequence[ServeEngine],
                 sink: Optional[StatsSink] = None):
        self.engines = list(engines)
        self.runtime = DeviceRuntime(n_slices=len(engines), alpha=0.3)
        self.router = ReplicaRouter(self.runtime)
        # keep_stats=False: a serving process is long-lived; per-batch
        # telemetry goes to the sink, not an unbounded list.
        self.balancer = Balancer(self.router, sink=sink, keep_stats=False)
        self._cb_engines = None

    @property
    def _cb(self):
        """Per-replica continuous-batching engines, built on first use so a
        router-only RoutedServer does not allocate slot state up front."""
        if self._cb_engines is None:
            self._cb_engines = [
                ContinuousBatchingEngine(e.cfg, e.params,
                                         max_slots=e.batch_size,
                                         max_seq=e.max_seq)
                for e in self.engines
            ]
        return self._cb_engines

    @property
    def capacities(self) -> np.ndarray:
        return np.array([e.batch_size for e in self.engines], dtype=np.int64)

    def serve_batch(self, prompts: np.ndarray, n_steps: int,
                    times_override: Optional[np.ndarray] = None):
        """Split ``prompts`` across replicas ∝ current ratios; run; feed
        times back.  ``times_override`` lets tests/benchmarks inject
        simulated heterogeneous replica speeds."""
        if len(prompts) == 0:
            return (np.zeros((0, prompts.shape[1] + n_steps),
                             dtype=prompts.dtype),
                    np.zeros(len(self.engines), dtype=np.int64),
                    np.zeros(len(self.engines)))
        if n_steps == 0:
            # Seed contract: a 0-step round returns the prompts unchanged.
            # Nothing is decoded, so nothing is measured or fed back.
            counts = clamp_to_capacity(self.balancer.plan(len(prompts)).counts,
                                       self.capacities)
            return (np.array(prompts, copy=True), counts,
                    np.zeros(len(self.engines)))
        # The (B, s0 + n_steps) output contract needs cache room for every
        # step on whichever replica a request lands on; fail loudly up
        # front rather than silently returning a narrower array.
        s0 = prompts.shape[1]
        short = min(e.max_seq for e in self.engines)
        if s0 + n_steps > short:
            raise ValueError(
                f"prompt_len {s0} + n_steps {n_steps} exceeds replica "
                f"max_seq {short}; build engines with max_seq >= "
                f"prompt_len + n_steps")
        # The proportional split can exceed a fast replica's slot count;
        # clamp to capacity and hand the overflow to other replicas.
        planned = self.balancer.plan(len(prompts))
        counts = clamp_to_capacity(planned.counts, self.capacities)
        plan = Plan(counts=counts, key=planned.key)
        with self.balancer.balanced_region(plan=plan) as region:
            results, start = [], 0
            for i, (cb, c) in enumerate(zip(self._cb, counts)):
                if c == 0:
                    continue
                chunk = prompts[start:start + c]
                start += c
                reqs = [Request(prompt=p, max_new_tokens=n_steps)
                        for p in chunk]
                with region.timed(i):
                    for r in reqs:
                        r.arrival_time = cb.now
                        cb.submit(r)
                    cb.run_until_idle()
                cb.poll_finished()  # keep the long-lived engine bounded
                results.append(np.stack([r.tokens for r in reqs]))
            if times_override is not None:
                # Replicas that served nothing have no measurement this
                # round; keep their time at 0 so EMA updates and telemetry
                # skip them instead of learning from a phantom sample.
                override = np.asarray(times_override, dtype=np.float64)
                region.times[:] = np.where(counts > 0, override, 0.0)
        return np.concatenate(results, axis=0), counts, region.times
