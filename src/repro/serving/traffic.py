"""Deterministic open-loop traffic generation (seeded Poisson arrivals).

Open-loop means arrival times are drawn independently of service progress
(the "millions of users" regime: clients do not wait for each other), so
the same seed always produces the same trace — the property the serving
benchmark and CI smoke runs rely on.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from .request import Request

__all__ = ["poisson_requests"]


def poisson_requests(n: int, *, rate: float, vocab_size: int,
                     prompt_len: int | Sequence[int],
                     max_new_tokens: int | Sequence[int],
                     seed: int = 0,
                     stop_token: Optional[int] = None) -> List[Request]:
    """``n`` requests with exponential inter-arrival gaps at ``rate`` req/s
    (``rate <= 0``: everything arrives at t=0).  ``prompt_len`` /
    ``max_new_tokens`` may be scalars or ``(lo, hi)`` ranges sampled
    uniformly per request.  Fully determined by ``seed``."""
    if n < 1:
        raise ValueError("need at least one request")
    rng = np.random.default_rng(seed)
    gaps = (rng.exponential(1.0 / rate, size=n) if rate > 0
            else np.zeros(n))
    gaps[0] = 0.0  # first request arrives at t=0
    arrivals = np.cumsum(gaps)

    def draw(spec) -> int:
        if isinstance(spec, (int, np.integer)):
            return int(spec)
        lo, hi = spec
        return int(rng.integers(lo, hi + 1))

    out = []
    for i in range(n):
        s0 = draw(prompt_len)
        out.append(Request(
            prompt=rng.integers(0, vocab_size, size=s0, dtype=np.int32),
            max_new_tokens=draw(max_new_tokens),
            arrival_time=float(arrivals[i]),
            stop_token=stop_token,
        ))
    return out
