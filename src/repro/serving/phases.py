"""Execution phases and the per-phase hybrid-CPU cost model.

The paper's Fig. 4 observation: balance ratios are *phase dependent* —
prefill is compute-bound (``avx_vnni``; P/E core ratios stay wide, ~2-3x)
while decode is memory-bound (``membw``; shared bandwidth compresses
ratios toward 1).  A single blended ratio table therefore misplans one of
the two phases.  Everything serving-side keys its
:class:`~repro.runtime.RatioTable` entries by phase — :data:`PREFILL` /
:data:`DECODE` — at both levels:

* core dispatch (:class:`HybridPhaseCost`): each serving iteration's
  prefill chunk and decode step are split across the simulated cores by a
  per-phase :class:`~repro.runtime.Balancer`, so the table converges to
  distinct "prefill" and "decode" entries;
* replica routing (:class:`~repro.serving.dispatch.InflightDispatcher`):
  per-replica tokens/s are learned separately per phase.

:class:`HybridPhaseCost` doubles as the engine's deterministic virtual
clock: on this 1-core container the real jitted model supplies *tokens*
while the simulated machine supplies *time*.
"""

from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

import numpy as np

from repro.core import events as _ev
from repro.core.hybrid_sim import SimulatedHybridCPU, make_machine
from repro.core.pool import VirtualWorkerPool
from repro.kernels import dispatch as _kernel
from repro.runtime import (
    Balancer,
    EvenPolicy,
    ProportionalPolicy,
    RatioTable,
    StatsSink,
    run_plan,
)

__all__ = ["PREFILL", "DECODE", "PHASES", "PHASE_ISA", "TRUNK_KINDS",
           "phase_kernel_key", "PhaseCostModel", "HybridPhaseCost",
           "LinearPhaseCost", "phase_balancers"]

PREFILL = "prefill"
DECODE = "decode"
PHASES = (PREFILL, DECODE)

# Each phase's primary ISA (paper §2.1: kernels sharing a bottleneck share
# ratio tables): prefill GEMMs are compute-bound VNNI work, decode GEMVs are
# bound by shared memory bandwidth.  Kernel-level dispatch (e.g. a
# :class:`~repro.models.layers.BalancedQuantLinear` head) keys its per-core
# ratio table with this map.
PHASE_ISA = {PREFILL: "avx_vnni", DECODE: "membw"}

# Balanced-trunk dispatch refines the keying to (phase ISA x layer kind):
# every projection family of the decode step owns a ratio vector per phase
# — "membw/attn_proj", "avx_vnni/mlp_up", ... (see repro.kernels.dispatch).
TRUNK_KINDS = _kernel.TRUNK_KINDS


def phase_kernel_key(phase: str, kind: Optional[str] = None) -> str:
    """Ratio-table key for a trunk projection in ``phase``:
    ``"<phase isa>/<kind>"`` (bare phase ISA when ``kind`` is None — the
    PR-3 balanced-head convention)."""
    return _kernel.kernel_key(PHASE_ISA[phase], kind)


def phase_balancers(table: RatioTable, sink: Optional[StatsSink] = None,
                    active=None):
    """One units-feedback Balancer per phase over a shared table — the
    construction both levels of the control loop (core dispatch here,
    replica dispatch in :mod:`repro.serving.dispatch`) run on.

    ``active`` is an optional zero-argument probe returning the current
    boolean worker mask (see :class:`~repro.runtime.ProportionalPolicy`):
    masked workers get zero-width shares and keep their learned ratios."""
    return {
        phase: Balancer(
            ProportionalPolicy(table, key=phase, feedback="units",
                               active=active),
            sink=sink, keep_stats=False)
        for phase in PHASES
    }


@runtime_checkable
class PhaseCostModel(Protocol):
    """Virtual-time source for one serving iteration's two phases."""

    def prefill_seconds(self, n_tokens: int, ctx: int) -> float: ...

    def decode_seconds(self, n_active: int, ctx: int) -> float: ...


class HybridPhaseCost:
    """Paper-faithful per-phase core dispatch on a simulated hybrid CPU.

    Each phase call plans a proportional split of the phase's work across
    the machine's cores (Eq. 3) under the phase's ratio-table key, runs it
    on a :class:`VirtualWorkerPool` with the phase's primary ISA, feeds the
    per-core times back (Eq. 2 + EMA), and returns the region makespan.

    Work-volume defaults model a llama2-7B-class checkpoint (Q4 weights):
    ``prefill_macs_per_token`` int8 MACs per prompt token and
    ``decode_bytes_per_step`` streamed weight bytes per decode step, plus
    ``kv_bytes_per_ctx_token`` per active request per context token.
    """

    def __init__(self, machine: SimulatedHybridCPU | str = "ultra-125h", *,
                 table: Optional[RatioTable] = None, alpha: float = 0.3,
                 seed: int = 0, sink: Optional[StatsSink] = None,
                 prefill_macs_per_token: float = 14e9,
                 decode_bytes_per_step: float = 3.9e9,
                 kv_bytes_per_ctx_token: float = 1e6,
                 decode_units: int = 4096, dynamic: bool = True):
        if isinstance(machine, str):
            machine = make_machine(machine, seed=seed)
        if hasattr(machine, "flattened"):
            # A MachineTopology: the phase cost model only needs total
            # compute and aggregate bandwidth for its virtual clock, so it
            # runs over the flattened view (socket-local kernel timing
            # lives in repro.topology.TopologyDispatcher).
            machine = machine.flattened()
        self.machine = machine
        self.table = table or RatioTable(machine.n_cores, alpha=alpha)
        if self.table.n_workers != machine.n_cores:
            raise ValueError("table size does not match machine core count")
        self.prefill_macs_per_token = prefill_macs_per_token
        self.decode_bytes_per_step = decode_bytes_per_step
        self.kv_bytes_per_ctx_token = kv_bytes_per_ctx_token
        self.decode_units = decode_units
        self.dynamic = dynamic
        self._pools = {phase: VirtualWorkerPool(machine, isa=PHASE_ISA[phase])
                       for phase in PHASES}
        if dynamic:
            # per-phase capacity probe: sample the machine's active mask
            # at *that phase's pool clock* (the instant its next region
            # starts), so a park event mid-serve zeroes the parked cores'
            # shares on the very next iteration with no extra wiring
            self._balancers = {
                phase: Balancer(
                    ProportionalPolicy(
                        self.table, key=phase, feedback="units",
                        active=(lambda p=phase: machine.active_mask(
                            self._pools[p].clock))),
                    sink=sink, keep_stats=False)
                for phase in PHASES
            }
        else:
            # the static (OpenMP balanced parallel-for) clock: equal
            # shares, no feedback, capacity-blind — bench_elastic's
            # baseline arm
            self._balancers = {
                phase: Balancer(EvenPolicy(machine.n_cores),
                                sink=sink, keep_stats=False)
                for phase in PHASES
            }
        # bytes-moved / busy-seconds accounting for the paper's achieved-
        # bandwidth fraction (decode is the bandwidth-bound phase).
        self._bytes = {phase: 0.0 for phase in PHASES}
        self._busy = {phase: 0.0 for phase in PHASES}

    def ratios(self, phase: str) -> np.ndarray:
        return self.table.ratios(phase)

    def _region(self, phase: str, n_units: int, work_per_unit: float,
                bytes_total: float = 0.0) -> float:
        bal = self._balancers[phase]
        pool = self._pools[phase]
        tracing = _ev.TRACER is not None
        t0 = pool.clock
        plan = bal.plan(n_units)
        times = run_plan(pool, plan, None, work_per_unit)
        st = bal.report(plan, times, bytes_moved=bytes_total)
        if bytes_total > 0 and st.makespan > 0:
            self._bytes[phase] += bytes_total
            self._busy[phase] += st.makespan
        if tracing:
            _ev.emit_span(f"phase:{phase}", phase, t0, pool.clock - t0,
                          cat="phase",
                          args=lambda: {"units": int(n_units),
                                        "imbalance": round(st.imbalance, 4)})
            _ev.emit_counter(
                f"ratio:{phase}", pool.clock,
                lambda: {f"w{i}": round(float(r), 5)
                         for i, r in enumerate(self.table.ratios(phase))})
            _ev.emit_counter(
                "capacity", pool.clock,
                lambda: {"active_cores": int(
                    self.machine.active_mask(pool.clock).sum())})
            if bytes_total > 0:
                _ev.emit_counter(
                    f"bw:{phase}", pool.clock,
                    lambda: {"achieved_bw_frac": round(
                        self.achieved_bandwidth_fraction(phase), 5)})
        return float(times.max(initial=0.0))

    def prefill_seconds(self, n_tokens: int, ctx: int) -> float:
        """Compute-bound chunk: split the token dimension across cores."""
        if n_tokens <= 0:
            return 0.0
        return self._region(PREFILL, int(n_tokens), self.prefill_macs_per_token)

    def decode_seconds(self, n_active: int, ctx: int) -> float:
        """Memory-bound step: weights stream once for the whole batch, KV
        reads scale with active requests x context; the split dimension is
        abstract weight-row tiles."""
        if n_active <= 0:
            return 0.0
        total_bytes = (self.decode_bytes_per_step
                       + n_active * max(ctx, 0) * self.kv_bytes_per_ctx_token)
        return self._region(DECODE, self.decode_units,
                            total_bytes / self.decode_units,
                            bytes_total=total_bytes)

    def achieved_bandwidth_fraction(self, phase: str = DECODE) -> float:
        """Achieved bytes/s of the phase's regions so far, as a fraction of
        the machine's streaming (MLC-analogue) socket bandwidth — the
        paper's >90% headline metric.  0 before any bytes moved."""
        busy = self._busy.get(phase, 0.0)
        if busy <= 0:
            return 0.0
        return (self._bytes[phase] / busy) / self.machine.socket_bandwidth


class LinearPhaseCost:
    """Trivial deterministic cost model (tests / heterogeneous-replica
    studies): prefill costs ``prefill_per_token`` per prompt token, decode
    ``decode_per_step`` per iteration plus ``decode_per_active`` per row."""

    def __init__(self, prefill_per_token: float = 1e-3,
                 decode_per_step: float = 1e-3,
                 decode_per_active: float = 0.0):
        self.prefill_per_token = prefill_per_token
        self.decode_per_step = decode_per_step
        self.decode_per_active = decode_per_active

    def prefill_seconds(self, n_tokens: int, ctx: int) -> float:
        return 0.0 if n_tokens <= 0 else self.prefill_per_token * n_tokens

    def decode_seconds(self, n_active: int, ctx: int) -> float:
        if n_active <= 0:
            return 0.0
        return self.decode_per_step + self.decode_per_active * n_active
