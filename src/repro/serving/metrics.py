"""Serving latency metrics: TTFT / TPOT percentiles and goodput.

Shared by ``repro.launch.serve`` and ``benchmarks/bench_serving.py`` so
the driver and the benchmark report identical numbers for identical
traffic.  All times are engine-clock seconds (deterministic under a
phase cost model).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import numpy as np

from .request import FinishReason, Request

__all__ = ["PERCENTILES", "percentiles", "LatencyReport"]

PERCENTILES = (50, 90, 99)


def percentiles(values: Sequence[float],
                ps: Sequence[int] = PERCENTILES) -> Dict[int, float]:
    """{p: value} with linear interpolation; empty input -> NaNs."""
    if len(values) == 0:
        return {p: float("nan") for p in ps}
    arr = np.asarray(list(values), dtype=np.float64)
    return {p: float(np.percentile(arr, p)) for p in ps}


@dataclass
class LatencyReport:
    """Aggregate serving metrics over a set of finished requests."""

    n_requests: int
    n_finished: int
    duration: float                  # engine-clock span of the run
    generated_tokens: int
    ttft: Dict[int, float]           # percentile -> seconds
    tpot: Dict[int, float]
    goodput: float                   # SLO-meeting finished requests / second
    n_shed: int = 0                  # rejected by admission, never executed
    n_degraded: int = 0              # served with admission-shrunk budgets

    @classmethod
    def from_requests(cls, requests: Sequence[Request], *,
                      duration: Optional[float] = None,
                      slo_ttft: Optional[float] = None,
                      slo_tpot: Optional[float] = None) -> "LatencyReport":
        done = [r for r in requests if r.finish_time is not None]
        # aborted and shed requests count as finished but never as served
        # or as goodput: cancelling stragglers (or rejecting arrivals at
        # the door) must not flatter the percentiles
        served = [r for r in done
                  if r.finish_reason not in (FinishReason.ABORTED,
                                             FinishReason.SHED)
                  and r.ttft is not None]
        if duration is None:
            t0 = min((r.arrival_time for r in requests), default=0.0)
            t1 = max((r.finish_time for r in done), default=0.0)
            duration = max(t1 - t0, 0.0)
        # single-token completions carry a TTFT sample but no TPOT sample
        # (tpot is None); they cannot violate a TPOT SLO
        good = [
            r for r in served
            if (slo_ttft is None or r.ttft <= slo_ttft)
            and (slo_tpot is None or r.tpot is None or r.tpot <= slo_tpot)
        ]
        return cls(
            n_requests=len(requests),
            n_finished=len(done),
            duration=duration,
            # served only: tokens of cancelled stragglers must not inflate
            # the reported throughput of completed work
            generated_tokens=sum(r.n_generated for r in served),
            ttft=percentiles([r.ttft for r in served]),
            tpot=percentiles([r.tpot for r in served
                              if r.tpot is not None]),
            goodput=len(good) / duration if duration > 0 else 0.0,
            n_shed=sum(1 for r in done
                       if r.finish_reason is FinishReason.SHED),
            n_degraded=sum(1 for r in served if r.degraded),
        )

    @property
    def throughput(self) -> float:
        """Generated tokens per engine-clock second."""
        if self.duration <= 0:
            return 0.0
        return self.generated_tokens / self.duration

    def lines(self, prefix: str = "[serve]") -> list:
        fmt = lambda d: " ".join(
            f"p{p}={v * 1e3:.2f}ms" for p, v in sorted(d.items()))
        extra = ""
        if self.n_shed or self.n_degraded:
            extra = f" (shed {self.n_shed}, degraded {self.n_degraded})"
        return [
            f"{prefix} finished {self.n_finished}/{self.n_requests} requests, "
            f"{self.generated_tokens} tokens in {self.duration:.3f}s "
            f"({self.throughput:.1f} tok/s, goodput {self.goodput:.2f} req/s)"
            f"{extra}",
            f"{prefix} ttft {fmt(self.ttft)}",
            f"{prefix} tpot {fmt(self.tpot)}",
        ]
