"""Serving latency metrics: TTFT / TPOT percentiles and goodput.

Shared by ``repro.launch.serve`` and ``benchmarks/bench_serving.py`` so
the driver and the benchmark report identical numbers for identical
traffic.  All request timestamps are engine-clock seconds — which clock
that is depends on the engine: ``clock="virtual"`` (a deterministic
phase cost model) or ``clock="wall"`` (real time).  ``wall_duration``
carries the real elapsed seconds alongside the engine-clock ``duration``
when both are known, so a virtual-clock report can still state how long
the simulation itself took.

:meth:`LatencyReport.to_dict` is the stable JSON schema
(``repro.serving.latency_report/1``) consumed by ``benchmarks/run.py
--json`` and the metrics exposition; :meth:`LatencyReport.publish`
mirrors the report into a :class:`repro.obs.MetricsRegistry`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .request import FinishReason, Request

__all__ = ["PERCENTILES", "percentiles", "slo_met", "LatencyReport"]

PERCENTILES = (50, 90, 99)

SCHEMA = "repro.serving.latency_report/1"


def percentiles(values: Sequence[float],
                ps: Sequence[int] = PERCENTILES) -> Dict[int, float]:
    """{p: value} with linear interpolation; empty input -> NaNs."""
    if len(values) == 0:
        return {p: float("nan") for p in ps}
    arr = np.asarray(list(values), dtype=np.float64)
    return {p: float(np.percentile(arr, p)) for p in ps}


def slo_met(r: Request, slo_ttft: Optional[float] = None,
            slo_tpot: Optional[float] = None) -> bool:
    """True when a served request meets both SLOs.  Single-token
    completions carry a TTFT sample but no TPOT sample (``tpot is
    None``); they cannot violate a TPOT SLO.  An unset SLO is always
    met."""
    if slo_ttft is not None and (r.ttft is None or r.ttft > slo_ttft):
        return False
    if slo_tpot is not None and r.tpot is not None and r.tpot > slo_tpot:
        return False
    return True


@dataclass
class LatencyReport:
    """Aggregate serving metrics over a set of finished requests."""

    n_requests: int
    n_finished: int
    duration: float                  # engine-clock span of the run
    generated_tokens: int
    ttft: Dict[int, float]           # percentile -> seconds
    tpot: Dict[int, float]
    goodput: float                   # SLO-meeting finished requests / second
    n_shed: int = 0                  # rejected by admission, never executed
    n_degraded: int = 0              # served with admission-shrunk budgets
    clock: str = "virtual"           # what the request timestamps are in
    wall_duration: Optional[float] = None  # real elapsed seconds, if known
    ttft_samples: Tuple[float, ...] = field(default=(), repr=False)
    tpot_samples: Tuple[float, ...] = field(default=(), repr=False)

    @classmethod
    def from_requests(cls, requests: Sequence[Request], *,
                      duration: Optional[float] = None,
                      slo_ttft: Optional[float] = None,
                      slo_tpot: Optional[float] = None,
                      clock: str = "virtual",
                      wall_duration: Optional[float] = None
                      ) -> "LatencyReport":
        done = [r for r in requests if r.finish_time is not None]
        # aborted and shed requests count as finished but never as served
        # or as goodput: cancelling stragglers (or rejecting arrivals at
        # the door) must not flatter the percentiles
        served = [r for r in done
                  if r.finish_reason not in (FinishReason.ABORTED,
                                             FinishReason.SHED)
                  and r.ttft is not None]
        if duration is None:
            t0 = min((r.arrival_time for r in requests), default=0.0)
            t1 = max((r.finish_time for r in done), default=0.0)
            duration = max(t1 - t0, 0.0)
        good = [r for r in served if slo_met(r, slo_ttft, slo_tpot)]
        ttft_samples = tuple(float(r.ttft) for r in served)
        tpot_samples = tuple(float(r.tpot) for r in served
                             if r.tpot is not None)
        return cls(
            n_requests=len(requests),
            n_finished=len(done),
            duration=duration,
            # served only: tokens of cancelled stragglers must not inflate
            # the reported throughput of completed work
            generated_tokens=sum(r.n_generated for r in served),
            ttft=percentiles(ttft_samples),
            tpot=percentiles(tpot_samples),
            goodput=len(good) / duration if duration > 0 else 0.0,
            n_shed=sum(1 for r in done
                       if r.finish_reason is FinishReason.SHED),
            n_degraded=sum(1 for r in served if r.degraded),
            clock=clock,
            wall_duration=wall_duration,
            ttft_samples=ttft_samples,
            tpot_samples=tpot_samples,
        )

    @property
    def throughput(self) -> float:
        """Generated tokens per engine-clock second."""
        if self.duration <= 0:
            return 0.0
        return self.generated_tokens / self.duration

    def to_dict(self) -> dict:
        """Stable JSON-safe schema (NaN percentiles become ``None``)."""
        clean = lambda v: None if not np.isfinite(v) else float(v)
        return {
            "schema": SCHEMA,
            "n_requests": int(self.n_requests),
            "n_finished": int(self.n_finished),
            "n_shed": int(self.n_shed),
            "n_degraded": int(self.n_degraded),
            "clock": self.clock,
            "duration_s": float(self.duration),
            "wall_duration_s": (None if self.wall_duration is None
                                else float(self.wall_duration)),
            "generated_tokens": int(self.generated_tokens),
            "throughput_tok_s": float(self.throughput),
            "goodput_req_s": float(self.goodput),
            "ttft_s": {f"p{p}": clean(v)
                       for p, v in sorted(self.ttft.items())},
            "tpot_s": {f"p{p}": clean(v)
                       for p, v in sorted(self.tpot.items())},
        }

    def publish(self, registry) -> None:
        """Mirror this report into a :class:`repro.obs.MetricsRegistry`:
        TTFT/TPOT histograms on the explicit SLO buckets plus
        request/token counters and throughput/goodput gauges."""
        from repro.obs import TPOT_BUCKETS, TTFT_BUCKETS

        registry.histogram(
            "repro_ttft_seconds", "Time to first token",
            buckets=TTFT_BUCKETS).observe_many(self.ttft_samples)
        registry.histogram(
            "repro_tpot_seconds", "Time per output token",
            buckets=TPOT_BUCKETS).observe_many(self.tpot_samples)
        registry.counter(
            "repro_requests_total",
            "Finished requests by outcome").inc(
                self.n_finished - self.n_shed, outcome="served")
        if self.n_shed:
            registry.counter("repro_requests_total",
                             "Finished requests by outcome").inc(
                                 self.n_shed, outcome="shed")
        if self.n_degraded:
            registry.counter("repro_requests_total",
                             "Finished requests by outcome").inc(
                                 self.n_degraded, outcome="degraded")
        registry.counter(
            "repro_generated_tokens_total",
            "Tokens generated by served requests").inc(
                self.generated_tokens)
        registry.gauge(
            "repro_throughput_tokens_per_second",
            "Generated tokens per engine-clock second").set(
                self.throughput)
        registry.gauge(
            "repro_goodput_requests_per_second",
            "SLO-meeting finished requests per second").set(self.goodput)

    def lines(self, prefix: str = "[serve]") -> list:
        fmt = lambda d: " ".join(
            f"p{p}={v * 1e3:.2f}ms" for p, v in sorted(d.items()))
        extra = ""
        if self.n_shed or self.n_degraded:
            extra = f" (shed {self.n_shed}, degraded {self.n_degraded})"
        return [
            f"{prefix} finished {self.n_finished}/{self.n_requests} requests, "
            f"{self.generated_tokens} tokens in {self.duration:.3f}s "
            f"({self.throughput:.1f} tok/s, goodput {self.goodput:.2f} req/s)"
            f"{extra}",
            f"{prefix} ttft {fmt(self.ttft)}",
            f"{prefix} tpot {fmt(self.tpot)}",
        ]
