"""Iteration-level scheduling for the continuous-batching engine.

One engine iteration = (at most one prefill chunk) + (one decode step for
the whole persistent batch).  The scheduler decides *which* prompt tokens
run in the prefill lane each iteration:

* Admission is arrival-ordered FIFO (deterministic): a waiting request is
  admitted as soon as it has arrived (``arrival_time <= now``) and a slot
  is free.
* Prefill is optionally *chunked* (``prefill_chunk``): long prompts are
  consumed up to ``chunk`` tokens per iteration so running decodes are
  never starved behind a long prompt — the usual continuous-batching
  trade between TTFT of the new request and TPOT of the running ones.
  Chunk lengths are bucketed to powers of two so the engine's jitted
  prefill compiles at most ``log2(prefill_chunk) + 1`` shapes, no matter
  how prompt lengths vary (decode already has one static shape).

The scheduler is pure host-side bookkeeping; the engine owns all jitted
execution and the slot state.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

from .request import Request, RequestState

__all__ = ["PrefillChunk", "IterationStats", "IterationScheduler"]


@dataclass(frozen=True)
class PrefillChunk:
    """One iteration's prefill work: ``request.prompt[start:start+length]``."""

    request: Request
    start: int
    length: int

    @property
    def is_last(self) -> bool:
        return self.start + self.length >= self.request.prompt_len


@dataclass
class IterationStats:
    """What one engine iteration did, in engine-clock seconds — the per-phase
    feedback consumed by the replica dispatcher's ratio tables."""

    now: float = 0.0
    prefill_tokens: int = 0
    prefill_seconds: float = 0.0
    decode_tokens: int = 0          # one per running slot stepped
    decode_seconds: float = 0.0
    n_running: int = 0
    n_waiting: int = 0
    admitted: List[int] = field(default_factory=list)    # request ids
    finished: List[int] = field(default_factory=list)


class IterationScheduler:
    """Admission queue + chunked-prefill cursor.

    At most one request is in the PREFILL state at a time; its prompt is
    consumed chunk by chunk across iterations, interleaved with decode
    steps of the running batch.
    """

    def __init__(self, prefill_chunk: Optional[int] = None):
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.prefill_chunk = prefill_chunk
        self.waiting: Deque[Request] = deque()
        self.prefilling: Optional[Request] = None

    # ------------------------------------------------------------- intake --
    def submit(self, request: Request) -> None:
        """Queue a request, keeping the queue sorted by arrival time (stable
        for equal arrivals, so submit order breaks ties deterministically)."""
        if request.state is not RequestState.WAITING:
            raise ValueError("only WAITING requests can be submitted")
        if self.waiting and request.arrival_time < self.waiting[-1].arrival_time:
            items = sorted(list(self.waiting) + [request],
                           key=lambda r: r.arrival_time)
            self.waiting = deque(items)
        else:
            self.waiting.append(request)

    def n_waiting(self, now: Optional[float] = None) -> int:
        if now is None:
            return len(self.waiting)
        return sum(1 for r in self.waiting if r.arrival_time <= now)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or self.prefilling is not None

    # ----------------------------------------------------------- per-step --
    def next_prefill(self, now: float, slot_available: bool) -> Optional[PrefillChunk]:
        """The prefill work for this iteration, admitting a new request from
        the queue when the lane is idle and a slot is free."""
        if self.prefilling is None:
            if (not slot_available or not self.waiting
                    or self.waiting[0].arrival_time > now):
                return None
            self.prefilling = self.waiting.popleft()
        req = self.prefilling
        remaining = req.prompt_len - req.prefill_done
        if self.prefill_chunk is None:
            length = remaining
        else:
            # largest power of two <= min(chunk, remaining): a bounded
            # shape set for the jitted prefill (one-shot mode instead
            # compiles per distinct prompt length, the caller's trade)
            length = min(self.prefill_chunk, remaining)
            length = 1 << (length.bit_length() - 1)
        return PrefillChunk(request=req, start=req.prefill_done, length=length)

    def prefill_advanced(self, chunk: PrefillChunk) -> None:
        """Mark ``chunk`` as executed; frees the prefill lane on the last
        chunk (the engine flips the request to RUNNING)."""
        if chunk.request is not self.prefilling:
            raise ValueError("chunk does not belong to the active prefill")
        if chunk.is_last:
            self.prefilling = None
