"""Iteration-level scheduling for the continuous-batching engine.

One engine iteration = (one batched prefill call over the active lanes) +
(one decode step for the whole persistent batch).  The scheduler decides
*which* prompt tokens run in the prefill lane(s) each iteration:

* Admission is arrival-ordered FIFO (deterministic): a waiting request is
  admitted as soon as it has arrived (``arrival_time <= now``), a slot is
  free, and a prefill lane (of ``prefill_lanes``, default 1) is open.
* Prefill is optionally *chunked* (``prefill_chunk``): long prompts are
  consumed up to ``chunk`` tokens per iteration so running decodes are
  never starved behind a long prompt — the usual continuous-batching
  trade between TTFT of the new request and TPOT of the running ones.
  Chunk lengths are bucketed to powers of two so the engine's jitted
  prefill compiles at most ``log2(prefill_chunk) + 1`` shapes, no matter
  how prompt lengths vary (decode already has one static shape).
* With ``prefill_lanes > 1`` every active lane advances by the *same*
  chunk length each iteration (the minimum of the per-lane bucketed
  lengths — a min of powers of two is itself a power of two, so the
  bounded-shape-set property survives): the engine then runs all lanes
  as one batched trunk call instead of one call per prompt.

The scheduler is pure host-side bookkeeping; the engine owns all jitted
execution and the slot state.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

from .request import Request, RequestState

__all__ = ["PrefillChunk", "IterationStats", "IterationScheduler"]


@dataclass(frozen=True)
class PrefillChunk:
    """One iteration's prefill work: ``request.prompt[start:start+length]``."""

    request: Request
    start: int
    length: int

    @property
    def is_last(self) -> bool:
        return self.start + self.length >= self.request.prompt_len


@dataclass
class IterationStats:
    """What one engine iteration did, in engine-clock seconds — the per-phase
    feedback consumed by the replica dispatcher's ratio tables."""

    now: float = 0.0
    prefill_tokens: int = 0
    prefill_seconds: float = 0.0
    decode_tokens: int = 0          # one per running slot stepped
    decode_seconds: float = 0.0
    n_running: int = 0
    n_waiting: int = 0
    admitted: List[int] = field(default_factory=list)    # request ids
    finished: List[int] = field(default_factory=list)


class IterationScheduler:
    """Admission queue + chunked-prefill cursors.

    At most ``prefill_lanes`` requests are in the PREFILL state at a time
    (default 1, the classic single-lane engine); their prompts are consumed
    chunk by chunk across iterations, interleaved with decode steps of the
    running batch.
    """

    def __init__(self, prefill_chunk: Optional[int] = None,
                 prefill_lanes: int = 1):
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if prefill_lanes < 1:
            raise ValueError("prefill_lanes must be >= 1")
        self.prefill_chunk = prefill_chunk
        self.prefill_lanes = prefill_lanes
        self.waiting: Deque[Request] = deque()
        self.lanes: List[Request] = []   # admission order, PREFILL state

    @property
    def prefilling(self) -> Optional[Request]:
        """Single-lane view: the oldest in-flight prefill (None when the
        lane set is empty) — the pre-multi-lane attribute, kept for
        callers of the classic one-lane engine."""
        return self.lanes[0] if self.lanes else None

    # ------------------------------------------------------------- intake --
    def submit(self, request: Request) -> None:
        """Queue a request, keeping the queue sorted by arrival time (stable
        for equal arrivals, so submit order breaks ties deterministically)."""
        if request.state is not RequestState.WAITING:
            raise ValueError("only WAITING requests can be submitted")
        if self.waiting and request.arrival_time < self.waiting[-1].arrival_time:
            items = sorted(list(self.waiting) + [request],
                           key=lambda r: r.arrival_time)
            self.waiting = deque(items)
        else:
            self.waiting.append(request)

    def n_waiting(self, now: Optional[float] = None) -> int:
        if now is None:
            return len(self.waiting)
        return sum(1 for r in self.waiting if r.arrival_time <= now)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.lanes)

    # ----------------------------------------------------------- per-step --
    def _desired_length(self, req: Request) -> int:
        remaining = req.prompt_len - req.prefill_done
        if self.prefill_chunk is None:
            return remaining
        # largest power of two <= min(chunk, remaining): a bounded
        # shape set for the jitted prefill (one-shot mode instead
        # compiles per distinct prompt length, the caller's trade)
        length = min(self.prefill_chunk, remaining)
        return 1 << (length.bit_length() - 1)

    def next_prefill(self, now: float, free_slots: int) -> List[PrefillChunk]:
        """The prefill work for this iteration — one chunk per active lane,
        all of the same length, admitting arrived requests into open lanes
        while ``free_slots`` allows (each new lane needs a decode slot)."""
        free = int(free_slots)
        while (len(self.lanes) < self.prefill_lanes and free > 0
               and self.waiting and self.waiting[0].arrival_time <= now):
            self.lanes.append(self.waiting.popleft())
            free -= 1
        if not self.lanes:
            return []
        length = min(self._desired_length(r) for r in self.lanes)
        return [PrefillChunk(request=r, start=r.prefill_done, length=length)
                for r in self.lanes]

    def prefill_advanced(self, chunk: PrefillChunk) -> None:
        """Mark ``chunk`` as executed; frees its lane on the last chunk
        (the engine flips the request to RUNNING)."""
        if chunk.request not in self.lanes:
            raise ValueError("chunk does not belong to an active prefill lane")
        if chunk.is_last:
            self.lanes.remove(chunk.request)

    def remove_lane(self, request: Request) -> None:
        """Drop an in-flight prefill (abort path)."""
        if request not in self.lanes:
            raise ValueError("request is not prefilling in this engine")
        self.lanes.remove(request)
