"""The Balancer facade: one object per balancing domain, uniform telemetry.

``Balancer`` wraps a :class:`~repro.runtime.policy.BalancePolicy` and runs
the paper's loop for its callers:

    plan -> execute (caller) -> report -> RegionStats -> sink

``balanced_region(total)`` is the highest-level entry point: it plans the
split, hands the caller a :class:`Region` whose ``timed(worker)`` context
records per-worker wall times, and feeds the times back automatically on
exit — the paper's "track the execution time of each thread during
executing kernels" as a context manager.

Telemetry is uniform across domains: every round emits one
:class:`RegionStats` (makespan, imbalance, ratio trace) to a pluggable
:class:`StatsSink`.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional, Protocol, runtime_checkable

import numpy as np

from repro.analysis import invariants as _contracts
from repro.core import events as _ev

from .policy import BalancePolicy, Plan

__all__ = [
    "RegionStats",
    "StatsSink",
    "ListSink",
    "Region",
    "Balancer",
]


@dataclass
class RegionStats:
    """Telemetry for one balanced parallel region (any domain).

    ``children`` makes the record recursive: when the region's workers are
    themselves balancing domains (a fleet routing over machines routing
    over sockets routing over cores), each worker's latest own
    :class:`RegionStats` is attached, so one emitted record carries the
    whole hierarchy's state for that round.  Flat domains leave it empty.
    """

    key: str
    counts: np.ndarray
    times: np.ndarray
    ratios: Optional[np.ndarray] = None  # table state after feedback
    bytes: float = 0.0                   # bytes moved by the region (0 = n/a)
    children: tuple = ()                 # per-worker child RegionStats

    @property
    def kernel(self) -> str:  # seed-era alias (RegionStats.kernel)
        return self.key

    @property
    def measured(self) -> np.ndarray:
        """Workers that both received work and reported a time this round.
        Zero-count workers never enter the timed region; their ``t == 0``
        (or an injected phantom time) is *absence of measurement*, not a
        measurement, and must not leak into telemetry or EMA updates."""
        times = np.asarray(self.times, dtype=np.float64)
        counts = np.asarray(self.counts)
        return (counts > 0) & np.isfinite(times) & (times > 0)

    @property
    def makespan(self) -> float:
        times = np.asarray(self.times, dtype=np.float64)
        return float(times[self.measured].max(initial=0.0))

    @property
    def imbalance(self) -> float:
        """max(t)/mean(t) over measured workers — 1.0 is perfectly
        balanced."""
        times = np.asarray(self.times, dtype=np.float64)
        active = times[self.measured]
        if active.size == 0:
            return 1.0
        return float(active.max() / active.mean())

    @property
    def bandwidth(self) -> float:
        """Achieved bytes/s over the region (bytes moved / makespan) — the
        numerator of the paper's achieved-bandwidth fraction.  0 when the
        region recorded no byte accounting or no time."""
        mk = self.makespan
        if self.bytes <= 0 or mk <= 0:
            return 0.0
        return self.bytes / mk


@runtime_checkable
class StatsSink(Protocol):
    """Anything that accepts per-region telemetry (logger, CSV writer,
    metrics exporter)."""

    def emit(self, stats: RegionStats) -> None: ...


@dataclass
class ListSink:
    """In-memory sink (the default for tests and benchmarks)."""

    records: list = field(default_factory=list)

    def emit(self, stats: RegionStats) -> None:
        self.records.append(stats)


class Region:
    """One in-flight balanced region: the plan plus a per-worker stopwatch."""

    def __init__(self, plan: Plan):
        self.plan = plan
        self.times = np.zeros(plan.n_workers)

    @property
    def counts(self) -> np.ndarray:
        return self.plan.counts

    @property
    def ranges(self) -> list:
        return self.plan.ranges

    @contextmanager
    def timed(self, worker: int):
        """Time one worker's slice; accumulates so a worker may run several
        chunks within the region."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.times[worker] += time.perf_counter() - t0

    def record(self, worker: int, seconds: float) -> None:
        """Record an externally measured time (simulators, device events)."""
        self.times[worker] += float(seconds)


class Balancer:
    """Facade tying a policy to telemetry.  All four seed balancing loops
    (CPU kernels, uneven DP, MoE capacity, replica routing) are instances
    of this one object with different policies."""

    def __init__(self, policy: BalancePolicy, sink: Optional[StatsSink] = None,
                 keep_stats: bool = True):
        self.policy = policy
        self.sink = sink
        self.keep_stats = keep_stats
        self.stats: list = []

    def plan(self, total: int) -> Plan:
        plan = self.policy.plan(total)
        if _contracts.contracts_enabled():
            _contracts.check_plan_partition(
                plan.counts, total,
                where=f"Balancer.plan[{plan.key}]")
        return plan

    def report(self, plan: Plan, times, *, update: bool = True,
               label: Optional[str] = None,
               bytes_moved: float = 0.0) -> RegionStats:
        """Feed observed times back through the policy and emit telemetry.
        ``label`` overrides the stats key (e.g. kernel name vs. ISA key);
        ``bytes_moved`` records the region's byte traffic for bandwidth
        accounting."""
        times = np.asarray(times, dtype=np.float64)
        ratios = self.policy.report(plan, times) if update else None
        # Recursive domains (policies with a collect_children hook, e.g.
        # RecursivePolicy) attach each worker's own latest RegionStats so
        # the emitted record spans the whole hierarchy.
        collect = getattr(self.policy, "collect_children", None)
        st = RegionStats(key=label or plan.key, counts=plan.counts,
                         times=times,
                         ratios=None if ratios is None else ratios.copy(),
                         bytes=float(bytes_moved),
                         children=() if collect is None else tuple(collect()))
        if self.keep_stats:
            self.stats.append(st)
        if self.sink is not None:
            self.sink.emit(st)
        if _ev.RECORDER is not None:
            _ev.record(
                "ratio", st.key,
                makespan=st.makespan,
                imbalance=round(st.imbalance, 6),
                counts=np.asarray(st.counts).tolist(),
                ratios=(None if st.ratios is None
                        else np.round(st.ratios, 6).tolist()))
        return st

    @contextmanager
    def balanced_region(self, total: Optional[int] = None, *,
                        plan: Optional[Plan] = None, update: bool = True,
                        label: Optional[str] = None):
        """Plan a region, let the caller execute + time it, feed back on
        exit::

            with balancer.balanced_region(len(batch)) as region:
                for w, (lo, hi) in enumerate(region.ranges):
                    with region.timed(w):
                        work(batch[lo:hi])
            # times fed back; stats emitted

        Pass ``plan=`` instead of ``total`` to run an externally adjusted
        plan (e.g. one clamped to per-worker capacity).  After exit
        ``region.stats`` holds the emitted :class:`RegionStats`; nothing is
        fed back if the body raises.
        """
        if plan is None:
            if total is None:
                raise TypeError("balanced_region needs total= or plan=")
            plan = self.plan(total)
        region = Region(plan)
        region.stats = None
        yield region
        region.stats = self.report(region.plan, region.times, update=update,
                                   label=label)
