"""repro.runtime — the single home of the paper's dynamic parallel method.

One stack, four layers of callers::

    RatioTable / RatioStore      keyed EMA ratio tables (Eq. 2), persisted
        |
    BalancePolicy (plan/report)  proportional split (Eq. 3) + feedback
        |
    Balancer / balanced_region   timing, automatic feedback, RegionStats
        |
    domain frontends             DynamicScheduler (CPU kernels),
                                 UnevenBatchPlanner (uneven DP),
                                 ExpertCapacityPlanner (MoE capacity),
                                 ReplicaRouter (serving)

The seed's ``repro.core.scheduler`` and ``repro.core.balance`` remain as
deprecation shims re-exporting from here.
"""

from .table import RatioTable, RatioStore
from .offsets import OffsetSpec, OffsetSnapshot
from .policy import (
    Plan,
    BalancePolicy,
    ProportionalPolicy,
    EvenPolicy,
    RecursivePolicy,
    clamp_to_capacity,
)
from .balancer import RegionStats, StatsSink, ListSink, Region, Balancer
from .scheduler import (
    KernelSpec,
    CPURuntime,
    DynamicScheduler,
    StaticScheduler,
    run_plan,
)
from .planners import (
    DeviceRuntime,
    MicrobatchPlan,
    UnevenBatchPlanner,
    ExpertCapacityPlanner,
    ReplicaRouter,
)

__all__ = [
    "RatioTable",
    "RatioStore",
    "OffsetSpec",
    "OffsetSnapshot",
    "Plan",
    "BalancePolicy",
    "ProportionalPolicy",
    "EvenPolicy",
    "RecursivePolicy",
    "clamp_to_capacity",
    "RegionStats",
    "StatsSink",
    "ListSink",
    "Region",
    "Balancer",
    "KernelSpec",
    "CPURuntime",
    "DynamicScheduler",
    "StaticScheduler",
    "run_plan",
    "DeviceRuntime",
    "MicrobatchPlan",
    "UnevenBatchPlanner",
    "ExpertCapacityPlanner",
    "ReplicaRouter",
]
