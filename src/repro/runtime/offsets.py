"""Ratio table -> device-array shard offsets (the compiled-decode snapshot).

The io_callback bridge re-plans every balanced region on the host, inside
the step.  The compiled lowering (:mod:`repro.kernels.compiled`) inverts
that contract — exactly the paper's "balance *before* the parallel work
starts": per-core shard boundaries are planned on the host *between* engine
steps and materialized as small int32 device arrays that the jitted decode
step consumes as ordinary inputs.  Nothing inside the compiled program ever
calls back into Python; the table only influences the next step's offsets.

:class:`OffsetSnapshot` owns that materialization for any planner:

* ``register(OffsetSpec(name, total, granularity))`` declares one call
  site's split dimension;
* ``refresh()`` re-plans every registered spec from the current ratio
  state (via the ``plan_counts`` callable the owner supplied — typically
  a dispatcher's Balancer) and returns ``{name: (n_workers + 1,) int32
  device array}`` of cumulative boundaries — worker ``w`` owns rows
  ``[b[w], b[w+1])``;
* ``boundaries(name)`` / ``counts(name)`` expose the host-side mirror of
  the latest snapshot (what feedback replay compares device-recovered
  shard sizes against).

The snapshot is deliberately dumb about *how* counts are planned — flat
per-core, two-level socket-then-core, even/static — the planner callable
decides; the snapshot only guarantees that what the device reads is the
plan the host will account for.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict

import numpy as np

from repro.analysis import invariants as _contracts
from repro.core import events as _ev

__all__ = ["OffsetSpec", "OffsetSnapshot"]


@dataclass(frozen=True)
class OffsetSpec:
    """One compiled call site's split dimension: ``total`` units planned
    under ``name`` (the snapshot dict key, unique per call-site shape)."""

    name: str
    total: int
    granularity: int = 1

    def __post_init__(self) -> None:
        if self.total < 0:
            raise ValueError("total must be >= 0")
        if self.granularity < 1:
            raise ValueError("granularity must be >= 1")


class OffsetSnapshot:
    """Named host plans mirrored as device boundary arrays.

    ``plan_counts(spec) -> (n_workers,) int64`` produces one plan from the
    owner's current ratio state; ``refresh()`` runs it for every registered
    spec and uploads the cumulative boundaries.  The returned dict is a
    fresh pytree each refresh — callers pass it *as an argument* into their
    jitted step (closing over it would bake the offsets in as constants and
    defeat the between-step update).
    """

    def __init__(self, plan_counts: Callable[[OffsetSpec], np.ndarray]):
        self._plan_counts = plan_counts
        self._specs: Dict[str, OffsetSpec] = {}
        self._host: Dict[str, np.ndarray] = {}
        self._device: Dict[str, object] = {}

    # -------------------------------------------------------- registration --
    def register(self, spec: OffsetSpec) -> OffsetSpec:
        """Declare (or re-declare, idempotently) one call site.  Re-using a
        name with a different shape is a programming error and is refused."""
        prev = self._specs.get(spec.name)
        if prev is not None:
            if prev != spec:
                raise ValueError(
                    f"offset spec {spec.name!r} already registered with "
                    f"total={prev.total}, granularity={prev.granularity}")
            return prev
        self._specs[spec.name] = spec
        return spec

    @property
    def names(self) -> list:
        return list(self._specs)

    def spec(self, name: str) -> OffsetSpec:
        return self._specs[name]

    # ------------------------------------------------------------- refresh --
    def refresh(self) -> Dict[str, object]:
        """Re-plan every registered spec from current ratio state; returns
        the new device snapshot ``{name: (n_workers + 1,) int32}``.

        The commit is atomic: both the host mirror and the device snapshot
        are staged in locals and published together only after *every* spec
        has planned successfully.  A planner exception mid-refresh must not
        leave the host mirror ahead of the device snapshot — feedback
        replay would then compare device-recovered shard sizes against
        boundaries the device never saw.
        """
        import jax.numpy as jnp

        host: Dict[str, np.ndarray] = {}
        device: Dict[str, object] = {}
        for name, spec in self._specs.items():
            counts = np.asarray(self._plan_counts(spec), dtype=np.int64)
            if int(counts.sum()) != spec.total:
                raise ValueError(
                    f"planner returned {int(counts.sum())} units for "
                    f"{name!r} (expected {spec.total})")
            bounds = np.zeros(len(counts) + 1, dtype=np.int32)
            np.cumsum(counts, out=bounds[1:])
            if _contracts.contracts_enabled():
                _contracts.check_offset_boundaries(
                    bounds, spec.total,
                    where=f"OffsetSnapshot.refresh[{name}]")
            host[name] = bounds
            device[name] = jnp.asarray(bounds)
        self._host = host
        self._device = device
        if _ev.RECORDER is not None:
            for name, bounds in host.items():
                _ev.record("offsets", name, boundaries=bounds.tolist())
        return device

    def device(self) -> Dict[str, object]:
        """The latest device snapshot (refreshing first if none exists)."""
        if not self._device and self._specs:
            return self.refresh()
        return self._device

    # ---------------------------------------------------------- host mirror --
    def boundaries(self, name: str) -> np.ndarray:
        """Host-side cumulative boundaries of the latest snapshot."""
        if name not in self._host:
            self.refresh()
        return self._host[name]

    def counts(self, name: str) -> np.ndarray:
        """Host-side per-worker counts of the latest snapshot."""
        b = self.boundaries(name)
        return (b[1:] - b[:-1]).astype(np.int64)
