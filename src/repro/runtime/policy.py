"""Balance policies: how a total amount of parallel work becomes a plan.

A :class:`BalancePolicy` owns the two halves of the paper's control loop —

    plan(total)         -> Plan      (Eq. 3: proportional split)
    report(plan, times) -> ratios    (Eq. 2 + EMA feedback)

— over whatever domain the policy is configured for.  Policies are pure
host-side objects (numpy in / numpy out); the :class:`~repro.runtime.
balancer.Balancer` facade adds timing, telemetry, and the context-manager
lifecycle on top.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.ratio import proportional_partition

from .table import RatioTable

__all__ = [
    "Plan",
    "BalancePolicy",
    "ProportionalPolicy",
    "EvenPolicy",
    "RecursivePolicy",
    "clamp_to_capacity",
]


@dataclass(frozen=True)
class Plan:
    """One round's work assignment: per-worker counts along the parallel
    dimension, plus the key it was planned under."""

    counts: np.ndarray
    key: str = ""
    granularity: int = 1

    @property
    def n_workers(self) -> int:
        return len(self.counts)

    @property
    def total(self) -> int:
        return int(np.asarray(self.counts).sum())

    @property
    def weights(self) -> np.ndarray:
        """Fractional shares — e.g. the gradient-combine weights for uneven
        data parallelism (``sum_i w_i g_i`` equals the plain average over
        all ``total`` microbatches)."""
        return np.asarray(self.counts, dtype=np.float64) / max(self.total, 1)

    @property
    def ranges(self) -> list:
        """Contiguous ``[start, end)`` per worker (the paper splits one
        dimension into contiguous blocks, preserving cache locality)."""
        out, cursor = [], 0
        for c in self.counts:
            out.append((cursor, cursor + int(c)))
            cursor += int(c)
        return out


@runtime_checkable
class BalancePolicy(Protocol):
    """The plan/report lifecycle every balancing domain implements."""

    def plan(self, total: int) -> Plan: ...

    def report(self, plan: Plan, times) -> np.ndarray: ...


@dataclass
class ProportionalPolicy:
    """The paper's policy: split ``total`` proportionally to ``table``'s
    current ratios for ``key`` (Eq. 3), feed observed times back (Eq. 2).

    ``min_per_worker >= 1`` keeps every worker participating (a zero-count
    worker loses its throughput measurement; the paper keeps even LP-E
    cores in the table).  ``feedback`` selects the Eq.-2 variant:
    ``"times"`` assumes this round's work was proportional to the current
    table; ``"units"`` reports the realized per-worker counts so the update
    holds even when the plan was clamped or floored.

    ``active`` is an optional zero-argument probe returning a boolean
    per-worker mask (e.g. ``machine.active_mask`` at the pool clock).
    Masked-out workers get zero counts — and, because the table's
    ``units > 0`` rule already treats zero-count workers as unmeasured,
    their learned ratio is carried over unchanged through EMA feedback:
    a parked core resumes at its last known speed when it returns.  The
    plan keeps full width (fixed shapes downstream: no retrace, the
    compiled path just emits zero-width shard slices).  An all-False mask
    degenerates to all-active (the caller has nothing else to run on).
    """

    table: RatioTable
    key: str
    granularity: int = 1
    min_per_worker: int = 0
    feedback: str = "times"
    active: "Callable[[], np.ndarray] | None" = None

    def __post_init__(self) -> None:
        if self.feedback not in ("times", "units"):
            raise ValueError("feedback must be 'times' or 'units'")

    @property
    def n_workers(self) -> int:
        return self.table.n_workers

    def _mask(self) -> "np.ndarray | None":
        if self.active is None:
            return None
        mask = np.asarray(self.active(), dtype=bool)
        if mask.shape != (self.table.n_workers,):
            raise ValueError(
                f"active mask shape {mask.shape} != ({self.table.n_workers},)")
        if not mask.any():
            return None  # nothing else to run on: plan over everyone
        return mask

    def plan(self, total: int) -> Plan:
        n = self.table.n_workers
        mask = self._mask()
        if mask is None or mask.all():
            floor = self.min_per_worker * n
            if total < floor:
                raise ValueError(
                    f"need >= {floor} units for {n} workers "
                    f"(min_per_worker={self.min_per_worker})")
            counts = np.full(n, self.min_per_worker, dtype=np.int64)
            counts += proportional_partition(total - floor,
                                             self.table.ratios(self.key),
                                             self.granularity)
            return Plan(counts=counts, key=self.key,
                        granularity=self.granularity)
        # masked plan: floor only active workers, zero ratio elsewhere
        # (proportional_partition assigns nothing to zero-ratio workers)
        n_active = int(mask.sum())
        floor = self.min_per_worker * n_active
        if total < floor:
            raise ValueError(
                f"need >= {floor} units for {n_active} active workers "
                f"(min_per_worker={self.min_per_worker})")
        counts = np.where(mask, self.min_per_worker, 0).astype(np.int64)
        ratios = np.where(mask, self.table.ratios(self.key), 0.0)
        counts += proportional_partition(total - floor, ratios,
                                         self.granularity)
        return Plan(counts=counts, key=self.key, granularity=self.granularity)

    def report(self, plan: Plan, times) -> np.ndarray:
        units = np.asarray(plan.counts) if self.feedback == "units" else None
        return self.table.update(self.key, times, units=units)


@dataclass
class RecursivePolicy:
    """Eq. 2/3 over workers that are themselves balancing domains.

    The recursive hierarchy (fleet -> machine -> socket -> core) runs the
    same control law at every level; what changes at an inner node is only
    that each "worker" of its table is a whole Balancer-backed dispatcher
    with its own table underneath.  Planning and feedback are exactly
    :class:`ProportionalPolicy` (``units=`` feedback by default — realized
    per-worker work, robust to clamped plans); the recursion shows up in
    telemetry: ``collect_children()`` snapshots each child domain's latest
    own :class:`~repro.runtime.balancer.RegionStats`, which
    :meth:`~repro.runtime.balancer.Balancer.report` attaches to the
    emitted record (``RegionStats.children``), so one report at the top
    carries the ratio state of every level below it.

    ``children`` is a sequence of zero-argument callables, one per worker,
    each returning that worker's latest ``RegionStats`` (or ``None`` when
    it has not reported yet — those are simply omitted).
    """

    table: RatioTable
    key: str
    children: Sequence[Callable[[], object]] = ()
    granularity: int = 1
    min_per_worker: int = 0
    feedback: str = "units"
    active: "Callable[[], np.ndarray] | None" = None

    def __post_init__(self) -> None:
        self._inner = ProportionalPolicy(
            self.table, key=self.key, granularity=self.granularity,
            min_per_worker=self.min_per_worker, feedback=self.feedback,
            active=self.active)
        if self.children and len(self.children) != self.table.n_workers:
            raise ValueError(
                f"{len(self.children)} children for "
                f"{self.table.n_workers} workers")

    @property
    def n_workers(self) -> int:
        return self.table.n_workers

    def plan(self, total: int) -> Plan:
        return self._inner.plan(total)

    def report(self, plan: Plan, times) -> np.ndarray:
        return self._inner.report(plan, times)

    def collect_children(self) -> list:
        """Latest per-worker child RegionStats (non-reporting children are
        dropped; order follows the worker order of those that reported)."""
        out = []
        for probe in self.children:
            st = probe()
            if st is not None:
                out.append(st)
        return out


@dataclass
class EvenPolicy:
    """The static (OpenMP balanced parallel-for) baseline: equal shares,
    no feedback."""

    n_workers: int
    granularity: int = 1
    key: str = "static"

    def plan(self, total: int) -> Plan:
        counts = proportional_partition(total, np.ones(self.n_workers),
                                        self.granularity)
        return Plan(counts=counts, key=self.key, granularity=self.granularity)

    def report(self, plan: Plan, times) -> np.ndarray:
        return np.ones(self.n_workers)


def clamp_to_capacity(counts, capacities) -> np.ndarray:
    """Clamp a plan's counts to per-worker capacities, redistributing the
    overflow to workers with headroom (largest headroom first).

    Raises ``ValueError`` when the total exceeds the aggregate capacity —
    no single-round assignment can serve it.
    """
    counts = np.asarray(counts, dtype=np.int64).copy()
    caps = np.asarray(capacities, dtype=np.int64)
    if counts.shape != caps.shape:
        raise ValueError("counts and capacities must have the same shape")
    total = int(counts.sum())
    if total > int(caps.sum()):
        raise ValueError(
            f"total work {total} exceeds aggregate capacity {int(caps.sum())}")
    counts = np.minimum(counts, caps)
    excess = total - int(counts.sum())
    while excess > 0:
        headroom = caps - counts
        i = int(np.argmax(headroom))
        take = min(excess, int(headroom[i]))
        counts[i] += take
        excess -= take
    return counts
