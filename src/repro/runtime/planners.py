"""Pod/MoE/serving planners re-expressed as thin policies over the unified
runtime (the seed's ``repro.core.balance``, minus its private EMA loops).

The heterogeneous "cores" of the paper become heterogeneous *mesh slices*
(pods / hosts / replicas): thermal throttling, co-tenant interference,
failing-slow HBM, or mixed hardware generations produce exactly the
imbalance the paper measures on P/E cores.  Each planner below is the same
three-step loop — measure, EMA the ratio table, split proportionally — at a
different layer:

* :class:`UnevenBatchPlanner` — per-pod gradient-accumulation trip counts
  (worker ``i`` runs ``k_i ∝ pr_i`` local steps; one weighted all-reduce
  joins pods, so unequal trip counts cannot deadlock SPMD collectives).
* :class:`ExpertCapacityPlanner` — per-expert buffer capacity tracking the
  realized routing distribution at fixed total compute.
* :class:`ReplicaRouter` — request-to-replica routing proportional to
  measured replica throughput.

All planners are pure (numpy in / numpy out) and satisfy the
:class:`~repro.runtime.policy.BalancePolicy` lifecycle, so any of them can
sit behind a :class:`~repro.runtime.balancer.Balancer`.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.ratio import proportional_partition

from .policy import Plan, ProportionalPolicy
from .table import RatioTable

__all__ = [
    "DeviceRuntime",
    "MicrobatchPlan",
    "UnevenBatchPlanner",
    "ExpertCapacityPlanner",
    "ReplicaRouter",
]


class DeviceRuntime(RatioTable):
    """Per-slice performance table, keyed by program name (≈ the paper's
    per-ISA tables keyed by kernel).  Times come from host-side step timing
    (``block_until_ready`` around the local accumulation loop)."""

    def __init__(self, n_slices: int, alpha: float = 0.3, **kwargs):
        super().__init__(n_slices, alpha=alpha, **kwargs)

    @property
    def n_slices(self) -> int:
        return self.n_workers


# The per-pod microbatch plan is just a Plan; the name survives because the
# training stack reads ``plan.weights`` as gradient-combine weights.
MicrobatchPlan = Plan


class UnevenBatchPlanner(ProportionalPolicy):
    """Plan per-pod gradient-accumulation trip counts ∝ measured throughput.

    ``min_per_slice >= 1`` keeps every pod participating (a zero-count pod
    would contribute a zero-weight gradient but still must enter the final
    all-reduce; giving it at least one microbatch also keeps its throughput
    measurement alive — the paper keeps even the LP-E cores in the table).
    """

    def __init__(self, runtime: RatioTable, program: str = "train_step",
                 min_per_slice: int = 1):
        super().__init__(table=runtime, key=program,
                         min_per_worker=min_per_slice, feedback="units")

    @property
    def runtime(self) -> RatioTable:
        return self.table

    @property
    def program(self) -> str:
        return self.key

    @property
    def min_per_slice(self) -> int:
        return self.min_per_worker


class ReplicaRouter(ProportionalPolicy):
    """Serving-side Eq. 3: route request batches across model replicas
    proportionally to their measured decode throughput."""

    def __init__(self, runtime: RatioTable, program: str = "serve_step"):
        super().__init__(table=runtime, key=program, feedback="units")

    @property
    def runtime(self) -> RatioTable:
        return self.table

    @property
    def program(self) -> str:
        return self.key

    def split(self, batch_size: int) -> np.ndarray:
        return self.plan(batch_size).counts

    def report(self, plan, times) -> np.ndarray:
        """Accepts either a :class:`Plan` or a raw counts array (the realized
        split may differ from the planned one after capacity clamping)."""
        if not isinstance(plan, Plan):
            plan = Plan(counts=np.asarray(plan, dtype=np.int64), key=self.key)
        return super().report(plan, times)


class ExpertCapacityPlanner:
    """Eq. 3 applied to MoE expert buffers.

    A uniform capacity factor provisions every expert for the *average* load;
    hot experts then drop tokens while cold experts waste compute — the MoE
    incarnation of "P-cores waiting for E-cores".  This planner keeps an EMA
    of realized expert load *fractions* in a sum-normalized
    :class:`RatioTable` and assigns per-expert capacity proportionally,
    holding the *total* buffer (= compute cost) fixed.

    Capacities are quantized to ``granularity`` (MXU-friendly multiples) and
    floored at ``min_capacity`` so an expert can recover from a cold spell.
    """

    KEY = "expert_load"

    def __init__(self, n_experts: int, total_capacity: int, alpha: float = 0.3,
                 min_capacity: int = 8, granularity: int = 8,
                 table: Optional[RatioTable] = None):
        if min_capacity * n_experts > total_capacity:
            raise ValueError("min_capacity * n_experts exceeds total capacity")
        self.n_experts = n_experts
        self.total_capacity = total_capacity
        self.alpha = alpha
        self.min_capacity = min_capacity
        self.granularity = granularity
        self.table = table or RatioTable(
            n_experts, alpha=alpha, init_ratio=1.0 / n_experts,
            normalize="sum")

    @property
    def load_ema(self) -> np.ndarray:
        return self.table.ratios(self.KEY)

    def observe(self, expert_counts) -> None:
        counts = np.asarray(expert_counts, dtype=np.float64)
        total = counts.sum()
        if total <= 0:
            return
        self.table.observe(self.KEY, counts / total)

    def capacities(self) -> np.ndarray:
        floor = self.min_capacity * self.n_experts
        if floor > self.total_capacity:
            raise ValueError("min_capacity * n_experts exceeds total capacity")
        extra = proportional_partition(
            self.total_capacity - floor, self.load_ema, self.granularity
        )
        return np.full(self.n_experts, self.min_capacity, dtype=np.int64) + extra

    # ------------------------------------------ BalancePolicy lifecycle --
    def plan(self, total: Optional[int] = None) -> Plan:
        """Plan the capacity split (``total`` defaults to the fixed buffer;
        any other value is split with the same load EMA)."""
        if total is None or total == self.total_capacity:
            counts = self.capacities()
        else:
            floor = self.min_capacity * self.n_experts
            if total < floor:
                raise ValueError(f"need >= {floor} total capacity")
            counts = np.full(self.n_experts, self.min_capacity, dtype=np.int64)
            counts += proportional_partition(total - floor, self.load_ema,
                                             self.granularity)
        return Plan(counts=counts, key=self.KEY,
                    granularity=self.granularity)

    def report(self, plan: Plan, loads) -> np.ndarray:
        """Feedback for this domain is the realized expert-load vector (the
        'times' of MoE dispatch: tokens routed per expert this round)."""
        self.observe(loads)
        return self.load_ema
