"""The one keyed EMA performance-ratio table (paper §2.1, Eq. 2).

``RatioTable`` subsumes the seed's ``core.scheduler.CPURuntime`` (keyed by
primary ISA) and ``core.balance.DeviceRuntime`` (keyed by program name): a
key is *any* domain string naming one balancing context — an ISA, a jitted
program, an MoE layer, a replica group.  Every key owns one length-``n``
ratio vector updated by the paper's loop:

    observed speed -> normalize -> EMA filter (alpha)          (Eq. 2)

Two observation modes share one normalization rule (``normalize``):

* ``update(key, times)`` — the paper's literal Eq. 2: work this round was
  assigned proportionally to the current table, so worker ``i``'s
  demonstrated speed is ``pr_i / t_i``.
* ``update(key, times, units=...)`` — generalized Eq. 2: ``units`` is the
  work each worker actually received (microbatch counts, request counts),
  removing the proportional-assignment assumption: speed is ``u_i / t_i``.

``normalize="mean"`` scales observations so the valid entries average 1
(the paper's Fig. 4 convention: an all-ones table on a homogeneous machine);
``normalize="sum"`` makes them sum to 1 (the literal Eq. 2 form, also the
natural convention for load *fractions* such as MoE expert shares).

``RatioStore`` persists a table as JSON so ratios warm-start across
processes — the paper keeps tables alive across kernels within one run; we
additionally keep them alive across runs.

This module is the single ``ema_update`` call path in the repository.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, Optional

import numpy as np

from repro.analysis import invariants as _contracts
from repro.core import events as _ev
from repro.core.ratio import ema_update, observed_ratios

__all__ = ["RatioTable", "RatioStore"]

_NORMALIZE_MODES = ("mean", "sum")


class RatioTable:
    """Keyed EMA performance-ratio tables over ``n_workers`` workers."""

    def __init__(self, n_workers: int, alpha: float = 0.3,
                 init_ratio: float = 1.0, normalize: str = "mean",
                 max_history: int = 512):
        if n_workers < 1:
            raise ValueError("need at least one worker")
        if normalize not in _NORMALIZE_MODES:
            raise ValueError(f"normalize must be one of {_NORMALIZE_MODES}")
        if max_history < 1:
            raise ValueError("max_history must be >= 1")
        self.n_workers = n_workers
        self.alpha = alpha
        self.init_ratio = init_ratio
        self.normalize = normalize
        self.max_history = max_history
        self._tables: Dict[str, np.ndarray] = {}
        self.history: Dict[str, list] = {}

    # ------------------------------------------------------------- access --
    def keys(self) -> list:
        return list(self._tables)

    def ratios(self, key: str) -> np.ndarray:
        """The current table for ``key`` (created at ``init_ratio`` on first
        use — the paper initializes every ratio to 1)."""
        if key not in self._tables:
            self._tables[key] = np.full(self.n_workers,
                                        float(self.init_ratio))
            self.history[key] = [self._tables[key].copy()]
        return self._tables[key]

    def set(self, key: str, values) -> np.ndarray:
        """Overwrite ``key``'s table (warm start / test injection)."""
        values = np.asarray(values, dtype=np.float64)
        if values.shape != (self.n_workers,):
            raise ValueError(
                f"expected shape ({self.n_workers},), got {values.shape}")
        self.ratios(key)  # ensure history exists
        self._tables[key] = values.copy()
        self._record(key, self._tables[key])
        return self._tables[key]

    # ------------------------------------------------------------- update --
    def update(self, key: str, times, units=None) -> np.ndarray:
        """One Eq.-2 + EMA step from observed wall times; returns the new
        table.  Workers with ``t_i <= 0`` (or ``units_i <= 0``) received no
        work; their ratio is carried over unchanged."""
        pr = self.ratios(key)
        times = np.asarray(times, dtype=np.float64)
        if times.shape != pr.shape:
            raise ValueError("times must have one entry per worker")
        if units is None:
            observed = observed_ratios(pr, times, normalize=self.normalize)
            if _contracts.contracts_enabled():
                valid = np.isfinite(times) & (times > 0) & (pr > 0)
                _contracts.check_observation(observed, valid, self.normalize,
                                             where=f"RatioTable.update[{key}]")
        else:
            units = np.asarray(units, dtype=np.float64)
            if units.shape != pr.shape:
                raise ValueError("units must have one entry per worker")
            valid = np.isfinite(times) & (times > 0) & (units > 0)
            observed = pr.copy()
            # like observed_ratios: a singleton measurement on a multi-
            # worker table carries no relative information; carry over
            # instead of normalizing it to 1.0 (which would EMA-erase
            # learned heterogeneity whenever one worker runs alone)
            if valid.sum() >= 2 or (valid.any() and self.n_workers == 1):
                speed = np.zeros_like(pr)
                speed[valid] = units[valid] / times[valid]
                denom = speed[valid].sum()
                if denom > 0:
                    scale = (float(valid.sum()) if self.normalize == "mean"
                             else 1.0)
                    observed[valid] = speed[valid] / denom * scale
            if _contracts.contracts_enabled():
                _contracts.check_observation(observed, valid, self.normalize,
                                             where=f"RatioTable.update[{key}]")
        return self.observe(key, observed)

    def observe(self, key: str, observed) -> np.ndarray:
        """EMA-filter an externally computed observation into ``key``'s
        table (e.g. MoE load fractions, where the observation is a share
        vector rather than a time vector).  This is the repository's single
        ``ema_update`` call site."""
        pr = self.ratios(key)
        observed = np.asarray(observed, dtype=np.float64)
        if _ev.TRACER is not None:
            _ev.emit_read(self, f"tables[{key}]", where="RatioTable.observe")
            _ev.emit_write(self, f"tables[{key}]", where="RatioTable.observe")
        new = ema_update(pr, observed, self.alpha)
        if _contracts.contracts_enabled():
            _contracts.check_ema_step(pr, observed, new,
                                      where=f"RatioTable.observe[{key}]")
        self._tables[key] = new
        self._record(key, new)
        return new

    def _record(self, key: str, table: np.ndarray) -> None:
        h = self.history[key]
        h.append(table.copy())
        if len(h) > self.max_history:
            del h[: len(h) - self.max_history]

    # -------------------------------------------------------- persistence --
    def to_json(self) -> str:
        return json.dumps({
            "version": 1,
            "n_workers": self.n_workers,
            "alpha": self.alpha,
            "init_ratio": self.init_ratio,
            "normalize": self.normalize,
            "tables": {k: v.tolist() for k, v in self._tables.items()},
        }, indent=2)

    @classmethod
    def from_json(cls, text: str, **overrides) -> "RatioTable":
        doc = json.loads(text)
        if doc.get("version") != 1:
            raise ValueError(f"unknown ratio-table version {doc.get('version')}")
        kwargs = dict(n_workers=doc["n_workers"], alpha=doc["alpha"],
                      init_ratio=doc.get("init_ratio", 1.0),
                      normalize=doc.get("normalize", "mean"))
        kwargs.update(overrides)
        table = cls(**kwargs)
        for key, values in doc["tables"].items():
            table.set(key, np.asarray(values, dtype=np.float64))
        return table


class RatioStore:
    """Atomic JSON persistence for a :class:`RatioTable` at a fixed path."""

    def __init__(self, path: str):
        self.path = path

    def exists(self) -> bool:
        return os.path.exists(self.path)

    def save(self, table: RatioTable) -> None:
        """Write-then-rename so a crashed writer never leaves a torn file."""
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(table.to_json())
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def load(self, **overrides) -> Optional[RatioTable]:
        """Reconstruct the stored table, or ``None`` if nothing is stored."""
        if not self.exists():
            return None
        with open(self.path) as f:
            return RatioTable.from_json(f.read(), **overrides)

    def load_into(self, table: RatioTable, active=None) -> bool:
        """Warm-start an existing table from the store.  Returns False (and
        leaves ``table`` untouched) when nothing compatible is stored.

        Compatible means same worker count *and* same learning conventions:
        a sum-normalized table loaded into a mean-normalized one (or vice
        versa) is off by a factor of ``n_workers`` and would corrupt the
        learned ratios, and a different ``alpha`` silently changes the
        filter the stored history was produced under — both are refused
        rather than blended.

        ``active`` (a boolean mask over ``table``'s full worker width)
        reconciles the *same machine* saved under a different capacity
        state — e.g. a table saved while some cores were parked, or loaded
        while some now are:

        * *expand* — ``active`` has ``table.n_workers`` entries and the
          store's width equals its True count: the store was saved by an
          active-width table; stored values land in the active positions,
          inactive workers keep their current (init or learned) ratios.
        * *compress* — ``active`` has ``stored.n_workers`` entries and the
          table's width equals its True count: the store is full-width but
          the live table only spans the active cores; the stored vector is
          projected down via ``stored[mask]``.

        Any other width combination is a genuinely different machine and
        is refused, exactly as before.  (The preferred design keeps tables
        full-width and masks planning instead — see
        ``ProportionalPolicy.active`` — so parked cores keep their stored
        ratios without any projection at all.)

        A torn or corrupt file (a crashed writer predating the atomic
        rename, or a truncated copy) is treated as "nothing stored":
        warm-start is an optimization, so a cold start beats crashing the
        serve."""
        try:
            stored = self.load()
        except (json.JSONDecodeError, KeyError, TypeError, ValueError):
            return False
        if (stored is None or stored.normalize != table.normalize
                or stored.alpha != table.alpha):
            return False
        if stored.n_workers == table.n_workers:
            for key in stored.keys():
                table.set(key, stored.ratios(key))
            return True
        if active is None:
            return False
        mask = np.asarray(active, dtype=bool)
        if (mask.shape == (table.n_workers,)
                and stored.n_workers == int(mask.sum())):
            # expand: active-width store -> full-width table
            for key in stored.keys():
                values = table.ratios(key).copy()
                values[mask] = stored.ratios(key)
                table.set(key, values)
            return True
        if (mask.shape == (stored.n_workers,)
                and table.n_workers == int(mask.sum())):
            # compress: full-width store -> active-width table
            for key in stored.keys():
                table.set(key, stored.ratios(key)[mask])
            return True
        return False  # not a masked view of this machine: refuse
