"""Kernel-level schedulers (paper §2.1–2.2) as thin policies over the
unified runtime.

``CPURuntime`` is the paper's per-ISA ratio table — now literally a
:class:`~repro.runtime.table.RatioTable` whose keys are primary ISAs.
``DynamicScheduler`` composes one :class:`~repro.runtime.balancer.Balancer`
per (ISA, granularity) over that table and dispatches kernel parallel
regions through it; ``StaticScheduler`` is the same dispatch over
:class:`~repro.runtime.policy.EvenPolicy` (the OpenMP-balanced baseline of
the paper's experiments, no feedback).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.pool import SubTask

from .balancer import Balancer, RegionStats, StatsSink
from .policy import EvenPolicy, Plan, ProportionalPolicy
from .table import RatioTable

__all__ = ["KernelSpec", "CPURuntime", "DynamicScheduler", "StaticScheduler"]


@dataclass(frozen=True)
class KernelSpec:
    """A parallel kernel as the scheduler sees it.

    ``work_per_unit`` converts one unit of the parallel dimension into
    abstract work (FLOPs / bytes) — used only by the virtual-time pool.
    ``key`` optionally separates the ratio-table key from the execution
    ISA: balanced-trunk dispatch learns one table per (ISA, layer kind)
    — e.g. ``"membw/attn_proj"`` — while the pool/machine still executes
    under the plain ISA.
    """

    name: str
    isa: str  # primary ISA, e.g. "avx_vnni", "avx2", "membw"
    granularity: int = 1  # tile size along the parallel dim
    work_per_unit: float = 1.0
    key: Optional[str] = None  # ratio-table key override (defaults to isa)

    @property
    def table_key(self) -> str:
        return self.key if self.key is not None else self.isa


class CPURuntime(RatioTable):
    """Per-core performance ratios, one table per ISA (paper §2.1).

    The paper found that kernels sharing a primary ISA share ratios, so
    tables are keyed by ISA and every kernel declares its primary ISA.
    """


def run_plan(pool, plan: Plan, fn: Optional[Callable[[int, int], None]],
             work_per_unit: float = 1.0) -> np.ndarray:
    """Execute one planned region on a worker pool; per-worker times."""
    subtasks = [
        SubTask(worker=w, start=lo, size=hi - lo,
                work=float(hi - lo) * work_per_unit, fn=fn)
        for w, (lo, hi) in enumerate(plan.ranges)
    ]
    return pool.run(subtasks)


class _PooledScheduler:
    """Shared dispatch machinery: a Balancer per (isa, granularity)."""

    def __init__(self, pool, sink: Optional[StatsSink] = None):
        self.pool = pool
        self.sink = sink
        self.stats: list = []
        self._balancers: Dict[tuple, Balancer] = {}

    def _policy(self, kernel: KernelSpec):
        raise NotImplementedError

    def balancer(self, kernel: KernelSpec) -> Balancer:
        key = (kernel.table_key, kernel.granularity)
        if key not in self._balancers:
            self._balancers[key] = Balancer(self._policy(kernel),
                                            sink=self.sink,
                                            keep_stats=False)
        return self._balancers[key]

    def partition(self, kernel: KernelSpec, s: int) -> np.ndarray:
        return self.balancer(kernel).plan(s).counts

    def dispatch(
        self,
        kernel: KernelSpec,
        s: int,
        fn: Optional[Callable[[int, int], None]] = None,
        *,
        update: bool = True,
    ) -> RegionStats:
        """Run one parallel region of size ``s`` along the kernel's dim."""
        bal = self.balancer(kernel)
        plan = bal.plan(s)
        times = run_plan(self.pool, plan, fn, kernel.work_per_unit)
        st = bal.report(plan, times, update=update, label=kernel.name)
        self.stats.append(st)
        return st


class DynamicScheduler(_PooledScheduler):
    """Paper §2.2: proportional dispatch + feedback (the contribution)."""

    def __init__(self, runtime: RatioTable, pool,
                 sink: Optional[StatsSink] = None):
        super().__init__(pool, sink=sink)
        self.runtime = runtime

    def _policy(self, kernel: KernelSpec) -> ProportionalPolicy:
        return ProportionalPolicy(self.runtime, key=kernel.table_key,
                                  granularity=kernel.granularity)


class StaticScheduler(_PooledScheduler):
    """OpenMP-style balanced dispatch: every worker gets an equal slice.

    This is the baseline of the paper's Fig. 2/3 ("OpenMP here uses the
    balanced work dispatch algorithm. Each thread computes the same size of
    sub-matrix").
    """

    def _policy(self, kernel: KernelSpec) -> EvenPolicy:
        return EvenPolicy(self.pool.n_workers, granularity=kernel.granularity)

    def dispatch(self, kernel, s, fn=None, *, update: bool = False):
        return super().dispatch(kernel, s, fn, update=update)
