"""TPU-scale adaptation of the paper's dynamic parallel method.

On a pod-scale machine the heterogeneous "cores" of the paper become
heterogeneous *mesh slices* (pods / hosts): thermal throttling, co-tenant
interference, failing-slow HBM, or mixed hardware generations all produce
exactly the imbalance the paper measures on P/E cores.  The same three-step
loop applies — measure per-worker times, update an EMA ratio table, dispatch
the next round proportionally — but the "parallel dimension" being split is
now one of:

* **microbatch counts** per data-parallel pod (gradient accumulation):
  :class:`UnevenBatchPlanner`.  Worker ``i`` runs ``k_i ∝ pr_i`` local
  accumulation steps (no collectives inside), then a single weighted
  all-reduce joins pods — unequal trip counts therefore cannot deadlock
  SPMD collectives.
* **expert capacity** in MoE dispatch: :class:`ExpertCapacityPlanner`
  applies Eq. 3 to observed expert loads so that per-expert buffer capacity
  tracks the realized routing distribution instead of a uniform
  ``capacity_factor``.
* **request-to-replica routing** for serving: :class:`ReplicaRouter` sends
  a share of each batch to each model replica proportional to its measured
  throughput.

All planners are pure (numpy in / numpy out) so they can be unit-tested and
run on the host between steps without touching device state.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from . import ratio as R

__all__ = [
    "DeviceRuntime",
    "UnevenBatchPlanner",
    "ExpertCapacityPlanner",
    "ReplicaRouter",
]


class DeviceRuntime:
    """Per-slice performance table, keyed by program name (≈ the paper's
    per-ISA tables keyed by kernel).  Times come from host-side step timing
    (``block_until_ready`` around the local accumulation loop)."""

    def __init__(self, n_slices: int, alpha: float = 0.3):
        self.n_slices = n_slices
        self.alpha = alpha
        self._tables: Dict[str, np.ndarray] = {}
        self.history: Dict[str, list[np.ndarray]] = {}

    def ratios(self, program: str) -> np.ndarray:
        if program not in self._tables:
            self._tables[program] = np.ones(self.n_slices)
            self.history[program] = [self._tables[program].copy()]
        return self._tables[program]

    def update(self, program: str, times: np.ndarray,
               units: Optional[np.ndarray] = None) -> np.ndarray:
        """Update from observed wall times.

        ``units`` is the work each slice actually received this round (e.g.
        its microbatch count).  The paper's Eq. 2 assumes work was assigned
        proportionally to the *current* table; passing ``units`` removes that
        assumption: speed_i = units_i / times_i.
        """
        pr = self.ratios(program)
        times = np.asarray(times, dtype=np.float64)
        if units is None:
            observed = R.observed_ratios(pr, times)
        else:
            units = np.asarray(units, dtype=np.float64)
            valid = (times > 0) & (units > 0)
            observed = pr.copy()
            if valid.any():
                speed = np.zeros_like(pr)
                speed[valid] = units[valid] / times[valid]
                observed[valid] = speed[valid] / speed[valid].sum() * valid.sum()
        new = R.ema_update(pr, observed, self.alpha)
        self._tables[program] = new
        self.history[program].append(new.copy())
        return new


@dataclass
class MicrobatchPlan:
    """Per-slice microbatch counts plus the weights for gradient combine.

    Gradients are averaged per-microbatch locally; the global combine is
    ``sum_i(w_i * g_i)`` with ``w_i = k_i / sum(k)`` so the result equals the
    plain average over all ``sum(k)`` microbatches.
    """

    counts: np.ndarray

    @property
    def total(self) -> int:
        return int(self.counts.sum())

    @property
    def weights(self) -> np.ndarray:
        return self.counts / max(self.total, 1)


class UnevenBatchPlanner:
    """Plan per-pod gradient-accumulation trip counts ∝ measured throughput.

    ``min_per_slice >= 1`` keeps every pod participating (a zero-count pod
    would contribute a zero-weight gradient but still must enter the final
    all-reduce; giving it at least one microbatch also keeps its throughput
    measurement alive — the paper keeps even the LP-E cores in the table).
    """

    def __init__(self, runtime: DeviceRuntime, program: str = "train_step",
                 min_per_slice: int = 1):
        self.runtime = runtime
        self.program = program
        self.min_per_slice = min_per_slice

    def plan(self, total_microbatches: int) -> MicrobatchPlan:
        n = self.runtime.n_slices
        if total_microbatches < n * self.min_per_slice:
            raise ValueError(
                f"need >= {n * self.min_per_slice} microbatches for {n} slices"
            )
        pr = self.runtime.ratios(self.program)
        floor = self.min_per_slice * n
        counts = np.full(n, self.min_per_slice, dtype=np.int64)
        counts += R.proportional_partition(total_microbatches - floor, pr)
        return MicrobatchPlan(counts=counts)

    def report(self, plan: MicrobatchPlan, times: np.ndarray) -> np.ndarray:
        return self.runtime.update(self.program, times, units=plan.counts)


class ExpertCapacityPlanner:
    """Eq. 3 applied to MoE expert buffers.

    A uniform capacity factor provisions every expert for the *average* load;
    hot experts then drop tokens while cold experts waste compute — the MoE
    incarnation of "P-cores waiting for E-cores".  This planner tracks an EMA
    of realized expert loads and assigns per-expert capacity proportionally,
    holding the *total* buffer (= compute cost) fixed.

    Capacities are quantized to ``granularity`` (MXU-friendly multiples) and
    floored at ``min_capacity`` so an expert can recover from a cold spell.
    """

    def __init__(self, n_experts: int, total_capacity: int, alpha: float = 0.3,
                 min_capacity: int = 8, granularity: int = 8):
        self.n_experts = n_experts
        self.total_capacity = total_capacity
        self.alpha = alpha
        self.min_capacity = min_capacity
        self.granularity = granularity
        self.load_ema = np.full(n_experts, 1.0 / n_experts)

    def observe(self, expert_counts: np.ndarray) -> None:
        counts = np.asarray(expert_counts, dtype=np.float64)
        total = counts.sum()
        if total <= 0:
            return
        self.load_ema = R.ema_update(self.load_ema, counts / total, self.alpha)

    def capacities(self) -> np.ndarray:
        floor = self.min_capacity * self.n_experts
        if floor > self.total_capacity:
            raise ValueError("min_capacity * n_experts exceeds total capacity")
        extra = R.proportional_partition(
            self.total_capacity - floor, self.load_ema, self.granularity
        )
        return np.full(self.n_experts, self.min_capacity, dtype=np.int64) + extra


class ReplicaRouter:
    """Serving-side Eq. 3: route request batches across model replicas
    proportionally to their measured decode throughput."""

    def __init__(self, runtime: DeviceRuntime, program: str = "serve_step"):
        self.runtime = runtime
        self.program = program

    def split(self, batch_size: int) -> np.ndarray:
        pr = self.runtime.ratios(self.program)
        return R.proportional_partition(batch_size, pr)

    def report(self, counts: np.ndarray, times: np.ndarray) -> np.ndarray:
        return self.runtime.update(self.program, times, units=counts)
