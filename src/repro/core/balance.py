"""Deprecated shim — the pod/MoE/serving planners moved to
:mod:`repro.runtime`.

``repro.core.balance`` was the seed's TPU-scale adaptation of the paper's
method, with its own private EMA loop (``DeviceRuntime``).  The
implementation now lives in :mod:`repro.runtime.planners`, where
``DeviceRuntime`` is a keyed :class:`repro.runtime.RatioTable` and every
planner is a thin :class:`repro.runtime.BalancePolicy`.  Import from
``repro.runtime`` — this module re-exports for one release and will then
be removed.
"""

from __future__ import annotations

from repro.runtime.planners import (
    DeviceRuntime,
    MicrobatchPlan,
    UnevenBatchPlanner,
    ExpertCapacityPlanner,
    ReplicaRouter,
)

__all__ = [
    "DeviceRuntime",
    "MicrobatchPlan",
    "UnevenBatchPlanner",
    "ExpertCapacityPlanner",
    "ReplicaRouter",
]
