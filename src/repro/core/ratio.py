"""Performance-ratio mathematics of the paper (Eqs. 1-3) plus the EMA filter.

The paper ("A dynamic parallel method for performance optimization on hybrid
CPUs", CS.DC 2024) models a parallel problem of size ``K`` solved by ``N``
workers with (unknown, drifting) throughputs.  Worker ``i`` holds a
*performance ratio* ``pr_i``; the scheduler assigns it a share

    s_i = pr_i / sum_j(pr_j) * s                                   (Eq. 3)

of the parallel dimension ``s``, which is makespan-optimal when the ratios
equal the true relative throughputs (Eq. 1).  After every parallel region the
observed per-worker times ``t_i`` update the table via

    pr_i' = pr_i / (t_i * sum_j(pr_j / t_j))                       (Eq. 2)

(i.e. the normalized *observed speed* ``(pr_i/t_i) / sum_j(pr_j/t_j)``),
followed by an exponential filter ``pr_i <- alpha*pr_i + (1-alpha)*pr_i'``.

Normalization note: Eq. 2 as printed normalizes the ratios to sum to 1,
while the paper initializes every ratio to 1 (sum = N) and Fig. 4 plots a
P-core ratio stabilizing near 3.5 on a 14-core part — both only consistent
with a *mean*-normalized table (sum = N).  Since Eq. 3 is scale-invariant,
the two conventions are behaviourally identical; we default to ``"mean"``
so that a homogeneous machine keeps the paper's all-ones table and Fig. 4
magnitudes reproduce, and keep ``"sum"`` available for the literal form.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "optimal_shares",
    "observed_ratios",
    "ema_update",
    "proportional_partition",
    "partition_ranges",
    "makespan",
]


def optimal_shares(ratios: np.ndarray) -> np.ndarray:
    """Eq. 1: the makespan-minimizing fractional shares ``theta_i``."""
    ratios = np.asarray(ratios, dtype=np.float64)
    if np.any(ratios < 0):
        raise ValueError("performance ratios must be non-negative")
    total = ratios.sum()
    if total <= 0:
        # Degenerate: nothing is known to be able to work; split evenly.
        return np.full_like(ratios, 1.0 / len(ratios))
    return ratios / total


def observed_ratios(
    ratios: np.ndarray, times: np.ndarray, *, normalize: str = "mean"
) -> np.ndarray:
    """Eq. 2: new ratios from the previous table and observed times.

    ``pr_i' = (pr_i / t_i) / sum_j (pr_j / t_j)`` — the speed each worker
    *demonstrated* this round (its assigned share was proportional to
    ``pr_i``, it took ``t_i``, hence speed ``pr_i/t_i``), renormalized.

    Workers that received no work report ``t_i == 0`` (or NaN); their ratio
    is carried over unchanged (renormalized with the rest).  A round in
    which only *one* of several workers was measured is also carried over
    whole: a singleton observation has no relative information, and
    normalizing it (to 1.0 under "mean") would erase whatever
    heterogeneity the table has already learned.
    """
    ratios = np.asarray(ratios, dtype=np.float64)
    times = np.asarray(times, dtype=np.float64)
    if ratios.shape != times.shape:
        raise ValueError("ratios and times must have the same shape")
    n = len(ratios)
    valid = np.isfinite(times) & (times > 0) & (ratios > 0)
    if not np.any(valid) or (n > 1 and valid.sum() == 1):
        return ratios.copy()
    if normalize not in ("mean", "sum"):
        raise ValueError("normalize must be 'mean' or 'sum'")
    speed = np.zeros_like(ratios)
    speed[valid] = ratios[valid] / times[valid]
    denom = speed[valid].sum()
    new = np.array(ratios, copy=True)
    if denom > 0:
        scale = float(valid.sum()) if normalize == "mean" else 1.0
        new[valid] = speed[valid] / denom * scale
    return new


def ema_update(
    ratios: np.ndarray, new_ratios: np.ndarray, alpha: float = 0.3
) -> np.ndarray:
    """The paper's constant-gain filter: ``alpha*pr + (1-alpha)*pr'``."""
    if not 0.0 <= alpha <= 1.0:
        raise ValueError("alpha must be in [0, 1]")
    ratios = np.asarray(ratios, dtype=np.float64)
    new_ratios = np.asarray(new_ratios, dtype=np.float64)
    return alpha * ratios + (1.0 - alpha) * new_ratios


def proportional_partition(
    s: int, ratios: np.ndarray, granularity: int = 1
) -> np.ndarray:
    """Eq. 3 with integer/tile constraints: split ``s`` units into per-worker
    counts ``s_i`` such that

      * ``sum(s_i) == s``,
      * each ``s_i`` is a multiple of ``granularity`` (except that the
        largest-share worker absorbs the non-divisible remainder),
      * ``s_i`` is (largest-remainder) rounded from the ideal real share
        ``pr_i / sum(pr) * s``.

    Returns an int64 array of length ``len(ratios)``.
    """
    if s < 0:
        raise ValueError("s must be non-negative")
    if granularity < 1:
        raise ValueError("granularity must be >= 1")
    ratios = np.asarray(ratios, dtype=np.float64)
    n = len(ratios)
    if n == 0:
        raise ValueError("need at least one worker")
    shares = optimal_shares(ratios)

    tiles, rem = divmod(s, granularity)
    # Floor of the ideal share, then makespan-aware greedy for the remainder:
    # each leftover tile goes to the worker whose completion time after
    # receiving it is smallest (LPT-style).  This is Eq. 3 up to integer
    # rounding and strictly dominates largest-remainder rounding when tiles
    # are coarse relative to slow workers' shares.
    ideal = shares * tiles
    base = np.floor(ideal).astype(np.int64)
    short = int(tiles - base.sum())
    if short > 0:
        pos = ratios > 0
        if not pos.any():
            pos = np.ones(n, dtype=bool)
        safe_pr = np.where(pos, np.where(ratios > 0, ratios, 1.0), 1.0)
        for _ in range(short):
            t_after = np.where(pos, (base + 1) / safe_pr, np.inf)
            base[int(np.argmin(t_after))] += 1
    counts = base * granularity
    if rem:
        # The non-divisible tail goes to the fastest worker (it hurts least).
        counts[int(np.argmax(ratios))] += rem
    assert counts.sum() == s
    return counts


def partition_ranges(
    s: int, ratios: np.ndarray, granularity: int = 1
) -> list[tuple[int, int]]:
    """Contiguous ``[start, end)`` ranges per worker (the paper splits along
    one dimension into contiguous blocks, preserving cache locality)."""
    counts = proportional_partition(s, ratios, granularity)
    out, cursor = [], 0
    for c in counts:
        out.append((cursor, cursor + int(c)))
        cursor += int(c)
    return out


def makespan(counts: np.ndarray, true_throughput: np.ndarray) -> float:
    """T = max_i (s_i / throughput_i) — the quantity Eq. 1 minimizes."""
    counts = np.asarray(counts, dtype=np.float64)
    tp = np.asarray(true_throughput, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        t = np.where(counts > 0, counts / tp, 0.0)
    return float(np.max(t))
