"""Worker pools with per-worker execution-time recording.

The paper's CPU runtime "binds each thread to a physical core and tracks the
execution time of each thread during executing kernels".  Two pools implement
the same interface:

* :class:`ThreadWorkerPool` — real OS threads, one per (simulated) core, with
  wall-clock timing.  On this 1-core container it is functionally correct but
  cannot exhibit hybrid-CPU timing, so it is used for correctness smoke tests.
* :class:`VirtualWorkerPool` — a deterministic virtual-time model of a hybrid
  CPU (see :mod:`repro.core.hybrid_sim`).  Sub-task "execution" optionally
  runs the real ``fn`` for correctness, while the reported per-worker times
  come from the core model:  ``t_i = work_i / effective_throughput_i``.

Both report times with the same shape so the scheduler/runtime code is
identical — exactly the property the paper relies on (the scheduler only ever
sees (worker, time) pairs).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

__all__ = ["SubTask", "ThreadWorkerPool", "VirtualWorkerPool"]


@dataclass
class SubTask:
    """One worker's slice of a parallel region.

    ``fn(start, size)`` performs the real computation (may be ``None`` for
    purely-modelled runs); ``work`` is the abstract work volume (e.g. FLOPs
    or bytes) used by the virtual-time model.
    """

    worker: int
    start: int
    size: int
    work: float
    fn: Optional[Callable[[int, int], None]] = None


class ThreadWorkerPool:
    """One persistent thread per worker; dispatch/join per parallel region.

    Threads are persistent (created once) to mirror the paper's bound thread
    pool — creating threads per region would swamp the timings the runtime
    learns from.
    """

    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self._tasks: list[Optional[SubTask]] = [None] * n_workers
        self._times = np.zeros(n_workers)
        self._go = [threading.Event() for _ in range(n_workers)]
        self._done = [threading.Event() for _ in range(n_workers)]
        self._stop = False
        self._threads = [
            threading.Thread(target=self._loop, args=(i,), daemon=True)
            for i in range(n_workers)
        ]
        for t in self._threads:
            t.start()

    def _loop(self, i: int) -> None:
        while True:
            self._go[i].wait()
            self._go[i].clear()
            if self._stop:
                return
            task = self._tasks[i]
            t0 = time.perf_counter()
            if task is not None and task.fn is not None and task.size > 0:
                task.fn(task.start, task.size)
            self._times[i] = time.perf_counter() - t0
            self._done[i].set()

    def run(self, subtasks: Sequence[SubTask]) -> np.ndarray:
        """Execute one parallel region; returns per-worker times (seconds).

        Workers with no sub-task report time 0 (skipped by the runtime).
        """
        self._times[:] = 0.0
        self._tasks = [None] * self.n_workers
        active = []
        for st in subtasks:
            if st.size > 0:
                self._tasks[st.worker] = st
                active.append(st.worker)
        for w in active:
            self._done[w].clear()
            self._go[w].set()
        for w in active:
            self._done[w].wait()
        return self._times.copy()

    def close(self) -> None:
        self._stop = True
        for e in self._go:
            e.set()
        for t in self._threads:
            t.join(timeout=1.0)


class VirtualWorkerPool:
    """Deterministic virtual-time pool backed by a hybrid-CPU model.

    ``machine`` is any object exposing
    ``task_time(worker: int, isa: str, work: float, now: float) -> float``
    (see :class:`repro.core.hybrid_sim.SimulatedHybridCPU`).  The pool keeps a
    virtual clock that advances by the *makespan* of each region, exactly as a
    barrier-synchronized parallel-for would.
    """

    def __init__(self, machine, isa: str = "avx2", execute: bool = False):
        self.machine = machine
        self.n_workers = machine.n_cores
        self.isa = isa
        self.execute = execute
        self.clock = 0.0

    def run(self, subtasks: Sequence[SubTask]) -> np.ndarray:
        times = np.zeros(self.n_workers)
        for st in subtasks:
            if st.size <= 0:
                continue
            if self.execute and st.fn is not None:
                st.fn(st.start, st.size)
            times[st.worker] = self.machine.task_time(
                st.worker, self.isa, st.work, self.clock
            )
        self.clock += float(times.max(initial=0.0))
        return times

    def close(self) -> None:  # interface parity
        pass
