"""Worker pools with per-worker execution-time recording.

The paper's CPU runtime "binds each thread to a physical core and tracks the
execution time of each thread during executing kernels".  Two pools implement
the same interface:

* :class:`ThreadWorkerPool` — real OS threads, one per (simulated) core, with
  wall-clock timing.  On this 1-core container it is functionally correct but
  cannot exhibit hybrid-CPU timing, so it is used for correctness smoke tests.
* :class:`VirtualWorkerPool` — a deterministic virtual-time model of a hybrid
  CPU (see :mod:`repro.core.hybrid_sim`).  Sub-task "execution" optionally
  runs the real ``fn`` for correctness, while the reported per-worker times
  come from the core model:  ``t_i = work_i / effective_throughput_i``.

Both report times with the same shape so the scheduler/runtime code is
identical — exactly the property the paper relies on (the scheduler only ever
sees (worker, time) pairs).

A region may assign *several* sub-tasks to the same worker (chunked shard
dispatch does this); each worker runs its sub-tasks sequentially and its
reported time is the sum over them.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core import events as _ev

__all__ = ["SubTask", "ThreadWorkerPool", "VirtualWorkerPool"]


@dataclass
class SubTask:
    """One worker's slice of a parallel region.

    ``fn(start, size)`` performs the real computation (may be ``None`` for
    purely-modelled runs); ``work`` is the abstract work volume (e.g. FLOPs
    or bytes) used by the virtual-time model.
    """

    worker: int
    start: int
    size: int
    work: float
    fn: Optional[Callable[[int, int], None]] = None


class ThreadWorkerPool:
    """One persistent thread per worker; dispatch/join per parallel region.

    Threads are persistent (created once) to mirror the paper's bound thread
    pool — creating threads per region would swamp the timings the runtime
    learns from.
    """

    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self._tasks: list[List[SubTask]] = [[] for _ in range(n_workers)]
        self._times = np.zeros(n_workers)
        self._errors: list[Optional[BaseException]] = [None] * n_workers
        self._region = 0
        self._task_labels: list[Optional[str]] = [None] * n_workers
        self._go = [threading.Event() for _ in range(n_workers)]
        self._done = [threading.Event() for _ in range(n_workers)]
        self._stop = False
        self._threads = [
            threading.Thread(target=self._loop, args=(i,), daemon=True)
            for i in range(n_workers)
        ]
        for t in self._threads:
            t.start()

    def _loop(self, i: int) -> None:
        while True:
            self._go[i].wait()
            self._go[i].clear()
            if self._stop:
                return
            t0 = time.perf_counter()
            label = self._task_labels[i]
            if label is not None:
                _ev.push_task(label)
            # A raising shard fn must not kill the worker thread: run()
            # joins on _done (a dead thread would deadlock it) and
            # re-raises the stored error on the caller's side.
            try:
                for task in self._tasks[i]:
                    if task.fn is not None and task.size > 0:
                        task.fn(task.start, task.size)
            except BaseException as e:
                self._errors[i] = e
            finally:
                self._times[i] = time.perf_counter() - t0
                if label is not None:
                    _ev.pop_task()
                self._done[i].set()

    def run(self, subtasks: Sequence[SubTask]) -> np.ndarray:
        """Execute one parallel region; returns per-worker times (seconds).

        Workers with no sub-task report time 0 (skipped by the runtime).
        A worker assigned several sub-tasks runs them back to back and
        reports the total.
        """
        self._times[:] = 0.0
        self._errors = [None] * self.n_workers
        self._tasks = [[] for _ in range(self.n_workers)]
        for st in subtasks:
            if st.size > 0:
                self._tasks[st.worker].append(st)
        active = [w for w in range(self.n_workers) if self._tasks[w]]
        tracing = _ev.TRACER is not None
        self._region += 1
        for w in active:
            if tracing:
                label = f"{_ev.label(self)}/r{self._region}/w{w}"
                self._task_labels[w] = label
                _ev.emit_fork(label, where="ThreadWorkerPool.run")
            else:
                self._task_labels[w] = None
            self._done[w].clear()
            self._go[w].set()
        for w in active:
            self._done[w].wait()
            if tracing and self._task_labels[w] is not None:
                _ev.emit_join(self._task_labels[w],
                              where="ThreadWorkerPool.run")
        errors = [e for e in self._errors if e is not None]
        if errors:
            # chain concurrent failures so none is silently discarded —
            # the traceback shows every worker's error, not just worker 0's
            for first, rest in zip(errors, errors[1:]):
                first.__cause__ = rest
            raise errors[0]
        return self._times.copy()

    def close(self) -> None:
        self._stop = True
        for e in self._go:
            e.set()
        for t in self._threads:
            t.join(timeout=1.0)


class VirtualWorkerPool:
    """Deterministic virtual-time pool backed by a hybrid-CPU model.

    ``machine`` is any object exposing
    ``task_time(worker: int, isa: str, work: float, now: float) -> float``
    (see :class:`repro.core.hybrid_sim.SimulatedHybridCPU`).  The pool keeps a
    virtual clock that advances by the *makespan* of each region, exactly as a
    barrier-synchronized parallel-for would.  A worker's sub-tasks run
    sequentially, each starting at the virtual instant the previous one
    finished, so time-varying background load lands on the right sub-task.
    """

    def __init__(self, machine, isa: str = "avx2", execute: bool = False):
        self.machine = machine
        self.n_workers = machine.n_cores
        self.isa = isa
        self.execute = execute
        self.clock = 0.0
        self._region = 0

    def run(self, subtasks: Sequence[SubTask]) -> np.ndarray:
        times = np.zeros(self.n_workers)
        # Sub-tasks execute sequentially here, but each (region, worker) is
        # its own *logical* task for the race detector: fork/join are the
        # only ordering edges a real parallel pool would provide, so the
        # replayed schedule exposes synchronization bugs this virtual
        # execution merely masks.
        tracing = _ev.TRACER is not None
        forked: dict = {}
        if tracing:
            self._region += 1
        for st in subtasks:
            if st.size <= 0:
                continue
            if tracing:
                label = forked.get(st.worker)
                if label is None:
                    label = f"{_ev.label(self)}/r{self._region}/w{st.worker}"
                    forked[st.worker] = label
                    _ev.emit_fork(label, where="VirtualWorkerPool.run")
                _ev.push_task(label)
            try:
                if self.execute and st.fn is not None:
                    st.fn(st.start, st.size)
            finally:
                if tracing:
                    _ev.pop_task()
            t_start = self.clock + times[st.worker]
            dt = self.machine.task_time(st.worker, self.isa, st.work, t_start)
            times[st.worker] += dt
            if tracing:
                _ev.emit_span(
                    f"core{st.worker}", self.isa, t_start, dt, cat="pool",
                    args=lambda st=st: {"start": st.start, "size": st.size,
                                        "work": st.work})
        if tracing:
            for label in forked.values():
                _ev.emit_join(label, where="VirtualWorkerPool.run")
        self.clock += float(times.max(initial=0.0))
        return times

    def close(self) -> None:  # interface parity
        pass
