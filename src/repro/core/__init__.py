"""Core of the reproduction: the paper's dynamic parallel method.

Faithful layer (paper §2): :mod:`ratio`, :mod:`pool`, :mod:`scheduler`,
:mod:`hybrid_sim`.  TPU-scale adaptation: :mod:`balance`, :mod:`tuner`.
"""

from .ratio import (
    optimal_shares,
    observed_ratios,
    ema_update,
    proportional_partition,
    partition_ranges,
    makespan,
)
from .pool import SubTask, ThreadWorkerPool, VirtualWorkerPool
from .scheduler import KernelSpec, CPURuntime, DynamicScheduler, StaticScheduler
from .hybrid_sim import CoreSpec, SimulatedHybridCPU, make_machine, MACHINES
from .balance import (
    DeviceRuntime,
    UnevenBatchPlanner,
    ExpertCapacityPlanner,
    ReplicaRouter,
)
from .tuner import KernelTuner, shape_class
from .pipeline import (
    PipelinePlan,
    plan_stages,
    choose_microbatches,
    layer_costs_from_config,
)

__all__ = [
    "optimal_shares",
    "observed_ratios",
    "ema_update",
    "proportional_partition",
    "partition_ranges",
    "makespan",
    "SubTask",
    "ThreadWorkerPool",
    "VirtualWorkerPool",
    "KernelSpec",
    "CPURuntime",
    "DynamicScheduler",
    "StaticScheduler",
    "CoreSpec",
    "SimulatedHybridCPU",
    "make_machine",
    "MACHINES",
    "DeviceRuntime",
    "UnevenBatchPlanner",
    "ExpertCapacityPlanner",
    "ReplicaRouter",
    "KernelTuner",
    "shape_class",
    "PipelinePlan",
    "plan_stages",
    "choose_microbatches",
    "layer_costs_from_config",
]
