"""Core of the reproduction: ratio math, worker pools, machine models.

Faithful layer (paper §2): :mod:`ratio`, :mod:`pool`, :mod:`hybrid_sim`.
The balancing loops themselves (ratio tables, schedulers, planners) live in
:mod:`repro.runtime`; :mod:`scheduler` and :mod:`balance` are deprecation
shims re-exporting from there, and this package lazily re-exports the same
names so seed-era ``from repro.core import ...`` imports keep working for
one release.
"""

from .ratio import (
    optimal_shares,
    observed_ratios,
    ema_update,
    proportional_partition,
    partition_ranges,
    makespan,
)
from .pool import SubTask, ThreadWorkerPool, VirtualWorkerPool
from .hybrid_sim import (
    CapacityEvent,
    CoreSpec,
    SimulatedHybridCPU,
    make_machine,
    MACHINES,
)
from .tuner import KernelTuner, TunerStore, shape_class
from .pipeline import (
    PipelinePlan,
    plan_stages,
    choose_microbatches,
    layer_costs_from_config,
)

# Names that moved to repro.runtime, resolved lazily (PEP 562) so importing
# repro.core does not circularly import repro.runtime (whose modules build
# on repro.core.ratio / repro.core.pool).
_MOVED_TO_RUNTIME = (
    "KernelSpec",
    "CPURuntime",
    "DynamicScheduler",
    "StaticScheduler",
    "DeviceRuntime",
    "MicrobatchPlan",
    "UnevenBatchPlanner",
    "ExpertCapacityPlanner",
    "ReplicaRouter",
)


def __getattr__(name):
    if name in _MOVED_TO_RUNTIME:
        import repro.runtime as _runtime

        return getattr(_runtime, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "optimal_shares",
    "observed_ratios",
    "ema_update",
    "proportional_partition",
    "partition_ranges",
    "makespan",
    "SubTask",
    "ThreadWorkerPool",
    "VirtualWorkerPool",
    "CapacityEvent",
    "CoreSpec",
    "SimulatedHybridCPU",
    "make_machine",
    "MACHINES",
    "KernelTuner",
    "TunerStore",
    "shape_class",
    "PipelinePlan",
    "plan_stages",
    "choose_microbatches",
    "layer_costs_from_config",
    *_MOVED_TO_RUNTIME,
]
