"""Deprecated shim — the schedulers moved to :mod:`repro.runtime`.

``repro.core.scheduler`` was the seed's home of the paper's CPU runtime
(§2.1) and thread scheduler (§2.2).  The implementation now lives in
:mod:`repro.runtime.scheduler` (``CPURuntime`` is a keyed
:class:`repro.runtime.RatioTable`; the schedulers are thin policies over
:class:`repro.runtime.Balancer`).  Import from ``repro.runtime`` — this
module re-exports for one release and will then be removed.
"""

from __future__ import annotations

from repro.runtime.balancer import RegionStats
from repro.runtime.scheduler import (
    KernelSpec,
    CPURuntime,
    DynamicScheduler,
    StaticScheduler,
)

__all__ = ["KernelSpec", "CPURuntime", "DynamicScheduler", "StaticScheduler",
           "RegionStats"]
