"""The paper's two components: CPU runtime (§2.1) and Thread scheduler (§2.2).

``CPURuntime`` owns one performance-ratio table per ISA (the paper found that
kernels sharing a primary ISA share ratios, so tables are keyed by ISA, and
every kernel declares its primary ISA).  ``DynamicScheduler`` splits each
kernel's parallel dimension proportionally to the current ratios (Eq. 3),
dispatches to the pool, then feeds observed times back through Eq. 2 + EMA.

``StaticScheduler`` is the OpenMP-parallel-for baseline of the paper's
experiments: equal-size partitions, no feedback.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import numpy as np

from . import ratio as R
from .pool import SubTask

__all__ = ["KernelSpec", "CPURuntime", "DynamicScheduler", "StaticScheduler"]


@dataclass(frozen=True)
class KernelSpec:
    """A parallel kernel as the scheduler sees it.

    ``work_per_unit`` converts one unit of the parallel dimension into
    abstract work (FLOPs / bytes) — used only by the virtual-time pool.
    """

    name: str
    isa: str  # primary ISA, e.g. "avx_vnni", "avx2", "membw"
    granularity: int = 1  # tile size along the parallel dim
    work_per_unit: float = 1.0


class CPURuntime:
    """Tracks per-core performance ratios, one table per ISA (paper §2.1)."""

    def __init__(self, n_workers: int, alpha: float = 0.3,
                 init_ratio: float = 1.0, normalize: str = "mean"):
        self.n_workers = n_workers
        self.alpha = alpha
        self.init_ratio = init_ratio
        self.normalize = normalize
        self._tables: Dict[str, np.ndarray] = {}
        self.history: Dict[str, list[np.ndarray]] = {}

    def ratios(self, isa: str) -> np.ndarray:
        if isa not in self._tables:
            self._tables[isa] = np.full(self.n_workers, float(self.init_ratio))
            self.history[isa] = [self._tables[isa].copy()]
        return self._tables[isa]

    def update(self, isa: str, times: np.ndarray) -> np.ndarray:
        """Eq. 2 followed by the EMA filter; returns the new table."""
        pr = self.ratios(isa)
        observed = R.observed_ratios(pr, times, normalize=self.normalize)
        new = R.ema_update(pr, observed, self.alpha)
        self._tables[isa] = new
        self.history[isa].append(new.copy())
        return new


@dataclass
class RegionStats:
    """Telemetry for one dispatched parallel region."""

    kernel: str
    counts: np.ndarray
    times: np.ndarray

    @property
    def makespan(self) -> float:
        return float(self.times.max(initial=0.0))

    @property
    def imbalance(self) -> float:
        """max(t)/mean(t>0) — 1.0 is perfectly balanced."""
        active = self.times[self.times > 0]
        if active.size == 0:
            return 1.0
        return float(active.max() / active.mean())


class DynamicScheduler:
    """Paper §2.2: proportional dispatch + feedback (the contribution)."""

    def __init__(self, runtime: CPURuntime, pool):
        self.runtime = runtime
        self.pool = pool
        self.stats: list[RegionStats] = []

    def partition(self, kernel: KernelSpec, s: int) -> np.ndarray:
        return R.proportional_partition(
            s, self.runtime.ratios(kernel.isa), kernel.granularity
        )

    def dispatch(
        self,
        kernel: KernelSpec,
        s: int,
        fn: Optional[Callable[[int, int], None]] = None,
        *,
        update: bool = True,
    ) -> RegionStats:
        """Run one parallel region of size ``s`` along the kernel's dim."""
        counts = self.partition(kernel, s)
        subtasks, cursor = [], 0
        for w, c in enumerate(counts):
            subtasks.append(
                SubTask(worker=w, start=cursor, size=int(c),
                        work=float(c) * kernel.work_per_unit, fn=fn)
            )
            cursor += int(c)
        times = self.pool.run(subtasks)
        if update:
            self.runtime.update(kernel.isa, times)
        st = RegionStats(kernel=kernel.name, counts=counts, times=times)
        self.stats.append(st)
        return st


class StaticScheduler:
    """OpenMP-style balanced dispatch: every worker gets an equal slice.

    This is the baseline of the paper's Fig. 2/3 ("OpenMP here uses the
    balanced work dispatch algorithm. Each thread computes the same size of
    sub-matrix").
    """

    def __init__(self, pool):
        self.pool = pool
        self.stats: list[RegionStats] = []

    def dispatch(
        self,
        kernel: KernelSpec,
        s: int,
        fn: Optional[Callable[[int, int], None]] = None,
    ) -> RegionStats:
        n = self.pool.n_workers
        counts = R.proportional_partition(s, np.ones(n), kernel.granularity)
        subtasks, cursor = [], 0
        for w, c in enumerate(counts):
            subtasks.append(
                SubTask(worker=w, start=cursor, size=int(c),
                        work=float(c) * kernel.work_per_unit, fn=fn)
            )
            cursor += int(c)
        times = self.pool.run(subtasks)
        st = RegionStats(kernel=kernel.name, counts=counts, times=times)
        self.stats.append(st)
        return st
