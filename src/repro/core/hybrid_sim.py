"""Deterministic virtual-time model of a hybrid CPU.

This container exposes a single physical core, so the paper's hardware
(Core i9-12900K: 8 P + 8 E cores; Core Ultra 7 125H: 4 P + 8 E + 2 LP-E)
cannot be exercised with real threads.  Instead we model each core's
throughput per ISA and let the :class:`repro.core.pool.VirtualWorkerPool`
convert assigned work into per-core times:

    t = work / (throughput(isa) * jitter * background_slowdown(now))

Throughput numbers below are calibrated to public microbenchmark ratios:
 * Golden Cove P-cores sustain roughly 3-4x the VNNI throughput of a
   Gracemont E-core (2x wider VNNI ports * ~1.5-1.7x frequency), and ~2-3x
   for plain AVX2 float work.
 * Memory-bound work (GEMV) is limited by the *shared* bandwidth, so per-core
   "throughput" ratios compress toward 1.5-2x — matching the paper's Fig. 4
   observation that decode-phase ratios are smaller than prefill-phase ones.

The model includes multiplicative log-normal jitter (frequency/dvfs noise)
and optional background-load intervals that throttle specific cores, which
is what the EMA filter (alpha = 0.3) is for.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from repro.core import events as _ev

__all__ = ["CoreSpec", "CapacityEvent", "SimulatedHybridCPU", "make_machine",
           "MACHINES"]


@dataclass(frozen=True)
class CoreSpec:
    name: str
    kind: str  # "P" | "E" | "LP"
    # work-units per second, per ISA.  Work units are kernel-defined
    # (e.g. MACs for GEMM, bytes for GEMV).
    throughput: Dict[str, float]
    jitter: float = 0.02  # lognormal sigma of per-task noise


@dataclass(frozen=True)
class CapacityEvent:
    """A scheduled capacity change on one core's virtual timeline.

    ``kind="park"``: the OS parks the core for ``[t_start, t_end)`` — work
    still *assigned* there crawls at the machine's ``park_slowdown`` (its
    thread is time-sliced onto a sibling), and :meth:`SimulatedHybridCPU.
    active_mask` reports the core inactive so planners stop assigning to
    it.  ``kind="scale"``: DVFS/thermal frequency scaling — throughput is
    divided by ``factor`` for the window but the core stays *active*
    (planners keep using it; the ratio loop re-learns its share).

    Unlike the ``background`` throttle list (which models *interference*
    the planner must learn around), capacity events are *observable*: the
    dispatcher may read ``active_mask`` the way a runtime reads
    ``sched_getaffinity``.
    """

    t_start: float
    t_end: float
    core: int
    kind: str = "park"  # "park" | "scale"
    factor: float = 1.0  # for "scale": throughput divisor (> 1 slows)

    def __post_init__(self) -> None:
        if self.kind not in ("park", "scale"):
            raise ValueError(f"unknown capacity event kind {self.kind!r}")
        if self.kind == "scale" and self.factor <= 0:
            raise ValueError("scale factor must be positive")


@dataclass
class SimulatedHybridCPU:
    cores: List[CoreSpec]
    seed: int = 0
    # background load: (t_start, t_end, core_index, slowdown_factor>1)
    background: List[Tuple[float, float, int, float]] = field(default_factory=list)
    # scheduled capacity changes (core parking / DVFS) — see CapacityEvent
    capacity: List[CapacityEvent] = field(default_factory=list)
    # effective slowdown of work left on a parked core: its thread is
    # time-sliced onto a sibling, so it crawls rather than stalls forever
    # (static planners that ignore active_mask still terminate)
    park_slowdown: float = 32.0

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    @property
    def n_cores(self) -> int:
        return len(self.cores)

    def true_throughput(self, isa: str) -> np.ndarray:
        return np.array([c.throughput[isa] for c in self.cores])

    # -------------------------------------------------- capacity schedule --
    def park(self, core: int, t_start: float = 0.0,
             t_end: float = float("inf")) -> None:
        """Park ``core`` for ``[t_start, t_end)`` (default: from now on,
        forever — the drift-test idiom that is valid on every pool timeline
        regardless of clock skew)."""
        self.capacity.append(CapacityEvent(t_start, t_end, core, "park"))
        if _ev.RECORDER is not None:
            _ev.record("capacity", f"core{core}", t=t_start, action="park",
                       t_end=None if t_end == float("inf") else t_end)

    def unpark(self, core: int) -> None:
        """Drop every park event for ``core`` (scale events stay)."""
        self.capacity = [ev for ev in self.capacity
                         if not (ev.kind == "park" and ev.core == core)]
        if _ev.RECORDER is not None:
            _ev.record("capacity", f"core{core}", action="unpark")

    def set_freq_scale(self, core: int, factor: float, t_start: float = 0.0,
                       t_end: float = float("inf")) -> None:
        """DVFS: divide ``core``'s throughput by ``factor`` over the window.
        The core stays active — planners keep it and re-learn its ratio."""
        self.capacity.append(CapacityEvent(t_start, t_end, core, "scale",
                                           factor))
        if _ev.RECORDER is not None:
            _ev.record("capacity", f"core{core}", t=t_start, action="scale",
                       factor=factor,
                       t_end=None if t_end == float("inf") else t_end)

    def clear_capacity(self, core: "int | None" = None) -> None:
        """Drop all capacity events (or just ``core``'s)."""
        if core is None:
            self.capacity = []
        else:
            self.capacity = [ev for ev in self.capacity if ev.core != core]

    def active_mask(self, now: float = 0.0) -> np.ndarray:
        """Boolean per-core mask: True where the core is *not* parked at
        ``now``.  This is the observable signal dispatchers probe at plan
        time; scale events do not deactivate a core."""
        mask = np.ones(self.n_cores, dtype=bool)
        for ev in self.capacity:
            if ev.kind == "park" and ev.t_start <= now < ev.t_end:
                mask[ev.core] = False
        return mask

    def capacity_slowdown(self, core: int, now: float) -> float:
        """Multiplicative slowdown from capacity events covering ``now``."""
        s = 1.0
        for ev in self.capacity:
            if ev.core == core and ev.t_start <= now < ev.t_end:
                s *= self.park_slowdown if ev.kind == "park" else ev.factor
        return s

    def background_slowdown(self, core: int, now: float) -> float:
        s = 1.0
        for t0, t1, idx, factor in self.background:
            if idx == core and t0 <= now < t1:
                s *= factor
        return s

    def _slowdown(self, core: int, now: float) -> float:
        s = self.background_slowdown(core, now)
        if self.capacity:
            s *= self.capacity_slowdown(core, now)
        return s

    def task_wall_time(self, core: int, start: float, base_seconds: float) -> float:
        """Wall seconds to complete ``base_seconds`` of unthrottled execution
        starting at virtual time ``start``, integrating the (piecewise-
        constant) slowdown — background throttles *and* capacity events —
        over the task's own interval rather than sampling it once at
        ``start``: an interval that begins or ends mid-task is applied
        exactly for the portion it overlaps.
        """
        if base_seconds <= 0:
            return 0.0
        boundaries = sorted(
            {t for t0, t1, idx, _ in self.background
             if idx == core for t in (t0, t1) if t > start}
            | {t for ev in self.capacity if ev.core == core
               for t in (ev.t_start, ev.t_end) if t > start})
        t, remaining = start, base_seconds
        for b in boundaries:
            s = self._slowdown(core, t)
            capacity = (b - t) / s  # base-seconds executable before b
            if remaining <= capacity:
                return (t + remaining * s) - start
            remaining -= capacity
            t = b
        return (t + remaining * self._slowdown(core, t)) - start

    def task_time(self, worker: int, isa: str, work: float, now: float) -> float:
        if work <= 0:
            return 0.0
        spec = self.cores[worker]
        tp = spec.throughput.get(isa)
        if tp is None:
            raise KeyError(f"core {spec.name} has no throughput entry for ISA {isa!r}")
        jitter = float(np.exp(self._rng.normal(0.0, spec.jitter)))
        return self.task_wall_time(worker, now, work / (tp * jitter))

    def optimal_makespan(self, isa: str, total_work: float) -> float:
        """Lower bound: all cores busy until the same instant (no jitter)."""
        return total_work / self.true_throughput(isa).sum()

    @property
    def socket_bandwidth(self) -> float:
        """Aggregate streaming bandwidth (bytes/s) when every core draws its
        sustainable share — the MLC-measured number the paper's >90% achieved-
        bandwidth claim is a fraction of."""
        return float(self.true_throughput("membw").sum())


def _core(name: str, kind: str, ghz: float, vnni_lanes: float, mem_share: float,
          jitter: float) -> CoreSpec:
    """Build a core's per-ISA throughput table from simple first principles.

    * ``avx_vnni`` (int8 MACs/s): lanes/cycle * freq — compute bound.
    * ``avx2`` (fp32 FLOPs/s): half the int8 lane width.
    * ``membw`` (bytes/s): share of socket bandwidth this core can draw when
      all cores stream (hybrid E-cores draw nearly as much as P-cores, which
      compresses decode-phase ratios — see paper Fig. 4).
    """
    return CoreSpec(
        name=name,
        kind=kind,
        throughput={
            "avx_vnni": vnni_lanes * ghz * 1e9,
            "avx2": vnni_lanes * 0.5 * ghz * 1e9,
            "membw": mem_share,
        },
        jitter=jitter,
    )


def make_ultra_125h(seed: int = 0) -> SimulatedHybridCPU:
    """Core Ultra 7 125H: 4 P (Redwood Cove) + 8 E (Crestmont) + 2 LP-E.

    Compute calibration (effective, within a VNNI GEMM micro-kernel):
    P ~ 64 int8 MAC/cycle @ 4.5 GHz = 288 GMAC/s; E-cores land at ~45% of a
    P-core (narrower VNNI ports, smaller L2 slice), LP-E at ~36%.  This puts
    the machine's static-partition penalty (= mean/min throughput, what an
    equal OpenMP split loses) at ~1.65, matching the paper's 65% GEMM
    improvement on this part.

    Memory calibration: socket ~89.6 GB/s (LPDDR5x-7467).  Bandwidth is a
    *shared* resource; what differs per core is the sustainable per-core
    draw (queue depth / fabric position), which is only mildly hybrid:
    P 7.2, E 6.0, LP-E 5.2 GB/s (sums to ~87 GB/s).  This reproduces the
    paper's small-but-real decode-phase gains (9-22%) and the Fig. 4
    observation that decode-phase ratios compress toward 1.
    """
    cores: list[CoreSpec] = []
    for i in range(4):
        cores.append(_core(f"P{i}", "P", 4.5, 64.0, 7.6e9, 0.03))
    for i in range(8):
        cores.append(_core(f"E{i}", "E", 4.05, 32.0, 6.0e9, 0.02))
    for i in range(2):
        cores.append(_core(f"LP{i}", "LP", 3.0, 32.0, 5.0e9, 0.02))
    return SimulatedHybridCPU(cores=cores, seed=seed)


def make_12900k(seed: int = 0) -> SimulatedHybridCPU:
    """Core i9-12900K: 8 P (Golden Cove ~4.9 GHz) + 8 E (Gracemont ~3.7 GHz).

    Effective GEMM throughput ratio P/E ~ 2.7 => static penalty
    (8*2.7+8)/16/1 ~ 1.85, matching the paper's 85% GEMM improvement.
    DDR5-4800 dual channel ~76.8 GB/s shared; per-core draws P 5.4 / E 4.4.
    """
    cores: list[CoreSpec] = []
    for i in range(8):
        cores.append(_core(f"P{i}", "P", 4.9, 64.0, 5.7e9, 0.03))
    for i in range(8):
        cores.append(_core(f"E{i}", "E", 3.7, 28.6, 4.1e9, 0.02))
    return SimulatedHybridCPU(cores=cores, seed=seed)


def make_homogeneous(n: int = 8, seed: int = 0) -> SimulatedHybridCPU:
    """Non-hybrid reference (server-like): dynamic == static expected."""
    cores = [_core(f"C{i}", "P", 3.0, 32.0, 9e9, 0.01) for i in range(n)]
    return SimulatedHybridCPU(cores=cores, seed=seed)


MACHINES = {
    "ultra-125h": make_ultra_125h,
    "core-12900k": make_12900k,
    "homogeneous-8": lambda seed=0: make_homogeneous(n=8, seed=seed),
}


def make_machine(name: str, seed: int = 0):
    """Resolve a machine name: flat hybrid CPUs from :data:`MACHINES`, or a
    multi-socket :class:`~repro.topology.machine.MachineTopology` from
    :data:`~repro.topology.machine.TOPOLOGIES` (lazily imported — the
    topology package builds on this module).  ``seed`` is forwarded to
    whichever constructor matches."""
    if name in MACHINES:
        return MACHINES[name](seed)
    from repro.topology.machine import make_topology

    # make_topology owns the topology registry and the unknown-name error
    # (which lists both registries)
    return make_topology(name, seed=seed)
