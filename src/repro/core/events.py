"""Access-event hooks for the happens-before race detector.

The balancing stack's correctness argument is "shared mutable state is only
touched between parallel regions (main task) or under a lock" — the class of
invariant behind the PR 3 worker-pool fixes.  This module makes that claim
machine-checkable: the worker pools and the shared state they touch
(:class:`~repro.core.tuner.KernelTuner`, :class:`~repro.runtime.table.
RatioTable` EMA updates, dispatcher bytes/busy accounting) emit lightweight
*access events* whenever a tracer is installed, and
:mod:`repro.analysis.races` replays the recorded schedule through a
vector-clock happens-before checker.

Cost when disabled is one global load and a ``None`` check per hook
(``TRACER`` is ``None`` by default); no event objects are built.

Event vocabulary (``kind``):

* ``read`` / ``write`` — one access to ``(obj, field)`` from the current
  logical task;
* ``acquire`` / ``release`` — lock edges (emit *after* acquiring and
  *before* releasing, inside the critical section);
* ``fork`` / ``join`` — task edges: the current task spawned / awaited the
  logical task named in ``obj``.

Logical tasks are strings, not OS threads: a :class:`~repro.core.pool.
VirtualWorkerPool` runs its sub-tasks sequentially on one thread, but each
``(region, worker)`` is its own logical task with only fork/join ordering —
so the checker finds schedules the virtual execution merely *masks*
(predictive race detection over the replayed pool schedule).
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass

__all__ = [
    "Event",
    "TRACER",
    "RECORDER",
    "install",
    "install_recorder",
    "current_task",
    "push_task",
    "pop_task",
    "task",
    "label",
    "emit_read",
    "emit_write",
    "emit_acquire",
    "emit_release",
    "emit_fork",
    "emit_join",
    "emit_span",
    "emit_counter",
    "emit_instant",
    "push_scope",
    "pop_scope",
    "record",
]

# The installed tracer (anything with ``emit(Event)``), or None.  Module
# global so the disabled-path check is a single load.
TRACER = None


@dataclass(frozen=True)
class Event:
    """One recorded schedule step."""

    kind: str      # "read" | "write" | "acquire" | "release" | "fork" | "join"
    task: str      # logical task the event happened on
    obj: str       # state label ("KernelTuner#1") or child-task / lock label
    field: str = ""   # field within obj for read/write ("tables['membw']")
    where: str = ""   # source label for reporting ("KernelTuner.report")


class _TaskCtx(threading.local):
    def __init__(self):
        self.stack = []


_ctx = _TaskCtx()


def current_task() -> str:
    """The current logical task: the innermost pushed label, else the OS
    thread's identity (every un-annotated thread is its own task)."""
    stack = _ctx.stack
    if stack:
        return stack[-1]
    return f"thread:{threading.current_thread().name}"


def push_task(name: str) -> None:
    _ctx.stack.append(name)


def pop_task() -> None:
    _ctx.stack.pop()


@contextmanager
def task(name: str):
    """Run a block as logical task ``name`` (pools wrap sub-task fns)."""
    push_task(name)
    try:
        yield
    finally:
        pop_task()


# ------------------------------------------------------------------ labels --
# Stable human-readable labels per traced object.  Keyed by id() — cleared on
# every install() so a recycled id cannot alias across trace sessions.
_label_by_id: dict = {}
_label_counts: dict = {}


def label(obj) -> str:
    """A stable ``ClassName#k`` label for ``obj`` within one trace."""
    if isinstance(obj, str):
        return obj
    key = id(obj)
    got = _label_by_id.get(key)
    if got is None:
        cls = type(obj).__name__
        n = _label_counts.get(cls, 0) + 1
        _label_counts[cls] = n
        got = f"{cls}#{n}"
        _label_by_id[key] = got
    return got


def install(tracer):
    """Install ``tracer`` (or ``None`` to disable); returns the previous
    tracer.  Resets the label registry so labels are per-session."""
    global TRACER
    prev = TRACER
    TRACER = tracer
    _label_by_id.clear()
    _label_counts.clear()
    return prev


# ------------------------------------------------------------------- emits --
def _emit(kind: str, obj, field: str, where: str) -> None:
    t = TRACER
    if t is None:
        return
    t.emit(Event(kind=kind, task=current_task(), obj=label(obj),
                 field=field, where=where))


def emit_read(obj, field: str, where: str = "") -> None:
    _emit("read", obj, field, where)


def emit_write(obj, field: str, where: str = "") -> None:
    _emit("write", obj, field, where)


def emit_acquire(lock, where: str = "") -> None:
    """Emit *after* physically acquiring ``lock``."""
    _emit("acquire", lock, "", where)


def emit_release(lock, where: str = "") -> None:
    """Emit *before* physically releasing ``lock``."""
    _emit("release", lock, "", where)


def emit_fork(child_task: str, where: str = "") -> None:
    """The current task is about to start ``child_task``."""
    _emit("fork", child_task, "", where)


def emit_join(child_task: str, where: str = "") -> None:
    """The current task has awaited ``child_task``'s completion."""
    _emit("join", child_task, "", where)


# ------------------------------------------------------------------- spans --
# Virtual-clock span/counter hooks for the ``repro.obs`` tracer.  The same
# TRACER slot serves both the race detector (which only implements ``emit``)
# and the span tracer: each hook duck-types on the tracer method it needs, so
# a tracer that lacks it costs one getattr and nothing else.  ``args`` and
# ``values`` may be zero-argument callables — evaluated only when a matching
# tracer is installed, so building the payload is free on the disabled path.

def emit_span(track: str, name: str, start: float, dur: float,
              cat: str = "", args=None) -> None:
    """One completed span on virtual-clock ``track`` (seconds)."""
    t = TRACER
    if t is None:
        return
    fn = getattr(t, "span", None)
    if fn is None:
        return
    if callable(args):
        args = args()
    fn(track, name, start, dur, cat, args)


def emit_counter(track: str, t_now: float, values) -> None:
    """Sampled counter values (``{series: number}``) on ``track``."""
    t = TRACER
    if t is None:
        return
    fn = getattr(t, "counter", None)
    if fn is None:
        return
    if callable(values):
        values = values()
    fn(track, t_now, values)


def emit_instant(track: str, name: str, t_now: float, args=None) -> None:
    """A zero-duration marker (routing/admission decisions)."""
    t = TRACER
    if t is None:
        return
    fn = getattr(t, "instant", None)
    if fn is None:
        return
    if callable(args):
        args = args()
    fn(track, name, t_now, args)


def push_scope(name: str) -> None:
    """Enter a naming scope (node/replica) grouping subsequent spans."""
    t = TRACER
    if t is None:
        return
    fn = getattr(t, "push_scope", None)
    if fn is not None:
        fn(name)


def pop_scope() -> None:
    t = TRACER
    if t is None:
        return
    fn = getattr(t, "pop_scope", None)
    if fn is not None:
        fn()


# ---------------------------------------------------------------- recorder --
# The flight-recorder channel is independent of the tracer: balancer
# decisions (ratio snapshots, offset refreshes, capacity/admission events)
# are recorded even when no trace is being exported, so an SLO burn or a
# tripped IV contract can dump the decisions that led up to it.
RECORDER = None


def install_recorder(recorder):
    """Install a decision recorder (anything with ``record(kind, key, t,
    payload)``), or ``None`` to disable; returns the previous recorder."""
    global RECORDER
    prev = RECORDER
    RECORDER = recorder
    return prev


def record(kind: str, key: str, t: float = 0.0, **payload) -> None:
    """Record one balancer/admission decision.  One global load + ``None``
    check when disabled; payload kwargs are only assembled by the caller, so
    keep call sites to cheap scalars."""
    r = RECORDER
    if r is None:
        return
    r.record(kind, key, t, payload)
