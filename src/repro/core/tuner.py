"""Per-kernel configuration tuner — the TPU analogue of per-ISA tables.

The paper keys its performance tables by ISA because P- and E-cores have
different relative throughput per instruction family.  A TPU chip is
internally homogeneous, but a Pallas kernel has the same phenomenon one
level up: the best *block configuration* (BlockSpec tile shapes) depends on
the problem shape and on which resource (MXU vs VMEM bandwidth) binds.  The
tuner keeps an EMA of measured runtime per (kernel, shape-class, config) and
selects the argmin config at dispatch time — converging online exactly like
the paper's ratio table, and re-adapting if the environment drifts.
"""

from __future__ import annotations

import json
import math
import os
import tempfile
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Hashable, Optional, Sequence, Tuple

from repro.core import events as _ev

__all__ = ["KernelTuner", "TunerStore", "shape_class"]


def shape_class(*dims: int) -> Tuple[int, ...]:
    """Bucket a shape so that near-identical problems share a table entry
    (next power of two per dim)."""
    return tuple(1 << max(0, math.ceil(math.log2(max(d, 1)))) for d in dims)


@dataclass
class _Entry:
    ema: float = math.inf
    count: int = 0


class KernelTuner:
    """Online EMA argmin over candidate configs.

    ``alpha`` follows the paper's filter (new measurement weighted 1-alpha).
    Exploration: until every candidate has ``min_trials`` measurements, the
    least-measured config is chosen (round-robin warmup, mirroring the
    paper's "ratios start at 1 and converge within a few kernels").
    """

    def __init__(self, alpha: float = 0.3, min_trials: int = 2):
        self.alpha = alpha
        self.min_trials = min_trials
        self._tables: Dict[Hashable, Dict[Hashable, _Entry]] = {}
        # shard dispatch reports from worker threads concurrently; the
        # read-modify-write of an entry's EMA must not interleave
        self._lock = threading.Lock()

    def _table(self, key: Hashable, configs: Sequence[Hashable]):
        tab = self._tables.setdefault(key, {})
        for c in configs:
            tab.setdefault(c, _Entry())
        return tab

    def select(self, key: Hashable, configs: Sequence[Hashable]) -> Hashable:
        with self._lock:
            if _ev.TRACER is not None:
                _ev.emit_acquire(self._lock, where="KernelTuner.select")
                _ev.emit_read(self, "tables", where="KernelTuner.select")
                _ev.emit_release(self._lock, where="KernelTuner.select")
            tab = self._table(key, configs)
            cold = [c for c in configs if tab[c].count < self.min_trials]
            if cold:
                return min(cold, key=lambda c: tab[c].count)
            return min(configs, key=lambda c: tab[c].ema)

    def report(self, key: Hashable, config: Hashable, seconds: float) -> None:
        with self._lock:
            if _ev.TRACER is not None:
                _ev.emit_acquire(self._lock, where="KernelTuner.report")
                _ev.emit_read(self, "tables", where="KernelTuner.report")
                _ev.emit_write(self, "tables", where="KernelTuner.report")
            e = self._tables.setdefault(key, {}).setdefault(config, _Entry())
            if e.count == 0 or not math.isfinite(e.ema):
                e.ema = seconds
            else:
                e.ema = self.alpha * e.ema + (1.0 - self.alpha) * seconds
            e.count += 1
            if _ev.TRACER is not None:
                _ev.emit_release(self._lock, where="KernelTuner.report")

    def best(self, key: Hashable) -> Hashable:
        with self._lock:
            tab = self._tables.get(key)
            if not tab:
                raise KeyError(f"no measurements for {key!r}")
            return min(tab, key=lambda c: tab[c].ema)

    # -------------------------------------------------------- persistence --
    def to_json(self) -> str:
        """Measured entries only (count > 0) as JSON — the block-shape
        analogue of :meth:`repro.runtime.RatioTable.to_json`, so tuned
        tables warm-start across processes like ratio tables do."""
        with self._lock:
            records = []
            for key, tab in self._tables.items():
                configs = [
                    {"config": _encode(c), "ema": e.ema, "count": e.count}
                    for c, e in tab.items() if e.count > 0
                ]
                if configs:
                    records.append({"key": _encode(key), "configs": configs})
        return json.dumps({
            "version": 1,
            "alpha": self.alpha,
            "min_trials": self.min_trials,
            "tables": records,
        }, indent=2)

    @classmethod
    def from_json(cls, text: str, **overrides) -> "KernelTuner":
        doc = json.loads(text)
        if doc.get("version") != 1:
            raise ValueError(f"unknown tuner-table version {doc.get('version')}")
        kwargs = dict(alpha=doc["alpha"], min_trials=doc["min_trials"])
        kwargs.update(overrides)
        tuner = cls(**kwargs)
        for rec in doc["tables"]:
            tab = tuner._tables.setdefault(_decode(rec["key"]), {})
            for c in rec["configs"]:
                tab[_decode(c["config"])] = _Entry(ema=float(c["ema"]),
                                                   count=int(c["count"]))
        return tuner


def _encode(obj):
    """Tuner keys/configs are (nested) tuples of str/int; JSON stores them
    as (nested) lists."""
    if isinstance(obj, tuple):
        return [_encode(o) for o in obj]
    return obj


def _decode(obj):
    if isinstance(obj, list):
        return tuple(_decode(o) for o in obj)
    return obj


class TunerStore:
    """Atomic JSON persistence for a :class:`KernelTuner` at a fixed path
    (mirrors :class:`repro.runtime.RatioStore`)."""

    def __init__(self, path: str):
        self.path = path

    def exists(self) -> bool:
        return os.path.exists(self.path)

    def save(self, tuner: KernelTuner) -> None:
        """Write-then-rename so a crashed writer never leaves a torn file."""
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(tuner.to_json())
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def load(self, **overrides) -> Optional[KernelTuner]:
        if not self.exists():
            return None
        with open(self.path) as f:
            return KernelTuner.from_json(f.read(), **overrides)

    def load_into(self, tuner: KernelTuner) -> bool:
        """Warm-start an existing tuner from the store.  Returns False (and
        leaves ``tuner`` untouched) when nothing compatible is stored — a
        different ``alpha`` changes the filter the stored EMAs were
        produced under and is refused rather than blended (same contract
        as :meth:`repro.runtime.RatioStore.load_into`).  A torn or corrupt
        file (a crashed writer predating the atomic rename, or a truncated
        copy) is treated as "nothing stored": warm-start is an
        optimization, so a cold start beats crashing the serve."""
        try:
            stored = self.load()
        except (json.JSONDecodeError, KeyError, TypeError, ValueError):
            return False
        if stored is None or stored.alpha != tuner.alpha:
            return False
        with tuner._lock:
            for key, tab in stored._tables.items():
                dst = tuner._tables.setdefault(key, {})
                for c, e in tab.items():
                    dst[c] = _Entry(ema=e.ema, count=e.count)
        return True
