"""Per-kernel configuration tuner — the TPU analogue of per-ISA tables.

The paper keys its performance tables by ISA because P- and E-cores have
different relative throughput per instruction family.  A TPU chip is
internally homogeneous, but a Pallas kernel has the same phenomenon one
level up: the best *block configuration* (BlockSpec tile shapes) depends on
the problem shape and on which resource (MXU vs VMEM bandwidth) binds.  The
tuner keeps an EMA of measured runtime per (kernel, shape-class, config) and
selects the argmin config at dispatch time — converging online exactly like
the paper's ratio table, and re-adapting if the environment drifts.
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Hashable, Sequence, Tuple

__all__ = ["KernelTuner", "shape_class"]


def shape_class(*dims: int) -> Tuple[int, ...]:
    """Bucket a shape so that near-identical problems share a table entry
    (next power of two per dim)."""
    return tuple(1 << max(0, math.ceil(math.log2(max(d, 1)))) for d in dims)


@dataclass
class _Entry:
    ema: float = math.inf
    count: int = 0


class KernelTuner:
    """Online EMA argmin over candidate configs.

    ``alpha`` follows the paper's filter (new measurement weighted 1-alpha).
    Exploration: until every candidate has ``min_trials`` measurements, the
    least-measured config is chosen (round-robin warmup, mirroring the
    paper's "ratios start at 1 and converge within a few kernels").
    """

    def __init__(self, alpha: float = 0.3, min_trials: int = 2):
        self.alpha = alpha
        self.min_trials = min_trials
        self._tables: Dict[Hashable, Dict[Hashable, _Entry]] = {}
        # shard dispatch reports from worker threads concurrently; the
        # read-modify-write of an entry's EMA must not interleave
        self._lock = threading.Lock()

    def _table(self, key: Hashable, configs: Sequence[Hashable]):
        tab = self._tables.setdefault(key, {})
        for c in configs:
            tab.setdefault(c, _Entry())
        return tab

    def select(self, key: Hashable, configs: Sequence[Hashable]) -> Hashable:
        with self._lock:
            tab = self._table(key, configs)
            cold = [c for c in configs if tab[c].count < self.min_trials]
            if cold:
                return min(cold, key=lambda c: tab[c].count)
            return min(configs, key=lambda c: tab[c].ema)

    def report(self, key: Hashable, config: Hashable, seconds: float) -> None:
        with self._lock:
            e = self._tables.setdefault(key, {}).setdefault(config, _Entry())
            if e.count == 0 or not math.isfinite(e.ema):
                e.ema = seconds
            else:
                e.ema = self.alpha * e.ema + (1.0 - self.alpha) * seconds
            e.count += 1

    def best(self, key: Hashable) -> Hashable:
        with self._lock:
            tab = self._tables.get(key)
            if not tab:
                raise KeyError(f"no measurements for {key!r}")
            return min(tab, key=lambda c: tab[c].ema)
