"""Pipeline-parallel planning with the paper's proportional method.

At 512+ chips the cross-pod axis is DCN (~10x slower than ICI), so deep
models run pipeline stages across pods.  Two classic problems map directly
onto the paper's Eq. 3:

* **Stage balancing**: layers have unequal costs (jamba interleaves Mamba,
  attention and MoE layers) and stages may run on *heterogeneous* pods.
  The optimal contiguous split assigns each stage work proportional to its
  pod's measured throughput — exactly `s_i = pr_i / sum(pr) * s`, with a
  :class:`repro.runtime.RatioTable` EMA feeding `pr` from observed stage
  times (pass it via ``plan_stages(..., table=..., key=...)``).
* **Schedule accounting**: 1F1B/GPipe bubble fraction = (S-1)/(M+S-1); the
  planner picks the microbatch count that keeps the bubble under a target,
  which trades against the per-microbatch weight-grad reduction traffic
  measured in EXPERIMENTS §Perf.

``plan_stages`` is exact for contiguous splits (DP over prefix sums) when
ratios are uniform, and proportional-greedy when they are not; both are
pure host-side planners (re-planned between steps, no recompilation
because stage assignment changes only which weights live where).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from . import ratio as R


@dataclass(frozen=True)
class PipelinePlan:
    boundaries: tuple      # stage s owns layers [boundaries[s], boundaries[s+1])
    stage_costs: tuple     # summed layer cost per stage (time units)
    stage_ratios: tuple    # pod throughput ratios used

    @property
    def n_stages(self) -> int:
        return len(self.stage_costs)

    @property
    def stage_times(self) -> tuple:
        return tuple(c / r for c, r in zip(self.stage_costs, self.stage_ratios))

    @property
    def makespan_per_microbatch(self) -> float:
        return max(self.stage_times)

    def bubble_fraction(self, n_microbatches: int) -> float:
        """1F1B bubble: (S-1) / (M + S-1)."""
        s = self.n_stages
        return (s - 1) / (n_microbatches + s - 1)

    def step_time(self, n_microbatches: int) -> float:
        """Ideal pipeline step time (ignoring comm): M*t_max + (S-1)*t_max."""
        return (n_microbatches + self.n_stages - 1) * self.makespan_per_microbatch


def _contiguous_split_dp(costs: np.ndarray, ratios: np.ndarray) -> list[int]:
    """Exact min-makespan contiguous split via DP over prefix sums.

    dp[s][i] = best makespan splitting layers[:i] into the first s stages;
    O(S * L^2) — fine for L <= a few hundred layers.
    """
    n_stages = len(ratios)
    n = len(costs)
    prefix = np.concatenate([[0.0], np.cumsum(costs)])
    inf = float("inf")
    dp = np.full((n_stages + 1, n + 1), inf)
    cut = np.zeros((n_stages + 1, n + 1), dtype=int)
    dp[0][0] = 0.0
    for s in range(1, n_stages + 1):
        for i in range(s, n + 1):
            # stage s-1 takes layers [j, i)
            for j in range(s - 1, i):
                t = (prefix[i] - prefix[j]) / ratios[s - 1]
                val = max(dp[s - 1][j], t)
                if val < dp[s][i]:
                    dp[s][i] = val
                    cut[s][i] = j
    bounds = [n]
    i = n
    for s in range(n_stages, 0, -1):
        i = cut[s][i]
        bounds.append(i)
    return list(reversed(bounds))


def plan_stages(
    layer_costs: Sequence[float],
    n_stages: int,
    stage_ratios: Optional[Sequence[float]] = None,
    *,
    table=None,
    key: str = "pipeline_stage",
) -> PipelinePlan:
    """Split layers into contiguous stages minimizing the pipeline makespan.

    ``stage_ratios``: per-stage pod throughput (repro.runtime RatioTable
    EMAs at pod granularity); defaults to uniform.  Instead of a raw
    vector, a live ``table``/``key`` (:class:`repro.runtime.RatioTable`)
    may be given and is read for the current ratios — replan between steps
    as stage-time feedback accumulates.  Stage s's ideal share of total
    work is ``ratios[s]/sum(ratios)`` (Eq. 3); the DP refines to the best
    layer-boundary realization.
    """
    costs = np.asarray(layer_costs, dtype=np.float64)
    if n_stages < 1 or n_stages > len(costs):
        raise ValueError("need 1 <= n_stages <= n_layers")
    if stage_ratios is None and table is not None:
        stage_ratios = table.ratios(key)
    ratios = (np.ones(n_stages) if stage_ratios is None
              else np.asarray(stage_ratios, dtype=np.float64))
    if len(ratios) != n_stages:
        raise ValueError("one ratio per stage")
    bounds = _contiguous_split_dp(costs, ratios)
    stage_costs = tuple(
        float(costs[bounds[s]: bounds[s + 1]].sum()) for s in range(n_stages)
    )
    return PipelinePlan(boundaries=tuple(bounds), stage_costs=stage_costs,
                        stage_ratios=tuple(float(r) for r in ratios))


def layer_costs_from_config(cfg) -> list[float]:
    """Per-layer forward FLOPs (train-shape agnostic relative costs) from
    the analytic model — the planner's default cost vector."""
    from repro.launch.analytic import _layer_fwd_flops_per_token

    return [
        _layer_fwd_flops_per_token(cfg, mixer, ffn, kv_len=2048.0)
        for mixer, ffn in cfg.layer_plan()
    ]


def choose_microbatches(plan: PipelinePlan, *, max_bubble: float = 0.1,
                        max_microbatches: int = 128) -> int:
    """Smallest microbatch count meeting the bubble target (fewer
    microbatches = fewer per-microbatch grad reductions — see §Perf)."""
    s = plan.n_stages
    if s == 1:
        return 1
    # (s-1)/(m+s-1) <= b  =>  m >= (s-1)(1-b)/b
    m = int(np.ceil((s - 1) * (1 - max_bubble) / max_bubble))
    return min(max(m, 1), max_microbatches)
