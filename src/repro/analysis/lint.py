"""Repo-specific AST lint for the balancing stack.

Generic linters can't see this repo's contracts; these rules encode the ones
that have actually bitten (or nearly bitten) previous PRs:

* **RL001 — wall clock in a virtual-clock path.**  ``time.time()`` /
  ``perf_counter()`` & friends are banned inside the deterministic
  virtual-clock modules (hybrid machine model, phase costs, topology/fleet
  simulation, ratio/plan math) and inside any ``Virtual*`` class: virtual
  time must flow through the machine model's clock, or determinism and
  replayability silently die.
* **RL002 — raw ratio-table key string.**  ``"membw/attn_proj"``-style key
  literals outside the ``kernel_key()`` / ``phase_kernel_key()``
  constructors fork the key namespace; a typo'd key trains a fresh table
  that never converges.
* **RL003 — pool ``run()`` off the join-or-propagate path.**  Discarding a
  pool ``run()`` result or swallowing its exceptions (``except: pass``)
  breaks the "every sub-task joined, every shard error propagated"
  guarantee behind the PR 3 deadlock fixes.
* **RL004 — ``jax.jit`` over a closure capturing mutable ratio state.**
  A jitted function that closes over a ``RatioTable`` / ``KernelTuner``
  bakes the state in as trace-time constants: the loop keeps learning but
  the compiled program never sees it.  Ratio state must enter a jitted step
  as an *argument* (the :class:`~repro.runtime.OffsetSnapshot` contract) or
  through an ordered callback.
* **RL005 — direct ``ema_update()`` call.**  The EMA must be applied by
  ``RatioTable.observe`` only, so the IV001/IV002 contracts and the race
  hooks see every update.
* **RL006 — raw ``print()`` in library code.**  Telemetry from the
  balancing stack must flow through a :class:`~repro.runtime.StatsSink`,
  the ``repro.core.events`` shim, or the ``repro.obs`` exporters — a
  stray ``print`` is unsinkable (no trace, no metrics, no recorder) and
  pollutes drivers' stdout.  CLI surfaces are exempt: anything under
  ``repro/launch/``, ``__main__.py`` modules, ``main()`` functions, and
  ``if __name__ == "__main__":`` blocks.

Escapes: ``# lint: virtual-clock-module`` anywhere in a file opts it into
the RL001 virtual set; a trailing ``# lint: allow(RL00x)`` (or bare
``# lint: allow``) suppresses findings on that line.
"""

from __future__ import annotations

import ast
import os
import re
from pathlib import Path
from typing import List, Optional

from .findings import Finding

__all__ = ["RULES", "lint_source", "lint_file", "run_pass"]

RULES = {
    "RL001": "wall-clock call in a virtual-clock path (route through the "
             "machine model's clock)",
    "RL002": "raw ratio-table key string outside kernel_key()/"
             "phase_kernel_key()",
    "RL003": "pool run() off the join-or-propagate path (result discarded "
             "or errors swallowed)",
    "RL004": "jax.jit over a closure capturing mutable ratio state (pass "
             "it as an argument or snapshot it)",
    "RL005": "ema_update() called outside RatioTable.observe",
    "RL006": "raw print() in library code (route telemetry through a "
             "StatsSink / the events shim / repro.obs)",
}

# Modules whose clocks are virtual by construction (suffix/prefix match on
# posix-normalized paths).  New modules can opt in with the marker comment.
VIRTUAL_CLOCK_FILES = (
    "repro/core/hybrid_sim.py",
    "repro/core/ratio.py",
    "repro/runtime/table.py",
    "repro/runtime/policy.py",
    "repro/runtime/offsets.py",
    "repro/serving/phases.py",
    "repro/serving/traffic.py",
)
VIRTUAL_CLOCK_DIRS = ("repro/topology/", "repro/fleet/")
VIRTUAL_MARKER = "# lint: virtual-clock-module"

# The only modules allowed to spell ratio-table keys / apply the EMA.
KEY_CONSTRUCTOR_FILES = ("repro/kernels/dispatch.py", "repro/serving/phases.py")
EMA_FILES = ("repro/core/ratio.py", "repro/runtime/table.py")

# RL006: CLI surfaces where print() IS the output channel.
PRINT_EXEMPT_DIRS = ("repro/launch/",)
PRINT_EXEMPT_FILES = ("__main__.py",)

_RAW_KEY_RE = re.compile(r"^(membw|avx_vnni|avx2)/[A-Za-z0-9_]+$")
_WALL_ATTRS = {"time", "perf_counter", "monotonic", "process_time",
               "time_ns", "perf_counter_ns", "monotonic_ns"}
_MUTABLE_CTORS = {"RatioTable", "KernelTuner", "CPURuntime"}
_MUTABLE_NAMES = {"table", "tuner", "ratio_table"}
_MUTABLE_ATTRS = {"table", "tuner"}
_ALLOW_RE = re.compile(r"#\s*lint:\s*allow(?:\(([A-Z0-9, ]+)\))?")


def _norm(path) -> str:
    return str(path).replace(os.sep, "/")


def _matches(path: str, files, dirs=()) -> bool:
    return any(path.endswith(f) for f in files) or \
        any(d in path for d in dirs)


class _Lines:
    """Per-line suppression lookups."""

    def __init__(self, source: str):
        self.lines = source.splitlines()

    def allowed(self, lineno: int, rule: str) -> bool:
        if not 1 <= lineno <= len(self.lines):
            return False
        m = _ALLOW_RE.search(self.lines[lineno - 1])
        if not m:
            return False
        rules = m.group(1)
        return rules is None or rule in rules


def _docstring_ids(tree) -> set:
    """ids of Constant nodes that are docstrings or bare-string statements
    (both are prose, not keys)."""
    out = set()
    for node in ast.walk(tree):
        body = getattr(node, "body", None)
        if not isinstance(body, list):
            continue
        for stmt in body:
            if isinstance(stmt, ast.Expr) and \
                    isinstance(stmt.value, ast.Constant) and \
                    isinstance(stmt.value.value, str):
                out.add(id(stmt.value))
    return out


def _receiver_mentions_pool(func: ast.Attribute) -> bool:
    value = func.value
    names = []
    if isinstance(value, ast.Name):
        names.append(value.id)
    elif isinstance(value, ast.Attribute):
        names.append(value.attr)
    elif isinstance(value, ast.Call):
        f = value.func
        if isinstance(f, ast.Name):
            names.append(f.id)
        elif isinstance(f, ast.Attribute):
            names.append(f.attr)
    return any("pool" in n.lower() for n in names)


def _is_jit_expr(node) -> bool:
    """``jax.jit`` or bare ``jit`` as an expression."""
    if isinstance(node, ast.Attribute) and node.attr == "jit":
        return True
    return isinstance(node, ast.Name) and node.id == "jit"


def _is_partial_of_jit(call: ast.Call) -> bool:
    f = call.func
    is_partial = (isinstance(f, ast.Attribute) and f.attr == "partial") or \
                 (isinstance(f, ast.Name) and f.id == "partial")
    return is_partial and any(_is_jit_expr(a) for a in call.args)


def _collect_locals(fn) -> set:
    """Parameter and locally-bound names of a function node (approximate:
    any Name in Store context counts as local)."""
    bound = set()
    args = fn.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs):
        bound.add(a.arg)
    if args.vararg:
        bound.add(args.vararg.arg)
    if args.kwarg:
        bound.add(args.kwarg.arg)
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
                bound.add(node.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                bound.add(node.name)
    return bound


def lint_source(source: str, path: str = "<string>", *,
                virtual: Optional[bool] = None) -> List[Finding]:
    """Lint one module's source; ``virtual`` overrides the RL001 path set."""
    norm = _norm(path)
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(rule="RL000", severity="error",
                        location=f"{norm}:{e.lineno or 0}",
                        message=f"syntax error: {e.msg}")]
    lines = _Lines(source)
    if virtual is None:
        virtual = _matches(norm, VIRTUAL_CLOCK_FILES, VIRTUAL_CLOCK_DIRS) or \
            VIRTUAL_MARKER in source
    findings: List[Finding] = []

    def report(rule: str, node, message: str) -> None:
        lineno = getattr(node, "lineno", 0)
        if lines.allowed(lineno, rule):
            return
        findings.append(Finding(rule=rule, severity="error",
                                location=f"{norm}:{lineno}",
                                message=message))

    # ---------------------------------------------------- import aliases --
    time_modules = set()
    wall_names = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "time":
                    time_modules.add(alias.asname or "time")
        elif isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                if alias.name in _WALL_ATTRS:
                    wall_names[alias.asname or alias.name] = alias.name

    def is_wall_call(call: ast.Call) -> Optional[str]:
        f = call.func
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id in time_modules and f.attr in _WALL_ATTRS:
            return f"time.{f.attr}"
        if isinstance(f, ast.Name) and f.id in wall_names:
            return f"time.{wall_names[f.id]}"
        return None

    # ------------------------------------------ RL001: wall clock misuse --
    def walk_rl001(node, in_virtual_class: bool) -> None:
        if isinstance(node, ast.ClassDef):
            in_virtual_class = in_virtual_class or \
                node.name.startswith("Virtual")
        if isinstance(node, ast.Call) and (virtual or in_virtual_class):
            wall = is_wall_call(node)
            if wall is not None:
                scope = "virtual-clock module" if virtual else \
                    "Virtual* class"
                report("RL001", node,
                       f"{wall}() in a {scope}; use the machine model's "
                       f"virtual clock")
        for child in ast.iter_child_nodes(node):
            walk_rl001(child, in_virtual_class)

    walk_rl001(tree, False)

    # ------------------------------------------- RL002: raw key strings --
    if not _matches(norm, KEY_CONSTRUCTOR_FILES):
        prose = _docstring_ids(tree)
        for node in ast.walk(tree):
            if isinstance(node, ast.Constant) and \
                    isinstance(node.value, str) and id(node) not in prose \
                    and _RAW_KEY_RE.match(node.value):
                report("RL002", node,
                       f"raw ratio-table key {node.value!r}; build it with "
                       f"kernel_key()/phase_kernel_key()")

    # --------------------------------------- RL003: pool run() handling --
    for node in ast.walk(tree):
        if isinstance(node, ast.Expr) and isinstance(node.value, ast.Call):
            f = node.value.func
            if isinstance(f, ast.Attribute) and f.attr == "run" and \
                    _receiver_mentions_pool(f):
                report("RL003", node,
                       "pool run() result discarded; its per-worker times "
                       "must be joined (fed back) or the call has no "
                       "propagation path")
        elif isinstance(node, ast.Try):
            swallows = any(
                all(isinstance(s, (ast.Pass, ast.Continue)) for s in h.body)
                for h in node.handlers)
            if not swallows:
                continue
            for inner in node.body:
                for call in ast.walk(inner):
                    if isinstance(call, ast.Call) and \
                            isinstance(call.func, ast.Attribute) and \
                            call.func.attr == "run" and \
                            _receiver_mentions_pool(call.func):
                        report("RL003", call,
                               "pool run() inside a try whose handler "
                               "swallows exceptions; shard errors must "
                               "propagate")

    # ------------------------------- RL004: jit over mutable ratio state --
    ratio_bound = set()
    fn_defs = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            ctor = node.value.func
            ctor_name = ctor.id if isinstance(ctor, ast.Name) else \
                ctor.attr if isinstance(ctor, ast.Attribute) else None
            if ctor_name in _MUTABLE_CTORS:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        ratio_bound.add(tgt.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fn_defs.setdefault(node.name, node)

    def check_jitted_body(fn, jit_node) -> None:
        bound = _collect_locals(fn)
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Name) and \
                        isinstance(node.ctx, ast.Load) and \
                        node.id not in bound and \
                        (node.id in _MUTABLE_NAMES or node.id in ratio_bound):
                    report("RL004", jit_node,
                           f"jitted closure captures mutable ratio state "
                           f"{node.id!r} (line {node.lineno}); pass it as "
                           f"an argument or snapshot offsets instead")
                    return
                if isinstance(node, ast.Attribute) and \
                        isinstance(node.ctx, ast.Load) and \
                        node.attr in _MUTABLE_ATTRS:
                    report("RL004", jit_node,
                           f"jitted closure reads mutable ratio state "
                           f"'.{node.attr}' (line {node.lineno}); pass it "
                           f"as an argument or snapshot offsets instead")
                    return

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _is_jit_expr(dec) or \
                        (isinstance(dec, ast.Call) and
                         (_is_jit_expr(dec.func) or _is_partial_of_jit(dec))):
                    check_jitted_body(node, node)
        elif isinstance(node, ast.Call):
            target = None
            if _is_jit_expr(node.func) and node.args:
                target = node.args[0]
            elif isinstance(node.func, ast.Call) and \
                    _is_partial_of_jit(node.func) and node.args:
                target = node.args[0]
            if target is None:
                continue
            if isinstance(target, ast.Lambda):
                check_jitted_body(target, node)
            elif isinstance(target, ast.Name) and target.id in fn_defs:
                check_jitted_body(fn_defs[target.id], node)

    # ------------------------------------ RL006: print() in library code --
    if not _matches(norm, PRINT_EXEMPT_FILES, PRINT_EXEMPT_DIRS):
        def _is_name_main_test(test) -> bool:
            return (isinstance(test, ast.Compare)
                    and isinstance(test.left, ast.Name)
                    and test.left.id == "__name__"
                    and any(isinstance(c, ast.Constant)
                            and c.value == "__main__"
                            for c in test.comparators))

        exempt = set()
        for node in ast.walk(tree):
            if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node.name == "main") or \
                    (isinstance(node, ast.If)
                     and _is_name_main_test(node.test)):
                for sub in ast.walk(node):
                    exempt.add(id(sub))
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id == "print" and id(node) not in exempt:
                report("RL006", node,
                       "raw print() in library code; emit through a "
                       "StatsSink, the events shim, or a repro.obs "
                       "exporter (CLI surfaces: repro/launch/, "
                       "__main__.py, main())")

    # --------------------------------------- RL005: stray ema_update() --
    if not _matches(norm, EMA_FILES):
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                f = node.func
                name = f.id if isinstance(f, ast.Name) else \
                    f.attr if isinstance(f, ast.Attribute) else None
                if name == "ema_update":
                    report("RL005", node,
                           "ema_update() must only be applied inside "
                           "RatioTable.observe (contracts and race hooks "
                           "instrument that call site)")

    return findings


def lint_file(path, *, virtual: Optional[bool] = None) -> List[Finding]:
    p = Path(path)
    return lint_source(p.read_text(), str(p), virtual=virtual)


def run_pass(root: str = "src", log=None) -> List[Finding]:
    """Lint every ``.py`` under ``root`` (or a single file)."""
    log = log or (lambda s: None)
    rootp = Path(root)
    files = [rootp] if rootp.is_file() else sorted(rootp.rglob("*.py"))
    findings: List[Finding] = []
    for f in files:
        findings.extend(lint_file(f))
    log(f"lint: {len(files)} file(s), {len(findings)} finding(s)")
    return findings
