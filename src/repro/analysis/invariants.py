"""Runtime invariant contracts for the balancing stack.

The paper's loop only works if a handful of numeric invariants hold at every
step; this module turns them into toggleable contracts checked *inside* the
hot paths:

* **IV001 — EMA boundedness.** ``RatioTable.observe`` must produce a convex
  combination: every updated ratio lies in the elementwise envelope of the
  previous ratio and the observation, and stays finite and positive.
* **IV002 — observation normalization.** A normalized observation fed into
  the EMA must satisfy the table's ``normalize`` convention (mean 1 over the
  valid workers for ``"mean"``, sum 1 for ``"sum"``).
* **IV003 — offset boundaries.** ``OffsetSnapshot`` boundaries are monotone
  *non-decreasing* (not strictly increasing) int32 cumsums starting at 0 and
  ending at exactly ``N`` — the device-side guarantee that compiled shards
  tile ``[0, N)``.  Equal adjacent boundaries are legal and meaningful:
  ``b[w] == b[w + 1]`` is worker ``w``'s zero-width shard, the fixed-shape
  encoding of a parked core under an elastic-capacity mask.
* **IV004 — plan partition.** Every shard plan's counts are non-negative and
  sum to exactly ``N``: contiguous shards partition the N-dim with no gap
  and no overlap.
* **IV005 — bytes conservation.** In two-level dispatch, the bytes a region
  adds to the aggregate accounting equal the bytes added across the
  per-socket dispatchers.

Contracts are **off by default** (the checks cost a cached-flag test).
Enable with ``REPRO_ANALYSIS_CONTRACTS=1`` in the environment (read once at
import), or programmatically / in tests::

    from repro.analysis import invariants
    with invariants.contracts():
        engine.run(...)

A violated contract raises :class:`ContractViolation` (an ``AssertionError``
subclass, so ``pytest`` reports it as a failure, not an error), after asking
an installed flight recorder (:mod:`repro.obs.recorder`) to dump its decision
ring.  This module imports only numpy and the stdlib-only events shim so
instrumented call sites stay cheap to import.
"""

from __future__ import annotations

import os
from contextlib import contextmanager

import numpy as np

from repro.core import events as _ev

from .findings import Finding

__all__ = [
    "RULES",
    "ContractViolation",
    "contracts_enabled",
    "enable",
    "disable",
    "contracts",
    "check_ema_step",
    "check_observation",
    "check_offset_boundaries",
    "check_plan_partition",
    "check_bytes_conserved",
    "run_pass",
]

RULES = {
    "IV001": "RatioTable EMA update left the [prev, observed] envelope or "
             "produced a non-finite/non-positive ratio",
    "IV002": "normalized observation violates the table's normalize "
             "convention (mean/sum over valid workers)",
    "IV003": "OffsetSnapshot boundaries are not a monotone int32 cumsum "
             "covering [0, N) exactly",
    "IV004": "shard plan does not partition the N-dim (negative count or "
             "counts do not sum to N)",
    "IV005": "bytes-moved accounting not conserved across socket/aggregate "
             "levels",
}

_ENV = os.environ.get("REPRO_ANALYSIS_CONTRACTS", "").strip().lower() in (
    "1", "true", "yes", "on")
_FORCED = None  # tri-state test/CLI override: None = follow env


class ContractViolation(AssertionError):
    """A runtime invariant contract failed."""

    def __init__(self, rule: str, message: str):
        self.rule = rule
        super().__init__(f"[{rule}] {message}")


def contracts_enabled() -> bool:
    """True when contract checks should run (env var or explicit override)."""
    if _FORCED is not None:
        return _FORCED
    return _ENV


def enable() -> None:
    global _FORCED
    _FORCED = True


def disable() -> None:
    global _FORCED
    _FORCED = False


@contextmanager
def contracts(on: bool = True):
    """Force contracts on (or off) within a block, restoring the previous
    override on exit."""
    global _FORCED
    prev = _FORCED
    _FORCED = on
    try:
        yield
    finally:
        _FORCED = prev


def _fail(rule: str, message: str):
    # A tripped contract is exactly the anomaly the flight recorder exists
    # for: dump the decision ring before raising so the violation ships with
    # the balancer decisions that led up to it.
    rec = _ev.RECORDER
    if rec is not None:
        trip = getattr(rec, "trip", None)
        if trip is not None:
            trip(f"contract {rule}: {message}")
    raise ContractViolation(rule, message)


# ----------------------------------------------------------------- checks --
# Checks are unconditional when called; call sites gate on
# ``contracts_enabled()`` so the disabled path never builds arrays.

def check_ema_step(prev, observed, updated, *, where: str = "RatioTable.observe") -> None:
    """IV001: ``updated`` is a convex combination of ``prev`` and ``observed``."""
    prev = np.asarray(prev, dtype=np.float64)
    observed = np.asarray(observed, dtype=np.float64)
    updated = np.asarray(updated, dtype=np.float64)
    if not np.all(np.isfinite(updated)):
        _fail("IV001", f"{where}: non-finite ratio after EMA: {updated}")
    if np.any(updated <= 0):
        _fail("IV001", f"{where}: non-positive ratio after EMA: {updated}")
    lo = np.minimum(prev, observed)
    hi = np.maximum(prev, observed)
    eps = 1e-9 + 1e-9 * np.maximum(np.abs(lo), np.abs(hi))
    if np.any(updated < lo - eps) or np.any(updated > hi + eps):
        _fail("IV001",
              f"{where}: EMA left the [prev, observed] envelope: "
              f"prev={prev} observed={observed} updated={updated}")


def check_observation(observed, valid, normalize: str, *,
                      where: str = "RatioTable.update") -> None:
    """IV002: the observation respects the table's normalize convention over
    the valid workers (only meaningful when >= 2 workers were measured)."""
    observed = np.asarray(observed, dtype=np.float64)
    valid = np.asarray(valid, dtype=bool)
    n_valid = int(valid.sum())
    if n_valid < 2:
        return  # singleton/empty measurements carry previous ratios over
    part = observed[valid]
    if not np.all(np.isfinite(part)) or np.any(part <= 0):
        _fail("IV002", f"{where}: invalid observed shares: {observed}")
    if normalize == "mean":
        stat, want = float(part.mean()), 1.0
    else:  # "sum"
        stat, want = float(part.sum()), 1.0
    if abs(stat - want) > 1e-6 * max(1.0, abs(want)):
        _fail("IV002",
              f"{where}: observation not normalized ({normalize} over "
              f"{n_valid} valid workers = {stat:.9f}, want {want})")


def check_offset_boundaries(bounds, total: int, *,
                            where: str = "OffsetSnapshot.refresh") -> None:
    """IV003: boundaries are a monotone non-decreasing int32 cumsum covering
    [0, total).  Equal adjacent entries (zero-width shards — parked cores)
    are legal; only a *decrease* violates the tiling."""
    bounds = np.asarray(bounds)
    if bounds.dtype != np.int32:
        _fail("IV003", f"{where}: boundaries dtype {bounds.dtype}, want int32")
    if bounds.ndim != 1 or bounds.size < 2:
        _fail("IV003", f"{where}: boundaries must be 1-D with >= 2 entries, "
                       f"got shape {bounds.shape}")
    if int(bounds[0]) != 0:
        _fail("IV003", f"{where}: boundaries start at {int(bounds[0])}, want 0")
    if int(bounds[-1]) != int(total):
        _fail("IV003", f"{where}: boundaries end at {int(bounds[-1])}, "
                       f"want N={int(total)}")
    if np.any(np.diff(bounds) < 0):
        _fail("IV003", f"{where}: boundaries decrease (zero-width shards "
                       f"are legal, negative ones are not): {bounds.tolist()}")


def check_plan_partition(counts, total: int, *, where: str = "Balancer.plan") -> None:
    """IV004: counts are non-negative and sum to exactly ``total``."""
    counts = np.asarray(counts)
    if np.any(counts < 0):
        _fail("IV004", f"{where}: negative shard count: {counts.tolist()}")
    got = int(np.asarray(counts, dtype=np.int64).sum())
    if got != int(total):
        _fail("IV004", f"{where}: shard counts sum to {got}, want N={int(total)} "
                       f"(gap/overlap in the partition): {counts.tolist()}")


def check_bytes_conserved(moved: float, inner_delta: float, *,
                          where: str = "TopologyDispatcher") -> None:
    """IV005: the bytes added to the aggregate level this region equal the
    bytes added across the per-socket dispatchers."""
    moved = float(moved)
    inner_delta = float(inner_delta)
    tol = 1e-6 * max(1.0, abs(moved))
    if abs(moved - inner_delta) > tol:
        _fail("IV005",
              f"{where}: aggregate accounted {moved:.6g} bytes this region "
              f"but socket dispatchers accounted {inner_delta:.6g}")


# --------------------------------------------------------------- CLI pass --
def run_pass(log=None) -> list:
    """Exercise the live stack with contracts force-enabled and report any
    violation as a Finding.  Used by ``python -m repro.analysis invariants``."""
    log = log or (lambda s: None)
    findings: list = []

    def _guard(name, fn):
        try:
            with contracts(True):
                fn()
            log(f"invariants: {name}: ok")
        except ContractViolation as e:
            findings.append(Finding(
                rule=e.rule, severity="error",
                location=f"contract:{name}",
                message=str(e)))

    def _ratio_table():
        from repro.runtime import RatioTable
        rng = np.random.default_rng(0)
        for normalize in ("mean", "sum"):
            table = RatioTable(4, alpha=0.3, normalize=normalize)
            key = "membw/attn_proj"  # lint: allow(RL002) self-exercise fixture
            for _ in range(32):
                times = rng.uniform(0.5, 2.0, size=4)
                table.update(key, times)
                table.update(key, times, units=rng.integers(1, 64, size=4))
            # degenerate shapes the loop must survive
            table.update(key, np.array([np.nan, 1.0, np.inf, 0.0]))
            table.update(key, np.array([1.0, 0.0, 0.0, 0.0]))

    def _offsets_and_plans():
        from repro.runtime import (Balancer, OffsetSpec, OffsetSnapshot,
                                   ProportionalPolicy, RatioTable)
        table = RatioTable(4, alpha=0.3)
        key = "membw/attn_proj"  # lint: allow(RL002) self-exercise fixture

        def counts(spec):
            policy = ProportionalPolicy(table, key=key,
                                        granularity=spec.granularity)
            return Balancer(policy, keep_stats=False).plan(spec.total).counts

        snap = OffsetSnapshot(counts)
        rng = np.random.default_rng(1)
        for i, total in enumerate((64, 96, 128)):
            snap.register(OffsetSpec(name=f"k{i}", total=total, granularity=8))
        for _ in range(8):
            snap.refresh()
            table.update(key, rng.uniform(0.5, 2.0, size=4))

    def _flat_dispatch():
        from repro.kernels.dispatch import GEMV_ISA, HybridKernelDispatcher
        from repro.runtime import KernelSpec
        d = HybridKernelDispatcher.virtual("ultra-125h", execute=False)
        try:
            spec = KernelSpec(name="gemv", isa=GEMV_ISA)
            for _ in range(6):
                d.dispatch(spec, 4096, bytes_per_unit=2048.0)
        finally:
            d.close()

    def _topology_dispatch():
        from repro.kernels.dispatch import GEMV_ISA
        from repro.runtime import KernelSpec
        from repro.topology.dispatch import TopologyDispatcher
        topo = TopologyDispatcher("dual-125h", execute=False)
        try:
            spec = KernelSpec(name="gemv", isa=GEMV_ISA)
            for _ in range(6):
                topo.dispatch(spec, 4096, bytes_per_unit=2048.0)
        finally:
            topo.close()

    _guard("ratio-table EMA/normalization", _ratio_table)
    _guard("offset snapshots + shard plans", _offsets_and_plans)
    _guard("flat dispatch loop", _flat_dispatch)
    _guard("two-level dispatch bytes conservation", _topology_dispatch)
    return findings
