"""Structured findings shared by all `repro.analysis` passes.

Every pass (lint, jaxpr audit, race detection, invariant contracts) reports
the same shape: a rule id, a severity, a location — ``file:line`` for static
rules, a trace location (``trace:…`` / ``jaxpr:…``) for dynamic ones — and a
human-readable message.  The CLI renders them one per line and fails the
build when any error-severity finding survives.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass

__all__ = ["Finding", "format_findings"]

SEVERITIES = ("error", "warning")


@dataclass(frozen=True)
class Finding:
    """One rule violation reported by an analysis pass."""

    rule: str        # "RL001", "JA002", "RC001", "IV003", ...
    severity: str    # "error" | "warning"
    location: str    # "src/repro/foo.py:42" | "trace:KernelTuner#1.tables" | "jaxpr:compiled step"
    message: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}, got {self.severity!r}")

    def format(self) -> str:
        return f"{self.location}: {self.severity}: [{self.rule}] {self.message}"

    def to_json(self) -> dict:
        return asdict(self)


def format_findings(findings) -> str:
    """Render findings one per line, errors first, stable within severity."""
    ordered = sorted(findings, key=lambda f: (f.severity != "error", f.rule, f.location))
    return "\n".join(f.format() for f in ordered)
