"""repro.analysis — static analysis & invariant verification for the
balancing stack.

Four passes behind one CLI (``python -m repro.analysis
[lint|audit|races|invariants|all]``), all reporting structured
:class:`~repro.analysis.findings.Finding`s:

* :mod:`~repro.analysis.lint` — repo-specific AST rules (RL001–RL005);
* :mod:`~repro.analysis.jaxpr_audit` — per-mode host-callback contracts
  over traced decode steps (JA001–JA004);
* :mod:`~repro.analysis.races` — vector-clock happens-before race
  detection over replayed pool schedules (RC001);
* :mod:`~repro.analysis.invariants` — toggleable runtime contracts
  (IV001–IV005, enabled with ``REPRO_ANALYSIS_CONTRACTS=1``).

Submodules are imported lazily: ``findings``/``lint``/``invariants`` are
stdlib+numpy only, and instrumented hot paths import ``invariants`` without
pulling jax-facing passes in.
"""

from .findings import Finding, format_findings

__all__ = [
    "Finding",
    "format_findings",
    "lint",
    "jaxpr_audit",
    "races",
    "invariants",
]

_SUBMODULES = ("lint", "jaxpr_audit", "races", "invariants", "findings")


def __getattr__(name):
    if name in _SUBMODULES:
        import importlib

        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
