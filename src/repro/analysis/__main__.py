"""CLI: ``python -m repro.analysis [lint|audit|races|invariants|all]``.

Runs the selected passes and prints structured findings one per line
(``location: severity: [RULE] message``).  Exit status 1 when any
error-severity finding survives — CI runs ``all`` over ``src/`` as the
static-analysis gate.
"""

from __future__ import annotations

import argparse
import json
import sys

from .findings import format_findings

PASSES = ("lint", "audit", "races", "invariants")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis & invariant verification passes.")
    parser.add_argument("passes", nargs="*", default=["all"],
                        choices=list(PASSES) + ["all"],
                        help="passes to run (default: all)")
    parser.add_argument("--root", default="src",
                        help="directory (or file) the lint pass walks "
                             "(default: src)")
    parser.add_argument("--json", action="store_true",
                        help="emit findings as JSON instead of text")
    parser.add_argument("--quiet", action="store_true",
                        help="suppress per-pass progress lines")
    args = parser.parse_args(argv)

    selected = list(PASSES) if "all" in args.passes else \
        [p for p in PASSES if p in args.passes]
    log = (lambda s: None) if args.quiet or args.json else \
        (lambda s: print(s, file=sys.stderr))

    findings = []
    for name in selected:
        if name == "lint":
            from . import lint
            findings.extend(lint.run_pass(args.root, log=log))
        elif name == "audit":
            from . import jaxpr_audit
            findings.extend(jaxpr_audit.run_pass(log=log))
        elif name == "races":
            from . import races
            findings.extend(races.run_pass(log=log))
        elif name == "invariants":
            from . import invariants
            findings.extend(invariants.run_pass(log=log))

    if args.json:
        print(json.dumps([f.to_json() for f in findings], indent=2))
    elif findings:
        print(format_findings(findings))
    errors = sum(1 for f in findings if f.severity == "error")
    if not args.json:
        print(f"repro.analysis: {len(selected)} pass(es), "
              f"{len(findings)} finding(s), {errors} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
