"""Happens-before race detection over replayed worker-pool schedules.

The measure→EMA→split loop is full of shared mutable state — the
:class:`~repro.core.tuner.KernelTuner` block cache, :class:`~repro.runtime.
table.RatioTable` EMA vectors, dispatcher bytes/busy accounting — touched
from worker-pool sub-tasks and from the main task between regions.  The PR 3
pool fixes and the thread-safe tuner were each found *after* a bug shipped;
this pass checks the synchronization discipline mechanically instead.

How it works:

1. The pools and shared state emit :class:`~repro.core.events.Event`s when a
   tracer is installed (see :mod:`repro.core.events`): ``fork``/``join`` for
   pool sub-tasks, ``acquire``/``release`` for locks, ``read``/``write`` for
   state accesses.
2. :func:`find_races` replays the recorded schedule through a vector-clock
   happens-before checker.  Two accesses to the same ``(obj, field)``
   conflict when they come from different logical tasks, at least one is a
   write, and neither happens-before the other through fork/join or lock
   edges — rule **RC001**.

Because logical tasks are pool sub-tasks (not OS threads), the checker is
*predictive*: a :class:`~repro.core.pool.VirtualWorkerPool` executes its
sub-tasks sequentially, but an unsynchronized access pattern between two
sub-tasks of one region is flagged anyway — the schedule that loses the
update merely hasn't happened yet.  This is the property that lets the CLI
vet threaded execution plans without ever racing for real.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, List, Tuple

from repro.core import events as ev
from .findings import Finding

__all__ = ["RULES", "Recorder", "trace", "find_races", "run_pass"]

RULES = {
    "RC001": "conflicting unsynchronized accesses (write involved) to "
             "shared mutable state from concurrent logical tasks",
}


class Recorder:
    """Thread-safe event sink; install via :func:`trace`."""

    def __init__(self):
        self.events: List[ev.Event] = []
        self._lock = threading.Lock()

    def emit(self, event: ev.Event) -> None:
        with self._lock:
            self.events.append(event)


@contextmanager
def trace():
    """Record all access events emitted within the block."""
    rec = Recorder()
    prev = ev.install(rec)
    try:
        yield rec
    finally:
        ev.install(prev)


# ------------------------------------------------------------- the checker --
class _Clock(dict):
    """Sparse vector clock: task -> count."""

    def merge(self, other: Dict[str, int]) -> None:
        for task, n in other.items():
            if n > self.get(task, 0):
                self[task] = n


def find_races(events, *, max_findings: int = 25) -> List[Finding]:
    """Run the vector-clock happens-before check over a recorded schedule."""
    clocks: Dict[str, _Clock] = {}
    lock_clocks: Dict[str, _Clock] = {}
    # (obj, field) -> list of (kind, task, clock-snapshot, where); pruned to
    # the latest access per (task, kind) — sound because a task's own clock
    # only grows, so its latest access is the hardest to order against.
    accesses: Dict[Tuple[str, str], Dict[Tuple[str, str], tuple]] = {}
    findings: List[Finding] = []
    seen = set()

    def clock(task: str) -> _Clock:
        c = clocks.get(task)
        if c is None:
            c = _Clock({task: 0})
            clocks[task] = c
        return c

    for e in events:
        c = clock(e.task)
        c[e.task] = c.get(e.task, 0) + 1
        if e.kind == "fork":
            child = clock(e.obj)
            child.merge(c)
        elif e.kind == "join":
            c.merge(clock(e.obj))
        elif e.kind == "acquire":
            held = lock_clocks.get(e.obj)
            if held is not None:
                c.merge(held)
        elif e.kind == "release":
            held = lock_clocks.setdefault(e.obj, _Clock())
            held.merge(c)
        elif e.kind in ("read", "write"):
            site = accesses.setdefault((e.obj, e.field), {})
            snap = dict(c)
            for (other_task, other_kind), (o_clock, o_where) in site.items():
                if other_task == e.task:
                    continue
                if e.kind == "read" and other_kind == "read":
                    continue
                if o_clock.get(other_task, 0) <= c.get(other_task, 0):
                    continue  # ordered: prior access happens-before this one
                dedup = (e.obj, e.field, other_kind, e.kind,
                         o_where, e.where)
                if dedup in seen:
                    continue
                seen.add(dedup)
                findings.append(Finding(
                    rule="RC001", severity="error",
                    location=f"trace:{e.obj}.{e.field}",
                    message=(f"unsynchronized {other_kind} at "
                             f"{o_where or other_task} conflicts with "
                             f"{e.kind} at {e.where or e.task} "
                             f"(tasks {other_task} vs {e.task})")))
                if len(findings) >= max_findings:
                    return findings
            site[(e.task, e.kind)] = (snap, e.where)
    return findings


# --------------------------------------------------------------- CLI pass --
def run_pass(log=None) -> List[Finding]:
    """Replay representative schedules of the real stack under the tracer
    and check them.  Used by ``python -m repro.analysis races``."""
    import numpy as np

    log = log or (lambda s: None)
    findings: List[Finding] = []

    def _run(name: str, fn) -> None:
        with trace() as rec:
            fn()
        found = find_races(rec.events)
        for f in found:
            findings.append(Finding(
                rule=f.rule, severity=f.severity,
                location=f"{f.location} [{name}]", message=f.message))
        log(f"races: {name}: {len(rec.events)} events, "
            f"{len(found)} race(s)")

    def _virtual_q4():
        import jax.numpy as jnp
        from repro.kernels.dispatch import HybridKernelDispatcher
        from repro.quant.q4 import quantize_q4_0
        d = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
        try:
            rng = np.random.default_rng(0)
            x = jnp.asarray(rng.normal(size=(1, 64)).astype(np.float32))
            qw = quantize_q4_0(jnp.asarray(
                rng.normal(size=(96, 64)).astype(np.float32)))
            for _ in range(2):
                d.q4_matmul(x, qw)
        finally:
            d.close()

    def _threaded_f32():
        from repro.kernels.dispatch import HybridKernelDispatcher
        d = HybridKernelDispatcher.threaded(2)
        try:
            rng = np.random.default_rng(1)
            x = rng.normal(size=(2, 32)).astype(np.float32)
            w = rng.normal(size=(64, 32)).astype(np.float32)
            for _ in range(2):
                d.f32_matmul(x, w)
        finally:
            d.close()

    def _threaded_accounting():
        from repro.core.pool import SubTask, ThreadWorkerPool
        from repro.kernels.dispatch import GEMV_ISA, HybridKernelDispatcher
        d = HybridKernelDispatcher.threaded(4)
        pool = ThreadWorkerPool(4)
        try:
            subtasks = [
                SubTask(worker=w, start=w, size=1, work=1.0,
                        fn=lambda s, z: d._account(GEMV_ISA, 128.0, 1e-3))
                for w in range(4)
            ]
            pool.run(subtasks)  # lint: allow(RL003) accounting-only schedule
        finally:
            pool.close()
            d.close()

    def _two_level():
        from repro.kernels.dispatch import GEMV_ISA
        from repro.runtime import KernelSpec
        from repro.topology.dispatch import TopologyDispatcher
        topo = TopologyDispatcher("dual-125h", execute=False)
        try:
            spec = KernelSpec(name="gemv", isa=GEMV_ISA)
            for _ in range(3):
                topo.dispatch(spec, 2048, bytes_per_unit=2048.0)
        finally:
            topo.close()

    _run("virtual q4 dispatch", _virtual_q4)
    _run("threaded f32 dispatch", _threaded_f32)
    _run("concurrent bytes/busy accounting", _threaded_accounting)
    _run("two-level topology dispatch", _two_level)
    return findings
