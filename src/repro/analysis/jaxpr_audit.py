"""Jaxpr auditor: per-mode host-callback contracts for traced decode steps.

PR 7's headline property — "the compiled decode step contains zero host
callbacks" — was one ad-hoc string count in ``tests/test_compiled.py``.
This pass turns it (and its bridged-mode dual) into a reusable audit over
the actual jaxpr, closed-call-aware, so every future trace mode is held to
an explicit contract:

* **JA001** — a ``mode="compiled"`` step must contain **zero**
  ``io_callback`` / ``pure_callback`` / ``debug_callback`` primitives
  anywhere in the (recursively walked) jaxpr.
* **JA002** — :class:`~repro.runtime.OffsetSnapshot` boundary arrays
  entering a compiled step may be consumed **only** by slice-style
  indexing and cheap shape/arithmetic ops (the cost-tape pattern
  ``bounds[1:] - bounds[:-1]``); an offset-derived value flowing into
  anything else — above all a callback — means the program's behaviour
  depends on balance state in a way feedback replay cannot account for.
* **JA003** — a ``mode="bridge"`` step must contain **exactly** the
  expected callback count: one fused q/k/v callback plus one ``wo`` per
  attention layer when ``fused=True`` (one per projection otherwise), one
  per MLP projection.
* **JA004** — every bridge callback must be **ordered** (unordered or pure
  callbacks can be elided/reordered by the compiler, which breaks the
  measure→EMA→split sequencing).

The walkers duck-type jaxprs (``.eqns`` / ``.jaxpr``) rather than importing
``jax.core`` names, so they track jax versions.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

from .findings import Finding

__all__ = [
    "RULES",
    "iter_eqns",
    "count_callbacks",
    "audit_compiled",
    "audit_bridge",
    "expected_bridge_callbacks",
    "trace_compiled_step",
    "trace_bridged_step",
    "TracedStep",
    "run_pass",
]

RULES = {
    "JA001": "host callback primitive inside a compiled (zero-callback) step",
    "JA002": "offset boundary array consumed by a non-slice primitive "
             "inside a compiled step",
    "JA003": "bridged step callback count differs from the per-layer "
             "contract",
    "JA004": "bridge callback is not an ordered io_callback",
}

# Primitives an offset boundary array may legally flow through inside a
# compiled step: slice-style indexing plus the cost-tape arithmetic
# (bounds[1:] - bounds[:-1], dtype casts, packing into tape outputs).
_ALLOWED_OFFSET_PRIMS = {
    "slice", "dynamic_slice", "gather", "squeeze", "reshape",
    "broadcast_in_dim", "convert_element_type", "sub", "add",
    "concatenate", "transpose", "copy", "stop_gradient",
    # index clamping emitted by lax.dynamic_slice on traced starts
    "lt", "le", "gt", "ge", "eq", "select_n", "max", "min", "clamp",
}


# ------------------------------------------------------------ jaxpr walking --
def _as_jaxpr(obj):
    """Unwrap ClosedJaxpr -> Jaxpr (duck-typed)."""
    inner = getattr(obj, "jaxpr", None)
    return inner if inner is not None and hasattr(inner, "eqns") else obj


def _sub_jaxprs(params: dict) -> list:
    """All jaxprs nested in an eqn's params (pjit/closed_call/scan/cond...)."""
    subs = []
    for value in params.values():
        items = value if isinstance(value, (list, tuple)) else (value,)
        for item in items:
            if hasattr(item, "eqns"):
                subs.append(item)
            elif hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
                subs.append(item.jaxpr)
    return subs


def iter_eqns(jaxpr) -> Iterable:
    """Every eqn of ``jaxpr`` (Jaxpr or ClosedJaxpr), recursing into
    closed/higher-order sub-jaxprs."""
    j = _as_jaxpr(jaxpr)
    for eqn in j.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def _is_callback(eqn) -> bool:
    return "callback" in eqn.primitive.name


def count_callbacks(jaxpr) -> Dict[str, int]:
    """Callback primitive name -> occurrence count, recursive."""
    counts: Dict[str, int] = {}
    for eqn in iter_eqns(jaxpr):
        if _is_callback(eqn):
            name = eqn.primitive.name
            counts[name] = counts.get(name, 0) + 1
    return counts


def _is_literal(var) -> bool:
    return hasattr(var, "val")


def _taint_walk(jaxpr, tainted: set, sink_names: set) -> List[int]:
    """Propagate offset taint through one jaxpr; records disallowed sink
    primitive names into ``sink_names``; returns tainted outvar indices."""
    j = _as_jaxpr(jaxpr)
    for eqn in j.eqns:
        hit = [i for i, v in enumerate(eqn.invars)
               if not _is_literal(v) and v in tainted]
        if not hit:
            continue
        name = eqn.primitive.name
        subs = _sub_jaxprs(eqn.params)
        if subs and len(subs) == 1 and \
                len(_as_jaxpr(subs[0]).invars) == len(eqn.invars):
            # call-like (pjit / closed_call / remat): positional mapping
            sub = _as_jaxpr(subs[0])
            sub_tainted = {sub.invars[i] for i in hit}
            for i in _taint_walk(sub, sub_tainted, sink_names):
                tainted.add(eqn.outvars[i])
        elif _is_callback(eqn):
            sink_names.add(name)
        elif name in _ALLOWED_OFFSET_PRIMS:
            for v in eqn.outvars:
                tainted.add(v)
        else:
            sink_names.add(name)
    return [i for i, v in enumerate(j.outvars)
            if not _is_literal(v) and v in tainted]


# ---------------------------------------------------------------- auditors --
def audit_compiled(jaxpr, offset_invars: Tuple[int, ...] = (), *,
                   where: str = "compiled step") -> List[Finding]:
    """JA001 + JA002 over a traced compiled step.  ``offset_invars`` are
    flat invar positions holding OffsetSnapshot boundary arrays."""
    findings: List[Finding] = []
    for name, n in sorted(count_callbacks(jaxpr).items()):
        findings.append(Finding(
            rule="JA001", severity="error", location=f"jaxpr:{where}",
            message=f"compiled step contains {n} {name} primitive(s); "
                    f"the zero-callback contract is broken"))
    j = _as_jaxpr(jaxpr)
    tainted = {j.invars[i] for i in offset_invars if i < len(j.invars)}
    if tainted:
        sinks: set = set()
        _taint_walk(jaxpr, tainted, sinks)
        for name in sorted(sinks):
            findings.append(Finding(
                rule="JA002", severity="error", location=f"jaxpr:{where}",
                message=f"offset boundary array flows into {name!r}; "
                        f"offsets may only be consumed via slice-style "
                        f"indexing (the cost-tape pattern)"))
    return findings


def audit_bridge(jaxpr, expected: Optional[int] = None, *,
                 where: str = "bridged step") -> List[Finding]:
    """JA003 + JA004 over a traced bridge-mode step."""
    findings: List[Finding] = []
    n_io = 0
    for eqn in iter_eqns(jaxpr):
        if not _is_callback(eqn):
            continue
        name = eqn.primitive.name
        if name == "io_callback":
            n_io += 1
            if not eqn.params.get("ordered", False):
                findings.append(Finding(
                    rule="JA004", severity="error",
                    location=f"jaxpr:{where}",
                    message="io_callback without ordered=True; the bridge "
                            "requires ordered callbacks so shard dispatch "
                            "follows program order"))
        elif name != "debug_callback":
            findings.append(Finding(
                rule="JA004", severity="error", location=f"jaxpr:{where}",
                message=f"bridge step routes a projection through "
                        f"{name}; only ordered io_callback is allowed"))
    if expected is not None and n_io != expected:
        findings.append(Finding(
            rule="JA003", severity="error", location=f"jaxpr:{where}",
            message=f"bridged step contains {n_io} io_callback(s), "
                    f"expected {expected} (one fused q/k/v + one wo per "
                    f"attention layer, one per MLP projection)"))
    return findings


# --------------------------------------------------------- trunk frontends --
@dataclass(frozen=True)
class TracedStep:
    """A traced step plus where its offset arrays sit in the flat invars."""

    jaxpr: object                       # ClosedJaxpr from jax.make_jaxpr
    offset_invars: Tuple[int, ...] = ()
    mode: str = "compiled"
    label: str = "step"


def expected_bridge_callbacks(trunk) -> int:
    """The per-layer callback contract for a bridge-mode trunk: fused
    attention is one fused q/k/v callback plus one ``wo``; unfused is one
    per attention projection; dense MLP is one per banked projection."""
    cfg = trunk.cfg
    period_len = len(cfg.period())
    total = 0
    for i, (mixer, ffn) in enumerate(cfg.layer_plan()):
        j = i % period_len
        if mixer == "attn":
            present = [n for n in ("wq", "wk", "wv", "wo")
                       if (j, "attn", n) in trunk.bank]
            if trunk.fused and all(
                    n in present for n in ("wq", "wk", "wv")):
                total += 1 + (1 if "wo" in present else 0)
            else:
                total += len(present)
        if ffn == "dense":
            total += sum(1 for k in trunk.bank if k[0] == j and k[1] == "ffn")
    return total


def trace_compiled_step(cfg, params, trunk, *, isa: str = "membw",
                        batch: int = 1, max_seq: int = 8) -> TracedStep:
    """Trace one full compiled decode step (trunk projections + head +
    cost tape) exactly as the engine runs it, and locate the offset
    arrays among the flat invars for the taint audit."""
    import jax
    import jax.numpy as jnp
    from jax.tree_util import tree_leaves

    from repro.models.transformer import forward, init_state

    state = init_state(cfg, batch, max_seq)
    tok = jnp.zeros((batch, 1), jnp.int32)
    offsets = trunk.compiled_refresh()

    def step(p, t, s, offs):
        tape = trunk.compiled_tape_begin()
        out = forward(cfg, p, t, state=s, apply_head=False, trunk=trunk,
                      trunk_isa=isa, trunk_offsets=offs)
        logits = trunk.apply_head(out.logits[:, -1, :], isa=isa,
                                  offsets=offs)
        return logits, out.state, trunk.compiled_tape_end(tape)

    closed = jax.make_jaxpr(step)(params, tok, state, offsets)
    lead = len(tree_leaves((params, tok, state)))
    n_off = len(tree_leaves(offsets))
    return TracedStep(jaxpr=closed,
                      offset_invars=tuple(range(lead, lead + n_off)),
                      mode="compiled", label="compiled decode step")


def trace_bridged_step(cfg, params, trunk, *, isa: str = "membw",
                       batch: int = 1, max_seq: int = 8) -> TracedStep:
    """Trace one bridge-mode decode step (projections only; the head is
    applied host-side outside the jit in bridge mode)."""
    import jax
    import jax.numpy as jnp

    from repro.models.transformer import forward, init_state

    state = init_state(cfg, batch, max_seq)
    tok = jnp.zeros((batch, 1), jnp.int32)

    def step(p, t, s):
        out = forward(cfg, p, t, state=s, apply_head=False, trunk=trunk,
                      trunk_isa=isa)
        return out.logits[:, -1, :], out.state

    closed = jax.make_jaxpr(step)(params, tok, state)
    return TracedStep(jaxpr=closed, offset_invars=(), mode="bridge",
                      label="bridged decode step")


def audit_step(step: TracedStep, *, expected: Optional[int] = None) -> List[Finding]:
    if step.mode == "compiled":
        return audit_compiled(step.jaxpr, step.offset_invars,
                              where=step.label)
    return audit_bridge(step.jaxpr, expected, where=step.label)


# --------------------------------------------------------------- CLI pass --
def run_pass(log=None) -> List[Finding]:
    """Trace the reduced trunk in both modes and audit every contract,
    including each projection kind and the head standalone.  Used by
    ``python -m repro.analysis audit``."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import reduced_config
    from repro.kernels.dispatch import GEMV_ISA, HybridKernelDispatcher
    from repro.models import BalancedTrunk, init_params

    log = log or (lambda s: None)
    findings: List[Finding] = []

    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    disp = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
    try:
        compiled = BalancedTrunk.from_params(cfg, params, disp, quant="q4",
                                             mode="compiled")
        step = trace_compiled_step(cfg, params, compiled, isa=GEMV_ISA)
        got = audit_step(step)
        findings.extend(got)
        log(f"audit: {step.label}: "
            f"{sum(count_callbacks(step.jaxpr).values())} callback(s), "
            f"{len(got)} finding(s)")

        # each projection kind + head, traced standalone
        offsets = compiled.compiled_refresh()
        rng = np.random.default_rng(0)
        x_d = jnp.asarray(rng.standard_normal(
            (2, cfg.d_model)).astype(np.float32))
        x_ff = jnp.asarray(rng.standard_normal(
            (2, cfg.d_ff)).astype(np.float32))
        sites = [(g, n) for (j, g, n) in sorted(compiled.bank) if j == 0]
        for group, name in sites:
            def one(offs, _g=group, _n=name):
                proj = compiled.projector(0, 0, _g, GEMV_ISA, offsets=offs)
                xin = x_ff if (_g, _n) == ("ffn", "wo") else x_d
                return proj(_n, xin, None)

            closed = jax.make_jaxpr(one)(offsets)
            from jax.tree_util import tree_leaves
            n_off = len(tree_leaves(offsets))
            got = audit_compiled(closed, tuple(range(n_off)),
                                 where=f"compiled {group}.{name}")
            findings.extend(got)
            log(f"audit: compiled {group}.{name}: "
                f"{sum(count_callbacks(closed).values())} callback(s)")

        def head(offs):
            return compiled.apply_head(x_d, isa=GEMV_ISA, offsets=offs)

        closed = jax.make_jaxpr(head)(offsets)
        from jax.tree_util import tree_leaves
        got = audit_compiled(closed,
                             tuple(range(len(tree_leaves(offsets)))),
                             where="compiled head")
        findings.extend(got)
        log(f"audit: compiled head: "
            f"{sum(count_callbacks(closed).values())} callback(s)")

        for fused in (False, True):
            bridged = BalancedTrunk.from_params(
                cfg, params, disp, quant="q4", pin_q4_blocks=True,
                fused=fused)
            step = trace_bridged_step(cfg, params, bridged, isa=GEMV_ISA)
            want = expected_bridge_callbacks(bridged)
            got = audit_step(step, expected=want)
            findings.extend(got)
            n_io = count_callbacks(step.jaxpr).get("io_callback", 0)
            log(f"audit: {step.label} (fused={fused}): {n_io} ordered "
                f"io_callback(s), expected {want}, {len(got)} finding(s)")
    finally:
        disp.close()
    return findings
