"""The paper's scenario end-to-end: llama2-7B Q4_0 inference on two hybrid
CPUs, static-OpenMP vs dynamic scheduling, with the Fig. 4 ratio trace.

  PYTHONPATH=src python examples/hybrid_cpu_inference.py
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.bench_e2e import simulate
from repro.core import VirtualWorkerPool, make_machine
from repro.runtime import CPURuntime, DynamicScheduler, KernelSpec

GEMM = KernelSpec("int8_gemm", "avx_vnni", granularity=16,
                  work_per_unit=2 * 1024 * 4096)


def main():
    for machine in ("ultra-125h", "core-12900k"):
        pf_d, dec_d = simulate(machine, dynamic=True)
        pf_s, dec_s = simulate(machine, dynamic=False)
        print(f"[{machine}] prefill {pf_s:.2f}s -> {pf_d:.2f}s "
              f"(+{(pf_s / pf_d - 1) * 100:.0f}%) | "
              f"decode {1 / dec_s:.1f} -> {1 / dec_d:.1f} tok/s "
              f"(+{(dec_s / dec_d - 1) * 100:.0f}%)")

    # Fig. 4: watch a P-core's ratio converge from the too-high init of 5,
    # then absorb a background program stealing half of core 0.
    machine = make_machine("ultra-125h")
    machine.background.append((0.05, 1e9, 0, 2.0))
    runtime = CPURuntime(machine.n_cores, alpha=0.3, init_ratio=5.0)
    sched = DynamicScheduler(runtime, VirtualWorkerPool(machine, isa="avx_vnni"))
    trace = []
    for _ in range(30):
        sched.dispatch(GEMM, 4096)
        trace.append(runtime.ratios("avx_vnni")[0])
    t = np.array(trace)
    print("[fig4] P0 ratio trace:", " ".join(f"{v:.2f}" for v in t[:10]), "...")
    print(f"[fig4] init 5.00 -> settled {t[-1]:.2f} "
          f"(background load at dispatch ~5 absorbed)")


if __name__ == "__main__":
    main()
