"""Quickstart: the paper's dynamic scheduler in 40 lines, plus a tiny
JAX model trained with the framework's stack.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import VirtualWorkerPool, make_machine
from repro.runtime import (
    CPURuntime, DynamicScheduler, StaticScheduler, KernelSpec,
)
from repro.configs import reduced_config
from repro.data import DataConfig, SyntheticLM
from repro.models import init_params
from repro.training import AdamWConfig, init_opt_state, make_train_step


def demo_scheduler():
    """Fig. 2 in miniature: dynamic vs static INT8 GEMM on a hybrid CPU."""
    gemm = KernelSpec(name="int8_gemm", isa="avx_vnni", granularity=16,
                      work_per_unit=2 * 1024 * 4096)
    machine = make_machine("ultra-125h")
    dyn = DynamicScheduler(CPURuntime(machine.n_cores, alpha=0.3),
                           VirtualWorkerPool(machine, isa="avx_vnni"))
    for _ in range(30):
        last = dyn.dispatch(gemm, 4096)
    static = StaticScheduler(VirtualWorkerPool(make_machine("ultra-125h"),
                                               isa="avx_vnni"))
    st = static.dispatch(gemm, 4096)
    print(f"[scheduler] static {st.makespan * 1e3:.2f} ms -> "
          f"dynamic {last.makespan * 1e3:.2f} ms "
          f"(+{(st.makespan / last.makespan - 1) * 100:.0f}%)")


def demo_training():
    """Train a reduced granite-8b for 30 steps on synthetic data."""
    cfg = reduced_config("granite-8b")
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=30)
    data = iter(SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                       global_batch=8, microbatch=4)))
    params = init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    first = last = None
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, m = step(params, opt, batch)
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    print(f"[training] loss {first:.3f} -> {last:.3f} over 30 steps")


if __name__ == "__main__":
    demo_scheduler()
    demo_training()
