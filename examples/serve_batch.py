"""Batched serving with heterogeneous replicas: the paper's Eq. 3 routes
requests proportionally to measured replica throughput.

This is the seed-era *whole-batch* API; ``serve_batch`` now executes
through the continuous-batching engine under the hood.  For request-level
serving (no batch barrier, per-phase ratios) see
``examples/continuous_serving.py``.

  PYTHONPATH=src python examples/serve_batch.py
"""

import numpy as np
import jax

from repro.configs import reduced_config
from repro.models import init_params
from repro.serving import RoutedServer, ServeEngine


def main():
    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    engines = [ServeEngine(cfg, params, batch_size=8, max_seq=48)
               for _ in range(2)]
    srv = RoutedServer(engines)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, size=(8, 8), dtype=np.int32)

    # Replica 1 simulated 3x slower (co-tenant / old hardware): watch the
    # router shift the batch split from 4:4 toward ~6:2.
    speeds = np.array([3.0, 1.0])
    for round_ in range(5):
        planned = srv.router.split(len(prompts))
        out, counts, _ = srv.serve_batch(
            prompts, n_steps=4,
            times_override=np.maximum(planned, 1e-3) / speeds)
        print(f"[serve] round {round_}: split={counts.tolist()} "
              f"ratios={srv.runtime.ratios('serve_step').round(2).tolist()}")
    assert out.shape[0] == len(prompts)
    print("[serve] done; generated shape:", out.shape)


if __name__ == "__main__":
    main()
