"""Q4_0 weight-only inference through the Pallas kernels — the paper's
actual compute path (fused dequant-matmul), validated against the float
model, with the KernelTuner picking block configs online.

  PYTHONPATH=src python examples/q4_inference.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core import KernelTuner, shape_class
from repro.kernels import TunedMatmul, q4_matmul, ref
from repro.models import forward, init_params
from repro.quant import quantize_q4_0, dequantize_q4_0, BYTES_PER_ELEM


def quantize_params(params):
    """Quantize every >=2D matmul weight of the trunk to Q4_0."""
    count = [0]

    def q(path, leaf):
        if leaf.ndim == 2 and min(leaf.shape) >= 32 and leaf.shape[0] % 32 == 0:
            count[0] += 1
            # store as (out, in) for y = x @ W: quantize W^T rows
            return quantize_q4_0(jnp.asarray(leaf).T)
        if leaf.ndim == 3 and min(leaf.shape[1:]) >= 32 and leaf.shape[1] % 32 == 0:
            count[0] += 1  # period-stacked (P, in, out)
            return jax.vmap(lambda w: quantize_q4_0(w.T))(jnp.asarray(leaf))
        return leaf

    return jax.tree_util.tree_map_with_path(q, params), count[0]


def main():
    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    qparams, n_quant = quantize_params(params)
    print(f"[q4] quantized {n_quant} weight matrices to Q4_0 "
          f"({BYTES_PER_ELEM} bytes/element vs 4)")

    # 1) kernel-level: fused Q4 matmul (Pallas, interpret) vs float matmul
    w = params["period"][0]["mixer"]["wq"][0]          # (d, H*hd)
    qw = quantize_q4_0(jnp.asarray(w).T)
    x = jax.random.normal(jax.random.key(1), (8, w.shape[0]), jnp.float32)
    y_pallas = q4_matmul(x, qw, interpret=True)
    y_ref = ref.q4_matmul_ref(x, qw)
    y_float = x @ w
    kernel_err = float(jnp.abs(y_pallas - y_ref).max())
    quant_rel = float(jnp.abs(y_pallas - y_float).max() /
                      jnp.abs(y_float).max())
    print(f"[q4] pallas-vs-oracle max err {kernel_err:.2e}; "
          f"quantization rel err {quant_rel:.3f}")

    # 2) model-level: dequantized-weights forward vs float forward (the
    #    paper reports Q4_0 is accurate enough for llama2-7b; here we show
    #    logits stay close on the reduced config)
    def dq(l):
        if not hasattr(l, "packed"):
            return l
        if l.packed.ndim == 3:  # period-stacked
            return jnp.swapaxes(jax.vmap(dequantize_q4_0)(l), 1, 2).astype(cfg.cdtype)
        return dequantize_q4_0(l).T.astype(cfg.cdtype)

    deq = jax.tree_util.tree_map(dq, qparams,
                                 is_leaf=lambda l: hasattr(l, "packed"))
    toks = jax.random.randint(jax.random.key(2), (2, 16), 0, cfg.vocab_size)
    lg_f = forward(cfg, params, toks).logits
    lg_q = forward(cfg, deq, toks).logits
    agree = float((jnp.argmax(lg_f, -1) == jnp.argmax(lg_q, -1)).mean())
    rel = float(jnp.linalg.norm(lg_f - lg_q) / jnp.linalg.norm(lg_f))
    print(f"[q4] greedy-token agreement float-vs-Q4: {agree:.1%} "
          f"(logits rel err {rel:.3f}; random-init logits are near-tied, "
          f"trained models agree far more)")

    # 3) online config tuning (the per-ISA table analogue)
    tm = TunedMatmul(KernelTuner(alpha=0.3, min_trials=1), interpret=True)
    for _ in range(4):
        tm.q4(x, qw)
    key = ("q4_matmul", shape_class(8, qw.out_features, x.shape[1]))
    print(f"[q4] tuner selected blocks {tm.tuner.best(key)} for shape "
          f"{shape_class(8, qw.out_features, x.shape[1])}")


if __name__ == "__main__":
    main()
