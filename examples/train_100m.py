"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps on synthetic data, with checkpoint/restart and the paper's
uneven-DP straggler mitigation running in simulation.

  PYTHONPATH=src python examples/train_100m.py [--steps 300]

The model: 12L x d512 x 8H (kv 4) x ff 2048, vocab 8192 -> ~101M params.
Loss drops fast because the stream is a learnable 2-gram (see repro.data).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.configs.base import ModelConfig
from repro.runtime import DeviceRuntime, UnevenBatchPlanner
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.models import init_params
from repro.training import (
    AdamWConfig, init_opt_state, make_train_step, local_accum,
    weighted_combine, adamw_update,
)

CFG = ModelConfig(
    name="llama-100m", family="dense", n_layers=12, d_model=512,
    n_heads=8, n_kv_heads=4, d_ff=2048, vocab_size=8192,
    dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    ap.add_argument("--uneven-every", type=int, default=0,
                    help="if >0, run the paper's uneven-DP step every N steps"
                         " (simulating 4 pods, one 2x slower)")
    args = ap.parse_args()

    print(f"[100m] params: {CFG.param_count() / 1e6:.0f}M")
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=30, total_steps=args.steps)
    data = SyntheticLM(DataConfig(vocab_size=CFG.vocab_size, seq_len=128,
                                  global_batch=16, microbatch=4))
    params = init_params(CFG, jax.random.key(0))
    opt = init_opt_state(params, opt_cfg)
    start = 0
    last_ck = latest_step(args.ckpt_dir)
    if last_ck is not None:
        tree, meta = restore(args.ckpt_dir, last_ck,
                             jax.eval_shape(lambda: {"p": params, "o": opt}))
        params, opt = tree["p"], tree["o"]
        start = last_ck
        data.seek(meta["extra"]["data_step"])
        print(f"[100m] resumed at step {start}")

    step_fn = jax.jit(make_train_step(CFG, opt_cfg))
    it = Prefetcher(iter(data), depth=2)

    # Paper adaptation: 4 simulated pods, pod 3 runs at half speed.
    pod_rt = DeviceRuntime(n_slices=4, alpha=0.3)
    planner = UnevenBatchPlanner(pod_rt)
    pod_speed = np.array([1.0, 1.0, 1.0, 0.5])

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        if args.uneven_every and (step + 1) % args.uneven_every == 0:
            plan = planner.plan(batch["tokens"].shape[0])
            shards, cursor = [], 0
            for c in plan.counts:
                shards.append({k: v[cursor:cursor + c] for k, v in batch.items()})
                cursor += int(c)
            grads, losses = [], []
            for shard in shards:
                l, g = local_accum(CFG, params, shard)
                losses.append(float(l))
                grads.append(g)
            g = weighted_combine(grads, plan.counts)
            params, opt, m = adamw_update(opt_cfg, params, g, opt)
            planner.report(plan, plan.counts / pod_speed)  # simulated times
            loss = float(np.average(losses, weights=plan.weights))
            extra = f" uneven counts={plan.counts.tolist()}"
        else:
            params, opt, m = step_fn(params, opt, batch)
            loss = float(m["loss"])
            extra = ""
        if (step + 1) % 25 == 0:
            print(f"[100m] step {step + 1:4d} loss={loss:.4f}{extra}")
        if (step + 1) % 100 == 0:
            save(args.ckpt_dir, step + 1, {"p": params, "o": opt},
                 extra={"data_step": data.step})
    print(f"[100m] {args.steps - start} steps in {time.time() - t0:.1f}s")
    it.close()


if __name__ == "__main__":
    main()
