"""Continuous batching with phase-aware replica routing: open-loop Poisson
traffic over two heterogeneous replicas.

Replica 0 decodes 3x slower (co-tenant / older memory) but prefills at the
same speed — exactly the situation where a single blended ratio misroutes:
the dispatcher learns *separate* "prefill" and "decode" ratio entries and
shifts decode-heavy traffic to replica 1 while still using replica 0's
prefill capacity.

  PYTHONPATH=src python examples/continuous_serving.py
"""

import jax
import numpy as np

from repro.configs import reduced_config
from repro.models import init_params
from repro.serving import (
    DECODE,
    PREFILL,
    ContinuousBatchingEngine,
    InflightDispatcher,
    LatencyReport,
    LinearPhaseCost,
    poisson_requests,
)


def main():
    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    costs = [
        LinearPhaseCost(prefill_per_token=1e-3, decode_per_step=3e-3),  # slow
        LinearPhaseCost(prefill_per_token=1e-3, decode_per_step=1e-3),  # fast
    ]
    engines = [
        ContinuousBatchingEngine(cfg, params, max_slots=4, max_seq=48,
                                 prefill_chunk=8, cost_model=c)
        for c in costs
    ]
    disp = InflightDispatcher(engines)

    requests = poisson_requests(32, rate=60.0, vocab_size=cfg.vocab_size,
                                prompt_len=(6, 12), max_new_tokens=(4, 10),
                                seed=0)
    routed = np.zeros(2, dtype=np.int64)
    for r in requests:
        i, _ = disp.submit(r)
        routed[i] += 1
        disp.run_until_idle(max_steps=2)  # replicas keep decoding in-flight
    disp.run_until_idle()

    print(f"[continuous] routed: replica0={routed[0]} replica1={routed[1]}")
    print(f"[continuous] prefill ratios: "
          f"{np.round(disp.table.ratios(PREFILL), 2).tolist()} (same speed)")
    print(f"[continuous] decode  ratios: "
          f"{np.round(disp.table.ratios(DECODE), 2).tolist()} (3x gap)")
    for line in LatencyReport.from_requests(requests).lines("[continuous]"):
        print(line)
    assert routed[1] > routed[0]  # decode-bound traffic prefers the fast replica


if __name__ == "__main__":
    main()
