"""Pallas kernel micro-bench (interpret mode = correctness + dispatch cost;
real TPU timings are out of scope on this host).  Reports us/call and max
error vs the pure-jnp oracle, plus the kernel's arithmetic volume."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels import int8_gemm, q4_matmul, ref
from repro.quant import quantize_q4_0

from .common import fmt


def _time(fn, *args, iters=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jnp.asarray(out).block_until_ready()
    return (time.perf_counter() - t0) / iters, out


def run() -> list[tuple]:
    rng = np.random.default_rng(0)
    rows = []

    m, n, k = 8, 512, 1024
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    qw = quantize_q4_0(jnp.asarray(rng.normal(size=(n, k)).astype(np.float32)))
    t, out = _time(lambda a, b: q4_matmul(a, b, interpret=True), x, qw)
    err = float(jnp.max(jnp.abs(out - ref.q4_matmul_ref(x, qw))))
    rows.append(("kernel_q4_matmul_interp", fmt(t),
                 f"flops={2 * m * n * k}|max_err={err:.2e}"))

    a = jnp.asarray(rng.integers(0, 256, size=(128, 512)), dtype=jnp.uint8)
    w = jnp.asarray(rng.integers(-127, 128, size=(256, 512)), dtype=jnp.int8)
    t, out = _time(lambda p, q: int8_gemm(p, q, interpret=True), a, w)
    exact = bool((out == ref.int8_gemm_ref(a, w)).all())
    rows.append(("kernel_int8_gemm_interp", fmt(t),
                 f"flops={2 * 128 * 256 * 512}|exact={exact}"))
    return rows
