"""Shared helpers for the paper-figure benchmarks.

Every benchmark emits rows ``(name, us_per_call, derived)`` where
``derived`` is a short ``key=value|key=value`` string — printed as CSV by
``benchmarks/run.py``.
"""

from __future__ import annotations

import numpy as np

from repro.core import VirtualWorkerPool, make_machine
from repro.runtime import (
    CPURuntime,
    DynamicScheduler,
    KernelSpec,
    StaticScheduler,
)

# Paper Fig. 2 kernel problems.
GEMM_SHAPE = (1024, 4096, 4096)   # M, N, K — prefill INT8 GEMM
GEMV_SHAPE = (1, 4096, 4096)      # decode INT4 GEMV
Q4_BYTES_PER_ELEM = 0.5625        # int4 + fp16 scale / group32

GEMM_KERNEL = KernelSpec(name="int8_gemm", isa="avx_vnni", granularity=16,
                         work_per_unit=2 * 1024 * 4096)      # MACs per N col
GEMV_KERNEL = KernelSpec(name="q4_gemv", isa="membw", granularity=8,
                         work_per_unit=4096 * Q4_BYTES_PER_ELEM)  # bytes/row


def steady_state(machine_name: str, kernel: KernelSpec, s: int, *,
                 iters: int = 40, tail: int = 10, seed: int = 0):
    """(dynamic steady-state makespan, static makespan, optimal, machine)."""
    machine = make_machine(machine_name, seed=seed)
    pool = VirtualWorkerPool(machine, isa=kernel.isa)
    sched = DynamicScheduler(CPURuntime(machine.n_cores, alpha=0.3), pool)
    for _ in range(iters):
        sched.dispatch(kernel, s)
    dyn = float(np.mean([st.makespan for st in sched.stats[-tail:]]))

    machine2 = make_machine(machine_name, seed=seed)
    static = StaticScheduler(VirtualWorkerPool(machine2, isa=kernel.isa))
    for _ in range(tail):
        static.dispatch(kernel, s)
    sta = float(np.mean([st.makespan for st in static.stats]))
    opt = machine.optimal_makespan(kernel.isa, s * kernel.work_per_unit)
    return dyn, sta, opt, machine


def fmt(seconds: float) -> float:
    """seconds -> microseconds."""
    return seconds * 1e6
