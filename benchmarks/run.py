"""Benchmark entrypoint — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Paper-claim checks are printed as
trailing comments so `python -m benchmarks.run` doubles as a reproduction
report.

``--json PATH`` additionally writes a machine-readable report
(``repro.benchmarks/1``): every row with its parsed derived metrics, the
paper-claim checks, the enforced margin gates from modules exposing
``check(rows)``, and the git sha the numbers were produced at.
``--smoke`` asks each module for its reduced problem sizes (modules
without a ``smoke=`` parameter run at full size), and ``--only NAME``
restricts the run to modules whose name contains NAME.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys

SCHEMA = "repro.benchmarks/1"


def git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return "unknown"


def run_module(mod, smoke: bool) -> list:
    """``mod.run(smoke=True)`` when asked and supported, else ``mod.run()``
    (modules without a smoke knob run at full size)."""
    if smoke:
        try:
            return mod.run(smoke=True)
        except TypeError:
            pass
    return mod.run()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--contracts", action="store_true",
                    help="run every figure reproduction under the IV "
                         "runtime contracts (repro.analysis.invariants)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced problem sizes where modules support it")
    ap.add_argument("--only", default=None, metavar="NAME",
                    help="run only benchmark modules whose name contains "
                         "NAME (e.g. 'fleet', 'serving')")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the machine-readable report "
                         "(rows + checks + margin gates + git sha)")
    args = ap.parse_args(argv)

    if args.contracts:
        # a violated invariant fails the report instead of silently
        # skewing a reproduced number
        from repro.analysis import invariants
        invariants.enable()

    from . import bench_gemm_parallel, bench_gemv_bandwidth, bench_e2e
    from . import bench_ratio_trace, bench_kernels, bench_serving
    from . import bench_fleet, bench_elastic

    modules = [bench_gemm_parallel, bench_gemv_bandwidth, bench_e2e,
               bench_ratio_trace, bench_kernels, bench_serving,
               bench_fleet, bench_elastic]
    if args.only:
        modules = [m for m in modules if args.only in m.__name__]
        if not modules:
            print(f"no benchmark module matches {args.only!r}",
                  file=sys.stderr)
            return 2

    rows = []
    rows_by_module = {}
    for mod in modules:
        mod_rows = run_module(mod, args.smoke)
        rows_by_module[mod] = mod_rows
        rows += mod_rows

    print("name,us_per_call,derived")
    derived = {}
    json_rows = []
    for name, us, extra in rows:
        print(f"{name},{us:.1f},{extra}")
        row_derived = {}
        for kv in str(extra).split("|"):
            if "=" in kv:
                k, v = kv.split("=", 1)
                derived[(name, k)] = v
                row_derived[k] = v
        json_rows.append({"name": name, "us_per_call": round(float(us), 3),
                          "derived": row_derived})

    def grab(name, key, cast=float):
        v = derived.get((name, key))
        if v is None:
            return None
        return cast(v.rstrip("%x"))

    print()
    print("# paper-claim checks (paper value -> reproduced)")
    checks = [
        ("GEMM improvement Ultra-125H", "65%",
         grab("fig2_gemm_dynamic_ultra-125h", "improvement_pct")),
        ("GEMM improvement 12900K", "85%",
         grab("fig2_gemm_dynamic_core-12900k", "improvement_pct")),
        ("GEMV bandwidth (>90% of MLC)", ">90%",
         grab("fig2_gemv_dynamic_ultra-125h", "of_mlc")),
        ("prefill vs static (20-30%)", "20-30%",
         grab("fig3_prefill_dynamic_ultra-125h", "vs_static_pct")),
        ("decode vs static (9-22%)", "9-22%",
         grab("fig3_decode_dynamic_ultra-125h", "vs_static_pct")),
        ("speedup vs llama.cpp (up to 3.7x)", "3.7x",
         grab("fig3_prefill_dynamic_ultra-125h", "vs_llamacpp_x")),
        ("decode tokens/s (~16)", "16",
         grab("fig3_decode_dynamic_ultra-125h", "tok_s")),
        ("fleet learned vs round-robin goodput", ">0%",
         grab("fleet_margin", "learned_vs_rr_pct")),
        ("fleet learned vs best static goodput", ">0%",
         grab("fleet_margin", "learned_vs_best_static_pct")),
        ("elastic recovery margin (dynamic vs static)", ">0s",
         grab("elastic_margin", "margin_s")),
    ]
    for label, paper, ours in checks:
        print(f"# {label}: paper={paper} ours={ours}")

    # enforced margin gates: modules exposing check(rows) assert their own
    # pass/fail over the rows they produced (e.g. learned > baselines)
    gates = []
    for mod, mod_rows in rows_by_module.items():
        gate = getattr(mod, "check", None)
        if gate is None:
            continue
        ok = bool(gate(mod_rows))
        gates.append({"module": mod.__name__.rsplit(".", 1)[-1],
                      "passed": ok})
        print(f"# gate {gates[-1]['module']}: "
              f"{'PASS' if ok else 'FAIL'}")

    if args.json:
        report = {
            "schema": SCHEMA,
            "git_sha": git_sha(),
            "smoke": bool(args.smoke),
            "contracts": bool(args.contracts),
            "rows": json_rows,
            "checks": [{"label": label, "paper": paper, "ours": ours}
                       for label, paper, ours in checks],
            "gates": gates,
            "all_gates_passed": all(g["passed"] for g in gates),
        }
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {args.json}")

    return 0 if all(g["passed"] for g in gates) else 1


if __name__ == "__main__":
    raise SystemExit(main())
