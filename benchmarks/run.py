"""Benchmark entrypoint — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Paper-claim checks are printed as
trailing comments so `python -m benchmarks.run` doubles as a reproduction
report.
"""

from __future__ import annotations

import sys


def main() -> None:
    if "--contracts" in sys.argv[1:]:
        # run every figure reproduction under the IV runtime contracts
        # (repro.analysis.invariants): a violated invariant fails the
        # report instead of silently skewing a reproduced number
        from repro.analysis import invariants
        invariants.enable()

    from . import bench_gemm_parallel, bench_gemv_bandwidth, bench_e2e
    from . import bench_ratio_trace, bench_kernels, bench_serving
    from . import bench_fleet, bench_elastic

    rows = []
    for mod in (bench_gemm_parallel, bench_gemv_bandwidth, bench_e2e,
                bench_ratio_trace, bench_kernels, bench_serving,
                bench_fleet, bench_elastic):
        rows += mod.run()

    print("name,us_per_call,derived")
    derived = {}
    for name, us, extra in rows:
        print(f"{name},{us:.1f},{extra}")
        for kv in str(extra).split("|"):
            if "=" in kv:
                k, v = kv.split("=", 1)
                derived[(name, k)] = v

    def grab(name, key, cast=float):
        v = derived.get((name, key))
        if v is None:
            return None
        return cast(v.rstrip("%x"))

    print()
    print("# paper-claim checks (paper value -> reproduced)")
    checks = [
        ("GEMM improvement Ultra-125H", "65%",
         grab("fig2_gemm_dynamic_ultra-125h", "improvement_pct")),
        ("GEMM improvement 12900K", "85%",
         grab("fig2_gemm_dynamic_core-12900k", "improvement_pct")),
        ("GEMV bandwidth (>90% of MLC)", ">90%",
         grab("fig2_gemv_dynamic_ultra-125h", "of_mlc")),
        ("prefill vs static (20-30%)", "20-30%",
         grab("fig3_prefill_dynamic_ultra-125h", "vs_static_pct")),
        ("decode vs static (9-22%)", "9-22%",
         grab("fig3_decode_dynamic_ultra-125h", "vs_static_pct")),
        ("speedup vs llama.cpp (up to 3.7x)", "3.7x",
         grab("fig3_prefill_dynamic_ultra-125h", "vs_llamacpp_x")),
        ("decode tokens/s (~16)", "16",
         grab("fig3_decode_dynamic_ultra-125h", "tok_s")),
        ("fleet learned vs round-robin goodput", ">0%",
         grab("fleet_margin", "learned_vs_rr_pct")),
        ("fleet learned vs best static goodput", ">0%",
         grab("fleet_margin", "learned_vs_best_static_pct")),
        ("elastic recovery margin (dynamic vs static)", ">0s",
         grab("elastic_margin", "margin_s")),
    ]
    for label, paper, ours in checks:
        print(f"# {label}: paper={paper} ours={ours}")


if __name__ == "__main__":
    main()
