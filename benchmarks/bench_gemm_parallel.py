"""Paper Fig. 2 (left): INT8 GEMM 1024x4096x4096 latency, static-OpenMP vs
dynamic, on both hybrid CPUs.

Paper reference results: +65% compute performance on Ultra-125H, +85% on
Core-12900K.
"""

from __future__ import annotations

from .common import GEMM_KERNEL, GEMM_SHAPE, fmt, steady_state


def run() -> list[tuple]:
    rows = []
    m, n, k = GEMM_SHAPE
    flops = 2 * m * n * k
    for machine in ("ultra-125h", "core-12900k"):
        dyn, sta, opt, _ = steady_state(machine, GEMM_KERNEL, n)
        improvement = (sta - dyn) / dyn * 100.0
        rows.append((
            f"fig2_gemm_static_{machine}", fmt(sta),
            f"gops={flops / sta / 1e9:.0f}",
        ))
        rows.append((
            f"fig2_gemm_dynamic_{machine}", fmt(dyn),
            f"gops={flops / dyn / 1e9:.0f}"
            f"|improvement_pct={improvement:.0f}"
            f"|of_optimal={opt / dyn:.2%}",
        ))
    return rows
