"""Elastic-capacity study: time-to-recover goodput when a socket's worth
of cores parks mid-serve, dynamic re-planning vs a static split.

One continuous-batching engine serves steady Poisson traffic on the
flattened ``2s-12900k`` (32 cores).  Mid-run the OS parks the upper half
of the cores — a socket's worth — and returns them a few seconds later.
Parking is *observable* (``sched_getaffinity`` analogue): the dynamic arm's
:class:`~repro.serving.HybridPhaseCost` probes
:meth:`~repro.core.SimulatedHybridCPU.active_mask` at plan time, so parked
cores get zero-width shares on the very next iteration and the engine's
soft ``slot_budget`` shrinks with capacity.  The static arm
(``dynamic=False`` — the OpenMP balanced parallel-for clock) keeps handing
every core an equal share, so each region now waits on a core running at
``park_slowdown`` (time-sliced onto a sibling), and goodput collapses
until well after the cores return.

Recovery metric: requests are bucketed by arrival into fixed windows;
a policy has *recovered* at the first post-park window from which every
later window's SLO-goodput fraction stays >= 90% of the pre-event mean.
The CI gate: the dynamic arm recovers (>= 90% of pre-event goodput) and
does so measurably sooner than the static arm.

A second scenario drives the same event through the fleet layer:
:meth:`repro.fleet.Node.replan_capacity` on a dual-socket node after
``park_socket`` — nominal capacity halves (parking is observable, unlike
the throttled box), the parked replica freezes rather than aborts, and
every request still finishes after unpark.

  PYTHONPATH=src python -m benchmarks.bench_elastic [--smoke]

Exits nonzero if the dynamic arm fails to recover or fails to beat the
static arm's recovery time (the CI gate).
"""

from __future__ import annotations

from typing import List, Optional

import jax
import numpy as np

from repro.fleet import Node, NodeSpec
from repro.models import init_params
from repro.models.transformer import ModelConfig
from repro.serving import (
    ContinuousBatchingEngine,
    HybridPhaseCost,
    LatencyReport,
    Request,
    slo_met,
)
from repro.serving.traffic import poisson_requests

from .common import fmt

SLO_TTFT = 2.0     # seconds (bench_serving convention)
SLO_TPOT = 0.25    # seconds/token

MACHINE = "2s-12900k"   # flattened: 16 P + 16 E across two sockets

# Steady open loop below *half* capacity, so the surviving cores can keep
# the SLOs during the park window — any goodput lost there is planner
# failure, not physics.  The park window covers a socket's worth (the
# upper 16 of 32 flattened cores).
FULL = dict(n_requests=36, rate=3.0, prompt_len=(8, 16), max_new=(6, 10),
            slots=4, chunk=8, t_park=3.0, t_unpark=7.0, window=1.0)
SMOKE = dict(n_requests=16, rate=3.0, prompt_len=(8, 12), max_new=(4, 8),
             slots=4, chunk=8, t_park=1.5, t_unpark=4.0, window=1.0)

SEED = 0


def _model():
    cfg = ModelConfig(name="elastic", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      dtype="float32")
    return cfg, init_params(cfg, jax.random.key(0))


def _traffic(cfg, p) -> List[Request]:
    return poisson_requests(
        p["n_requests"], rate=p["rate"], vocab_size=cfg.vocab_size,
        prompt_len=p["prompt_len"], max_new_tokens=p["max_new"],
        seed=SEED + 1)


def window_fractions(requests: List[Request], width: float) -> List[Optional[float]]:
    """SLO-goodput fraction per arrival window (None = empty window).
    SLO verdicts come from :func:`repro.serving.slo_met` — the same rule
    :class:`LatencyReport` applies, so windows and goodput agree."""
    horizon = max(r.arrival_time for r in requests) + 1e-9
    n_win = int(np.ceil(horizon / width))
    out: List[Optional[float]] = []
    for w in range(n_win):
        t0, t1 = w * width, (w + 1) * width
        rs = [r for r in requests if t0 <= r.arrival_time < t1]
        out.append(None if not rs else
                   sum(slo_met(r, SLO_TTFT, SLO_TPOT) for r in rs) / len(rs))
    return out


def recovery_time(fracs: List[Optional[float]], width: float, t_park: float,
                  threshold: float, horizon: float) -> tuple:
    """(seconds from t_park to sustained recovery, recovered?).

    Recovery = the first window starting at/after ``t_park`` from which
    *every* later non-empty window stays >= ``threshold`` (no flapping).
    Unrecovered runs are right-censored at ``horizon``.
    """
    first = int(np.ceil(t_park / width))
    for w in range(first, len(fracs)):
        tail = [f for f in fracs[w:] if f is not None]
        if tail and all(f >= threshold for f in tail):
            return max(0.0, w * width - t_park), True
    return max(0.0, horizon - t_park), False


def run_arm(p, *, dynamic: bool, model=None):
    """One engine run with a mid-serve park window over half the cores.

    Returns (LatencyReport, window fractions, horizon, cost model)."""
    cfg, params = model or _model()
    cost = HybridPhaseCost(MACHINE, seed=SEED, dynamic=dynamic)
    n = cost.machine.n_cores
    parked = range(n // 2, n)
    eng = ContinuousBatchingEngine(
        cfg, params, max_slots=p["slots"],
        max_seq=p["prompt_len"][1] + p["max_new"][1] + 8,
        prefill_chunk=p["chunk"], cost_model=cost)
    requests = _traffic(cfg, p)
    for r in requests:
        eng.submit(r)

    def park():
        # from-now-on [0, inf) events: valid on every pool timeline even
        # when a phase clock lags the engine clock (idle fast-forward)
        for c in parked:
            cost.machine.park(c)
        if dynamic:
            # the engine-level half of the re-plan: shrink admission
            # headroom with capacity (no shape change, no retrace)
            eng.set_slot_budget(max(1, eng.max_slots // 2))

    def unpark():
        for c in parked:
            cost.machine.unpark(c)
        if dynamic:
            eng.set_slot_budget(eng.max_slots)

    for t_ev, apply in ((p["t_park"], park), (p["t_unpark"], unpark)):
        while eng.has_work and eng.now < t_ev:
            eng.step()
        apply()
    eng.run_until_idle()

    rep = LatencyReport.from_requests(requests, slo_ttft=SLO_TTFT,
                                      slo_tpot=SLO_TPOT)
    fracs = window_fractions(requests, p["window"])
    horizon = max((r.finish_time or eng.now) for r in requests)
    return rep, fracs, horizon, cost


def run_node_replan(p, model=None):
    """The fleet-layer path: park a whole socket on a dual-socket node,
    replan, serve through it, unpark, replan again; everything finishes."""
    cfg, params = model or _model()
    node = Node(NodeSpec("n0", MACHINE, max_slots=p["slots"],
                         prefill_chunk=p["chunk"]),
                cfg, params,
                max_seq=p["prompt_len"][1] + p["max_new"][1] + 8, seed=SEED)
    requests = _traffic(cfg, p)
    cap_full = node.nominal_capacity
    for r in requests:     # arrival times gate admission inside the engines
        node.submit(r)
    parked, cap_parked = False, cap_full
    while node.has_work:   # has_work counts *active* replicas only
        if not parked and node.now >= p["t_park"]:
            node.topology.park_socket(1)
            node.replan_capacity()
            cap_parked = node.nominal_capacity
            parked = True
        elif parked and node.now >= p["t_unpark"]:
            node.topology.unpark_socket(1)
            node.replan_capacity()
            parked = False
        node.step()
    if parked:
        # only frozen work was left on the parked replica: the return
        # event fires and the admitted requests resume where they stopped
        node.topology.unpark_socket(1)
        node.replan_capacity()
        while node.has_work:
            node.step()
    finished = sum(r.finish_time is not None for r in requests)
    return cap_parked / cap_full, finished, len(requests)


def run(smoke: bool = False) -> list:
    p = SMOKE if smoke else FULL
    model = _model()
    rows = []
    arms = {}
    for label, dynamic in (("dynamic", True), ("static", False)):
        rep, fracs, horizon, cost = run_arm(p, dynamic=dynamic, model=model)
        pre_windows = [f for f in fracs[:int(p["t_park"] // p["window"])]
                       if f is not None]
        pre = float(np.mean(pre_windows)) if pre_windows else 1.0
        ttr, recovered = recovery_time(fracs, p["window"], p["t_park"],
                                       0.9 * pre, horizon)
        post = [f for f in fracs[int(np.ceil(p["t_park"] / p["window"])):]
                if f is not None]
        post_min_after = min(post[-2:]) if post else 0.0
        arms[label] = dict(pre=pre, ttr=ttr, recovered=recovered)
        rows.append((
            f"elastic_{label}", fmt(rep.ttft[50]),
            f"goodput={rep.goodput:.3f}"
            f"|pre_frac={pre:.2f}"
            f"|recover_s={ttr:.2f}"
            f"|recovered={int(recovered)}"
            f"|tail_frac={post_min_after:.2f}"
            f"|bw_frac={cost.achieved_bandwidth_fraction():.2f}",
        ))
    cap_ratio, finished, total = run_node_replan(p, model=model)
    rows.append((
        "elastic_node_replan", fmt(0.0),
        f"cap_ratio={cap_ratio:.3f}|finished={finished}/{total}",
    ))
    rows.append((
        "elastic_margin", fmt(0.0),
        f"dyn_recover_s={arms['dynamic']['ttr']:.2f}"
        f"|static_recover_s={arms['static']['ttr']:.2f}"
        f"|margin_s={arms['static']['ttr'] - arms['dynamic']['ttr']:.2f}"
        f"|dyn_recovered={int(arms['dynamic']['recovered'])}",
    ))
    return rows


def check(rows) -> bool:
    """The CI gate: the dynamic arm recovers >= 90% of pre-event goodput
    and measurably sooner than the static arm, and the fleet-layer replan
    halves nominal capacity without losing a request."""
    ok_margin = ok_node = False
    for name, _, extra in rows:
        vals = dict(kv.split("=") for kv in extra.split("|"))
        if name == "elastic_margin":
            ok_margin = (int(vals["dyn_recovered"]) == 1
                         and float(vals["margin_s"]) > 0)
        elif name == "elastic_node_replan":
            done, total = vals["finished"].split("/")
            ok_node = (0.35 <= float(vals["cap_ratio"]) <= 0.65
                       and done == total)
    return ok_margin and ok_node


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny deterministic run for CI")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, extra in rows:
        print(f"{name},{us:.1f},{extra}")
    if not check(rows):
        print("# FAIL: dynamic did not recover faster than static")
        return 1
    print("# OK: dynamic recovers goodput faster than static after parking")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
