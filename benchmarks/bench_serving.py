"""Serving benchmark: continuous batching vs the whole-batch barrier under
identical open-loop Poisson traffic on the virtual hybrid CPUs.

Both policies are timed by the same per-phase cost model
(:class:`repro.serving.HybridPhaseCost` — paper-faithful dynamic core
dispatch with separate "prefill"/"decode" ratio keys), so the difference
measured here is purely the *scheduling* policy:

* ``continuous`` — request-level admission into an in-flight decode batch,
  chunked prefill interleaved with decode (the real engine, real tokens).
* ``barrier`` — the seed-era policy replayed analytically: arrived
  requests are admitted in whole batches; late arrivals wait for the full
  round (prefill + all decode steps) to drain.

Deterministic: seeded arrivals, seeded machine jitter, virtual clock.
Emits TTFT/TPOT percentiles (us_per_call column = TTFT p50) and goodput.

  PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]
"""

from __future__ import annotations

import jax

from repro.configs import reduced_config
from repro.models import init_params
from repro.serving import (
    DECODE,
    PREFILL,
    ContinuousBatchingEngine,
    HybridPhaseCost,
    LatencyReport,
    Request,
    poisson_requests,
)

from .common import fmt

MACHINES = ("ultra-125h", "core-12900k")

# rate chosen near ~75% utilization of the 8-slot virtual machine so the
# percentiles reflect scheduling, not unbounded overload queueing.
FULL = dict(n_requests=24, prompt_len=32, steps=16, slots=8, chunk=16,
            rate=2.0)
SMOKE = dict(n_requests=6, prompt_len=8, steps=4, slots=4, chunk=4,
             rate=100.0)

# SLOs for goodput: generous multiples of the unloaded virtual latencies.
SLO_TTFT = 2.0     # seconds
SLO_TPOT = 0.25    # seconds/token


def _traffic(cfg, p, seed=0):
    return poisson_requests(
        p["n_requests"], rate=p["rate"], vocab_size=cfg.vocab_size,
        prompt_len=p["prompt_len"], max_new_tokens=p["steps"], seed=seed)


def run_continuous(machine: str, p, seed: int = 0):
    """Real engine, virtual clock; returns (report, cost model)."""
    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    cost = HybridPhaseCost(machine, seed=seed)
    eng = ContinuousBatchingEngine(
        cfg, params, max_slots=p["slots"],
        max_seq=p["prompt_len"] + p["steps"] + 8,
        prefill_chunk=p["chunk"], cost_model=cost)
    requests = _traffic(cfg, p, seed)
    for r in requests:
        eng.submit(r)
    eng.run_until_idle()
    return LatencyReport.from_requests(
        requests, slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT), cost


def run_barrier(machine: str, p, seed: int = 0):
    """Whole-batch policy replayed analytically under the same cost model:
    at each round, admit up to ``slots`` *arrived* requests behind one
    barrier (prefill all prompts, then all decode steps); nobody joins
    mid-round."""
    cfg = reduced_config("granite-8b")
    cost = HybridPhaseCost(machine, seed=seed)
    requests = _traffic(cfg, p, seed)
    queue = sorted(requests, key=lambda r: r.arrival_time)
    now = 0.0
    while queue:
        now = max(now, queue[0].arrival_time)
        batch = [r for r in queue if r.arrival_time <= now][: p["slots"]]
        queue = [r for r in queue if r not in batch]
        for r in batch:
            now += cost.prefill_seconds(r.prompt_len, ctx=r.prompt_len)
        for r in batch:
            r.first_token_time = now  # first tokens only after the barrier
        for i in range(p["steps"] - 1):
            now += cost.decode_seconds(len(batch), ctx=p["prompt_len"] + i)
        for r in batch:
            r.generated = [0] * p["steps"]
            r.finish_time = now
    return LatencyReport.from_requests(
        requests, slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT), cost


def _rows(machine: str, p):
    cont, cost = run_continuous(machine, p)
    barr, barr_cost = run_barrier(machine, p)
    pf = cost.ratios(PREFILL)
    dec = cost.ratios(DECODE)
    rows = [
        (f"serving_continuous_{machine}", fmt(cont.ttft[50]),
         f"ttft_p90_ms={cont.ttft[90] * 1e3:.1f}"
         f"|ttft_p99_ms={cont.ttft[99] * 1e3:.1f}"
         f"|tpot_p50_ms={cont.tpot[50] * 1e3:.2f}"
         f"|tpot_p99_ms={cont.tpot[99] * 1e3:.2f}"
         f"|tok_s={cont.throughput:.1f}"
         f"|goodput={cont.goodput:.2f}"
         f"|ratio_spread_prefill={pf.max() / pf.min():.2f}"
         f"|ratio_spread_decode={dec.max() / dec.min():.2f}"
         f"|decode_bw_frac={cost.achieved_bandwidth_fraction():.3f}"),
        (f"serving_barrier_{machine}", fmt(barr.ttft[50]),
         f"ttft_p90_ms={barr.ttft[90] * 1e3:.1f}"
         f"|tok_s={barr.throughput:.1f}"
         f"|goodput={barr.goodput:.2f}"
         f"|decode_bw_frac={barr_cost.achieved_bandwidth_fraction():.3f}"
         f"|ttft_p50_win_pct="
         f"{(barr.ttft[50] / max(cont.ttft[50], 1e-9) - 1) * 100:.0f}"),
    ]
    return rows


def run(smoke: bool = False) -> list:
    p = SMOKE if smoke else FULL
    rows = []
    for machine in MACHINES:
        rows += _rows(machine, p)
    return rows


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny deterministic run for CI")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, extra in run(smoke=args.smoke):
        print(f"{name},{us:.1f},{extra}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
