"""Serving benchmark: continuous batching vs the whole-batch barrier under
identical open-loop Poisson traffic on the virtual hybrid CPUs.

Both policies are timed by the same per-phase cost model
(:class:`repro.serving.HybridPhaseCost` — paper-faithful dynamic core
dispatch with separate "prefill"/"decode" ratio keys), so the difference
measured here is purely the *scheduling* policy:

* ``continuous`` — request-level admission into an in-flight decode batch,
  chunked prefill interleaved with decode (the real engine, real tokens).
* ``barrier`` — the seed-era policy replayed analytically: arrived
  requests are admitted in whole batches; late arrivals wait for the full
  round (prefill + all decode steps) to drain.

Deterministic: seeded arrivals, seeded machine jitter, virtual clock.
Emits TTFT/TPOT percentiles (us_per_call column = TTFT p50) and goodput.

Two extra modes:

* balanced-trunk rows (always emitted): the engine decodes with *every*
  projection through :class:`repro.kernels.HybridKernelDispatcher` shards
  (fp32 path — shard-exact), once dynamic and once static; the derived
  column reports the whole-decode-step achieved-bandwidth fraction over a
  post-warmup window (paper claim: >=0.90 dynamic vs <=0.85 static).
* ``--sweep`` — overload study: goodput vs open-loop arrival rate on one
  machine (monotone non-increasing past saturation).
* compiled-trunk rows (always emitted): the same balanced-trunk engine
  timed on the *host* clock, once through the io_callback bridge and once
  through the compiled (zero-callback, on-device shard offsets) lowering;
  the run aborts unless compiled sustains at least
  ``MIN_COMPILED_SPEEDUP``x the bridged wall-clock steps/sec on every
  machine, with token identity between the two runs as the correctness
  gate.

  PYTHONPATH=src python -m benchmarks.bench_serving [--smoke] [--sweep]
"""

from __future__ import annotations

import dataclasses
import time

import jax

from repro.configs import reduced_config
from repro.kernels import HybridKernelDispatcher
from repro.models import BalancedTrunk, init_params
from repro.topology import TopologyDispatcher
from repro.serving import (
    DECODE,
    PREFILL,
    ContinuousBatchingEngine,
    HybridPhaseCost,
    LatencyReport,
    Request,
    poisson_requests,
)

from .common import fmt

MACHINES = ("ultra-125h", "core-12900k")
TOPOLOGY_MACHINES = ("dual-125h", "2s-12900k")

# rate chosen near ~75% utilization of the 8-slot virtual machine so the
# percentiles reflect scheduling, not unbounded overload queueing.
FULL = dict(n_requests=24, prompt_len=32, steps=16, slots=8, chunk=16,
            rate=2.0)
SMOKE = dict(n_requests=6, prompt_len=8, steps=4, slots=4, chunk=4,
             rate=100.0)

# Balanced-trunk runs use a widened reduced config: projection N dims must
# comfortably exceed n_cores x rounding so the achieved-bandwidth fraction
# measures balance quality, not integer-granularity noise.
TRUNK = dict(n_requests=8, prompt_len=16, steps=12, slots=4, chunk=8,
             rate=20.0, warmup_requests=4)
TRUNK_SMOKE = dict(n_requests=4, prompt_len=8, steps=8, slots=2, chunk=8,
                   rate=50.0, warmup_requests=3)

# Overload sweep: open-loop arrival rates (req/s) under a fixed request
# population and a tighter TTFT SLO (the study is about queueing-induced
# SLO misses, not service latency).  The 4-slot virtual engine saturates
# near SWEEP_SATURATION req/s; past it goodput is monotone non-increasing
# (below it the duration denominator dominates, so no claim is made).
SWEEP = dict(n_requests=12, prompt_len=8, steps=8, slots=4, chunk=8,
             slo_ttft=1.0)
SWEEP_SATURATION = 16.0
SWEEP_RATES = (1.0, 4.0, 16.0, 64.0, 256.0)
SWEEP_RATES_SMOKE = (16.0, 64.0, 256.0)

# SLOs for goodput: generous multiples of the unloaded virtual latencies.
SLO_TTFT = 2.0     # seconds
SLO_TPOT = 0.25    # seconds/token

# Wall-clock floor for the compiled lowering over the io_callback bridge.
# The bridge pays a host round-trip per projection per step; the compiled
# path traces the whole decode step callback-free, so the margin is large
# — 1.3x is the enforced floor, not the expectation.
MIN_COMPILED_SPEEDUP = 1.3


def _traffic(cfg, p, seed=0, n=None, rate=None):
    return poisson_requests(
        n or p["n_requests"], rate=rate or p["rate"],
        vocab_size=cfg.vocab_size,
        prompt_len=p["prompt_len"], max_new_tokens=p["steps"], seed=seed)


def run_continuous(machine: str, p, seed: int = 0, model=None):
    """Real engine, virtual clock; returns (report, cost model).
    ``model=(cfg, params)`` reuses prebuilt weights (rate sweeps)."""
    cfg, params = model or (None, None)
    if cfg is None:
        cfg = reduced_config("granite-8b")
        params = init_params(cfg, jax.random.key(0))
    cost = HybridPhaseCost(machine, seed=seed)
    eng = ContinuousBatchingEngine(
        cfg, params, max_slots=p["slots"],
        max_seq=p["prompt_len"] + p["steps"] + 8,
        prefill_chunk=p["chunk"], cost_model=cost)
    requests = _traffic(cfg, p, seed)
    for r in requests:
        eng.submit(r)
    eng.run_until_idle()
    return LatencyReport.from_requests(
        requests, slo_ttft=p.get("slo_ttft", SLO_TTFT),
        slo_tpot=SLO_TPOT), cost


def trunk_config():
    """Reduced granite-8b widened so every projection N is >= a few rows
    per simulated core (d_model 256, GQA 4:1 -> q/o 256, k/v 64 rows;
    MLP 512; head 2048)."""
    return dataclasses.replace(
        reduced_config("granite-8b"), d_model=256, d_ff=512,
        vocab_size=2048)


def numa_trunk_config():
    """Wider still for the dual-socket rows: the outer socket split halves
    every region's per-core rows, so N must be ~2x the single-socket
    config for the aggregate fraction to measure balance rather than
    integer-granularity rounding across 28 cores."""
    return dataclasses.replace(
        reduced_config("granite-8b"), d_model=512, d_ff=1024,
        vocab_size=4096)


def run_balanced_trunk(machine: str, p, *, dynamic: bool, seed: int = 0,
                       model=None, topology: bool = False,
                       socket_local: bool = True):
    """Engine with the whole trunk (+head) through balanced fp32 shard
    dispatch; returns (report, decode achieved-bw fraction measured after a
    warmup batch converged the per-kind ratio tables, dispatcher).

    ``topology=True`` treats ``machine`` as a multi-socket topology name:
    socket-local two-level dispatch with NUMA-placed weights, or — with
    ``socket_local=False`` — the socket-oblivious flat baseline (the
    virtual clock runs on the flattened machine either way)."""
    cfg, params = model or (None, None)
    if cfg is None:
        cfg = trunk_config()
        params = init_params(cfg, jax.random.key(0))
    if topology:
        disp = TopologyDispatcher(machine, seed=seed, dynamic=dynamic,
                                  socket_local=socket_local, execute=True,
                                  keep_stats=False)
    else:
        disp = HybridKernelDispatcher.virtual(machine, seed=seed,
                                              dynamic=dynamic, execute=True,
                                              keep_stats=False)
    trunk = BalancedTrunk.from_params(cfg, params, disp, quant="fp32")
    eng = ContinuousBatchingEngine(
        cfg, params, max_slots=p["slots"],
        max_seq=p["prompt_len"] + p["steps"] + 8,
        prefill_chunk=p["chunk"],
        cost_model=HybridPhaseCost(machine, seed=seed),
        balanced_trunk=trunk)
    warm = _traffic(cfg, p, seed, n=p["warmup_requests"])
    for r in warm:
        eng.submit(r)
    eng.run_until_idle()
    eng.poll_finished()
    disp.reset_bandwidth_accounting()  # measure steady state only
    requests = _traffic(cfg, p, seed + 1)
    for r in requests:
        r.arrival_time += eng.now  # arrivals continue from the warm clock
        eng.submit(r)
    eng.run_until_idle()
    report = LatencyReport.from_requests(
        requests, slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT)
    return report, disp.achieved_bandwidth_fraction(), disp


def run_trunk_steps(machine: str, p, *, mode: str, model=None,
                    seed: int = 0):
    """Host-clock engine throughput of the balanced fp32 trunk in ``mode``
    ("bridge" = io_callback shard execution inside jit, "compiled" =
    on-device shard offsets, zero host callbacks).  A warmup batch absorbs
    jit compilation and converges the ratio tables; only the measured
    batch is timed.  Returns (steps/sec, n engine steps, generated-token
    tuples for the identity gate)."""
    cfg, params = model or (None, None)
    if cfg is None:
        cfg = trunk_config()
        params = init_params(cfg, jax.random.key(0))
    disp = HybridKernelDispatcher.virtual(machine, seed=seed, dynamic=True,
                                          execute=True, keep_stats=False)
    trunk = BalancedTrunk.from_params(cfg, params, disp, quant="fp32",
                                      mode=mode)
    eng = ContinuousBatchingEngine(
        cfg, params, max_slots=p["slots"],
        max_seq=p["prompt_len"] + p["steps"] + 8,
        prefill_chunk=p["chunk"],
        cost_model=HybridPhaseCost(machine, seed=seed),
        balanced_trunk=trunk)
    warm = _traffic(cfg, p, seed, n=p["warmup_requests"])
    for r in warm:
        eng.submit(r)
    eng.run_until_idle()
    eng.poll_finished()
    requests = _traffic(cfg, p, seed + 1)
    for r in requests:
        r.arrival_time += eng.now
        eng.submit(r)
    t0 = time.perf_counter()
    stats = eng.run_until_idle()
    wall = time.perf_counter() - t0
    tokens = [tuple(r.generated) for r in requests]
    return len(stats) / max(wall, 1e-9), len(stats), tokens


def _compiled_rows(machine: str, p, model=None) -> list:
    """Compiled vs bridged wall-clock steps/sec on one machine; aborts the
    benchmark when either gate (token identity, speedup floor) fails."""
    comp_sps, n_steps, comp_tok = run_trunk_steps(machine, p,
                                                  mode="compiled",
                                                  model=model)
    brid_sps, _, brid_tok = run_trunk_steps(machine, p, mode="bridge",
                                            model=model)
    if comp_tok != brid_tok:
        raise SystemExit(
            f"compiled trunk tokens diverge from the bridged trunk on "
            f"{machine}")
    speedup = comp_sps / max(brid_sps, 1e-9)
    if speedup < MIN_COMPILED_SPEEDUP:
        raise SystemExit(
            f"compiled trunk sustains {speedup:.2f}x the bridged steps/sec "
            f"on {machine}, below the required "
            f"{MIN_COMPILED_SPEEDUP:.1f}x floor")
    return [
        (f"serving_trunk_compiled_{machine}", fmt(1.0 / comp_sps),
         f"steps_s={comp_sps:.1f}"
         f"|steps_s_bridged={brid_sps:.1f}"
         f"|compiled_speedup={speedup:.2f}"
         f"|min_speedup={MIN_COMPILED_SPEEDUP:.1f}"
         f"|n_steps={n_steps}"
         f"|tokens_identical=1"
         f"|margin_ok=1"),
    ]


def run_barrier(machine: str, p, seed: int = 0):
    """Whole-batch policy replayed analytically under the same cost model:
    at each round, admit up to ``slots`` *arrived* requests behind one
    barrier (prefill all prompts, then all decode steps); nobody joins
    mid-round."""
    cfg = reduced_config("granite-8b")
    cost = HybridPhaseCost(machine, seed=seed)
    requests = _traffic(cfg, p, seed)
    queue = sorted(requests, key=lambda r: r.arrival_time)
    now = 0.0
    while queue:
        now = max(now, queue[0].arrival_time)
        batch = [r for r in queue if r.arrival_time <= now][: p["slots"]]
        queue = [r for r in queue if r not in batch]
        for r in batch:
            now += cost.prefill_seconds(r.prompt_len, ctx=r.prompt_len)
        for r in batch:
            r.first_token_time = now  # first tokens only after the barrier
        for i in range(p["steps"] - 1):
            now += cost.decode_seconds(len(batch), ctx=p["prompt_len"] + i)
        for r in batch:
            r.generated = [0] * p["steps"]
            r.finish_time = now
    return LatencyReport.from_requests(
        requests, slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT), cost


def _rows(machine: str, p):
    cont, cost = run_continuous(machine, p)
    barr, barr_cost = run_barrier(machine, p)
    pf = cost.ratios(PREFILL)
    dec = cost.ratios(DECODE)
    rows = [
        (f"serving_continuous_{machine}", fmt(cont.ttft[50]),
         f"ttft_p90_ms={cont.ttft[90] * 1e3:.1f}"
         f"|ttft_p99_ms={cont.ttft[99] * 1e3:.1f}"
         f"|tpot_p50_ms={cont.tpot[50] * 1e3:.2f}"
         f"|tpot_p99_ms={cont.tpot[99] * 1e3:.2f}"
         f"|tok_s={cont.throughput:.1f}"
         f"|goodput={cont.goodput:.2f}"
         f"|ratio_spread_prefill={pf.max() / pf.min():.2f}"
         f"|ratio_spread_decode={dec.max() / dec.min():.2f}"
         f"|decode_bw_frac={cost.achieved_bandwidth_fraction():.3f}"),
        (f"serving_barrier_{machine}", fmt(barr.ttft[50]),
         f"ttft_p90_ms={barr.ttft[90] * 1e3:.1f}"
         f"|tok_s={barr.throughput:.1f}"
         f"|goodput={barr.goodput:.2f}"
         f"|decode_bw_frac={barr_cost.achieved_bandwidth_fraction():.3f}"
         f"|ttft_p50_win_pct="
         f"{(barr.ttft[50] / max(cont.ttft[50], 1e-9) - 1) * 100:.0f}"),
    ]
    return rows


def _numa_rows(machine: str, p, model=None) -> list:
    """Dual-socket serving rows: socket-local dynamic trunk dispatch vs the
    socket-oblivious baseline, both through the real engine (paper claim at
    topology scale: >=0.90 aggregate achieved-bandwidth fraction vs <=0.85
    for socket-oblivious)."""
    loc, loc_frac, loc_disp = run_balanced_trunk(
        machine, p, dynamic=True, model=model, topology=True)
    obl, obl_frac, _ = run_balanced_trunk(
        machine, p, dynamic=True, model=model, topology=True,
        socket_local=False)
    sockets = "|".join(
        f"socket{s}_bw_frac={loc_disp.achieved_bandwidth_fraction(socket=s):.3f}"
        for s in range(loc_disp.n_sockets))
    return [
        (f"serving_numa_local_{machine}", fmt(loc.ttft[50]),
         f"decode_bw_frac={loc_frac:.3f}|{sockets}"
         f"|tok_s={loc.throughput:.1f}"
         f"|goodput={loc.goodput:.2f}"),
        (f"serving_numa_oblivious_{machine}", fmt(obl.ttft[50]),
         f"decode_bw_frac={obl_frac:.3f}"
         f"|tok_s={obl.throughput:.1f}"
         f"|goodput={obl.goodput:.2f}"
         f"|socket_local_bw_gain_pct="
         f"{(loc_frac / max(obl_frac, 1e-9) - 1) * 100:.0f}"),
    ]


def _trunk_rows(machine: str, p, model=None) -> list:
    dyn, dyn_frac, _ = run_balanced_trunk(machine, p, dynamic=True,
                                          model=model)
    sta, sta_frac, _ = run_balanced_trunk(machine, p, dynamic=False,
                                          model=model)
    return [
        (f"serving_trunk_dynamic_{machine}", fmt(dyn.ttft[50]),
         f"decode_bw_frac={dyn_frac:.3f}"
         f"|tok_s={dyn.throughput:.1f}"
         f"|goodput={dyn.goodput:.2f}"),
        (f"serving_trunk_static_{machine}", fmt(sta.ttft[50]),
         f"decode_bw_frac={sta_frac:.3f}"
         f"|tok_s={sta.throughput:.1f}"
         f"|goodput={sta.goodput:.2f}"
         f"|dynamic_bw_gain_pct={(dyn_frac / max(sta_frac, 1e-9) - 1) * 100:.0f}"),
    ]


def run_sweep(machine: str = "ultra-125h", p=None, rates=SWEEP_RATES,
              seed: int = 0) -> list:
    """Goodput-vs-arrival-rate sweep (overload study) under one shared
    model; returns [(rate, LatencyReport)] in ascending rate order."""
    p = p or SWEEP
    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    out = []
    for rate in sorted(rates):
        rep, _ = run_continuous(machine, dict(p, rate=rate), seed,
                                model=(cfg, params))
        out.append((rate, rep))
    return out


def _sweep_rows(machine: str, p, rates) -> list:
    rows = []
    for rate, rep in run_sweep(machine, p, rates):
        rows.append((
            f"serving_sweep_{machine}_rate{rate:g}", fmt(rep.ttft[50]),
            f"rate={rate:g}"
            f"|goodput={rep.goodput:.3f}"
            f"|tok_s={rep.throughput:.1f}"
            f"|ttft_p99_ms={rep.ttft[99] * 1e3:.1f}",
        ))
    return rows


def run(smoke: bool = False, sweep: bool = False) -> list:
    rows = []
    if sweep:
        rates = SWEEP_RATES_SMOKE if smoke else SWEEP_RATES
        return _sweep_rows("ultra-125h", SWEEP, rates)
    p = SMOKE if smoke else FULL
    for machine in MACHINES:
        rows += _rows(machine, p)
    tp = TRUNK_SMOKE if smoke else TRUNK
    cfg = trunk_config()
    model = (cfg, init_params(cfg, jax.random.key(0)))
    for machine in MACHINES:
        rows += _trunk_rows(machine, tp, model=model)
    for machine in MACHINES:
        rows += _compiled_rows(machine, tp, model=model)
    numa_cfg = numa_trunk_config()
    numa_model = (numa_cfg, init_params(numa_cfg, jax.random.key(0)))
    for machine in TOPOLOGY_MACHINES:
        rows += _numa_rows(machine, tp, model=numa_model)
    return rows


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny deterministic run for CI")
    ap.add_argument("--sweep", action="store_true",
                    help="goodput-vs-arrival-rate overload sweep instead "
                         "of the policy comparison")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, extra in run(smoke=args.smoke, sweep=args.sweep):
        print(f"{name},{us:.1f},{extra}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
