"""Paper Fig. 3: end-to-end llama2-7B (Q4_0) inference latency through the
scheduler — prefill (1024-token prompt, INT8 compute-bound) and decode
(memory-bound), static-OpenMP vs dynamic, plus a llama.cpp-style baseline.

Modeling notes (documented in EXPERIMENTS.md):
 * Every GEMM/GEMV of each layer is dispatched through the scheduler on the
   virtual hybrid machine; multi-head attention is dispatched *statically*
   in BOTH variants — the paper applies its method to GEMM kernels only
   ("Other kernels, like multi-head attention, do not benefit"), which is
   why e2e gains are lower than kernel-level gains.
 * llama.cpp = static scheduling + less-optimized compute kernels; its
   INT8/INT4 compute kernels are modeled at 45% of Neural Speed's
   throughput (Shen et al. 2023 report ~2.2x kernel gains over llama.cpp),
   memory-bound GEMV at 90%.
 * Paper reference: prefill +20-30%, decode +9-22% over static Neural
   Speed; up to 3.7x vs llama.cpp; decode ~16 tokens/s.
"""

from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import VirtualWorkerPool, make_machine
from repro.runtime import (
    CPURuntime,
    DynamicScheduler,
    KernelSpec,
    StaticScheduler,
)

from .common import Q4_BYTES_PER_ELEM, fmt

PROMPT = 1024
DECODE_STEPS = 16


def _prefill_kernels(cfg, s: int, eff: float, attn_factor: float = 4.0):
    """(name, N, work_MACs_per_N_unit) for one layer, prefill phase."""
    d, hd = cfg.d_model, cfg.hd
    qkv_n = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
    return [
        ("qkv", qkv_n, s * d / eff),
        # attention runs the fp32 (non-VNNI) path: ~4x the MAC-equivalent
        # work; static in both variants (paper: MHA is not dispatched)
        ("attn", cfg.n_heads, attn_factor * 2 * s * s * hd),
        ("wo", d, s * cfg.n_heads * hd / eff),
        ("w13", 2 * cfg.d_ff, s * d / eff),
        ("w2", d, s * cfg.d_ff / eff),
    ]


def _decode_kernels(cfg, ctx: int, eff: float):
    """(name, N, work_bytes_per_N_unit) for one layer, decode phase."""
    d, hd = cfg.d_model, cfg.hd
    qkv_n = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
    kv_bytes = 2 * ctx * hd * 2  # k+v fp16 per kv head
    return [
        ("qkv", qkv_n, d * Q4_BYTES_PER_ELEM / eff),
        ("attn", cfg.n_kv_heads, kv_bytes / eff),      # static in both
        ("wo", d, cfg.n_heads * hd * Q4_BYTES_PER_ELEM / eff),
        ("w13", 2 * cfg.d_ff, d * Q4_BYTES_PER_ELEM / eff),
        ("w2", d, cfg.d_ff * Q4_BYTES_PER_ELEM / eff),
    ]


def simulate(machine_name: str, *, dynamic: bool, gemm_eff: float = 1.0,
             gemv_eff: float = 1.0, warm_iters: int = 3,
             attn_factor: float = 4.0):
    """Returns (prefill_seconds, decode_seconds_per_token)."""
    cfg = get_config("llama2-7b")
    machine = make_machine(machine_name)
    runtime = CPURuntime(machine.n_cores, alpha=0.3)

    def run_phase(isa: str, kernels, layers: int, head_work: float,
                  elt_bytes_per_layer: float = 0.0):
        pool = VirtualWorkerPool(machine, isa=isa)
        dyn = DynamicScheduler(runtime, pool)
        sta = StaticScheduler(pool)
        # norms / rope / residual / dynamic-quant passes: bandwidth-bound
        # elementwise work, outside the scheduler in both variants
        elt = elt_bytes_per_layer / machine.true_throughput("membw").sum()
        t0 = pool.clock
        for _ in range(layers):
            for name, n, work in kernels:
                spec = KernelSpec(name=name, isa=isa, granularity=8,
                                  work_per_unit=work)
                if name == "attn" or not dynamic:
                    sta.dispatch(spec, n)
                else:
                    dyn.dispatch(spec, n)
            pool.clock += elt
        head = KernelSpec(name="head", isa=isa, granularity=8,
                          work_per_unit=head_work)
        (dyn if dynamic else sta).dispatch(head, cfg.vocab_size)
        return pool.clock - t0

    elt_prefill = 20 * PROMPT * cfg.d_model  # bytes per layer
    elt_decode = 20 * cfg.d_model
    # warm the ratio table the way the paper does (first kernels adapt fast)
    for _ in range(warm_iters):
        run_phase("avx_vnni", _prefill_kernels(cfg, PROMPT, gemm_eff, attn_factor),
                  cfg.n_layers, PROMPT * cfg.d_model / gemm_eff, elt_prefill)
    prefill = run_phase("avx_vnni", _prefill_kernels(cfg, PROMPT, gemm_eff, attn_factor),
                        cfg.n_layers, PROMPT * cfg.d_model / gemm_eff,
                        elt_prefill)
    for _ in range(warm_iters):
        run_phase("membw", _decode_kernels(cfg, PROMPT, gemv_eff),
                  cfg.n_layers, cfg.d_model * Q4_BYTES_PER_ELEM / gemv_eff,
                  elt_decode)
    decode = np.mean([
        run_phase("membw", _decode_kernels(cfg, PROMPT + i, gemv_eff),
                  cfg.n_layers, cfg.d_model * Q4_BYTES_PER_ELEM / gemv_eff,
                  elt_decode)
        for i in range(DECODE_STEPS)
    ])
    return prefill, float(decode)


def run() -> list[tuple]:
    rows = []
    for machine in ("ultra-125h", "core-12900k"):
        pf_dyn, dec_dyn = simulate(machine, dynamic=True)
        pf_sta, dec_sta = simulate(machine, dynamic=False)
        pf_cpp, dec_cpp = simulate(machine, dynamic=False,
                                   gemm_eff=0.45, gemv_eff=0.9)
        # sensitivity: cache-hostile unblocked fp32 MHA (16x MAC-equiv),
        # bracketing the paper's 20-30% e2e prefill band
        pf_dyn_c, _ = simulate(machine, dynamic=True, attn_factor=16.0)
        pf_sta_c, _ = simulate(machine, dynamic=False, attn_factor=16.0)
        rows += [
            (f"fig3_prefill_llamacpp_{machine}", fmt(pf_cpp), ""),
            (f"fig3_prefill_static_{machine}", fmt(pf_sta), ""),
            (f"fig3_prefill_dynamic_{machine}", fmt(pf_dyn),
             f"vs_static_pct={(pf_sta - pf_dyn) / pf_dyn * 100:.0f}"
             f"|vs_llamacpp_x={pf_cpp / pf_dyn:.1f}"),
            (f"fig3_prefill_dynamic_slowmha_{machine}", fmt(pf_dyn_c),
             f"vs_static_pct={(pf_sta_c - pf_dyn_c) / pf_dyn_c * 100:.0f}"),
            (f"fig3_decode_llamacpp_{machine}", fmt(dec_cpp),
             f"tok_s={1 / dec_cpp:.1f}"),
            (f"fig3_decode_static_{machine}", fmt(dec_sta),
             f"tok_s={1 / dec_sta:.1f}"),
            (f"fig3_decode_dynamic_{machine}", fmt(dec_dyn),
             f"tok_s={1 / dec_dyn:.1f}"
             f"|vs_static_pct={(dec_sta - dec_dyn) / dec_dyn * 100:.0f}"),
        ]
    return rows
