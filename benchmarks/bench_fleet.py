"""Fleet goodput study: learned three-level routing vs round-robin and
static partitions under node failure + diurnal traffic.

Four heterogeneous nodes — a dual-socket flagship, a dual-socket desktop
part, a flat single-socket box, and a *throttled* single-socket box whose
nominal capacity (all a static partition can see) is 3x its real
throughput — serve identical seeded traffic: heavy-tailed prompts under
a diurnal arrival-rate swing, with the flagship failing mid-run and
recovering later.  Every policy runs on the same
:class:`repro.fleet.FleetRouter` code path (same stepping, feedback, and
failure handling); only the per-request argmin differs, so the goodput
gap isolates the routing decision:

* ``learned`` — ratio-normalized backlog over the node-level RatioTable
  (fed back via ``units=``), scaled by per-node TTFT/TPOT SLO headroom;
* ``round_robin`` — cycle over live nodes;
* ``static_equal`` / ``static_capacity`` — weighted round-robin by equal
  shares / nominal-bandwidth shares.

The paper's claim at fleet scale: the measured split beats any a-priori
partition because nominal capacity lies (the throttled box) and drifts
(failure, diurnal load).  A final ``learned+admission`` row adds the
SLO-aware front door (queue caps + degradation) to show shed/degraded
accounting under the same traffic.

The model is a tiny dense transformer: engine latency comes from the
per-socket :class:`repro.serving.HybridPhaseCost` virtual clocks, so
model width changes token content but not virtual timing, and a 6-socket
fleet stays cheap to build.

  PYTHONPATH=src python -m benchmarks.bench_fleet [--smoke]

Exits nonzero if learned routing fails to beat round-robin AND the best
static partition on SLO goodput (the CI gate).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.fleet import (
    AdmissionController,
    Cluster,
    FleetRouter,
    NodeSpec,
    failure_window,
    fleet_requests,
)
from repro.models import init_params
from repro.models.transformer import ModelConfig
from repro.serving import DECODE, PREFILL, LatencyReport

from .common import fmt

SLO_TTFT = 2.0     # seconds (bench_serving convention)
SLO_TPOT = 0.25    # seconds/token

# >= 3 heterogeneous node types: NUMA flagship, NUMA desktop, flat box,
# and the throttled box whose nominal bandwidth is a 3x lie.
SPECS = (
    NodeSpec("big", "dual-125h", max_slots=4, prefill_lanes=2),
    NodeSpec("mid", "2s-12900k", max_slots=4, prefill_lanes=2),
    NodeSpec("flat", "ultra-125h", max_slots=4),
    NodeSpec("slow", "ultra-125h", max_slots=4, throttle=3.0),
)

# Near-saturation open loop: low enough that a good split meets the SLOs,
# high enough that routing onto the throttled box (or a queue that built
# up during the outage) blows them.  The failure window drops the largest
# node across a diurnal crest.
FULL = dict(n_requests=96, base_rate=10.0, prompt_len=(4, 40),
            max_new=(6, 12), swing=0.6, period=6.0,
            fail_at=2.5, recover_at=7.0, adm_cap=20, adm_degrade=10)
SMOKE = dict(n_requests=28, base_rate=9.0, prompt_len=(4, 24),
             max_new=(4, 8), swing=0.6, period=4.0,
             fail_at=1.0, recover_at=3.0, adm_cap=10, adm_degrade=5)

POLICIES = (
    ("learned", "learned", None),
    ("round_robin", "round_robin", None),
    ("static_equal", "static", "equal"),
    ("static_capacity", "static", "capacity"),
)

SEED = 0


def _model():
    cfg = ModelConfig(name="fleet", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      dtype="float32")
    return cfg, init_params(cfg, jax.random.key(0))


def _traffic(cfg, p):
    return fleet_requests(
        p["n_requests"], base_rate=p["base_rate"],
        vocab_size=cfg.vocab_size, prompt_len=p["prompt_len"],
        max_new_tokens=p["max_new"], swing=p["swing"], period=p["period"],
        seed=SEED + 1)


def run_policy(p, policy: str, shares=None, model=None, admission=None):
    """One fleet run: fresh cluster, identical seeded traffic + failure
    window; returns (LatencyReport, router)."""
    cfg, params = model or _model()
    cluster = Cluster.build(
        SPECS, cfg, params,
        max_seq=p["prompt_len"][1] + p["max_new"][1] + 8, seed=SEED)
    static = (np.ones(len(SPECS)) if shares == "equal" else None)
    router = FleetRouter(cluster, policy=policy, static_shares=static,
                         slo_ttft=SLO_TTFT, slo_tpot=SLO_TPOT,
                         admission=admission)
    requests = _traffic(cfg, p)
    events = failure_window("big", fail_at=p["fail_at"],
                            recover_at=p["recover_at"])
    done = router.run(requests, events)
    report = LatencyReport.from_requests(done, slo_ttft=SLO_TTFT,
                                         slo_tpot=SLO_TPOT)
    return report, router


def run(smoke: bool = False) -> list:
    p = SMOKE if smoke else FULL
    model = _model()
    rows, goodput = [], {}
    for label, policy, shares in POLICIES:
        rep, router = run_policy(p, policy, shares, model=model)
        goodput[label] = rep.goodput
        pf = router.table.ratios(PREFILL)
        dec = router.table.ratios(DECODE)
        rows.append((
            f"fleet_{label}", fmt(rep.ttft[50]),
            f"goodput={rep.goodput:.3f}"
            f"|tok_s={rep.throughput:.1f}"
            f"|ttft_p99_ms={rep.ttft[99] * 1e3:.1f}"
            f"|tpot_p99_ms={rep.tpot[99] * 1e3:.2f}"
            f"|routed={'/'.join(map(str, router.routed.tolist()))}"
            f"|requeued={router.n_requeued}"
            f"|ratio_pf={'/'.join(f'{r:.2f}' for r in pf)}"
            f"|ratio_dec={'/'.join(f'{r:.2f}' for r in dec)}",
        ))
    # the SLO-aware front door on top of learned routing: cap the fleet
    # queue and halve budgets under pressure; goodput must not collapse
    # and the sacrifice is accounted, not hidden
    adm = AdmissionController(queue_cap=p["adm_cap"],
                              degrade_depth=p["adm_degrade"])
    rep, router = run_policy(p, "learned", model=model, admission=adm)
    rows.append((
        "fleet_learned_admission", fmt(rep.ttft[50]),
        f"goodput={rep.goodput:.3f}"
        f"|shed={rep.n_shed}"
        f"|degraded={rep.n_degraded}"
        f"|ttft_p99_ms={rep.ttft[99] * 1e3:.1f}",
    ))
    best_static = max(goodput["static_equal"], goodput["static_capacity"])
    margin_rr = goodput["learned"] / max(goodput["round_robin"], 1e-9) - 1
    margin_st = goodput["learned"] / max(best_static, 1e-9) - 1
    rows.append((
        "fleet_margin", fmt(0.0),
        f"learned_vs_rr_pct={margin_rr * 100:.1f}"
        f"|learned_vs_best_static_pct={margin_st * 100:.1f}",
    ))
    return rows


def check(rows) -> bool:
    """The CI gate: learned strictly beats round-robin and the best static
    partition on SLO goodput."""
    for name, _, extra in rows:
        if name != "fleet_margin":
            continue
        vals = dict(kv.split("=") for kv in extra.split("|"))
        return (float(vals["learned_vs_rr_pct"]) > 0
                and float(vals["learned_vs_best_static_pct"]) > 0)
    return False


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny deterministic run for CI")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, extra in rows:
        print(f"{name},{us:.1f},{extra}")
    if not check(rows):
        print("# FAIL: learned routing did not beat both baselines")
        return 1
    print("# OK: learned > round_robin and learned > best static goodput")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
