"""Paper Fig. 4: performance-ratio trace of a P-core on Ultra-125H across
the prefill phase (AVX-VNNI table) and the decode phase (memory table).

Reference behaviour: init ratio deliberately 5 -> drops within a few kernel
dispatches to the machine's true relative throughput; decode-phase ratios
are distinctly smaller than prefill-phase ratios (different bottleneck);
alpha = 0.3.  Writes the trace to experiments/fig4_trace.csv.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import VirtualWorkerPool, make_machine
from repro.runtime import CPURuntime, DynamicScheduler

from .common import GEMM_KERNEL, GEMV_KERNEL, fmt

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "fig4_trace.csv")


def run() -> list[tuple]:
    machine = make_machine("ultra-125h")
    runtime = CPURuntime(machine.n_cores, alpha=0.3, init_ratio=5.0)

    sched = DynamicScheduler(runtime, VirtualWorkerPool(machine, isa="avx_vnni"))
    for _ in range(40):
        sched.dispatch(GEMM_KERNEL, 4096)
    sched2 = DynamicScheduler(runtime, VirtualWorkerPool(machine, isa="membw"))
    for _ in range(40):
        sched2.dispatch(GEMV_KERNEL, 4096)

    prefill_trace = np.array([h[0] for h in runtime.history["avx_vnni"]])
    decode_trace = np.array([h[0] for h in runtime.history["membw"]])

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        f.write("phase,update,p0_ratio\n")
        for i, r in enumerate(prefill_trace):
            f.write(f"prefill,{i},{r:.4f}\n")
        for i, r in enumerate(decode_trace):
            f.write(f"decode,{i},{r:.4f}\n")

    tp = machine.true_throughput("avx_vnni")
    expected = tp[0] / tp.mean()
    settle = int(np.argmax(np.abs(prefill_trace - expected)
                           / expected < 0.10))

    # paper §3.2: "sudden changes in the system background" — throttle core
    # 0 by 3x mid-run and count updates until the makespan recovers to
    # within 10% of the new optimum.
    machine2 = make_machine("ultra-125h")
    machine2.background.append((0.0, 1e9, 0, 3.0))
    runtime2 = CPURuntime(machine2.n_cores, alpha=0.3)
    # warm-start with the *unthrottled* converged table (worst case)
    runtime2.set("avx_vnni", runtime.ratios("avx_vnni"))
    sched3 = DynamicScheduler(runtime2, VirtualWorkerPool(machine2,
                                                          isa="avx_vnni"))
    tp2 = machine2.true_throughput("avx_vnni").copy()
    tp2[0] /= 3.0
    opt2 = 4096 * GEMM_KERNEL.work_per_unit / tp2.sum()
    recover = -1
    for i in range(40):
        st = sched3.dispatch(GEMM_KERNEL, 4096)
        if recover < 0 and st.makespan < opt2 * 1.10:
            recover = i + 1
    return [
        ("fig4_p0_init", 0.0, f"ratio={prefill_trace[0]:.2f}"),
        ("fig4_p0_prefill_settled", 0.0,
         f"ratio={prefill_trace[-1]:.2f}|expected={expected:.2f}"
         f"|updates_to_10pct={settle}"),
        ("fig4_p0_decode_settled", 0.0,
         f"ratio={decode_trace[-1]:.2f}"
         f"|prefill_vs_decode={prefill_trace[-1] / decode_trace[-1]:.2f}"),
        ("fig4_background_throttle_recovery", 0.0,
         f"updates_to_10pct_of_new_opt={recover}"),
    ]
