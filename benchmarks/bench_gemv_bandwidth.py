"""Paper Fig. 2 (right): INT4 (Fp32-Int4-Fp32) GEMV 1x4096x4096 bandwidth,
as a fraction of the machine's streaming bandwidth (MLC analogue).

Runs through :class:`repro.kernels.HybridKernelDispatcher` — the same
per-core shard dispatch the model hot path uses — once dynamic (ratio-table
planned, Eq. 3) and once static (equal shards, the OpenMP baseline), on
both hybrid machines.  Every region records its bytes moved, so the
achieved-bandwidth fraction is read straight off the dispatcher telemetry.

Paper reference results: +19% bandwidth on Ultra-125H; dynamic reaches >90%
of the MLC-measured bandwidth where static stays materially lower.

  PYTHONPATH=src python -m benchmarks.bench_gemv_bandwidth [--smoke]
"""

from __future__ import annotations

from repro.kernels import GEMV_ISA, HybridKernelDispatcher
from repro.runtime import KernelSpec

from .common import GEMV_SHAPE, Q4_BYTES_PER_ELEM, fmt

MACHINES = ("ultra-125h", "core-12900k")


def steady_state_dispatch(machine: str, *, dynamic: bool, iters: int = 40,
                          tail: int = 10, seed: int = 0):
    """Steady-state GEMV dispatch through the shard dispatcher; returns
    (mean tail makespan seconds, achieved-bandwidth fraction of the tail)."""
    _, n, k = GEMV_SHAPE
    disp = HybridKernelDispatcher.virtual(machine, seed=seed, dynamic=dynamic)
    spec = KernelSpec("q4_gemv", isa=GEMV_ISA, granularity=8,
                      work_per_unit=k * Q4_BYTES_PER_ELEM)
    for _ in range(iters):
        disp.dispatch(spec, n, bytes_per_unit=k * Q4_BYTES_PER_ELEM)
    window = disp.stats[-tail:]
    makespan = sum(st.makespan for st in window) / len(window)
    moved = sum(st.bytes for st in window)
    busy = sum(st.makespan for st in window)
    frac = (moved / busy) / disp.machine.socket_bandwidth
    return makespan, frac


def _measure(iters: int = 40, tail: int = 10) -> dict:
    """Per machine: (dynamic makespan, dynamic frac, static makespan,
    static frac)."""
    return {
        machine: (*steady_state_dispatch(machine, dynamic=True, iters=iters,
                                         tail=tail),
                  *steady_state_dispatch(machine, dynamic=False, iters=tail,
                                         tail=tail))
        for machine in MACHINES
    }


def _rows(measured: dict) -> list[tuple]:
    _, n, k = GEMV_SHAPE
    total_bytes = n * k * Q4_BYTES_PER_ELEM
    rows = []
    for machine, (dyn, dyn_frac, sta, sta_frac) in measured.items():
        rows.append((
            f"fig2_gemv_static_{machine}", fmt(sta),
            f"gbps={total_bytes / sta / 1e9:.1f}"
            f"|of_mlc={sta_frac:.2%}"
            f"|achieved_bw_frac={sta_frac:.3f}",
        ))
        rows.append((
            f"fig2_gemv_dynamic_{machine}", fmt(dyn),
            f"gbps={total_bytes / dyn / 1e9:.1f}"
            f"|of_mlc={dyn_frac:.2%}"
            f"|achieved_bw_frac={dyn_frac:.3f}"
            f"|improvement_pct={(sta - dyn) / dyn * 100:.0f}",
        ))
    return rows


def run(iters: int = 40, tail: int = 10) -> list[tuple]:
    return _rows(_measure(iters, tail))


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short deterministic run for CI")
    args = ap.parse_args()
    measured = _measure(iters=16, tail=4) if args.smoke else _measure()
    print("name,us_per_call,derived")
    for name, us, extra in _rows(measured):
        print(f"{name},{us:.1f},{extra}")
    for machine, (_, dyn_frac, _, sta_frac) in measured.items():
        print(f"# {machine}: dynamic achieved_bw_frac={dyn_frac:.3f} "
              f"static={sta_frac:.3f}")
        if not dyn_frac > sta_frac:
            print(f"# FAIL: dynamic did not beat static on {machine}")
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
