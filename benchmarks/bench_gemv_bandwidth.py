"""Paper Fig. 2 (right): INT4 (Fp32-Int4-Fp32) GEMV 1x4096x4096 bandwidth,
as a fraction of the machine's streaming bandwidth (MLC analogue).

Runs through :class:`repro.kernels.HybridKernelDispatcher` — the same
per-core shard dispatch the model hot path uses — once dynamic (ratio-table
planned, Eq. 3) and once static (equal shards, the OpenMP baseline), on
both hybrid machines.  Every region records its bytes moved, so the
achieved-bandwidth fraction is read straight off the dispatcher telemetry.

Paper reference results: +19% bandwidth on Ultra-125H; dynamic reaches >90%
of the MLC-measured bandwidth where static stays materially lower.

The trunk section extends Fig. 2 from the lone LM-head GEMV to a whole
llama2-7B decode step: per layer-kind regions (q/k/v/o attention
projections, MLP up/gate/down, head) each dispatch under their own
``membw/<kind>`` ratio key, and the reported fraction is over the *sum* of
the step's byte traffic — the trunk-level achieved-bandwidth fraction the
serving engine's balanced-trunk mode reproduces end to end.

The NUMA section poses the same question on the dual-socket machines:
socket-local two-level dispatch (outer split across bandwidth domains,
Eq. 2/3 within each) against the socket-oblivious baseline (one flat
dispatcher, interleaved pages paying the fabric penalty).  Target:
socket-local dynamic >= 0.90 of *aggregate* bandwidth, oblivious <= 0.85.

  PYTHONPATH=src python -m benchmarks.bench_gemv_bandwidth [--smoke]
"""

from __future__ import annotations

from repro.kernels import GEMV_ISA, HybridKernelDispatcher, kernel_key
from repro.runtime import KernelSpec
from repro.topology import TopologyDispatcher

from .common import GEMV_SHAPE, Q4_BYTES_PER_ELEM, fmt

MACHINES = ("ultra-125h", "core-12900k")
TOPOLOGY_MACHINES = ("dual-125h", "2s-12900k")

# One llama2-7B decode step's Q4 GEMV regions: (kind, N rows, K cols,
# calls per step) — d_model 4096, d_ff 11008, vocab 32000, per layer:
# q/k/v/o + gate/up (mlp_up x2) + down, plus the head once.
TRUNK_STEP = (
    ("attn_proj", 4096, 4096, 4),
    ("mlp_up", 11008, 4096, 2),
    ("mlp_down", 4096, 11008, 1),
    ("head", 32000, 4096, 1),
)


def steady_state_dispatch(machine: str, *, dynamic: bool, iters: int = 40,
                          tail: int = 10, seed: int = 0):
    """Steady-state GEMV dispatch through the shard dispatcher; returns
    (mean tail makespan seconds, achieved-bandwidth fraction of the tail)."""
    _, n, k = GEMV_SHAPE
    disp = HybridKernelDispatcher.virtual(machine, seed=seed, dynamic=dynamic)
    spec = KernelSpec("q4_gemv", isa=GEMV_ISA, granularity=8,
                      work_per_unit=k * Q4_BYTES_PER_ELEM)
    for _ in range(iters):
        disp.dispatch(spec, n, bytes_per_unit=k * Q4_BYTES_PER_ELEM)
    window = disp.stats[-tail:]
    makespan = sum(st.makespan for st in window) / len(window)
    moved = sum(st.bytes for st in window)
    busy = sum(st.makespan for st in window)
    frac = (moved / busy) / disp.machine.socket_bandwidth
    return makespan, frac


def trunk_steady_state(machine: str, *, dynamic: bool, iters: int = 20,
                       warmup: int = 8, seed: int = 0):
    """Whole-decode-step dispatch: every TRUNK_STEP region per iteration,
    each under its per-kind ``membw/<kind>`` table key; returns
    (step makespan seconds, trunk achieved-bandwidth fraction) over the
    post-warmup window."""
    disp = HybridKernelDispatcher.virtual(machine, seed=seed,
                                          dynamic=dynamic, keep_stats=False)
    specs = [
        (KernelSpec(f"q4_gemv_{kind}", isa=GEMV_ISA, granularity=8,
                    work_per_unit=k * Q4_BYTES_PER_ELEM,
                    key=kernel_key(GEMV_ISA, kind)),
         n, k, calls)
        for kind, n, k, calls in TRUNK_STEP
    ]
    step_seconds = 0.0
    for i in range(iters):
        if i == warmup:
            disp.reset_bandwidth_accounting()
        step_seconds = 0.0
        for spec, n, k, calls in specs:
            for _ in range(calls):
                st = disp.dispatch(spec, n,
                                   bytes_per_unit=k * Q4_BYTES_PER_ELEM)
                step_seconds += st.makespan
    return step_seconds, disp.achieved_bandwidth_fraction()


def numa_steady_state(machine: str, *, socket_local: bool, iters: int = 40,
                      warmup: int = 20, seed: int = 0):
    """Steady-state GEMV dispatch on a dual-socket machine: socket-local
    two-level split or the socket-oblivious flat baseline (both dynamic —
    the comparison isolates topology awareness, not ratio learning).
    Returns (mean post-warmup makespan, aggregate achieved-bandwidth
    fraction, per-socket fractions)."""
    _, n, k = GEMV_SHAPE
    disp = TopologyDispatcher(machine, socket_local=socket_local, seed=seed,
                              keep_stats=False)
    spec = KernelSpec("q4_gemv", isa=GEMV_ISA, granularity=8,
                      work_per_unit=k * Q4_BYTES_PER_ELEM)
    makespans = []
    for i in range(iters):
        if i == warmup:
            disp.reset_bandwidth_accounting()
        st = disp.dispatch(spec, n, bytes_per_unit=k * Q4_BYTES_PER_ELEM)
        if i >= warmup:
            makespans.append(st.makespan)
    per_socket = ([disp.achieved_bandwidth_fraction(socket=s)
                   for s in range(disp.n_sockets)] if socket_local else [])
    return (sum(makespans) / len(makespans),
            disp.achieved_bandwidth_fraction(), per_socket)


def _measure_numa(iters: int = 40, warmup: int = 20) -> dict:
    """Per dual-socket machine: (local makespan, local aggregate frac,
    local per-socket fracs, oblivious makespan, oblivious frac)."""
    return {
        machine: (*numa_steady_state(machine, socket_local=True,
                                     iters=iters, warmup=warmup),
                  *numa_steady_state(machine, socket_local=False,
                                     iters=iters, warmup=warmup)[:2])
        for machine in TOPOLOGY_MACHINES
    }


def _numa_rows(measured: dict) -> list[tuple]:
    _, n, k = GEMV_SHAPE
    total_bytes = n * k * Q4_BYTES_PER_ELEM
    rows = []
    for machine, (loc, loc_frac, per_socket, obl, obl_frac) in measured.items():
        sockets = "|".join(f"socket{i}_bw_frac={f:.3f}"
                           for i, f in enumerate(per_socket))
        rows.append((
            f"numa_gemv_oblivious_{machine}", fmt(obl),
            f"gbps={total_bytes / obl / 1e9:.1f}"
            f"|achieved_bw_frac={obl_frac:.3f}",
        ))
        rows.append((
            f"numa_gemv_socket_local_{machine}", fmt(loc),
            f"gbps={total_bytes / loc / 1e9:.1f}"
            f"|achieved_bw_frac={loc_frac:.3f}|{sockets}"
            f"|improvement_pct={(obl - loc) / loc * 100:.0f}",
        ))
    return rows


def _measure(iters: int = 40, tail: int = 10) -> dict:
    """Per machine: (dynamic makespan, dynamic frac, static makespan,
    static frac)."""
    return {
        machine: (*steady_state_dispatch(machine, dynamic=True, iters=iters,
                                         tail=tail),
                  *steady_state_dispatch(machine, dynamic=False, iters=tail,
                                         tail=tail))
        for machine in MACHINES
    }


def _measure_trunk(iters: int = 20, warmup: int = 8) -> dict:
    return {
        machine: (*trunk_steady_state(machine, dynamic=True, iters=iters,
                                      warmup=warmup),
                  *trunk_steady_state(machine, dynamic=False, iters=iters,
                                      warmup=warmup))
        for machine in MACHINES
    }


def _rows(measured: dict) -> list[tuple]:
    _, n, k = GEMV_SHAPE
    total_bytes = n * k * Q4_BYTES_PER_ELEM
    rows = []
    for machine, (dyn, dyn_frac, sta, sta_frac) in measured.items():
        rows.append((
            f"fig2_gemv_static_{machine}", fmt(sta),
            f"gbps={total_bytes / sta / 1e9:.1f}"
            f"|of_mlc={sta_frac:.2%}"
            f"|achieved_bw_frac={sta_frac:.3f}",
        ))
        rows.append((
            f"fig2_gemv_dynamic_{machine}", fmt(dyn),
            f"gbps={total_bytes / dyn / 1e9:.1f}"
            f"|of_mlc={dyn_frac:.2%}"
            f"|achieved_bw_frac={dyn_frac:.3f}"
            f"|improvement_pct={(sta - dyn) / dyn * 100:.0f}",
        ))
    return rows


def _trunk_rows(measured: dict) -> list[tuple]:
    step_bytes = sum(n * k * Q4_BYTES_PER_ELEM * calls
                     for _, n, k, calls in TRUNK_STEP)
    rows = []
    for machine, (dyn, dyn_frac, sta, sta_frac) in measured.items():
        rows.append((
            f"trunk_step_static_{machine}", fmt(sta),
            f"gbps={step_bytes / sta / 1e9:.1f}"
            f"|achieved_bw_frac={sta_frac:.3f}",
        ))
        rows.append((
            f"trunk_step_dynamic_{machine}", fmt(dyn),
            f"gbps={step_bytes / dyn / 1e9:.1f}"
            f"|achieved_bw_frac={dyn_frac:.3f}"
            f"|improvement_pct={(sta - dyn) / dyn * 100:.0f}",
        ))
    return rows


def run(iters: int = 40, tail: int = 10, trunk_iters: int = 20,
        trunk_warmup: int = 8, numa_iters: int = 40,
        numa_warmup: int = 20) -> list[tuple]:
    return (_rows(_measure(iters, tail))
            + _trunk_rows(_measure_trunk(trunk_iters, trunk_warmup))
            + _numa_rows(_measure_numa(numa_iters, numa_warmup)))


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short deterministic run for CI")
    args = ap.parse_args()
    measured = _measure(iters=16, tail=4) if args.smoke else _measure()
    trunk = (_measure_trunk(iters=10, warmup=6) if args.smoke
             else _measure_trunk())
    numa = (_measure_numa(iters=24, warmup=16) if args.smoke
            else _measure_numa())
    print("name,us_per_call,derived")
    for name, us, extra in (_rows(measured) + _trunk_rows(trunk)
                            + _numa_rows(numa)):
        print(f"{name},{us:.1f},{extra}")
    for machine, (_, dyn_frac, _, sta_frac) in measured.items():
        print(f"# {machine}: dynamic achieved_bw_frac={dyn_frac:.3f} "
              f"static={sta_frac:.3f}")
        if not dyn_frac > sta_frac:
            print(f"# FAIL: dynamic did not beat static on {machine}")
            return 1
    for machine, (_, dyn_frac, _, sta_frac) in trunk.items():
        print(f"# {machine} trunk: dynamic achieved_bw_frac={dyn_frac:.3f} "
              f"static={sta_frac:.3f}")
        if not dyn_frac > sta_frac:
            print(f"# FAIL: trunk dynamic did not beat static on {machine}")
            return 1
    for machine, (_, loc_frac, _, _, obl_frac) in numa.items():
        print(f"# {machine} numa: socket_local achieved_bw_frac="
              f"{loc_frac:.3f} oblivious={obl_frac:.3f}")
        if not loc_frac >= 0.90:
            print(f"# FAIL: socket-local dispatch below 0.90 aggregate "
                  f"bandwidth on {machine}")
            return 1
        if not obl_frac <= 0.85:
            print(f"# FAIL: socket-oblivious baseline above 0.85 on "
                  f"{machine} (penalty model broken?)")
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
