"""Paper Fig. 2 (right): INT4 (Fp32-Int4-Fp32) GEMV 1x4096x4096 bandwidth,
as a fraction of the machine's streaming bandwidth (MLC analogue).

Paper reference results: +19% bandwidth on Ultra-125H; dynamic reaches >90%
of the MLC-measured bandwidth.
"""

from __future__ import annotations

from .common import GEMV_KERNEL, GEMV_SHAPE, Q4_BYTES_PER_ELEM, fmt, steady_state


def run() -> list[tuple]:
    rows = []
    _, n, k = GEMV_SHAPE
    total_bytes = n * k * Q4_BYTES_PER_ELEM
    for machine in ("ultra-125h", "core-12900k"):
        dyn, sta, opt, mach = steady_state(machine, GEMV_KERNEL, n)
        mlc_bw = mach.true_throughput("membw").sum()  # MLC analogue
        bw_dyn = total_bytes / dyn
        bw_sta = total_bytes / sta
        rows.append((
            f"fig2_gemv_static_{machine}", fmt(sta),
            f"gbps={bw_sta / 1e9:.1f}|of_mlc={bw_sta / mlc_bw:.2%}",
        ))
        rows.append((
            f"fig2_gemv_dynamic_{machine}", fmt(dyn),
            f"gbps={bw_dyn / 1e9:.1f}|of_mlc={bw_dyn / mlc_bw:.2%}"
            f"|improvement_pct={(sta - dyn) / dyn * 100:.0f}",
        ))
    return rows
