"""Tests for the repro.analysis subsystem (PR 8).

Four groups:

* corpus — every lint/audit rule flags its known-bad fixture and passes its
  known-good twin (``tests/analysis_corpus/``);
* races — the vector-clock checker on synthetic schedules and on the real
  dispatcher accounting (the satellite race fix's regression test);
* invariants — each IV contract fires on bad inputs, stays silent on good,
  and the enable/disable gating works;
* persistence — RatioStore/TunerStore survive torn/corrupt files.
"""

import importlib.util
import json
import sys
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import invariants
from repro.analysis.findings import Finding, format_findings
from repro.analysis.invariants import ContractViolation
from repro.analysis.jaxpr_audit import (audit_bridge, audit_compiled,
                                        count_callbacks)
from repro.analysis.lint import lint_file, lint_source
from repro.analysis.races import find_races, trace
from repro.core.events import Event

CORPUS = Path(__file__).parent / "analysis_corpus"
LINT_RULES = ["RL001", "RL002", "RL003", "RL004", "RL005", "RL006"]


def _load_corpus_module(relpath: str):
    path = CORPUS / relpath
    name = f"analysis_corpus_{path.stem}"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------------------ lint corpus --
@pytest.mark.parametrize("rule", LINT_RULES)
def test_lint_flags_bad_fixture(rule):
    findings = lint_file(CORPUS / "lint" / f"bad_{rule.lower()}.py")
    assert findings, f"{rule} bad fixture produced no findings"
    assert {f.rule for f in findings} == {rule}


@pytest.mark.parametrize("rule", LINT_RULES)
def test_lint_passes_good_fixture(rule):
    findings = lint_file(CORPUS / "lint" / f"good_{rule.lower()}.py")
    assert findings == [], format_findings(findings)


def test_lint_allow_comment_suppresses():
    src = 'import time\nnow = time.time()  # lint: allow(RL001)\n'
    assert lint_source(src, "x.py", virtual=True) == []
    src_other = 'import time\nnow = time.time()  # lint: allow(RL002)\n'
    assert len(lint_source(src_other, "x.py", virtual=True)) == 1


def test_lint_virtual_set_is_path_or_marker_based():
    src = "import time\n\n\ndef f():\n    return time.perf_counter()\n"
    # ordinary module: wall clocks are fine
    assert lint_source(src, "repro/kernels/ops.py") == []
    # path inside the virtual set: flagged
    assert any(f.rule == "RL001"
               for f in lint_source(src, "repro/topology/machine.py"))
    # marker opts any file in
    marked = "# lint: virtual-clock-module\n" + src
    assert any(f.rule == "RL001" for f in lint_source(marked, "x.py"))


def test_lint_syntax_error_is_reported_not_raised():
    findings = lint_source("def broken(:\n", "x.py")
    assert [f.rule for f in findings] == ["RL000"]


def test_lint_clean_on_src_tree():
    """The CI gate: the shipped source tree lints clean."""
    from repro.analysis.lint import run_pass
    findings = run_pass("src")
    assert findings == [], format_findings(findings)


# ----------------------------------------------------------- audit corpus --
def test_audit_good_compiled_is_clean():
    steps = _load_corpus_module("audit/steps.py")
    jaxpr = steps.good_compiled()
    assert audit_compiled(jaxpr, (0,), where="corpus good") == []
    assert count_callbacks(jaxpr) == {}


def test_audit_ja001_callback_in_compiled():
    steps = _load_corpus_module("audit/steps.py")
    findings = audit_compiled(steps.bad_compiled_callback(), ())
    assert any(f.rule == "JA001" for f in findings)


def test_audit_ja002_offset_sink():
    steps = _load_corpus_module("audit/steps.py")
    findings = audit_compiled(steps.bad_compiled_offset_sink(), (0,))
    assert any(f.rule == "JA002" for f in findings)
    assert any("mul" in f.message for f in findings)


def test_audit_ja003_bridge_count_contract():
    steps = _load_corpus_module("audit/steps.py")
    jaxpr = steps.good_bridge(2)
    assert audit_bridge(jaxpr, expected=2) == []
    findings = audit_bridge(jaxpr, expected=3)
    assert [f.rule for f in findings] == ["JA003"]


def test_audit_ja004_unordered_and_pure_callbacks():
    steps = _load_corpus_module("audit/steps.py")
    got = audit_bridge(steps.bad_bridge_unordered(), expected=1)
    assert any(f.rule == "JA004" for f in got)
    got = audit_bridge(steps.bad_bridge_pure_callback())
    assert any(f.rule == "JA004" for f in got)


# -------------------------------------------------------- race detection --
def _ev(kind, task, obj, field="", where=""):
    return Event(kind=kind, task=task, obj=obj, field=field, where=where)


def test_races_unsynchronized_writes_flagged():
    events = [
        _ev("fork", "main", "w0"),
        _ev("fork", "main", "w1"),
        _ev("write", "w0", "Disp#1", "bytes", where="a"),
        _ev("write", "w1", "Disp#1", "bytes", where="b"),
        _ev("join", "main", "w0"),
        _ev("join", "main", "w1"),
    ]
    findings = find_races(events)
    assert len(findings) == 1
    assert findings[0].rule == "RC001"
    assert "Disp#1" in findings[0].location


def test_races_lock_ordered_accesses_clean():
    events = [
        _ev("fork", "main", "w0"),
        _ev("fork", "main", "w1"),
        _ev("acquire", "w0", "lock"),
        _ev("write", "w0", "Disp#1", "bytes"),
        _ev("release", "w0", "lock"),
        _ev("acquire", "w1", "lock"),
        _ev("write", "w1", "Disp#1", "bytes"),
        _ev("release", "w1", "lock"),
    ]
    assert find_races(events) == []


def test_races_fork_join_ordered_accesses_clean():
    events = [
        _ev("write", "main", "Table#1", "t"),
        _ev("fork", "main", "w0"),
        _ev("write", "w0", "Table#1", "t"),
        _ev("join", "main", "w0"),
        _ev("write", "main", "Table#1", "t"),
    ]
    assert find_races(events) == []


def test_races_concurrent_reads_clean():
    events = [
        _ev("fork", "main", "w0"),
        _ev("fork", "main", "w1"),
        _ev("read", "w0", "Table#1", "t"),
        _ev("read", "w1", "Table#1", "t"),
    ]
    assert find_races(events) == []


def test_races_read_write_conflict_flagged():
    events = [
        _ev("fork", "main", "w0"),
        _ev("fork", "main", "w1"),
        _ev("read", "w0", "Table#1", "t"),
        _ev("write", "w1", "Table#1", "t"),
    ]
    findings = find_races(events)
    assert len(findings) == 1


def test_races_accounting_schedule_is_clean():
    """Satellite regression: concurrent shard reports into the dispatcher's
    bytes/busy aggregate go through the locked ``_account`` and replay
    race-free; stripping the lock edges from the same schedule is flagged
    (proving the lock is what makes it clean)."""
    from repro.core.pool import SubTask, ThreadWorkerPool
    from repro.kernels.dispatch import GEMV_ISA, HybridKernelDispatcher

    d = HybridKernelDispatcher.threaded(4)
    pool = ThreadWorkerPool(4)
    try:
        with trace() as rec:
            subtasks = [
                SubTask(worker=w, start=w, size=1, work=1.0,
                        fn=lambda s, z: d._account(GEMV_ISA, 64.0, 1e-3))
                for w in range(4)
            ]
            pool.run(subtasks)  # lint: allow(RL003) accounting-only schedule
    finally:
        pool.close()
        d.close()
    assert any(e.kind == "acquire" for e in rec.events)
    assert find_races(rec.events) == []
    # the counterfactual: same accesses without the lock edges race
    unlocked = [e for e in rec.events if e.kind not in ("acquire", "release")]
    assert any(f.rule == "RC001" for f in find_races(unlocked))


def test_account_is_thread_safe_exact_totals():
    """Satellite regression: hammering ``_account`` from 8 threads loses no
    update — the totals are exact, not approximately right."""
    from repro.kernels.dispatch import GEMV_ISA, HybridKernelDispatcher
    from repro.topology.dispatch import TopologyDispatcher

    flat = HybridKernelDispatcher.virtual("ultra-125h", execute=False)
    topo = TopologyDispatcher("dual-125h", execute=False)
    try:
        n_threads, n_calls = 8, 200

        def hammer(disp):
            for _ in range(n_calls):
                disp._account(GEMV_ISA, 1.0, 1e-6)

        for disp in (flat, topo):
            threads = [threading.Thread(target=hammer, args=(disp,))
                       for _ in range(n_threads)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert disp._bytes[GEMV_ISA] == float(n_threads * n_calls)
            assert disp._busy[GEMV_ISA] == pytest.approx(
                n_threads * n_calls * 1e-6)
    finally:
        flat.close()
        topo.close()


# -------------------------------------------------------------- contracts --
def test_contracts_gating():
    with invariants.contracts(True):
        assert invariants.contracts_enabled()
        with invariants.contracts(False):
            assert not invariants.contracts_enabled()
        assert invariants.contracts_enabled()


def test_iv001_ema_envelope():
    prev = np.array([1.0, 1.0])
    obs = np.array([0.5, 2.0])
    good = 0.3 * prev + 0.7 * obs
    invariants.check_ema_step(prev, obs, good)
    with pytest.raises(ContractViolation, match=r"IV001"):
        invariants.check_ema_step(prev, obs, np.array([3.0, 1.5]))
    with pytest.raises(ContractViolation, match=r"IV001"):
        invariants.check_ema_step(prev, obs, np.array([np.nan, 1.0]))
    with pytest.raises(ContractViolation, match=r"IV001"):
        invariants.check_ema_step(prev, obs, np.array([-0.1, 1.0]))


def test_iv002_observation_normalization():
    valid = np.array([True, True, True, False])
    obs = np.array([0.5, 1.0, 1.5, 7.0])   # mean over valid = 1
    invariants.check_observation(obs, valid, "mean")
    with pytest.raises(ContractViolation, match=r"IV002"):
        invariants.check_observation(obs * 2, valid, "mean")
    shares = np.array([0.2, 0.3, 0.5, 7.0])  # sum over valid = 1
    invariants.check_observation(shares, valid, "sum")
    with pytest.raises(ContractViolation, match=r"IV002"):
        invariants.check_observation(shares * 2, valid, "sum")
    # singleton measurement: carried over, never checked
    invariants.check_observation(np.array([5.0, 1.0]),
                                 np.array([True, False]), "mean")


def test_iv003_offset_boundaries():
    good = np.array([0, 2, 4, 8], dtype=np.int32)
    invariants.check_offset_boundaries(good, 8)
    with pytest.raises(ContractViolation, match=r"IV003"):
        invariants.check_offset_boundaries(good.astype(np.int64), 8)
    with pytest.raises(ContractViolation, match=r"IV003"):
        invariants.check_offset_boundaries(
            np.array([0, 2, 4], dtype=np.int32), 8)   # ends short of N
    with pytest.raises(ContractViolation, match=r"IV003"):
        invariants.check_offset_boundaries(
            np.array([0, 4, 2, 8], dtype=np.int32), 8)  # not monotone
    with pytest.raises(ContractViolation, match=r"IV003"):
        invariants.check_offset_boundaries(
            np.array([1, 4, 8], dtype=np.int32), 8)   # starts past 0


def test_iv004_plan_partition():
    invariants.check_plan_partition(np.array([3, 0, 5]), 8)
    with pytest.raises(ContractViolation, match=r"IV004"):
        invariants.check_plan_partition(np.array([3, 4]), 8)   # gap
    with pytest.raises(ContractViolation, match=r"IV004"):
        invariants.check_plan_partition(np.array([5, 4]), 8)   # overlap
    with pytest.raises(ContractViolation, match=r"IV004"):
        invariants.check_plan_partition(np.array([-1, 9]), 8)  # negative


def test_iv005_bytes_conserved():
    invariants.check_bytes_conserved(1024.0, 1024.0)
    with pytest.raises(ContractViolation, match=r"IV005"):
        invariants.check_bytes_conserved(1024.0, 512.0)


def test_contracts_live_in_ratio_table_and_offsets():
    """The instrumented hot paths run their checks when contracts are on
    (and a deliberately broken planner is caught)."""
    from repro.runtime import OffsetSnapshot, OffsetSpec, RatioTable

    with invariants.contracts(True):
        table = RatioTable(4, alpha=0.3)
        rng = np.random.default_rng(0)
        for _ in range(8):
            table.update("gemv", rng.uniform(0.5, 2.0, size=4))

        snap = OffsetSnapshot(lambda spec: np.array([1, 3], dtype=np.int64))
        snap.register(OffsetSpec(name="k", total=4, granularity=1))
        snap.refresh()   # 1 + 3 == 4: clean

        # a broken planner returning a negative count still sums to total
        # (passing the snapshot's own sum check) but breaks monotonicity
        bad = OffsetSnapshot(lambda spec: np.array([6, -1], dtype=np.int64))
        bad.register(OffsetSpec(name="k", total=5, granularity=1))
        with pytest.raises(ContractViolation, match=r"IV003"):
            bad.refresh()


def test_invariants_run_pass_clean():
    from repro.analysis.invariants import run_pass
    assert run_pass() == []


# ------------------------------------------------------------ persistence --
def test_ratio_store_tolerates_torn_file(tmp_path):
    from repro.runtime import RatioTable
    from repro.runtime.table import RatioStore

    path = tmp_path / "ratios.json"
    store = RatioStore(str(path))
    table = RatioTable(4, alpha=0.3)
    table.set("gemv", np.array([1.0, 1.1, 0.9, 1.0]))
    store.save(table)
    # no stray temp files after the atomic rename
    assert [p.name for p in tmp_path.iterdir()] == ["ratios.json"]

    # simulate a torn write: truncate the file mid-JSON
    full = path.read_text()
    path.write_text(full[: len(full) // 2])
    fresh = RatioTable(4, alpha=0.3)
    assert store.load_into(fresh) is False
    assert fresh.keys() == []          # untouched

    # corrupt-but-valid JSON (wrong schema) is also a cold start
    path.write_text(json.dumps({"version": 1, "tables": "nope"}))
    assert store.load_into(fresh) is False

    # and a healthy file round-trips
    store.save(table)
    assert store.load_into(fresh) is True
    np.testing.assert_allclose(fresh.ratios("gemv"), table.ratios("gemv"))


def test_tuner_store_tolerates_torn_file(tmp_path):
    from repro.core.tuner import KernelTuner, TunerStore

    path = tmp_path / "tuner.json"
    store = TunerStore(str(path))
    tuner = KernelTuner(alpha=0.3)
    tuner.report("gemv", 128, 1e-3)
    tuner.report("gemv", 256, 2e-3)
    store.save(tuner)
    assert [p.name for p in tmp_path.iterdir()] == ["tuner.json"]

    full = path.read_text()
    path.write_text(full[: len(full) // 2])
    fresh = KernelTuner(alpha=0.3)
    assert store.load_into(fresh) is False

    path.write_text("{not json")
    assert store.load_into(fresh) is False

    store.save(tuner)
    assert store.load_into(fresh) is True
    assert fresh.select("gemv", [128, 256]) == tuner.select("gemv", [128, 256])


# ------------------------------------------------------------- formatting --
def test_finding_format_and_sort():
    a = Finding(rule="RL001", severity="warning", location="x.py:1",
                message="w")
    b = Finding(rule="JA001", severity="error", location="y.py:2",
                message="e")
    out = format_findings([a, b])
    assert out.index("JA001") < out.index("RL001")   # errors first
    assert "x.py:1: warning: [RL001] w" in out
