"""Corpus: minimal traced steps for each jaxpr-audit contract.

Each builder returns a ClosedJaxpr (plus metadata where needed) that the
tests feed to ``audit_compiled`` / ``audit_bridge``.  The shapes mimic the
real decode step at toy scale: ``bounds`` plays the OffsetSnapshot
boundary array, ``x`` the activations.
"""

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import io_callback


def _bounds():
    return jnp.asarray(np.array([0, 2, 4], dtype=np.int32))


def _x():
    return jnp.ones((4,), jnp.float32)


# --------------------------------------------------------------- compiled --
def good_compiled():
    """Zero callbacks; bounds consumed only via the cost-tape pattern
    (slice / sub / cast) and a dynamic-slice shard pick."""

    def step(bounds, x):
        sizes = (bounds[1:] - bounds[:-1]).astype(jnp.int32)
        shard = jax.lax.dynamic_slice(x, (bounds[0],), (2,))
        return sizes, shard, x * 2.0

    return jax.make_jaxpr(step)(_bounds(), _x())


def bad_compiled_callback():
    """JA001: an io_callback inside a compiled step."""

    def step(x):
        y = io_callback(lambda v: np.asarray(v),
                        jax.ShapeDtypeStruct(x.shape, x.dtype), x,
                        ordered=True)
        return y + 1.0

    return jax.make_jaxpr(step)(_x())


def bad_compiled_offset_sink():
    """JA002: an offset boundary array flowing into dense arithmetic."""

    def step(bounds, x):
        w = bounds[1:].astype(jnp.float32)
        return x[:2] * w[:2]           # mul consumes offset-derived value

    return jax.make_jaxpr(step)(_bounds(), _x())


# ----------------------------------------------------------------- bridge --
def _shape(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def good_bridge(n_callbacks: int = 2):
    """``n_callbacks`` ordered io_callbacks, the bridge contract shape."""

    def step(x):
        for _ in range(n_callbacks):
            x = io_callback(lambda v: np.asarray(v) + 1.0, _shape(x), x,
                            ordered=True)
        return x

    return jax.make_jaxpr(step)(_x())


def bad_bridge_unordered():
    """JA004: an io_callback without ordered=True."""

    def step(x):
        return io_callback(lambda v: np.asarray(v), _shape(x), x,
                           ordered=False)

    return jax.make_jaxpr(step)(_x())


def bad_bridge_pure_callback():
    """JA004: a projection routed through pure_callback (elidable)."""

    def step(x):
        return jax.pure_callback(lambda v: np.asarray(v) * 2.0,
                                 _shape(x), x)

    return jax.make_jaxpr(step)(_x())
