"""Corpus: RL004 good — ratio state enters jitted code as an argument
(the OffsetSnapshot contract), never via closure."""

import jax


@jax.jit
def step(x, ratios):
    return x * ratios[0]               # ratios passed in each call


def make_step(table):
    snapshot = table.ratios("gemv").copy()      # read outside the jit
    jitted = jax.jit(lambda x, r: x * r[0])
    return lambda x: jitted(x, snapshot)
