"""Corpus: RL006 bad — raw print() telemetry in library code."""


def report_imbalance(stats):
    print(f"imbalance={stats.imbalance:.3f}")   # flagged: unsinkable
    return stats.makespan


class Dispatcher:
    def step(self):
        print("stepping")                       # flagged: library class
        return []
