"""Corpus: RL005 bad — the EMA applied outside RatioTable.observe."""

from repro.core.ratio import ema_update


def refresh(pr, observed, alpha):
    return ema_update(pr, observed, alpha)     # flagged: bypasses contracts
