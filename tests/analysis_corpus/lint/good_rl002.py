"""Corpus: RL002 good — keys built by the constructors; a raw key such as
``membw/attn_proj`` may appear in prose (this docstring) without tripping
the rule."""


def update(table, times, kernel_key):
    key = kernel_key("q4_matmul")      # constructed, never spelled
    table.update(key, times)
    return table.ratios(key)


PINNED = "membw/q4_matmul"  # lint: allow(RL002) golden-file fixture name
