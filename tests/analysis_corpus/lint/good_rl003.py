"""Corpus: RL003 good — every pool run() joined and errors propagated."""


def run_region(pool, tasks, region):
    times = pool.run(tasks)            # joined: times fed back
    region.record_times(times)
    return times


def run_with_cleanup(pool, tasks):
    try:
        return pool.run(tasks)
    finally:
        pool.close()                   # finally does not swallow
