"""Corpus: RL005 good — observations routed through RatioTable.observe,
the one instrumented EMA call site."""


def refresh(table, key, observed):
    return table.observe(key, observed)
