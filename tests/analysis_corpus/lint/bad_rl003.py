"""Corpus: RL003 bad — pool run() off the join-or-propagate path."""


def fire_and_forget(pool, tasks):
    pool.run(tasks)                    # flagged: result discarded


def swallow(worker_pool, tasks):
    try:
        times = worker_pool.run(tasks)
        return times
    except Exception:
        pass                           # flagged: shard errors swallowed
