"""Corpus: RL006 good — telemetry sinked, prints only on CLI surfaces."""


def report_imbalance(stats, sink):
    sink.emit(stats)
    return stats.makespan


def main():
    print("CLI output is fine inside main()")
    return 0


if __name__ == "__main__":
    print("and inside the __main__ block")
    raise SystemExit(main())
