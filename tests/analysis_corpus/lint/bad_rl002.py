"""Corpus: RL002 bad — raw ratio-table key literals outside the key
constructors."""

KEY = "membw/q4_matmul"                # flagged: module-level literal


def update(table, times):
    table.update("avx2/f32_matmul", times)      # flagged: call argument
    return table.ratios("avx_vnni/int8_gemm")   # flagged
