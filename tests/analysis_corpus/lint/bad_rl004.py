"""Corpus: RL004 bad — jitted closures capturing mutable ratio state."""

import jax

from repro.runtime import RatioTable

table = RatioTable(4)


@jax.jit
def step(x):
    return x * table.ratios("gemv")[0]     # flagged: free `table` baked in


def make_step(runtime):
    return jax.jit(lambda x: x + runtime.table.ratios("gemv")[0])  # flagged
