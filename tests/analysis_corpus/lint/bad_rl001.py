"""Corpus: RL001 bad — wall-clock calls inside virtual-clock code."""
# lint: virtual-clock-module

import time
from time import perf_counter as pc


def advance(sim):
    sim.now = time.perf_counter()      # flagged: module is virtual-clock
    return sim.now


def sample():
    return pc()                        # flagged: aliased from-import


class VirtualTicker:
    def tick(self):
        return time.monotonic()        # flagged: Virtual* class too
