"""Corpus: RL001 good — virtual-clock module routing time through the
machine model's clock; ``time`` may still be imported for sleep etc."""
# lint: virtual-clock-module

import time


def advance(sim, clock):
    sim.now = clock()          # clock injected by the machine model
    return sim.now


def backoff():
    time.sleep(0)              # sleep is not a wall-clock *reading*


class VirtualTicker:
    def __init__(self, clock):
        self._clock = clock

    def tick(self):
        return self._clock()
