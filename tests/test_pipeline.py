"""Pipeline-stage planner tests (Eq. 3 applied to stage assignment)."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.pipeline import (
    PipelinePlan,
    choose_microbatches,
    layer_costs_from_config,
    plan_stages,
)


def test_uniform_layers_uniform_pods():
    plan = plan_stages([1.0] * 16, 4)
    assert plan.boundaries == (0, 4, 8, 12, 16)
    assert plan.makespan_per_microbatch == pytest.approx(4.0)


def test_heterogeneous_pods_get_proportional_layers():
    """A 2x-faster pod should own ~2x the layers (Eq. 3 on stages)."""
    plan = plan_stages([1.0] * 12, 2, stage_ratios=[2.0, 1.0])
    n0 = plan.boundaries[1] - plan.boundaries[0]
    n1 = plan.boundaries[2] - plan.boundaries[1]
    assert n0 == 8 and n1 == 4
    # balanced stage *times*
    t = plan.stage_times
    assert abs(t[0] - t[1]) / max(t) < 1e-9


def test_unequal_layer_costs():
    # one huge layer: the split must isolate it
    costs = [1, 1, 1, 10, 1, 1]
    plan = plan_stages(costs, 2)
    assert plan.makespan_per_microbatch < sum(costs) - 1  # better than naive
    # DP is exact: enumerate all contiguous splits
    best = min(max(sum(costs[:i]), sum(costs[i:])) for i in range(1, 6))
    assert plan.makespan_per_microbatch == pytest.approx(best)


def test_dp_beats_or_matches_even_split():
    pytest.importorskip("hypothesis", reason="property test needs the dev extra")
    from hypothesis import given, settings, strategies as st

    @given(
        st.lists(st.floats(min_value=0.1, max_value=10), min_size=4,
                 max_size=24),
        st.integers(min_value=1, max_value=4),
    )
    @settings(max_examples=30, deadline=None)
    def check(costs, n_stages):
        n_stages = min(n_stages, len(costs))
        plan = plan_stages(costs, n_stages)
        # compare against the naive equal-count split
        n = len(costs)
        step = n // n_stages
        bounds = [min(i * step, n) for i in range(n_stages)] + [n]
        naive = max(sum(costs[bounds[s]: bounds[s + 1]])
                    for s in range(n_stages))
        assert plan.makespan_per_microbatch <= naive + 1e-9
        # partition invariants
        assert plan.boundaries[0] == 0 and plan.boundaries[-1] == n
        assert all(b2 > b1
                   for b1, b2 in zip(plan.boundaries, plan.boundaries[1:]))

    check()


def test_jamba_stage_plan_isolates_moe_attention_load():
    """Jamba's per-layer costs differ (mamba vs attn vs MoE); the planner
    must beat the equal-count split."""
    cfg = get_config("jamba-1.5-large-398b")
    costs = layer_costs_from_config(cfg)
    assert len(costs) == 72
    plan = plan_stages(costs, 8)
    even = max(sum(costs[i * 9:(i + 1) * 9]) for i in range(8))
    assert plan.makespan_per_microbatch <= even
    assert plan.bubble_fraction(32) == pytest.approx(7 / 39)


def test_choose_microbatches():
    plan = plan_stages([1.0] * 8, 4)
    m = choose_microbatches(plan, max_bubble=0.1)
    assert plan.bubble_fraction(m) <= 0.1 + 1e-9
    assert choose_microbatches(plan_stages([1.0], 1)) == 1
