"""Replica routing: per-phase dispatch, heterogeneous capacities,
zero-count masking, and the serve_batch compatibility wrapper."""

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.launch.serve import replica_slot_counts
from repro.models import init_params
from repro.runtime import ListSink, RatioTable, RegionStats
from repro.serving import (
    DECODE,
    PREFILL,
    ContinuousBatchingEngine,
    GenerationResult,
    InflightDispatcher,
    LinearPhaseCost,
    Request,
    RoutedServer,
    ServeEngine,
)

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32")
PARAMS = init_params(CFG, jax.random.key(0))


def _cb_engine(cost=None, slots=4, max_seq=32):
    return ContinuousBatchingEngine(CFG, PARAMS, max_slots=slots,
                                    max_seq=max_seq,
                                    cost_model=cost or LinearPhaseCost())


def _req(rng, steps=4, prompt_len=6, **kw):
    return Request(prompt=rng.integers(0, 128, size=prompt_len),
                   max_new_tokens=steps, **kw)


# ------------------------------------------------------ in-flight routing --
def test_dispatcher_prefers_fast_decode_replica():
    disp = InflightDispatcher([_cb_engine(), _cb_engine()])
    disp.table.set(DECODE, np.array([0.5, 1.5]))
    rng = np.random.default_rng(0)
    i, _ = disp.submit(_req(rng))
    assert i == 1  # idle replicas, decode ratio 3x -> fast one wins


def test_dispatcher_accounts_for_backlog():
    disp = InflightDispatcher([_cb_engine(), _cb_engine()])
    disp.table.set(DECODE, np.array([0.5, 1.5]))
    rng = np.random.default_rng(0)
    # pile work on the fast replica until the slow one is the better choice
    routed = [disp.submit(_req(rng))[0] for _ in range(6)]
    assert routed[0] == 1
    assert 0 in routed  # backlog eventually overcomes the ratio advantage


def test_dispatcher_learns_per_phase_ratios():
    """Replica 0 decodes 3x slower but prefills at the same speed: the
    "decode" table entry must separate while "prefill" stays flat."""
    slow = LinearPhaseCost(prefill_per_token=1e-3, decode_per_step=3e-3)
    fast = LinearPhaseCost(prefill_per_token=1e-3, decode_per_step=1e-3)
    disp = InflightDispatcher([_cb_engine(slow), _cb_engine(fast)])
    rng = np.random.default_rng(1)
    for i in range(24):
        disp.submit(_req(rng, steps=6, arrival_time=0.004 * i))
        disp.run_until_idle(max_steps=4)
    disp.run_until_idle(max_steps=2000)
    assert not disp.has_work
    pf, dec = disp.table.ratios(PREFILL), disp.table.ratios(DECODE)
    assert dec[1] > dec[0] + 0.2
    assert abs(pf[1] - pf[0]) < 0.2


def test_dispatcher_poll_finished_is_deterministic():
    disp = InflightDispatcher([_cb_engine(), _cb_engine()])
    rng = np.random.default_rng(2)
    reqs = [_req(rng, arrival_time=0.001 * i) for i in range(6)]
    for r in reqs:
        disp.submit(r)
    disp.run_until_idle(max_steps=1000)
    done = disp.poll_finished()
    assert len(done) == 6
    times = [r.finish_time for r in done]
    assert times == sorted(times)


def test_dispatcher_routes_around_small_cache_replicas():
    disp = InflightDispatcher([_cb_engine(max_seq=12), _cb_engine(max_seq=48)])
    rng = np.random.default_rng(4)
    i, _ = disp.submit(_req(rng, prompt_len=20))  # only replica 1 fits
    assert i == 1
    # prompt fits replica 0 but the full generation does not: prefer the
    # roomy replica over silent truncation
    i2, _ = disp.submit(_req(rng, prompt_len=6, steps=20))
    assert i2 == 1
    # nobody can hold the whole generation -> best-effort prompt-fit tier
    assert disp.route(_req(rng, prompt_len=8, steps=100)) in (0, 1)
    with pytest.raises(ValueError, match="fits no replica"):
        disp.route(_req(rng, prompt_len=60))


def test_windowed_feedback_learns_from_non_overlapping_rounds():
    """Replicas that never work in the same iteration must still teach the
    per-phase table: solo rounds accumulate until a relative comparison
    is possible."""
    slow = LinearPhaseCost(prefill_per_token=3e-3)
    fast = LinearPhaseCost(prefill_per_token=1e-3)
    disp = InflightDispatcher([_cb_engine(slow), _cb_engine(fast)])
    rng = np.random.default_rng(5)
    for k in range(12):
        disp.engines[k % 2].submit(_req(rng, steps=2, prompt_len=8))
        disp.run_until_idle(max_steps=50)  # drain: prefills never overlap
    pf = disp.table.ratios(PREFILL)
    assert pf[1] > pf[0] + 0.3


def test_singleton_measurement_does_not_erase_learned_ratios():
    """One replica running alone is the common dispatcher case: its solo
    measurement has no relative information and must not EMA-drag the
    learned per-phase ratios back toward 1.0."""
    t = RatioTable(2)
    t.set(DECODE, np.array([2.0, 0.5]))
    for _ in range(5):
        t.update(DECODE, times=[1.0, 0.0], units=[4, 0])   # units path
    np.testing.assert_allclose(t.ratios(DECODE), [2.0, 0.5])
    for _ in range(5):
        t.update(DECODE, times=[1.0, 0.0])                 # times path
    np.testing.assert_allclose(t.ratios(DECODE), [2.0, 0.5])
    # two measured workers: the update applies as before
    t.update(DECODE, times=[1.0, 1.0], units=[1, 1])
    assert t.ratios(DECODE)[0] < 2.0
    # a 1-worker table keeps its trivial fixpoint semantics
    solo = RatioTable(1)
    solo.update("k", times=[2.0], units=[4])
    np.testing.assert_allclose(solo.ratios("k"), [1.0])


# ------------------------------------------- zero-count masking satellite --
def test_zero_count_replica_masked_from_ema_and_telemetry():
    sink = ListSink()
    engines = [ServeEngine(CFG, PARAMS, batch_size=4, max_seq=16)
               for _ in range(2)]
    srv = RoutedServer(engines, sink=sink)
    # replica 0 looks useless: the whole batch goes to replica 1
    srv.runtime.set("serve_step", np.array([1e-6, 1.0]))
    prompts = np.random.default_rng(0).integers(0, 128, size=(4, 4),
                                                dtype=np.int32)
    before = srv.runtime.ratios("serve_step").copy()
    out, counts, times = srv.serve_batch(
        prompts, n_steps=2, times_override=np.array([123.0, 1.0]))
    assert counts[0] == 0 and counts[1] == 4
    assert out.shape == (4, 6)
    # the phantom 123s never reaches telemetry or the EMA
    assert times[0] == 0.0
    after = srv.runtime.ratios("serve_step")
    assert after[0] == pytest.approx(before[0])
    st = sink.records[-1]
    assert list(st.measured) == [False, True]
    assert st.makespan == pytest.approx(1.0)
    assert st.imbalance == pytest.approx(1.0)


def test_region_stats_measured_mask_direct():
    st = RegionStats(key="k", counts=np.array([0, 2, 3]),
                     times=np.array([7.0, 1.0, 3.0]))
    assert list(st.measured) == [False, True, True]
    assert st.makespan == pytest.approx(3.0)
    assert st.imbalance == pytest.approx(3.0 / 2.0)
    empty = RegionStats(key="k", counts=np.array([0]), times=np.array([9.0]))
    assert empty.imbalance == 1.0 and empty.makespan == 0.0


# ------------------------------------- heterogeneous capacities / wrapper --
def test_serve_batch_heterogeneous_capacities_with_overflow():
    engines = [ServeEngine(CFG, PARAMS, batch_size=2, max_seq=16),
               ServeEngine(CFG, PARAMS, batch_size=6, max_seq=16)]
    srv = RoutedServer(engines)
    # raw Eq.-3 split of 8 would be [7, 1]: replica 0 overflows its 2 slots
    srv.runtime.set("serve_step", np.array([7.0, 1.0]))
    prompts = np.random.default_rng(1).integers(0, 128, size=(8, 4),
                                                dtype=np.int32)
    out, counts, _ = srv.serve_batch(prompts, n_steps=2)
    assert counts.tolist() == [2, 6]  # clamped + redistributed
    assert out.shape == (8, 6)
    with pytest.raises(ValueError):  # beyond aggregate capacity: real error
        srv.serve_batch(np.zeros((9, 4), dtype=np.int32), n_steps=1)


def test_serve_batch_rejects_steps_beyond_max_seq():
    """The (B, s0 + n_steps) output contract cannot be met when the cache
    is too small; that must be a loud error, not a narrower array."""
    srv = RoutedServer([ServeEngine(CFG, PARAMS, batch_size=2, max_seq=8)])
    prompts = np.zeros((2, 6), dtype=np.int32)
    with pytest.raises(ValueError, match="max_seq"):
        srv.serve_batch(prompts, n_steps=4)


def test_serve_batch_zero_steps_returns_prompts_unchanged():
    srv = RoutedServer([ServeEngine(CFG, PARAMS, batch_size=4, max_seq=16)])
    prompts = np.random.default_rng(3).integers(0, 128, size=(3, 4),
                                                dtype=np.int32)
    out, counts, times = srv.serve_batch(prompts, n_steps=0)
    np.testing.assert_array_equal(out, prompts)
    assert counts.sum() == 3 and times.sum() == 0.0


def test_serve_batch_reuses_engines_across_rounds():
    engines = [ServeEngine(CFG, PARAMS, batch_size=4, max_seq=16)]
    srv = RoutedServer(engines)
    prompts = np.random.default_rng(2).integers(0, 128, size=(3, 4),
                                                dtype=np.int32)
    for _ in range(3):
        out, counts, _ = srv.serve_batch(prompts, n_steps=2)
        assert out.shape == (3, 6)
        assert counts.sum() == 3
    # long-lived engine stays bounded: finished requests are drained
    assert srv._cb[0].finished == []
    assert srv._cb[0].manager.n_free == 4


# ----------------------------------------------------- satellite fixes ----
def test_replica_slot_counts_cover_batch_with_remainder():
    assert replica_slot_counts(4, 2) == [2, 2]
    assert replica_slot_counts(5, 2) == [3, 2]
    assert replica_slot_counts(7, 3) == [3, 2, 2]
    assert replica_slot_counts(2, 4) == [1, 1, 1, 1]  # every replica >= 1
    with pytest.raises(ValueError):
        replica_slot_counts(4, 0)


def test_tokens_per_second_uses_real_request_count():
    tokens = np.zeros((4, 10), dtype=np.int32)  # 2 real rows + 2 padding
    r = GenerationResult(tokens=tokens, prefill_seconds=0.1,
                         decode_seconds=1.0, steps=5, n_requests=2)
    assert r.tokens_per_second == pytest.approx(10.0)
    legacy = GenerationResult(tokens=tokens, prefill_seconds=0.1,
                              decode_seconds=1.0, steps=5)
    assert legacy.tokens_per_second == pytest.approx(20.0)
