"""Tests for the TPU-scale adaptation planners (repro.core.balance)."""

import numpy as np
import pytest

from repro.core import (
    DeviceRuntime,
    ExpertCapacityPlanner,
    ReplicaRouter,
    UnevenBatchPlanner,
)


def test_uneven_batch_planner_converges():
    """Pods with 2x throughput end up with ~2x the microbatches."""
    rt = DeviceRuntime(n_slices=4, alpha=0.3)
    planner = UnevenBatchPlanner(rt)
    tp = np.array([2.0, 2.0, 1.0, 1.0])  # true microbatches/sec
    plan = planner.plan(24)
    assert plan.total == 24
    np.testing.assert_array_equal(plan.counts, [6, 6, 6, 6])  # cold start: even
    for _ in range(30):
        times = plan.counts / tp
        planner.report(plan, times)
        plan = planner.plan(24)
    np.testing.assert_array_equal(plan.counts, [8, 8, 4, 4])
    # weights are consistent for the weighted all-reduce
    np.testing.assert_allclose(plan.weights.sum(), 1.0)


def test_uneven_batch_min_per_slice():
    rt = DeviceRuntime(n_slices=4)
    planner = UnevenBatchPlanner(rt, min_per_slice=1)
    # Extremely skewed table must still give every pod >= 1.
    rt.set("train_step", np.array([100.0, 1e-6, 1e-6, 1e-6]))
    plan = planner.plan(8)
    assert plan.total == 8
    assert np.all(plan.counts >= 1)


def test_uneven_batch_too_few_microbatches():
    rt = DeviceRuntime(n_slices=8)
    with pytest.raises(ValueError):
        UnevenBatchPlanner(rt).plan(4)


def _check_expert_capacity_invariants(n_experts, seed):
    rng = np.random.default_rng(seed)
    total = 64 * n_experts
    p = ExpertCapacityPlanner(n_experts, total, min_capacity=8, granularity=8)
    for _ in range(5):
        p.observe(rng.integers(0, 100, size=n_experts))
        caps = p.capacities()
        assert caps.sum() == total          # fixed compute budget
        assert np.all(caps >= 8)            # floor
        assert p.load_ema.shape == (n_experts,)


@pytest.mark.parametrize("n_experts,seed", [(2, 0), (16, 3), (64, 10)])
def test_expert_capacity_invariants(n_experts, seed):
    _check_expert_capacity_invariants(n_experts, seed)


def test_expert_capacity_invariants_property():
    pytest.importorskip("hypothesis", reason="property test needs the dev extra")
    from hypothesis import given, strategies as st

    given(st.integers(min_value=2, max_value=64),
          st.integers(min_value=0, max_value=10))(
        _check_expert_capacity_invariants)()


def test_expert_capacity_tracks_hot_expert():
    p = ExpertCapacityPlanner(4, total_capacity=400, min_capacity=8,
                              granularity=8, alpha=0.3)
    for _ in range(20):
        p.observe(np.array([700, 100, 100, 100]))
    caps = p.capacities()
    assert caps[0] > 2.5 * caps[1]
    assert caps.sum() == 400


def test_replica_router():
    rt = DeviceRuntime(n_slices=2, alpha=0.0)  # no smoothing: immediate
    router = ReplicaRouter(rt)
    counts = router.split(12)
    np.testing.assert_array_equal(counts, [6, 6])
    router.report(np.array([6, 6]), np.array([1.0, 3.0]))  # replica 1 is 3x slower
    counts = router.split(12)
    assert counts[0] == 9 and counts[1] == 3


def test_device_runtime_units_update():
    """Update with explicit units does not assume proportional assignment."""
    rt = DeviceRuntime(n_slices=2, alpha=0.0)
    rt.update("p", times=np.array([1.0, 1.0]), units=np.array([3.0, 1.0]))
    pr = rt.ratios("p")
    np.testing.assert_allclose(pr / pr.sum(), [0.75, 0.25])


def test_device_runtime_history():
    rt = DeviceRuntime(n_slices=2)
    rt.update("p", np.array([1.0, 2.0]))
    rt.update("p", np.array([1.0, 2.0]))
    assert len(rt.history["p"]) == 3  # init + 2 updates
