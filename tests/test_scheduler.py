"""System tests for the dynamic scheduler on simulated hybrid CPUs.

These verify the paper's *claims*: dynamic proportional dispatch converges to
near-optimal makespan on hybrid machines, substantially beating the static
(OpenMP-balanced) baseline, while being neutral on homogeneous machines.
"""

import numpy as np
import pytest

from repro.core import (
    CPURuntime,
    DynamicScheduler,
    StaticScheduler,
    KernelSpec,
    SubTask,
    ThreadWorkerPool,
    VirtualWorkerPool,
    make_machine,
)

# Fig. 2 GEMM (1024x4096x4096): Neural Speed splits the N dim; one unit of
# the parallel dim = one output column = 2*M*K MACs.
GEMM = KernelSpec(name="int8_gemm", isa="avx_vnni", granularity=16,
                  work_per_unit=2 * 1024 * 4096)
# Fig. 2 GEMV (1x4096x4096): memory bound; one output element reads one
# Q4_0 weight row of K=4096 -> 4096 * 0.5625 bytes (int4 + fp16 scale /32).
GEMV = KernelSpec(name="q4_gemv", isa="membw", granularity=8,
                  work_per_unit=4096 * 0.5625)


def run_steady_state(machine_name, kernel, s, iters=40, tail=10, seed=0):
    """Returns (mean dynamic makespan over the steady-state tail, mean static
    makespan, noise-free optimal makespan)."""
    machine = make_machine(machine_name, seed=seed)
    pool = VirtualWorkerPool(machine, isa=kernel.isa)
    runtime = CPURuntime(machine.n_cores, alpha=0.3)
    sched = DynamicScheduler(runtime, pool)
    for _ in range(iters):
        sched.dispatch(kernel, s)
    dyn = float(np.mean([st.makespan for st in sched.stats[-tail:]]))
    static_pool = VirtualWorkerPool(make_machine(machine_name, seed=seed),
                                    isa=kernel.isa)
    static = StaticScheduler(static_pool)
    for _ in range(tail):
        static.dispatch(kernel, s)
    st = float(np.mean([x.makespan for x in static.stats]))
    opt = machine.optimal_makespan(kernel.isa, s * kernel.work_per_unit)
    return dyn, st, opt


@pytest.mark.parametrize("machine", ["ultra-125h", "core-12900k"])
def test_dynamic_beats_static_gemm(machine):
    dyn, st, opt = run_steady_state(machine, GEMM, s=4096)
    speedup = st / dyn
    # Paper: 65% (125H) and 85% (12900K) GEMM improvement.
    assert speedup > 1.5, f"{machine}: speedup {speedup:.2f}"
    # ...and we approach the machine's optimal makespan within 10%.
    assert dyn < opt * 1.10


@pytest.mark.parametrize("machine", ["ultra-125h", "core-12900k"])
def test_dynamic_gemv_bandwidth(machine):
    dyn, st, opt = run_steady_state(machine, GEMV, s=4096)
    # bandwidth utilization = optimal_time / achieved_time
    util = opt / dyn
    assert util > 0.90, f"{machine}: bandwidth util {util:.2%}"  # paper: >90%


def test_homogeneous_no_regression():
    dyn, st, opt = run_steady_state("homogeneous-8", GEMM, s=4096)
    # On a non-hybrid machine dynamic must not be materially worse.
    assert dyn <= st * 1.05


def test_ratio_trace_converges_and_adapts():
    """Fig. 4: init ratio 5 converges to ~3-3.5 for a P-core on 125H, and
    the table *re-adapts* when the bottleneck changes (prefill->decode)."""
    machine = make_machine("ultra-125h")
    runtime = CPURuntime(machine.n_cores, alpha=0.3, init_ratio=5.0)
    pool = VirtualWorkerPool(machine, isa="avx_vnni")
    sched = DynamicScheduler(runtime, pool)
    for _ in range(40):
        sched.dispatch(GEMM, 4096)
    p0 = runtime.ratios("avx_vnni")[0]
    tp = machine.true_throughput("avx_vnni")
    expected = tp[0] / tp.mean()
    # Converged to the machine's true relative throughput (paper Fig. 4
    # plots 3-3.5 under its own undisclosed normalization; the invariant we
    # can check exactly is convergence-to-truth + the init-5 drop).
    assert abs(p0 - expected) / expected < 0.10
    assert p0 < 5.0  # dropped from the deliberately-too-high init

    # Decode phase: memory-bound kernel has its own (smaller) ratios.
    pool2 = VirtualWorkerPool(machine, isa="membw")
    sched2 = DynamicScheduler(runtime, pool2)
    for _ in range(40):
        sched2.dispatch(GEMV, 4096)
    p0_mem = runtime.ratios("membw")[0]
    assert p0_mem < p0  # decode ratios compress toward 1 (Fig. 4)


def test_adapts_to_background_load():
    """A sudden background program throttling core 0 must be absorbed."""
    machine = make_machine("ultra-125h")
    machine.background.append((0.0, 1e9, 0, 3.0))  # core 0 3x slower, forever
    pool = VirtualWorkerPool(machine, isa="avx_vnni")
    runtime = CPURuntime(machine.n_cores, alpha=0.3)
    sched = DynamicScheduler(runtime, pool)
    for _ in range(40):
        last = sched.dispatch(GEMM, 4096)
    tp = machine.true_throughput("avx_vnni").copy()
    tp[0] /= 3.0
    opt = (4096 * GEMM.work_per_unit) / tp.sum()
    assert last.makespan < opt * 1.10


def test_thread_pool_executes_correctly():
    """Real-thread mode: the partitioned execution computes the right thing."""
    out = np.zeros(1000)
    x = np.arange(1000, dtype=np.float64)

    def fn(start, size):
        out[start:start + size] = x[start:start + size] * 2

    pool = ThreadWorkerPool(4)
    try:
        runtime = CPURuntime(4)
        sched = DynamicScheduler(runtime, pool)
        kernel = KernelSpec(name="scale", isa="avx2", granularity=8)
        stats = sched.dispatch(kernel, 1000, fn=fn)
        np.testing.assert_allclose(out, x * 2)
        assert stats.counts.sum() == 1000
    finally:
        pool.close()


def test_virtual_pool_execute_mode():
    """Virtual pool can also run the real fn (used by e2e benchmarks)."""
    acc = np.zeros(64)

    def fn(start, size):
        acc[start:start + size] += 1

    machine = make_machine("ultra-125h")
    pool = VirtualWorkerPool(machine, isa="avx2", execute=True)
    runtime = CPURuntime(machine.n_cores)
    sched = DynamicScheduler(runtime, pool)
    sched.dispatch(KernelSpec("inc", "avx2"), 64, fn=fn)
    np.testing.assert_allclose(acc, 1.0)


def test_imbalance_metric():
    machine = make_machine("core-12900k")
    pool = VirtualWorkerPool(machine, isa="avx_vnni")
    runtime = CPURuntime(machine.n_cores)
    sched = DynamicScheduler(runtime, pool)
    first = sched.dispatch(GEMM, 4096)
    for _ in range(30):
        last = sched.dispatch(GEMM, 4096)
    # Static-equal first dispatch is imbalanced; steady state is balanced.
    assert first.imbalance > 1.5
    assert last.imbalance < 1.1
