"""Tests for the unified repro.runtime Balancer API.

Covers: partition invariants (sum / granularity / floor), convergence of
the full table -> policy -> balancer loop under a fixed heterogeneous
simulator, RatioStore save/load round-trip, the normalization regression
pinning both seed behaviors (CPURuntime mean vs DeviceRuntime units path),
capacity clamping, the balanced_region lifecycle, and the repro.core
deprecation shims.
"""

import numpy as np
import pytest

from repro.runtime import (
    Balancer,
    CPURuntime,
    DeviceRuntime,
    EvenPolicy,
    ListSink,
    Plan,
    ProportionalPolicy,
    RatioStore,
    RatioTable,
    RegionStats,
    clamp_to_capacity,
)


# ------------------------------------------------------- plan invariants --
@pytest.mark.parametrize("total", [0, 1, 7, 64, 1000, 4096])
@pytest.mark.parametrize("granularity", [1, 3, 8, 16])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_plan_sums_to_total_and_respects_granularity(total, granularity, seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 12))
    table = RatioTable(n)
    table.set("k", rng.uniform(0.1, 5.0, size=n))
    policy = ProportionalPolicy(table, "k", granularity=granularity)
    plan = policy.plan(total)
    assert plan.total == total
    assert np.all(plan.counts >= 0)
    # every worker's count is a granularity multiple except the largest-
    # share worker, which absorbs the non-divisible remainder
    off_grid = np.nonzero(plan.counts % granularity)[0]
    assert len(off_grid) <= 1
    # contiguous ranges tile [0, total)
    ranges = plan.ranges
    assert ranges[0][0] == 0 and ranges[-1][1] == total
    for (a, b), (c, d) in zip(ranges, ranges[1:]):
        assert b == c


def test_plan_min_per_worker_floor():
    table = RatioTable(4)
    table.set("k", np.array([100.0, 1e-6, 1e-6, 1e-6]))
    policy = ProportionalPolicy(table, "k", min_per_worker=1)
    plan = policy.plan(8)
    assert plan.total == 8
    assert np.all(plan.counts >= 1)
    with pytest.raises(ValueError):
        policy.plan(3)


def test_plan_property_based():
    pytest.importorskip("hypothesis", reason="property test needs the dev extra")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(min_value=0, max_value=100_000),
           st.integers(min_value=1, max_value=64),
           st.lists(st.floats(min_value=0.01, max_value=100),
                    min_size=1, max_size=32))
    @settings(max_examples=50, deadline=None)
    def check(total, granularity, ratios):
        table = RatioTable(len(ratios))
        table.set("k", np.asarray(ratios))
        plan = ProportionalPolicy(table, "k", granularity=granularity).plan(total)
        assert plan.total == total
        assert np.all(plan.counts >= 0)
        assert (plan.counts % granularity != 0).sum() <= 1

    check()


# ---------------------------------------------------------- convergence --
def test_loop_converges_on_fixed_heterogeneous_simulator():
    """The full plan -> simulate -> report loop converges: counts become
    proportional to the true speeds and the ratio trace goes quiet."""
    speeds = np.array([4.0, 2.0, 1.0, 1.0])
    table = RatioTable(4, alpha=0.3)
    bal = Balancer(ProportionalPolicy(table, "sim"))
    plan = bal.plan(64)
    for _ in range(40):
        times = np.where(plan.counts > 0, plan.counts / speeds, 0.0)
        bal.report(plan, times)
        plan = bal.plan(64)
    np.testing.assert_array_equal(plan.counts, [32, 16, 8, 8])
    # ratios match mean-normalized true speeds
    np.testing.assert_allclose(table.ratios("sim"),
                               speeds / speeds.mean(), rtol=0.05)
    # steady state: the last few tables are essentially identical
    tail = table.history["sim"][-3:]
    np.testing.assert_allclose(tail[0], tail[-1], rtol=1e-3)


def test_even_policy_is_static():
    bal = Balancer(EvenPolicy(4))
    plan = bal.plan(64)
    np.testing.assert_array_equal(plan.counts, [16, 16, 16, 16])
    bal.report(plan, np.array([8.0, 1.0, 1.0, 1.0]))
    np.testing.assert_array_equal(bal.plan(64).counts, [16, 16, 16, 16])


# ------------------------------------------------------- normalization ---
def test_normalization_regression_cpu_mean():
    """Pin the seed CPURuntime behavior: normalize='mean' keeps an all-equal
    table at 1.0 (paper Fig. 4 magnitudes), 'sum' gives the literal Eq. 2."""
    mean_rt = CPURuntime(2, alpha=0.0)
    mean_rt.update("isa", np.array([1.0, 1.0]))
    np.testing.assert_allclose(mean_rt.ratios("isa"), [1.0, 1.0])

    sum_rt = CPURuntime(2, alpha=0.0, normalize="sum")
    sum_rt.update("isa", np.array([1.0, 1.0]))
    np.testing.assert_allclose(sum_rt.ratios("isa"), [0.5, 0.5])


def test_normalization_regression_units_path():
    """Pin the seed DeviceRuntime units path: mean-normalized over valid
    entries (speed [3,1] -> observed [1.5, 0.5]), and show the same knob
    now controls it (normalize='sum' -> [0.75, 0.25])."""
    rt = DeviceRuntime(n_slices=2, alpha=0.0)
    rt.update("p", times=np.array([1.0, 1.0]), units=np.array([3.0, 1.0]))
    np.testing.assert_allclose(rt.ratios("p"), [1.5, 0.5])

    rt_sum = RatioTable(2, alpha=0.0, normalize="sum")
    rt_sum.update("p", times=np.array([1.0, 1.0]), units=np.array([3.0, 1.0]))
    np.testing.assert_allclose(rt_sum.ratios("p"), [0.75, 0.25])


def test_units_path_skips_idle_workers():
    rt = RatioTable(3, alpha=0.0)
    rt.update("p", times=np.array([1.0, 1.0, 0.0]),
              units=np.array([2.0, 2.0, 0.0]))
    pr = rt.ratios("p")
    assert pr[2] == 1.0  # idle worker's ratio carried over
    np.testing.assert_allclose(pr[:2], [1.0, 1.0])


# -------------------------------------------------------------- history ---
def test_history_is_bounded():
    rt = RatioTable(2, max_history=5)
    for _ in range(20):
        rt.update("k", np.array([1.0, 2.0]))
    assert len(rt.history["k"]) == 5


# ---------------------------------------------------------- persistence ---
def test_ratio_store_roundtrip(tmp_path):
    table = RatioTable(3, alpha=0.25, init_ratio=2.0, normalize="sum")
    table.update("gemm", np.array([1.0, 2.0, 4.0]))
    table.update("gemv", np.array([2.0, 2.0, 1.0]))
    store = RatioStore(str(tmp_path / "sub" / "ratios.json"))
    assert not store.exists()
    store.save(table)
    loaded = store.load()
    assert loaded is not None
    assert loaded.n_workers == 3
    assert loaded.alpha == 0.25
    assert loaded.normalize == "sum"
    assert sorted(loaded.keys()) == ["gemm", "gemv"]
    for key in table.keys():
        np.testing.assert_allclose(loaded.ratios(key), table.ratios(key))


def test_ratio_store_load_into(tmp_path):
    src = RatioTable(2)
    src.update("k", np.array([1.0, 3.0]))
    store = RatioStore(str(tmp_path / "ratios.json"))
    store.save(src)
    dst = RatioTable(2)
    assert store.load_into(dst)
    np.testing.assert_allclose(dst.ratios("k"), src.ratios("k"))
    # mismatched worker count: refuse, leave target untouched
    other = RatioTable(5)
    assert not store.load_into(other)
    assert other.keys() == []
    # missing file
    assert RatioStore(str(tmp_path / "nope.json")).load() is None


def test_ratio_store_load_into_rejects_convention_mismatch(tmp_path):
    """A sum-normalized table silently loaded into a mean-normalized one is
    off by n_workers and corrupts learned ratios; a different alpha changes
    the filter the stored history was produced under.  Both must refuse."""
    src = RatioTable(2, alpha=0.3, normalize="sum")
    src.update("k", np.array([1.0, 3.0]))
    store = RatioStore(str(tmp_path / "ratios.json"))
    store.save(src)
    # normalize mismatch
    dst = RatioTable(2, alpha=0.3, normalize="mean")
    assert not store.load_into(dst)
    assert dst.keys() == []
    # alpha mismatch
    dst = RatioTable(2, alpha=0.5, normalize="sum")
    assert not store.load_into(dst)
    assert dst.keys() == []
    # exact convention match still loads
    dst = RatioTable(2, alpha=0.3, normalize="sum")
    assert store.load_into(dst)
    np.testing.assert_allclose(dst.ratios("k"), src.ratios("k"))


def test_warm_start_skips_cold_start_imbalance(tmp_path):
    """The point of persistence: a warm-started run plans proportionally
    from dispatch #1 instead of re-learning the machine."""
    speeds = np.array([3.0, 1.0])
    table = RatioTable(2, alpha=0.3)
    bal = Balancer(ProportionalPolicy(table, "k"))
    plan = bal.plan(16)
    for _ in range(30):
        bal.report(plan, plan.counts / speeds)
        plan = bal.plan(16)
    store = RatioStore(str(tmp_path / "ratios.json"))
    store.save(table)

    fresh = RatioTable(2, alpha=0.3)
    assert RatioStore(store.path).load_into(fresh)
    first = ProportionalPolicy(fresh, "k").plan(16)
    np.testing.assert_array_equal(first.counts, [12, 4])


# ------------------------------------------------------ balancer/region ---
def test_balanced_region_times_and_feeds_back():
    table = RatioTable(2, alpha=0.0)
    sink = ListSink()
    bal = Balancer(ProportionalPolicy(table, "r"), sink=sink)
    with bal.balanced_region(8) as region:
        np.testing.assert_array_equal(region.counts, [4, 4])
        for w in range(2):
            with region.timed(w):
                pass
        # deterministic times for the assertion: worker 1 is 3x slower
        region.times[:] = [1.0, 3.0]
    assert isinstance(region.stats, RegionStats)
    assert region.stats.makespan == 3.0
    assert region.stats.imbalance == pytest.approx(1.5)
    assert len(sink.records) == 1 and sink.records[0] is region.stats
    assert bal.plan(8).counts[0] > bal.plan(8).counts[1]  # fed back


def test_balanced_region_no_feedback_on_exception():
    table = RatioTable(2, alpha=0.0)
    bal = Balancer(ProportionalPolicy(table, "r"))
    with pytest.raises(RuntimeError):
        with bal.balanced_region(8) as region:
            raise RuntimeError("kernel failed")
    np.testing.assert_allclose(table.ratios("r"), [1.0, 1.0])
    assert bal.stats == []


def test_region_timed_accumulates_real_time():
    import time
    table = RatioTable(1)
    bal = Balancer(ProportionalPolicy(table, "t"))
    with bal.balanced_region(4) as region:
        with region.timed(0):
            time.sleep(0.01)
    assert region.times[0] >= 0.01
    assert region.stats.ratios is not None


# ------------------------------------------------------------- clamping ---
def test_clamp_to_capacity():
    counts = clamp_to_capacity([7, 1], [4, 4])
    np.testing.assert_array_equal(counts, [4, 4])
    counts = clamp_to_capacity([5, 1, 0], [4, 4, 4])
    assert counts.sum() == 6 and np.all(counts <= 4)
    np.testing.assert_array_equal(clamp_to_capacity([2, 2], [4, 4]), [2, 2])
    with pytest.raises(ValueError):
        clamp_to_capacity([5, 5], [4, 4])


# ----------------------------------------------------- deprecation shims --
def test_core_shims_resolve_to_runtime():
    import repro.core
    import repro.core.balance as balance
    import repro.core.scheduler as scheduler
    import repro.runtime as runtime

    assert repro.core.CPURuntime is runtime.CPURuntime
    assert repro.core.DeviceRuntime is runtime.DeviceRuntime
    assert scheduler.DynamicScheduler is runtime.DynamicScheduler
    assert scheduler.RegionStats is runtime.RegionStats
    assert balance.UnevenBatchPlanner is runtime.UnevenBatchPlanner
    assert balance.ExpertCapacityPlanner is runtime.ExpertCapacityPlanner
    assert balance.ReplicaRouter is runtime.ReplicaRouter
    # RegionStats keeps its seed-era .kernel alias
    st = runtime.RegionStats(key="k", counts=np.array([1]),
                             times=np.array([1.0]))
    assert st.kernel == "k"


def test_planners_are_balance_policies():
    from repro.runtime import BalancePolicy, UnevenBatchPlanner

    table = DeviceRuntime(n_slices=2)
    planner = UnevenBatchPlanner(table)
    assert isinstance(planner, BalancePolicy)
    plan = planner.plan(8)
    assert isinstance(plan, Plan)
    assert plan.total == 8
    np.testing.assert_allclose(plan.weights.sum(), 1.0)
    # Balancer drives any planner uniformly
    bal = Balancer(planner)
    st = bal.report(plan, np.array([1.0, 2.0]))
    assert st.ratios is not None


# ------------------------------------------------- table key separation ---
def test_kernel_spec_table_key_defaults_to_isa():
    from repro.runtime import KernelSpec

    assert KernelSpec("k", isa="membw").table_key == "membw"
    spec = KernelSpec("k", isa="membw", key="membw/attn_proj")
    assert spec.table_key == "membw/attn_proj"
    assert spec.isa == "membw"


# ----------------------------------------- RatioTable property tests ------
def test_ratio_table_normalization_property():
    """Under any all-valid update sequence the table's mean (normalize=
    'mean') / sum (normalize='sum') follows the exact EMA contraction
    toward 1 — mean-normalized tables stay at mean 1 forever."""
    pytest.importorskip("hypothesis", reason="property test needs the dev extra")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(min_value=2, max_value=8),
           st.floats(min_value=0.0, max_value=1.0),
           st.data())
    @settings(max_examples=40, deadline=None)
    def check(n, alpha, data):
        times_vec = st.lists(
            st.floats(min_value=1e-3, max_value=1e3,
                      allow_nan=False, allow_infinity=False),
            min_size=n, max_size=n)
        rounds = data.draw(st.lists(times_vec, min_size=1, max_size=6))
        for normalize in ("mean", "sum"):
            table = RatioTable(n, alpha=alpha, normalize=normalize)
            agg = np.mean if normalize == "mean" else np.sum
            prev = agg(table.ratios("k"))
            for times in rounds:
                table.update("k", np.asarray(times))
                cur = agg(table.ratios("k"))
                np.testing.assert_allclose(
                    cur, alpha * prev + (1 - alpha), rtol=1e-9)
                prev = cur

    check()


def test_ratio_table_ema_bounded_by_observed_extremes():
    """Every EMA step is a convex combination: each entry stays inside
    [min(old, observed), max(old, observed)] — so the table is globally
    bounded by the initial value and the observation extremes."""
    pytest.importorskip("hypothesis", reason="property test needs the dev extra")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(min_value=1, max_value=8),
           st.floats(min_value=0.0, max_value=1.0),
           st.data())
    @settings(max_examples=40, deadline=None)
    def check(n, alpha, data):
        obs_vec = st.lists(
            st.floats(min_value=1e-6, max_value=1e6,
                      allow_nan=False, allow_infinity=False),
            min_size=n, max_size=n)
        rounds = data.draw(st.lists(obs_vec, min_size=1, max_size=6))
        table = RatioTable(n, alpha=alpha)
        lo = np.full(n, 1.0)
        hi = np.full(n, 1.0)
        for obs in rounds:
            obs = np.asarray(obs)
            old = table.ratios("k").copy()
            new = table.observe("k", obs)
            assert np.all(new >= np.minimum(old, obs) - 1e-12)
            assert np.all(new <= np.maximum(old, obs) + 1e-12)
            lo, hi = np.minimum(lo, obs), np.maximum(hi, obs)
        assert np.all(table.ratios("k") >= lo - 1e-12)
        assert np.all(table.ratios("k") <= hi + 1e-12)

    check()


def test_ratio_store_json_round_trip_lossless():
    """RatioStore save -> load reproduces every table bit-exactly (json
    floats round-trip through repr) plus the learning conventions."""
    pytest.importorskip("hypothesis", reason="property test needs the dev extra")
    import os
    import tempfile

    from hypothesis import given, settings, strategies as st

    @given(st.integers(min_value=1, max_value=6),
           st.sampled_from(["mean", "sum"]),
           st.floats(min_value=0.0, max_value=1.0),
           st.data())
    @settings(max_examples=25, deadline=None)
    def check(n, normalize, alpha, data):
        keys = data.draw(st.lists(
            st.text(alphabet="abcdef/_", min_size=1, max_size=8),
            min_size=1, max_size=4, unique=True))
        table = RatioTable(n, alpha=alpha, normalize=normalize)
        for key in keys:
            values = data.draw(st.lists(
                st.floats(min_value=1e-9, max_value=1e9,
                          allow_nan=False, allow_infinity=False),
                min_size=n, max_size=n))
            table.set(key, np.asarray(values))
        with tempfile.TemporaryDirectory() as d:
            store = RatioStore(os.path.join(d, "ratios.json"))
            store.save(table)
            loaded = store.load()
        assert loaded.n_workers == n
        assert loaded.alpha == alpha
        assert loaded.normalize == normalize
        assert sorted(loaded.keys()) == sorted(table.keys())
        for key in keys:
            np.testing.assert_array_equal(loaded.ratios(key),
                                          table.ratios(key))

    check()
