"""Unit tests for the roofline machinery: HLO collective parsing with
while-trip attribution, wire factors, analytic accounting."""

import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.launch import analytic, roofline as R

HLO = """
HloModule jit_step, num_partitions=16

%add.1 (x: f32[], y: f32[]) -> f32[] {
  ROOT %a = f32[] add(%x, %y)
}

%body.1 (arg: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ar = f32[128,256]{1,0} all-reduce(%gte), replica_groups={{0,1,2,3}}, to_apply=%add.1, metadata={op_name="jit(step)/dot_general"}
  ROOT %t = (s32[], f32[128,256]) tuple(%i, %ar)
}

%cond.1 (arg: (s32[], f32[128,256])) -> pred[] {
  %c = s32[] constant(10)
  ROOT %cmp = pred[] compare(%gte0, %c), direction=LT
}

ENTRY %main.1 (p0: f32[128,256]) -> f32[128,256] {
  %ag = bf16[64,512]{1,0} all-gather(%x), replica_groups=[2,8]<=[16], dimensions={0}
  %w = (s32[], f32[128,256]) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = f32[128,256] get-tuple-element(%w), index=1
}
"""


def test_parse_collectives_with_trips():
    stats = R.parse_collectives(HLO, default_group=16)
    # all-gather outside the loop: counted once
    assert stats.ops["all-gather"] == 1
    # all-reduce inside the while: x10 trips
    assert stats.ops["all-reduce"] == 10
    ar_bytes = 128 * 256 * 4
    ag_bytes = 64 * 512 * 2
    expect = (ag_bytes * (8 - 1) / 8          # group of 8
              + 10 * ar_bytes * 2 * (4 - 1) / 4)  # ring AR, group of 4
    assert abs(stats.wire_bytes - expect) / expect < 1e-9


def test_f32_dot_artifact_halved():
    stats = R.parse_collectives(HLO, default_group=16)
    # the AR is f32 + dot metadata -> halved in the TPU-adjusted metric;
    # the bf16 AG is unchanged.
    ar_wire = 10 * 128 * 256 * 4 * 2 * 3 / 4
    ag_wire = 64 * 512 * 2 * 7 / 8
    assert abs(stats.wire_bytes_tpu - (ag_wire + ar_wire / 2)) < 1.0


def test_shape_bytes_tuple():
    assert R._shape_bytes("(f32[2,3]{1,0}, bf16[4]{0})") == 2 * 3 * 4 + 4 * 2
    assert R._shape_bytes("pred[8]") == 8


def test_group_size_formats():
    assert R._group_size("replica_groups={{0,1,2}}", 99) == 3
    assert R._group_size("replica_groups=[8,32]<=[256]", 99) == 32
    assert R._group_size("no groups here", 99) == 99


def test_wire_factors():
    assert R._wire_factor("all-reduce", 4) == pytest.approx(1.5)
    assert R._wire_factor("all-gather", 4) == pytest.approx(0.75)
    assert R._wire_factor("collective-permute", 2) == 1.0
    assert R._wire_factor("all-reduce", 1) == 0.0


def test_analytic_train_flops_close_to_6nd():
    """Dense arch: analytic fwd+bwd+remat flops ~ 8*N*D (remat => 8 not 6)
    within the attention/logits correction."""
    cfg = get_config("granite-8b")
    shape = SHAPES["train_4k"]
    cost = analytic.analyze_cell(cfg, shape, n_devices=256)
    n = cfg.param_count()
    d_tokens = shape.batch * shape.seq
    ratio = cost.flops * 256 / (8 * n * d_tokens)
    assert 0.8 < ratio < 1.6  # attention quadratic term pushes it above 1


def test_analytic_decode_memory_dominated_by_params_and_kv():
    cfg = get_config("granite-8b")
    shape = SHAPES["decode_32k"]
    cost = analytic.analyze_cell(cfg, shape, n_devices=256)
    kv = shape.batch * analytic.state_bytes_per_seq(cfg, shape.seq)
    floor = (analytic.active_param_bytes(cfg) + kv) / 256
    assert cost.hbm_bytes >= floor
    assert cost.hbm_bytes < floor * 1.5


def test_roofline_terms_and_bottleneck():
    r = R.Roofline(arch="a", shape="s", mesh="16x16",
                   flops=197e12, hbm_bytes=819e9 / 2, wire_bytes=50e9 * 2,
                   per_device_output_bytes=0, model_flops=100e12)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(0.5)
    assert r.t_collective == pytest.approx(2.0)
    assert r.bottleneck == "collective"
    assert r.roofline_fraction == pytest.approx(0.5)
