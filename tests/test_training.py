"""Training stack tests: accumulation identities, uneven DP, compression,
optimizer behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data import DataConfig, SyntheticLM
from repro.models import init_params, loss_fn
from repro.training import (
    AdamWConfig,
    adamw_update,
    init_opt_state,
    lr_at,
    local_accum,
    make_train_step,
    microbatch_grads,
    uneven_data_parallel_step,
    weighted_combine,
)
from repro.training import grad_compress as GC

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32")
KEY = jax.random.key(0)


def make_micro(n_micro, mb=2, s=16, seed=0):
    toks = jax.random.randint(jax.random.key(seed), (n_micro, mb, s), 0, 128)
    return {"tokens": toks, "labels": toks}


def test_microbatch_accumulation_equals_big_batch():
    """mean over k microbatches == one big batch (loss is token-mean with
    equal valid counts)."""
    params = init_params(CFG, KEY)
    batch = make_micro(4)
    _, g_micro, _ = microbatch_grads(CFG, params, batch)
    big = {k: v.reshape(1, 8, 16) for k, v in batch.items()}
    _, g_big, _ = microbatch_grads(CFG, params, big)
    for a, b in zip(jax.tree.leaves(g_micro), jax.tree.leaves(g_big)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_uneven_equals_even():
    """Paper's uneven DP: weighted combine of per-pod local grads equals
    the global average — regardless of the split."""
    params = init_params(CFG, KEY)
    batch = make_micro(8)
    _, g_all, _ = microbatch_grads(CFG, params, batch)

    counts = np.array([4, 2, 1, 1])
    shards, start = [], 0
    for c in counts:
        shards.append({k: v[start:start + c] for k, v in batch.items()})
        start += c
    grads_list = [local_accum(CFG, params, s)[1] for s in shards]
    g_comb = weighted_combine(grads_list, counts)
    for a, b in zip(jax.tree.leaves(g_comb), jax.tree.leaves(g_all)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_uneven_dp_step_runs_and_learns():
    params = init_params(CFG, KEY)
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=100)
    opt = init_opt_state(params)
    batch = make_micro(8, seed=3)
    shards = [{k: v[i * 2:(i + 1) * 2] for k, v in batch.items()}
              for i in range(4)]
    losses = []
    for _ in range(5):
        params, opt, loss = uneven_data_parallel_step(
            CFG, opt_cfg, params, opt, shards, np.array([2, 2, 2, 2]))
        losses.append(loss)
    assert losses[-1] < losses[0]


def test_train_step_reduces_loss():
    params = init_params(CFG, KEY)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(CFG, opt_cfg))
    data = SyntheticLM(DataConfig(vocab_size=128, seq_len=16, global_batch=8,
                                  microbatch=4))
    it = iter(data)
    losses = []
    for _ in range(20):
        b = next(it)
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3
    assert np.isfinite(losses).all()


def test_lr_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(lr_at(cfg, jnp.asarray(110))) == pytest.approx(0.1, abs=1e-3)


def test_grad_clip_applied():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}
    cfg = AdamWConfig(grad_clip=1.0, lr=1.0, warmup_steps=0)
    _, _, m = adamw_update(cfg, params, grads, init_opt_state(params))
    assert float(m["grad_norm"]) > 1.0  # reported pre-clip


def test_compression_error_feedback():
    """With error feedback, the *running sum* of decompressed gradients
    tracks the true sum (bias-free) even at int8 precision."""
    rng = np.random.default_rng(0)
    g_seq = [jnp.asarray(rng.normal(size=(64,)) * 0.01) for _ in range(50)]
    err = jnp.zeros((64,))
    acc_deq, acc_true = np.zeros(64), np.zeros(64)
    for g in g_seq:
        c, err = GC.compress(g, err)
        acc_deq += np.asarray(GC.decompress(c))
        acc_true += np.asarray(g)
    resid = np.abs(acc_deq - acc_true).max()
    scale_step = float(np.abs(acc_true).max()) / 127
    assert resid < 5 * scale_step  # bounded by O(1) quantization steps


def test_compression_tree_roundtrip_shapes():
    params = {"a": jnp.ones((8, 8)), "b": {"c": jnp.ones((4,))}}
    errs = GC.init_errors(params)
    comp, errs2 = GC.compress_tree(params, errs)
    deq = GC.decompress_tree(comp)
    assert jax.tree.structure(deq) == jax.tree.structure(params)
    np.testing.assert_allclose(np.asarray(deq["a"]), 1.0, rtol=1e-2)
