"""Drift adaptation at trunk level (paper §3.2, fig4-style, mid-serve).

The bare-loop study in ``bench_ratio_trace`` throttles a core between two
scheduler runs; these tests do it to a *serving engine in flight*: a
background-load interval lands on the simulated machine mid-serve, and the
whole stack — per-kind trunk ratio tables at kernel level, per-phase core
tables at the cost-model level, the socket-level split at topology level —
must re-converge while goodput dips boundedly rather than collapsing.
"""

import numpy as np
import jax
import pytest

from repro.configs import reduced_config
from repro.kernels import GEMV_ISA, HybridKernelDispatcher, kernel_key
from repro.models import BalancedTrunk, init_params
from repro.runtime import KernelSpec
from repro.serving import (
    DECODE,
    ContinuousBatchingEngine,
    HybridPhaseCost,
    LatencyReport,
    poisson_requests,
)
from repro.topology import TopologyDispatcher

THROTTLE = 3.0     # background slowdown factor on the victim core
FOREVER = (0.0, 1e18)

# enough decode steps per batch for the alpha=0.3 EMA to re-converge
SERVE = dict(n_requests=6, prompt_len=6, steps=10, slots=2, chunk=4)


def _serve_batch(engine, cfg, seed, start_at=0.0):
    requests = poisson_requests(
        SERVE["n_requests"], rate=100.0, vocab_size=cfg.vocab_size,
        prompt_len=SERVE["prompt_len"], max_new_tokens=SERVE["steps"],
        seed=seed)
    for r in requests:
        r.arrival_time += start_at
        engine.submit(r)
    engine.run_until_idle()
    engine.poll_finished()
    return LatencyReport.from_requests(requests, slo_ttft=5.0, slo_tpot=1.0)


def _trunk_engine(machine="ultra-125h"):
    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    disp = HybridKernelDispatcher.virtual(machine, execute=True)
    trunk = BalancedTrunk.from_params(cfg, params, disp, quant="fp32")
    cost = HybridPhaseCost(machine)
    engine = ContinuousBatchingEngine(
        cfg, params, max_slots=SERVE["slots"],
        max_seq=SERVE["prompt_len"] + SERVE["steps"] + 4,
        prefill_chunk=SERVE["chunk"], cost_model=cost, balanced_trunk=trunk)
    return engine, cfg, disp, cost


def test_per_kind_trunk_ratios_reconverge_after_midserve_throttle():
    """Throttle P0 3x mid-serve: every per-kind decode table must track the
    drop — P0's learned ratio falls by ~the throttle factor relative to its
    converged value, for each projection family independently."""
    engine, cfg, disp, cost = _trunk_engine()
    _serve_batch(engine, cfg, seed=0)
    kinds = [kernel_key(GEMV_ISA, k)
             for k in ("attn_proj", "mlp_up", "mlp_down", "head")]
    before = {k: disp.table.ratios(k).copy() for k in kinds}
    for k in kinds:  # converged tables differentiate the hybrid cores
        assert before[k].max() / before[k].min() > 1.1

    # the throttle lands on the dispatcher's machine *and* the cost
    # model's machine: kernel timing and the virtual clock see the same
    # event (each pool samples background in its own virtual time; a
    # from-zero interval covers every future task)
    disp.machine.background.append((*FOREVER, 0, THROTTLE))
    cost.machine.background.append((*FOREVER, 0, THROTTLE))
    _serve_batch(engine, cfg, seed=1, start_at=engine.now)

    for k in kinds:
        after = disp.table.ratios(k)
        others_before = np.delete(before[k], 0)
        others_after = np.delete(after, 0)
        # P0's share of the table collapses toward 1/THROTTLE of its old
        # relative standing; the other 13 cores barely move relative to
        # each other
        rel_before = before[k][0] / others_before.mean()
        rel_after = after[0] / others_after.mean()
        assert rel_after < rel_before / (THROTTLE * 0.6), k
        assert rel_after > rel_before / (THROTTLE * 1.6), k


def test_goodput_dip_is_bounded_under_midserve_throttle():
    """Losing ~2/3 of one of 14 cores' bandwidth (~6% of the pool) must
    cost single-digit throughput, not a collapse: the dynamic split stops
    waiting on the slow core within a few EMA updates."""
    engine, cfg, disp, cost = _trunk_engine()
    before = _serve_batch(engine, cfg, seed=0)
    disp.machine.background.append((*FOREVER, 0, THROTTLE))
    cost.machine.background.append((*FOREVER, 0, THROTTLE))
    after = _serve_batch(engine, cfg, seed=1, start_at=engine.now)
    assert after.throughput > 0.75 * before.throughput
    assert after.goodput >= before.goodput * 0.75
    # and the kernel-level loop kept streaming: post-throttle bandwidth
    # fraction stays within 15% of the pre-throttle steady state
    frac = disp.achieved_bandwidth_fraction()
    assert frac > 0.75


def test_decode_phase_tables_reconverge_at_cost_model_level():
    """The engine's per-phase core dispatch (HybridPhaseCost) adapts too:
    the decode-phase table drops the throttled core's ratio by ~3x."""
    engine, cfg, disp, cost = _trunk_engine()
    _serve_batch(engine, cfg, seed=0)
    before = cost.table.ratios(DECODE).copy()
    cost.machine.background.append((*FOREVER, 0, THROTTLE))
    disp.machine.background.append((*FOREVER, 0, THROTTLE))
    _serve_batch(engine, cfg, seed=1, start_at=engine.now)
    after = cost.table.ratios(DECODE)
    assert after[0] < before[0] / (THROTTLE * 0.6)


def test_engine_goodput_over_throttled_socket():
    """E2E socket drift through the real serving stack: a dual-socket node
    (one engine replica per socket behind an InflightDispatcher) gets every
    core of socket 1 throttled 2x mid-serve.  The replica-level per-phase
    split must re-converge toward socket 0 and goodput must dip boundedly
    — the engine-level twin of the bare-loop socket test below."""
    from repro.fleet import Node, NodeSpec
    from repro.models.transformer import ModelConfig
    from repro.serving import InflightDispatcher  # noqa: F401  (doc link)

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    # one slot per socket engine: decode cost is dominated by the
    # weight-streaming read (near-flat in batch size), so equal batch
    # shapes keep the tokens/s feedback a pure per-socket speed probe
    node = Node(NodeSpec("box", "2s-12900k", max_slots=1,
                         prefill_chunk=SERVE["chunk"]),
                cfg, params, max_seq=SERVE["prompt_len"] + SERVE["steps"] + 4)
    disp = node.dispatcher

    def serve(seed, start_at=0.0):
        # open loop, arrivals spread out: feedback from early requests
        # must get the chance to steer the routing of later ones (a burst
        # would be split blind, before any post-throttle window lands)
        requests = poisson_requests(
            8, rate=6.0, vocab_size=cfg.vocab_size,
            prompt_len=SERVE["prompt_len"], max_new_tokens=SERVE["steps"],
            seed=seed)
        for r in requests:
            r.arrival_time += start_at
            while disp.has_work and disp.now < r.arrival_time:
                disp.step()
            disp.submit(r)
        disp.run_until_idle()
        disp.poll_finished()
        return LatencyReport.from_requests(requests, slo_ttft=5.0,
                                           slo_tpot=1.0)

    before = serve(0)
    split_before = disp.table.ratios(DECODE).copy()
    # symmetric sockets: the converged split is near-even
    assert split_before[0] / split_before[1] == pytest.approx(1.0, abs=0.5)
    m1 = node.topology.machines[1]
    for core in range(m1.n_cores):
        m1.background.append((*FOREVER, core, 2.0))
    after = serve(1, start_at=disp.now)
    split_after = disp.table.ratios(DECODE)
    # the split re-converges toward the unthrottled socket...
    assert (split_after[0] / split_after[1]
            > 1.4 * split_before[0] / split_before[1])
    # ...and losing half of one of two sockets (~25% of the pool) costs a
    # bounded slice of goodput, not a collapse
    assert after.goodput >= 0.6 * before.goodput
    assert after.throughput >= 0.6 * before.throughput


def test_socket_level_split_adapts_to_throttled_socket():
    """Topology drift: throttling every core of socket 1 by 2x must shift
    the learned socket split toward socket 0 (~2/3 of the rows) and keep
    the outer loop's feedback consistent with the new throughputs."""
    disp = TopologyDispatcher("dual-125h")
    spec = KernelSpec("q4_gemv", isa=GEMV_ISA, granularity=8,
                      work_per_unit=4096 * 0.5625)
    for _ in range(25):
        st = disp.dispatch(spec, 4096, bytes_per_unit=4096 * 0.5625)
    counts_before = st.counts.copy()
    assert counts_before[0] / counts_before.sum() == pytest.approx(0.5,
                                                                   abs=0.05)
    m1 = disp.topology.machines[1]
    for core in range(m1.n_cores):
        m1.background.append((*FOREVER, core, 2.0))
    for _ in range(30):
        st = disp.dispatch(spec, 4096, bytes_per_unit=4096 * 0.5625)
    ratios = disp.socket_ratios(GEMV_ISA)
    assert ratios[0] / ratios[1] == pytest.approx(2.0, rel=0.2)
    assert st.counts[0] / st.counts.sum() == pytest.approx(2 / 3, rel=0.1)
