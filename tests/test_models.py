"""Model-zoo correctness: chunked forms vs references, cache consistency,
MoE dispatch vs oracle, expert permutation invariance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, XLSTMConfig
from repro.models import forward, init_params, init_state, loss_fn
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X
from repro.models.attention import attn_fwd, init_attn
from repro.models.layers import apply_rope

KEY = jax.random.key(0)


def tiny(family="dense", **kw):
    base = dict(
        name="tiny", family=family, n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


# ----------------------------------------------------------- attention ----
def test_attn_chunked_equals_direct():
    cfg = tiny(attn_chunk=8)
    cfg_direct = tiny(attn_chunk=1024)
    p = init_attn(cfg, KEY)
    x = jax.random.normal(jax.random.key(1), (2, 32, 64), jnp.float32)
    pos = jnp.arange(32)[None, :].repeat(2, 0)
    y1, _ = attn_fwd(cfg, p, x, pos)
    y2, _ = attn_fwd(cfg_direct, p, x, pos)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)


def test_attn_causality():
    """Changing a future token never changes past outputs."""
    cfg = tiny()
    p = init_attn(cfg, KEY)
    x = jax.random.normal(jax.random.key(1), (1, 16, 64), jnp.float32)
    pos = jnp.arange(16)[None, :]
    y1, _ = attn_fwd(cfg, p, x, pos)
    x2 = x.at[:, -1].add(10.0)
    y2, _ = attn_fwd(cfg, p, x2, pos)
    np.testing.assert_allclose(np.asarray(y1[:, :-1]), np.asarray(y2[:, :-1]),
                               rtol=1e-5, atol=1e-6)
    assert np.abs(np.asarray(y1[:, -1]) - np.asarray(y2[:, -1])).max() > 1e-4


def test_rope_fraction_partial():
    x = jax.random.normal(KEY, (1, 2, 8, 64))
    pos = jnp.arange(8)
    y = apply_rope(x, pos, theta=1e4, fraction=0.5)
    # untouched second half
    np.testing.assert_allclose(np.asarray(y[..., 32:]), np.asarray(x[..., 32:]))
    assert np.abs(np.asarray(y[..., :32]) - np.asarray(x[..., :32])).max() > 1e-4


def test_rope_relative_shift_invariance():
    """Attention scores depend only on relative positions."""
    q = jax.random.normal(KEY, (1, 1, 4, 64))
    k = jax.random.normal(jax.random.key(2), (1, 1, 4, 64))
    def scores(offset):
        qr = apply_rope(q, jnp.arange(4) + offset, theta=1e4)
        kr = apply_rope(k, jnp.arange(4) + offset, theta=1e4)
        return np.asarray(jnp.einsum("bhqd,bhkd->bhqk", qr, kr))
    np.testing.assert_allclose(scores(0), scores(100), rtol=2e-4, atol=1e-4)


# ----------------------------------------------------------------- MoE ----
def _moe_oracle(cfg, p, x):
    """Per-token dense loop oracle (no capacity drops)."""
    m = cfg.moe
    b, s, d = x.shape
    xf = np.asarray(x.reshape(b * s, d), dtype=np.float32)
    router = np.asarray(p["router"])
    logits = xf @ router
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = np.asarray(top_p / top_p.sum(-1, keepdims=True))
    top_e = np.asarray(top_e)
    wi, wg, wo = (np.asarray(p[k], dtype=np.float32) for k in ("wi", "wg", "wo"))
    y = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        for j in range(m.top_k):
            e = top_e[t, j]
            h = jax.nn.silu(jnp.asarray(xf[t] @ wg[e])) * (xf[t] @ wi[e])
            y[t] += top_p[t, j] * np.asarray(h @ wo[e])
    return y.reshape(b, s, d)


def test_moe_matches_oracle_no_drops():
    cfg = tiny("moe", mlp="none", moe=MoEConfig(n_experts=4, top_k=2,
                                                capacity_factor=8.0))
    p = M.init_moe(cfg, KEY)
    x = jax.random.normal(jax.random.key(3), (2, 8, 64), jnp.float32)
    y, aux = M.moe_fwd(cfg, p, x)
    assert float(aux["dropped"]) == 0.0
    np.testing.assert_allclose(np.asarray(y), _moe_oracle(cfg, p, x),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_tokens():
    cfg = tiny("moe", mlp="none",
               moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=8.0))
    p = M.init_moe(cfg, KEY)
    x = jax.random.normal(jax.random.key(3), (2, 32, 64), jnp.float32)
    y, aux = M.moe_fwd(cfg, p, x, capacity=8)  # 64 tokens*2/4 = 32 >> 8
    assert float(aux["dropped"]) > 0.1
    assert np.isfinite(np.asarray(y)).all()
    assert aux["load"].shape == (4,)


def test_moe_expert_permutation_invariant():
    cfg = tiny("moe", mlp="none",
               moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=8.0))
    p = M.init_moe(cfg, KEY)
    x = jax.random.normal(jax.random.key(4), (2, 8, 64), jnp.float32)
    y1, _ = M.moe_fwd(cfg, p, x)
    perm = M.balanced_expert_assignment(np.arange(8, dtype=float), 4)
    p2 = M.apply_expert_permutation(p, perm)
    y2, _ = M.moe_fwd(cfg, p2, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)


def test_balanced_expert_assignment_lpt():
    load = np.array([10.0, 9.0, 1.0, 2.0, 8.0, 7.0, 3.0, 4.0])
    perm = M.balanced_expert_assignment(load, 4)
    shard_loads = load[perm].reshape(4, 2).sum(-1)
    assert sorted(perm.tolist()) == list(range(8))
    # LPT on this instance is optimal: every shard carries exactly 11.
    np.testing.assert_allclose(shard_loads, 11.0)
    # vs naive contiguous placement (imbalance 19 vs 3)
    naive = load.reshape(4, 2).sum(-1)
    assert shard_loads.max() < naive.max()


# --------------------------------------------------------------- mamba ----
def test_mamba_chunked_equals_single():
    cfg = tiny("hybrid", mixer_pattern=("mamba",), ssm=SSMConfig(chunk=4))
    cfg1 = tiny("hybrid", mixer_pattern=("mamba",), ssm=SSMConfig(chunk=64))
    p = S.init_mamba(cfg, KEY)
    x = jax.random.normal(jax.random.key(5), (2, 16, 64), jnp.float32)
    y1, _ = S.mamba_fwd(cfg, p, x)
    y2, _ = S.mamba_fwd(cfg1, p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)


def test_mamba_prefill_decode_consistency():
    cfg = tiny("hybrid", mixer_pattern=("mamba",), ssm=SSMConfig(chunk=4))
    p = S.init_mamba(cfg, KEY)
    x = jax.random.normal(jax.random.key(6), (2, 9, 64), jnp.float32)
    # full pass
    y_full, _ = S.mamba_fwd(cfg, p, x)
    # prefill 8 then decode 1
    st = S.init_mamba_state(cfg, 2)
    y_pre, st = S.mamba_fwd(cfg, p, x[:, :8], st)
    y_dec, _ = S.mamba_fwd(cfg, p, x[:, 8:9], st)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, 8:9]),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- xlstm ----
def test_mlstm_chunkwise_equals_recurrent():
    b, h, t, dk, dv = 2, 3, 16, 8, 12
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, h, t, dk)) * 0.5
    k = jax.random.normal(ks[1], (b, h, t, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, h, t, dv))
    ig = jax.random.normal(ks[3], (b, h, t))
    fg = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, h, t)) + 2.0)
    st0 = (jnp.zeros((b, h, dv, dk)), jnp.zeros((b, h, dk)),
           jnp.full((b, h), X.NEG))
    h_ref, st_ref = X.mlstm_recurrent_reference(q, k, v, ig, fg, st0)
    h_chunk, st_chunk = X._mlstm_chunk(q, k, v, ig, fg, st0)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)
    for a, b_ in zip(st_chunk, st_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)


def test_mlstm_fwd_chunked_equals_single():
    cfg = tiny("ssm", n_heads=2, n_kv_heads=2, mlp="none",
               mixer_pattern=("mlstm",), xlstm=XLSTMConfig(chunk=4))
    cfg1 = tiny("ssm", n_heads=2, n_kv_heads=2, mlp="none",
                mixer_pattern=("mlstm",), xlstm=XLSTMConfig(chunk=64))
    p = X.init_mlstm(cfg, KEY)
    x = jax.random.normal(jax.random.key(7), (2, 16, 64), jnp.float32)
    y1, _ = X.mlstm_fwd(cfg, p, x)
    y2, _ = X.mlstm_fwd(cfg1, p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)


def test_mlstm_prefill_decode_consistency():
    cfg = tiny("ssm", n_heads=2, n_kv_heads=2, mlp="none",
               mixer_pattern=("mlstm",), xlstm=XLSTMConfig(chunk=4))
    p = X.init_mlstm(cfg, KEY)
    x = jax.random.normal(jax.random.key(8), (2, 9, 64), jnp.float32)
    y_full, _ = X.mlstm_fwd(cfg, p, x)
    st = X.init_mlstm_state(cfg, 2)
    _, st = X.mlstm_fwd(cfg, p, x[:, :8], st)
    y_dec, _ = X.mlstm_fwd(cfg, p, x[:, 8:9], st)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, 8:9]),
                               rtol=1e-4, atol=1e-4)


def test_slstm_chunked_and_decode():
    cfg = tiny("ssm", n_heads=2, n_kv_heads=2, mlp="none",
               mixer_pattern=("slstm",), xlstm=XLSTMConfig(chunk=4))
    p = X.init_slstm(cfg, KEY)
    x = jax.random.normal(jax.random.key(9), (2, 12, 64), jnp.float32)
    y_full, _ = X.slstm_fwd(cfg, p, x)
    cfg1 = tiny("ssm", n_heads=2, n_kv_heads=2, mlp="none",
                mixer_pattern=("slstm",), xlstm=XLSTMConfig(chunk=64))
    y_one, _ = X.slstm_fwd(cfg1, p, x)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_one),
                               rtol=1e-5, atol=1e-5)
    st = X.init_slstm_state(cfg, 2)
    _, st = X.slstm_fwd(cfg, p, x[:, :8], st)
    y_dec, _ = X.slstm_fwd(cfg, p, x[:, 8:9], st)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, 8:9]),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- trunk ----
@pytest.mark.parametrize("pattern,extra", [
    (("attn",), {}),
    (("mamba", "mamba", "mamba", "attn"), {"ssm": SSMConfig(chunk=4)}),
    (("mlstm", "slstm"), {"mlp": "none", "xlstm": XLSTMConfig(chunk=4),
                          "n_heads": 2, "n_kv_heads": 2}),
])
def test_trunk_prefill_decode_matches_full(pattern, extra):
    cfg = tiny("dense", n_layers=len(pattern) * 2, mixer_pattern=pattern, **extra)
    p = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.key(10), (2, 9), 0, 256)
    full = forward(cfg, p, toks)
    st = init_state(cfg, 2, 16)
    pre = forward(cfg, p, toks[:, :8], state=st, pos_offset=0)
    dec = forward(cfg, p, toks[:, 8:9], state=pre.state, pos_offset=8)
    np.testing.assert_allclose(np.asarray(dec.logits[:, -1]),
                               np.asarray(full.logits[:, -1]),
                               rtol=5e-3, atol=5e-3)


def test_loss_decreases_with_sgd():
    cfg = tiny(n_layers=2)
    p = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.key(11), (4, 16), 0, 256)
    batch = {"tokens": toks, "labels": toks}

    @jax.jit
    def step(p):
        (l, m), g = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(p)
        p = jax.tree.map(lambda w, gw: w - 0.5 * gw.astype(w.dtype), p, g)
        return p, l

    losses = []
    for _ in range(10):
        p, l = step(p)
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.5
