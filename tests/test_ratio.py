"""Property tests (hypothesis) for the paper's Eq. 1-3 + EMA filter."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the dev extra")
from hypothesis import given, settings, strategies as st

from repro.core import ratio as R

ratios_strategy = st.lists(
    st.floats(min_value=0.05, max_value=100.0, allow_nan=False),
    min_size=1, max_size=32,
).map(np.array)


@given(ratios_strategy)
def test_optimal_shares_normalized(pr):
    shares = R.optimal_shares(pr)
    assert shares.shape == pr.shape
    assert abs(shares.sum() - 1.0) < 1e-9
    assert np.all(shares >= 0)


@given(ratios_strategy, st.integers(min_value=0, max_value=100_000),
       st.integers(min_value=1, max_value=64))
def test_partition_sums_and_granularity(pr, s, g):
    counts = R.proportional_partition(s, pr, g)
    assert counts.sum() == s
    assert np.all(counts >= 0)
    # All but the fastest worker receive exact tile multiples.
    fastest = int(np.argmax(pr))
    for i, c in enumerate(counts):
        if i != fastest:
            assert c % g == 0


@given(ratios_strategy, st.integers(min_value=1, max_value=1_000_000))
def test_partition_proportionality(pr, s):
    """Integer counts are within one granule of the ideal share."""
    counts = R.proportional_partition(s, pr, 1)
    ideal = R.optimal_shares(pr) * s
    assert np.all(np.abs(counts - ideal) <= len(pr))


@given(ratios_strategy)
def test_observed_ratios_fixpoint(pr):
    """Equal times => ratios proportional to previous table (scale-invariant
    fixpoint of Eq. 2)."""
    times = np.ones_like(pr)
    new = R.observed_ratios(pr, times, normalize="mean")
    np.testing.assert_allclose(
        new / new.sum(), pr / pr.sum(), rtol=1e-9, atol=1e-12
    )
    assert abs(new.sum() - len(pr)) < 1e-6  # mean-normalized


@given(ratios_strategy)
def test_observed_ratios_sum_normalization(pr):
    new = R.observed_ratios(pr, np.ones_like(pr), normalize="sum")
    assert abs(new.sum() - 1.0) < 1e-9


def test_observed_ratios_recovers_truth():
    """If work was assigned ∝ pr and true speeds are tp, one exact update
    recovers tp (up to scale): t_i = pr_i/tp_i => pr'_i ∝ tp_i."""
    pr = np.array([1.0, 1.0, 1.0, 1.0])
    tp = np.array([4.0, 2.0, 1.0, 1.0])  # true throughputs
    times = (pr / pr.sum()) / tp  # time for proportional share
    new = R.observed_ratios(pr, times)
    np.testing.assert_allclose(new / new.sum(), tp / tp.sum(), rtol=1e-9)


def test_idle_worker_keeps_ratio():
    pr = np.array([3.0, 1.0, 2.0])
    times = np.array([0.5, 0.0, 0.4])  # worker 1 got no work
    new = R.observed_ratios(pr, times)
    # worker 1 carried over unchanged
    assert new[1] == pr[1]


@given(ratios_strategy, st.floats(min_value=0.0, max_value=1.0))
def test_ema_bounds(pr, alpha):
    new = pr * 2.0
    out = R.ema_update(pr, new, alpha)
    assert np.all(out >= np.minimum(pr, new) - 1e-12)
    assert np.all(out <= np.maximum(pr, new) + 1e-12)


def test_ema_paper_alpha():
    out = R.ema_update(np.array([5.0]), np.array([3.0]), alpha=0.3)
    np.testing.assert_allclose(out, [0.3 * 5 + 0.7 * 3])


@given(st.integers(min_value=2, max_value=16), st.integers(min_value=1, max_value=40))
def test_update_converges_to_truth(n, seed):
    """Iterating (partition ∝ pr) -> (observe true times) -> Eq.2+EMA drives
    pr to the true relative throughput — the paper's Fig. 4 behaviour."""
    rng = np.random.default_rng(seed)
    tp = rng.uniform(0.5, 8.0, size=n)
    pr = np.full(n, 5.0)  # paper's "initially set at 5"
    for _ in range(60):
        shares = R.optimal_shares(pr)
        times = shares / tp
        pr = R.ema_update(pr, R.observed_ratios(pr, times), alpha=0.3)
    np.testing.assert_allclose(pr / pr.sum(), tp / tp.sum(), rtol=5e-3)


def test_makespan_optimality_of_eq3():
    """Eq. 1: proportional shares minimize makespan vs any random split."""
    rng = np.random.default_rng(0)
    tp = np.array([4.0, 4.0, 1.0, 1.0])
    s = 10_000
    opt = R.proportional_partition(s, tp)
    t_opt = R.makespan(opt, tp)
    for _ in range(200):
        w = rng.dirichlet(np.ones(4))
        counts = np.round(w * s).astype(int)
        counts[-1] += s - counts.sum()
        if np.any(counts < 0):
            continue
        assert R.makespan(counts, tp) >= t_opt - 1e-9


def test_partition_degenerate_zero_ratios():
    counts = R.proportional_partition(100, np.zeros(4))
    assert counts.sum() == 100


def test_partition_more_workers_than_tiles():
    counts = R.proportional_partition(2, np.ones(8), granularity=1)
    assert counts.sum() == 2
    assert (counts > 0).sum() == 2
