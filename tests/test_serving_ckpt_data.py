"""Serving engine, checkpoint, and data-pipeline tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import all_steps, latest_step, restore, save
from repro.configs.base import ModelConfig
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.models import forward, init_params
from repro.serving import RoutedServer, ServeEngine
from repro.training import AdamWConfig, init_opt_state

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32")
KEY = jax.random.key(0)


# --------------------------------------------------------------- serving --
def test_serve_engine_matches_full_forward():
    params = init_params(CFG, KEY)
    eng = ServeEngine(CFG, params, batch_size=2, max_seq=32)
    prompts = jax.random.randint(jax.random.key(1), (2, 8), 0, 128)
    r = eng.generate(prompts, n_steps=4)
    assert r.tokens.shape == (2, 12)
    # greedy decode must equal argmax of the uncached full forward
    full = forward(CFG, params, jnp.asarray(r.tokens[:, :-1]))
    expect_last = np.asarray(jnp.argmax(full.logits[:, -1], -1))
    np.testing.assert_array_equal(r.tokens[:, -1], expect_last)


def test_routed_server_adapts_to_slow_replica():
    params = init_params(CFG, KEY)
    engines = [ServeEngine(CFG, params, batch_size=8, max_seq=16)
               for _ in range(2)]
    srv = RoutedServer(engines)
    prompts = np.random.default_rng(0).integers(0, 128, size=(8, 4),
                                                dtype=np.int32)
    # replica 1 is 3x slower: simulate time_i = counts_i / speed_i
    speeds = np.array([3.0, 1.0])
    for _ in range(6):
        planned = srv.router.split(8)
        out, counts, _ = srv.serve_batch(
            prompts, n_steps=2,
            times_override=np.maximum(planned, 1e-3) / speeds)
    counts = srv.router.split(8)
    assert counts[0] >= 5  # ~3:1 split
    assert counts.sum() == 8
    assert out.shape[0] == 8


def test_routed_server_clamps_split_to_replica_capacity():
    """A replica's proportional share can exceed its static batch size; the
    overflow must be redistributed instead of crashing the pad path."""
    params = init_params(CFG, KEY)
    engines = [ServeEngine(CFG, params, batch_size=4, max_seq=16)
               for _ in range(2)]
    srv = RoutedServer(engines)
    # Make replica 0 look 7x faster: the raw Eq.-3 split of 8 would be
    # [7, 1], over replica 0's capacity of 4.
    srv.runtime.set("serve_step", np.array([7.0, 1.0]))
    prompts = np.random.default_rng(1).integers(0, 128, size=(8, 4),
                                                dtype=np.int32)
    out, counts, _ = srv.serve_batch(prompts, n_steps=2)
    assert counts.sum() == 8
    assert np.all(counts <= 4)
    assert out.shape[0] == 8
    # ...but a batch beyond aggregate capacity is a real error
    big = np.zeros((9, 4), dtype=np.int32)
    with pytest.raises(ValueError):
        srv.serve_batch(big, n_steps=1)


def test_routed_server_empty_batch():
    params = init_params(CFG, KEY)
    engines = [ServeEngine(CFG, params, batch_size=2, max_seq=16)]
    srv = RoutedServer(engines)
    out, counts, times = srv.serve_batch(
        np.zeros((0, 4), dtype=np.int32), n_steps=3)
    assert out.shape == (0, 7)
    assert counts.sum() == 0 and times.sum() == 0.0


# ------------------------------------------------------------ checkpoint --
def test_checkpoint_roundtrip_and_resume(tmp_path):
    params = init_params(CFG, KEY)
    opt = init_opt_state(params)
    d = str(tmp_path / "ckpt")
    save(d, 10, {"params": params, "opt": opt}, extra={"data_step": 10})
    save(d, 20, {"params": params, "opt": opt}, extra={"data_step": 20})
    assert latest_step(d) == 20
    template = jax.eval_shape(lambda: {"params": init_params(CFG, KEY),
                                       "opt": init_opt_state(params)})
    tree, meta = restore(d, 20, template)
    assert meta["extra"]["data_step"] == 20
    for a, b in zip(jax.tree.leaves(tree["params"]), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_last(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in range(5):
        save(d, s, {"x": jnp.ones((2,))}, keep_last=2)
    assert all_steps(d) == [3, 4]


def test_checkpoint_atomic_no_partial(tmp_path):
    """A leftover .tmp dir from a crashed writer is never listed."""
    d = str(tmp_path / "ckpt")
    save(d, 1, {"x": jnp.ones((2,))})
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert all_steps(d) == [1]


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto a different sharding layout (elastic scaling)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ckpt")
    x = jnp.arange(16, dtype=jnp.float32).reshape(4, 4)
    save(d, 1, {"x": x})
    mesh = jax.make_mesh((1,), ("data",))
    shard = NamedSharding(mesh, P("data", None))
    tree, _ = restore(d, 1, jax.eval_shape(lambda: {"x": x}),
                      shardings={"x": shard})
    np.testing.assert_array_equal(np.asarray(tree["x"]), np.asarray(x))
    assert tree["x"].sharding == shard


# ----------------------------------------------------------------- data ---
def test_data_deterministic_and_restartable():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8, microbatch=4)
    a = SyntheticLM(cfg)
    b = SyntheticLM(cfg)
    b.seek(0)
    x1 = next(iter(a))
    x2 = next(iter(b))
    np.testing.assert_array_equal(x1["tokens"], x2["tokens"])
    # restart mid-stream
    it = iter(a)  # a.step is now 1
    y2 = next(it)
    c = SyntheticLM(cfg)
    c.seek(1)
    y2c = next(iter(c))
    np.testing.assert_array_equal(y2["tokens"], y2c["tokens"])


def test_data_host_sharding_disjoint():
    kw = dict(vocab_size=128, seq_len=8, global_batch=8, microbatch=2, n_hosts=2)
    h0 = next(iter(SyntheticLM(DataConfig(host_id=0, **kw))))
    h1 = next(iter(SyntheticLM(DataConfig(host_id=1, **kw))))
    assert h0["tokens"].shape == (2, 2, 8)  # 4 rows per host
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_data_labels_shifted():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4, microbatch=4)
    b = next(iter(SyntheticLM(cfg)))
    np.testing.assert_array_equal(b["labels"][..., :-1], b["tokens"][..., 1:])
    assert (b["labels"][..., -1] == -100).all()


def test_prefetcher():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=4, microbatch=4)
    pf = Prefetcher(iter(SyntheticLM(cfg)), depth=2)
    xs = [next(pf) for _ in range(3)]
    assert len(xs) == 3
    pf.close()
