"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness (assignment deliverable f).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, EXTRA_ARCHS, get_config, reduced_config
from repro.models import forward, init_params, init_state, loss_fn
from repro.models.modality import audio_frame_stub, vlm_prefix_stub

KEY = jax.random.key(0)
B, S = 2, 16


def make_batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.embed_input:  # audio stub: precomputed frame embeddings
        batch["embeds"] = audio_frame_stub(cfg, B, S, ks[0])
        batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    else:
        toks = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
        batch["tokens"] = toks
        batch["labels"] = toks
        if cfg.n_prefix:  # vlm stub: patch embeddings, no loss on prefix
            batch["prefix_embeds"] = vlm_prefix_stub(cfg, B, ks[2])
    return batch


@pytest.mark.parametrize("arch", ARCHS + EXTRA_ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    # exact full config must at least construct and report sane plans
    full = get_config(arch)
    assert full.n_layers % len(full.period()) == 0
    assert full.param_count() > 0

    params = init_params(cfg, KEY)
    batch = make_batch(cfg, jax.random.key(1))

    out = forward(cfg, params, batch.get("tokens"),
                  embeds=batch.get("embeds"),
                  prefix_embeds=batch.get("prefix_embeds"))
    exp_s = S + (cfg.n_prefix if cfg.n_prefix else 0)
    assert out.logits.shape == (B, exp_s, cfg.vocab_size)
    assert bool(jnp.isfinite(out.logits).all()), "NaN/inf in logits"

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), "non-finite grads"
    assert any(float(jnp.abs(g).max()) > 0 for g in flat), "all-zero grads"


@pytest.mark.parametrize("arch", ["granite-8b", "jamba-1.5-large-398b",
                                  "xlstm-1.3b", "musicgen-medium",
                                  "internvl2-26b"])
def test_reduced_decode_step(arch):
    """Prefill + one decode step on the reduced config (serve path)."""
    cfg = reduced_config(arch)
    params = init_params(cfg, KEY)
    st = init_state(cfg, B, S + 4)
    if cfg.embed_input:
        emb = audio_frame_stub(cfg, B, S)
        pre = forward(cfg, params, embeds=emb, state=st)
        step_in = dict(embeds=audio_frame_stub(cfg, B, 1, jax.random.key(9)))
    else:
        toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
        pre = forward(cfg, params, toks, state=st)
        nxt = jnp.argmax(pre.logits[:, -1:], -1)
        step_in = dict(tokens=nxt)
    dec = forward(cfg, params, step_in.get("tokens"),
                  embeds=step_in.get("embeds"), state=pre.state, pos_offset=S)
    assert dec.logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(dec.logits).all())


def test_all_full_configs_match_assignment():
    """Exact published numbers from the assignment table."""
    expect = {
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 49155),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 202048),
        "granite-8b": (36, 4096, 32, 8, 49152),
        "chatglm3-6b": (28, 4096, 32, 2, 65024),
        "starcoder2-15b": (40, 6144, 48, 4, 49152),
        "olmo-1b": (16, 2048, 16, 16, 50304),
        "xlstm-1.3b": (48, 2048, 4, 4, 50304),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 65536),
        "internvl2-26b": (48, 6144, 48, 8, 92553),
        "musicgen-medium": (48, 1536, 24, 24, 2048),
    }
    for arch, (nl, d, h, kv, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.vocab_size) == (nl, d, h, kv, v), arch
    # MoE structure
    assert get_config("granite-moe-1b-a400m").moe.n_experts == 32
    assert get_config("granite-moe-1b-a400m").moe.top_k == 8
    assert get_config("llama4-maverick-400b-a17b").moe.n_experts == 128
    assert get_config("llama4-maverick-400b-a17b").moe.top_k == 1
    assert get_config("jamba-1.5-large-398b").moe.n_experts == 16
    assert get_config("jamba-1.5-large-398b").moe.top_k == 2
    # hybrid interleave 1:7
    jamba = get_config("jamba-1.5-large-398b")
    mixers = [m for m, _ in jamba.layer_plan()]
    assert mixers.count("attn") * 8 == len(mixers)
    # param-count sanity vs advertised sizes (rough band)
    assert 350e9 < get_config("llama4-maverick-400b-a17b").param_count() < 450e9
    assert 330e9 < get_config("jamba-1.5-large-398b").param_count() < 450e9
    assert 6e9 < get_config("granite-8b").param_count() < 9e9
    assert 1.0e9 < get_config("xlstm-1.3b").param_count() < 2.0e9
    # MoE active params
    mav = get_config("llama4-maverick-400b-a17b")
    assert 12e9 < mav.active_param_count() < 25e9
