"""Elastic capacity (ISSUE 9): cores and sockets that come and go
mid-serve, and the re-plan path through every layer.

* the machine model's :class:`~repro.core.CapacityEvent` schedule — park
  and frequency-scale windows on the virtual clock, integrated exactly by
  ``task_wall_time``, observable via ``active_mask`` (unlike the
  ``background`` throttle list, which planners must *learn* around);
* masked planning: :class:`~repro.runtime.ProportionalPolicy.active`
  probes zero out parked workers while the full-width
  :class:`~repro.runtime.RatioTable` carries their learned ratios;
* dispatcher masks at both levels (core
  :class:`~repro.kernels.dispatch.HybridKernelDispatcher`, socket
  :class:`~repro.topology.TopologyDispatcher`) and the per-phase probes
  inside :class:`~repro.serving.HybridPhaseCost`;
* the engine's soft ``slot_budget`` and
  :meth:`repro.fleet.Node.replan_capacity` (partial park -> smaller
  budget, full park -> frozen replica + requeued waiting work);
* the satellite bugfixes: :meth:`OffsetSnapshot.refresh` atomic commit,
  :meth:`InflightDispatcher.submit` deferring instead of crashing when
  every replica is inactive, and :meth:`RatioStore.load_into` masked
  projection onto the same machine's full-width table.
"""

import jax
import numpy as np
import pytest

from repro.core import CapacityEvent, make_machine
from repro.fleet import Node, NodeSpec
from repro.kernels.dispatch import (
    GEMV_ISA,
    HybridKernelDispatcher,
    KernelSpec,
)
from repro.models import init_params
from repro.models.transformer import ModelConfig
from repro.runtime import (
    Balancer,
    OffsetSnapshot,
    OffsetSpec,
    ProportionalPolicy,
    RatioStore,
    RatioTable,
)
from repro.serving import (
    ContinuousBatchingEngine,
    HybridPhaseCost,
    InflightDispatcher,
    LinearPhaseCost,
    Request,
)
from repro.topology import TopologyDispatcher, make_topology

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32")


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.key(0))


def _requests(n, *, arrival=0.0, prompt=6, new=4):
    return [Request(prompt=np.arange(1, prompt + 1) % CFG.vocab_size,
                    max_new_tokens=new,
                    arrival_time=arrival + 1e-3 * i) for i in range(n)]


# ------------------------------------------------------ capacity events --
class TestCapacityEvents:
    def test_event_validation(self):
        with pytest.raises(ValueError):
            CapacityEvent(0.0, 1.0, 0, kind="nap")
        with pytest.raises(ValueError):
            CapacityEvent(0.0, 1.0, 0, kind="scale", factor=0.0)

    def test_active_mask_window(self):
        m = make_machine("ultra-125h")
        m.park(2, 1.0, 2.0)
        assert m.active_mask(0.5).all()
        assert not m.active_mask(1.0)[2]
        assert not m.active_mask(1.5)[2]
        assert m.active_mask(2.0).all()          # [t_start, t_end)

    def test_scale_does_not_deactivate(self):
        m = make_machine("ultra-125h")
        m.set_freq_scale(0, 2.0, 0.0, 10.0)
        assert m.active_mask(5.0).all()
        assert m.capacity_slowdown(0, 5.0) == pytest.approx(2.0)

    def test_unpark_keeps_scale_events(self):
        m = make_machine("ultra-125h")
        m.park(0)
        m.set_freq_scale(0, 3.0, 0.0, 10.0)
        m.unpark(0)
        assert m.active_mask(1.0)[0]
        assert m.capacity_slowdown(0, 1.0) == pytest.approx(3.0)
        m.clear_capacity()
        assert m.capacity_slowdown(0, 1.0) == pytest.approx(1.0)

    def test_task_wall_time_integrates_park_window(self):
        m = make_machine("homogeneous-8")
        m.park(0, t_start=1.0)                   # parks forever at t=1
        # 2.0 base-seconds from t=0: 1.0 runs clean, the remaining 1.0
        # crawls at park_slowdown on the time-sliced sibling
        wall = m.task_wall_time(0, 0.0, 2.0)
        assert wall == pytest.approx(1.0 + 1.0 * m.park_slowdown)

    def test_task_wall_time_scale_window_mid_task(self):
        m = make_machine("homogeneous-8")
        m.set_freq_scale(0, 2.0, 1.0, 2.0)
        # 1s clean + the [1,2) window executes 0.5 base + 0.5 clean after
        assert m.task_wall_time(0, 0.0, 2.0) == pytest.approx(2.5)

    def test_infinite_park_still_terminates(self):
        m = make_machine("homogeneous-8")
        m.park(3)                                # [0, inf)
        wall = m.task_wall_time(3, 0.0, 1.0)
        assert np.isfinite(wall)
        assert wall == pytest.approx(m.park_slowdown)

    def test_background_and_capacity_compose(self):
        m = make_machine("homogeneous-8")
        m.background.append((0.0, 10.0, 0, 2.0))
        m.set_freq_scale(0, 3.0, 0.0, 10.0)
        assert m._slowdown(0, 5.0) == pytest.approx(6.0)


# ------------------------------------------------------- masked planning --
class TestMaskedPolicy:
    def test_masked_plan_zeroes_parked_workers(self):
        table = RatioTable(4)
        table.set("k", [2.0, 1.0, 1.0, 1.0])
        mask = np.array([True, True, False, True])
        pol = ProportionalPolicy(table, key="k", min_per_worker=1,
                                 active=lambda: mask)
        counts = pol.plan(32).counts
        assert counts[2] == 0
        assert counts.sum() == 32
        assert (counts[[0, 1, 3]] >= 1).all()

    def test_all_false_mask_degenerates_to_unmasked(self):
        table = RatioTable(4)
        pol = ProportionalPolicy(table, key="k", min_per_worker=1,
                                 active=lambda: np.zeros(4, dtype=bool))
        counts = pol.plan(8).counts
        assert counts.sum() == 8
        assert (counts >= 1).all()               # nothing else to run on

    def test_masked_floor_validation(self):
        table = RatioTable(4)
        pol = ProportionalPolicy(table, key="k", min_per_worker=2,
                                 active=lambda: np.array([1, 1, 0, 1], bool))
        with pytest.raises(ValueError):
            pol.plan(5)                          # floor is 2 * 3 active
        assert pol.plan(6).counts.sum() == 6

    def test_bad_mask_shape_raises(self):
        table = RatioTable(4)
        pol = ProportionalPolicy(table, key="k",
                                 active=lambda: np.ones(3, dtype=bool))
        with pytest.raises(ValueError):
            pol.plan(8)

    def test_parked_worker_keeps_learned_ratio_through_feedback(self):
        table = RatioTable(4, alpha=0.5)
        table.set("k", [2.0, 1.0, 0.5, 0.5])
        parked_before = float(table.ratios("k")[2])
        mask = np.array([True, True, False, True])
        bal = Balancer(ProportionalPolicy(table, key="k", feedback="units",
                                          active=lambda: mask))
        for _ in range(4):
            plan = bal.plan(64)
            assert plan.counts[2] == 0
            # equal shard times => the active workers' ratios even out,
            # the parked worker's entry must ride along unchanged
            bal.report(plan, np.where(plan.counts > 0, 0.1, 0.0))
        after = table.ratios("k")
        assert after[2] == pytest.approx(parked_before, rel=0.35)
        assert after[2] > 0


# ----------------------------------------------------- dispatcher masks --
class TestDispatcherMasks:
    SPEC = KernelSpec(name="q4_gemv", isa=GEMV_ISA, granularity=1,
                      work_per_unit=4096.0)

    def test_set_active_masks_plans(self):
        d = HybridKernelDispatcher.virtual("ultra-125h")
        d.set_active(3, False)
        assert not d.capacity_mask()[3]
        st = d.dispatch(self.SPEC, 64)
        assert st.counts[3] == 0
        assert st.counts.sum() == 64
        d.set_active(3, True)
        assert d.capacity_mask().all()
        with pytest.raises(IndexError):
            d.set_active(99, False)

    def test_machine_park_visible_through_capacity_mask(self):
        d = HybridKernelDispatcher.virtual("ultra-125h")
        d.dispatch(self.SPEC, 32)                # creates the ISA pool
        d.machine.park(1)                        # [0, inf): every timeline
        assert not d.capacity_mask()[1]
        st = d.dispatch(self.SPEC, 64)
        assert st.counts[1] == 0
        d.machine.unpark(1)
        assert d.capacity_mask().all()

    def test_socket_mask_and_masked_two_level_dispatch(self):
        topo = make_topology("2s-12900k")
        td = TopologyDispatcher(topo)
        assert td.socket_mask().tolist() == [True, True]
        for c in range(topo.machines[1].n_cores):
            topo.machines[1].park(c)
        assert td.socket_mask().tolist() == [True, False]
        st = td.dispatch(self.SPEC, 256)
        assert st.counts.sum() == 256
        # second-level check: socket 1 executed nothing
        s1 = td.socket_dispatchers[1]
        assert s1.achieved_bandwidth(GEMV_ISA) == 0.0

    def test_topology_park_socket_roundtrip(self):
        topo = make_topology("2s-12900k")
        full = topo.active_bandwidth(0.0)
        topo.park_socket(1)
        assert topo.active_mask(0.0).sum() == topo.machines[0].n_cores
        assert topo.active_bandwidth(0.0) == pytest.approx(full / 2, rel=0.2)
        topo.unpark_socket(1)
        assert topo.active_mask(0.0).all()
        assert topo.active_bandwidth(0.0) == pytest.approx(full)

    def test_park_core_routes_global_index(self):
        topo = make_topology("2s-12900k")
        n0 = topo.machines[0].n_cores
        topo.park_core(n0 + 2)                   # third core of socket 1
        assert not topo.machines[1].active_mask(0.0)[2]
        assert topo.machines[0].active_mask(0.0).all()
        topo.unpark_core(n0 + 2)
        assert topo.active_mask(0.0).all()


# ------------------------------------------------- phase cost re-planning --
class TestPhaseCostElastic:
    def test_dynamic_masks_parked_cores_static_stalls(self):
        dyn = HybridPhaseCost("ultra-125h", dynamic=True)
        sta = HybridPhaseCost("ultra-125h", dynamic=False)
        for cost in (dyn, sta):
            cost.decode_seconds(1, 0)            # warm the ratio loop
            n = cost.machine.n_cores
            for c in range(n // 2, n):
                cost.machine.park(c)
        t_dyn = dyn.decode_seconds(1, 0)
        t_sta = sta.decode_seconds(1, 0)
        # static hands the parked cores equal shares and waits for the
        # park_slowdown crawl; dynamic re-plans onto the active half
        assert t_sta > 4 * t_dyn

    def test_parked_ratio_survives_unpark(self):
        cost = HybridPhaseCost("ultra-125h", dynamic=True)
        for _ in range(3):
            cost.decode_seconds(2, 4)
        before = cost.ratios("decode").copy()
        n = cost.machine.n_cores
        for c in range(n // 2, n):
            cost.machine.park(c)
        for _ in range(3):
            cost.decode_seconds(2, 4)
        parked = cost.ratios("decode")[n // 2:]
        assert (parked > 0).all()                # carried, not zeroed
        for c in range(n // 2, n):
            cost.machine.unpark(c)
        cost.decode_seconds(2, 4)
        assert cost.ratios("decode").shape == before.shape


# --------------------------------------------------- engine slot budget --
class TestSlotBudget:
    def test_budget_clamps(self, params):
        eng = ContinuousBatchingEngine(CFG, params, max_slots=4, max_seq=16,
                                       cost_model=LinearPhaseCost())
        assert eng.set_slot_budget(0) == 1       # 0 would wedge the queue
        assert eng.set_slot_budget(99) == 4
        assert eng.set_slot_budget(2) == 2

    def test_budget_caps_admission_without_evicting(self, params):
        eng = ContinuousBatchingEngine(CFG, params, max_slots=4, max_seq=16,
                                       prefill_chunk=8,
                                       cost_model=LinearPhaseCost())
        for r in _requests(6):
            eng.submit(r)
        eng.set_slot_budget(2)
        for _ in range(6):
            eng.step()
            assert eng.manager.n_active <= 2
        eng.set_slot_budget(4)
        eng.run_until_idle()
        assert all(r.finish_time is not None for r in eng.finished)


# ------------------------------------------------------ node re-planning --
class TestNodeReplan:
    def test_partial_park_shrinks_slot_budget(self, params):
        node = Node(NodeSpec("n0", "2s-12900k", max_slots=4), CFG, params,
                    max_seq=16)
        node.topology.park_core(0)
        node.topology.park_core(1)               # 2 of 16 on socket 0
        node.replan_capacity()
        assert node.engines[0].slot_budget == round(4 * 14 / 16)
        assert node.engines[1].slot_budget == 4
        assert node.dispatcher.active.all()

    def test_full_socket_park_freezes_and_resumes(self, params):
        node = Node(NodeSpec("n0", "2s-12900k", max_slots=2), CFG, params,
                    max_seq=16)
        for r in _requests(8):
            node.submit(r)
        for _ in range(2):
            node.step()
        full_cap = node.topology.active_bandwidth(0.0)
        node.topology.park_socket(1)
        node.replan_capacity()
        assert not node.dispatcher.active[1]
        assert node.nominal_capacity < full_cap
        # the live socket keeps serving while socket 1 is frozen
        for _ in range(4):
            node.step()
        node.topology.unpark_socket(1)
        node.replan_capacity()
        assert node.dispatcher.active[1]
        assert node.engines[1].slot_budget == 2
        while node.has_work:
            node.step()
        done = node.poll_finished()
        assert len(done) == 8
        # park freezes, never aborts: every request generated its tokens
        assert all(r.n_generated == r.max_new_tokens for r in done)

    def test_all_sockets_parked_defers_to_pending(self, params):
        node = Node(NodeSpec("n0", "2s-12900k", max_slots=2), CFG, params,
                    max_seq=16)
        node.topology.park_socket(0)
        node.topology.park_socket(1)
        node.replan_capacity()
        assert not node.dispatcher.active.any()
        i, slot = node.submit(_requests(1)[0])
        assert i == -1                           # deferred, not a crash
        assert len(node.dispatcher.pending) == 1
        node.topology.unpark_socket(0)
        node.topology.unpark_socket(1)
        node.replan_capacity()                   # reactivation flushes
        assert not node.dispatcher.pending
        while node.has_work:
            node.step()
        assert len(node.poll_finished()) == 1


# ------------------------------------ InflightDispatcher pending queue --
class TestDispatcherPending:
    def _disp(self, params, n=2):
        engines = [ContinuousBatchingEngine(CFG, params, max_slots=2,
                                            max_seq=16,
                                            cost_model=LinearPhaseCost())
                   for _ in range(n)]
        return InflightDispatcher(engines)

    def test_submit_with_all_replicas_inactive_defers(self, params):
        disp = self._disp(params)
        disp.set_active(0, False)
        disp.set_active(1, False)
        rs = _requests(3)
        for r in rs:
            i, slot = disp.submit(r)
            assert i == -1 and slot is None
        assert disp.pending == rs
        assert not disp.has_work                 # stepping cannot progress
        disp.set_active(1, True)                 # first recovery flushes
        assert not disp.pending
        assert disp.has_work
        while disp.has_work:
            disp.step()
        assert len(disp.poll_finished()) == 3

    def test_flush_preserves_arrival_order(self, params):
        disp = self._disp(params)
        disp.set_active(0, False)
        disp.set_active(1, False)
        rs = _requests(4)
        for r in rs:
            disp.submit(r)
        disp.set_active(0, True)
        waiting = [r for e in disp.engines for r in e.outstanding()]
        assert [r.arrival_time for r in waiting] == sorted(
            r.arrival_time for r in rs)


# ------------------------------------------- OffsetSnapshot atomic commit --
class TestAtomicRefresh:
    def test_failed_refresh_leaves_consistent_snapshot(self):
        plans = {"a": np.array([3, 5]), "b": np.array([6, 6])}
        broken = {"flag": False}

        def plan(spec):
            if broken["flag"] and spec.name == "b":
                raise RuntimeError("planner died mid-refresh")
            return plans[spec.name]

        snap = OffsetSnapshot(plan)
        snap.register(OffsetSpec("a", total=8))
        snap.register(OffsetSpec("b", total=12))
        snap.refresh()
        old_a = snap.boundaries("a").copy()
        # the planner now produces a *new* split for "a" but dies on "b":
        # the pre-fix torn commit would publish the new "a" host mirror
        # against the old device snapshot
        plans["a"] = np.array([4, 4])
        broken["flag"] = True
        with pytest.raises(RuntimeError):
            snap.refresh()
        np.testing.assert_array_equal(snap.boundaries("a"), old_a)
        np.testing.assert_array_equal(
            np.asarray(snap.device()["a"]), old_a)
        broken["flag"] = False                   # planner heals: commit
        snap.refresh()
        np.testing.assert_array_equal(snap.boundaries("a"), [0, 4, 8])


# ------------------------------------------------ RatioStore masked load --
class TestRatioStoreMasked:
    def test_expand_active_width_store_into_full_table(self, tmp_path):
        active = np.array([1, 1, 0, 1, 0, 1], dtype=bool)
        small = RatioTable(4)
        small.set("k", [4.0, 3.0, 2.0, 1.0])
        store = RatioStore(str(tmp_path / "r.json"))
        store.save(small)
        full = RatioTable(6)
        assert not store.load_into(full)         # width mismatch, no mask
        assert store.load_into(full, active=active)
        got = full.ratios("k")
        np.testing.assert_allclose(got[active], small.ratios("k"))
        np.testing.assert_allclose(got[~active], 1.0)   # init preserved

    def test_compress_full_store_into_active_width_table(self, tmp_path):
        active = np.array([1, 0, 1, 1, 0, 1], dtype=bool)
        full = RatioTable(6)
        full.set("k", [6.0, 5.0, 4.0, 3.0, 2.0, 1.0])
        store = RatioStore(str(tmp_path / "r.json"))
        store.save(full)
        small = RatioTable(4)
        assert store.load_into(small, active=active)
        np.testing.assert_allclose(small.ratios("k"),
                                   full.ratios("k")[active])

    def test_genuinely_different_machine_still_refused(self, tmp_path):
        small = RatioTable(4)
        small.set("k", [1.0, 1.0, 1.0, 1.0])
        store = RatioStore(str(tmp_path / "r.json"))
        store.save(small)
        other = RatioTable(6)
        # a mask that matches neither width combination is not a masked
        # view of the same machine
        assert not store.load_into(other,
                                   active=np.ones(5, dtype=bool))
        assert not store.load_into(other,
                                   active=np.ones(6, dtype=bool))
        mismatched = RatioTable(4, normalize="sum")
        assert not store.load_into(mismatched)   # conventions still refused
