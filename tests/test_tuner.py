"""Tests for the kernel-config tuner (per-ISA table analogue) and its
JSON persistence (TunerStore — block-shape tables warm-start across
processes like ratio tables do)."""

import os

from repro.core import KernelTuner, TunerStore, shape_class


def test_shape_class_buckets():
    assert shape_class(1000, 4096) == (1024, 4096)
    assert shape_class(1, 1) == (1, 1)


def test_tuner_warmup_then_argmin():
    t = KernelTuner(alpha=0.3, min_trials=2)
    key = ("q4_matmul", shape_class(1024, 4096, 4096))
    configs = ["a", "b", "c"]
    # Warmup: every config must be tried min_trials times.
    seen = []
    for _ in range(6):
        c = t.select(key, configs)
        seen.append(c)
        t.report(key, c, {"a": 3.0, "b": 1.0, "c": 2.0}[c])
    assert sorted(seen) == ["a", "a", "b", "b", "c", "c"]
    assert t.select(key, configs) == "b"
    assert t.best(key) == "b"


def test_tuner_readapts_on_drift():
    t = KernelTuner(alpha=0.3, min_trials=1)
    key = "k"
    for c, s in [("a", 1.0), ("b", 2.0)]:
        t.select(key, ["a", "b"])
        t.report(key, c, s)
    assert t.select(key, ["a", "b"]) == "a"
    # Environment drifts: config a becomes slow.
    for _ in range(10):
        t.report(key, "a", 5.0)
    assert t.select(key, ["a", "b"]) == "b"


# ------------------------------------------------------- persistence ------
KEY = ("q4_matmul", shape_class(1, 4096, 4096))
CONFIGS = [(8, 128, 512), (8, 256, 512), (16, 256, 256)]
SPEEDS = {(8, 128, 512): 3.0, (8, 256, 512): 1.0, (16, 256, 256): 2.0}


def _trained_tuner() -> KernelTuner:
    t = KernelTuner(alpha=0.3, min_trials=2)
    for _ in range(2 * len(CONFIGS)):
        c = t.select(KEY, CONFIGS)
        t.report(KEY, c, SPEEDS[c])
    return t


def test_tuner_json_round_trip_preserves_state():
    t = _trained_tuner()
    u = KernelTuner.from_json(t.to_json())
    assert u.alpha == t.alpha and u.min_trials == t.min_trials
    assert u.best(KEY) == t.best(KEY) == (8, 256, 512)
    # counts survive too: a round-tripped tuner is past warmup
    assert u.select(KEY, CONFIGS) == (8, 256, 512)


def test_tuner_store_warm_start_vs_cold(tmp_path):
    """ROADMAP item: a warm-started tuner selects the learned argmin on
    its first dispatch; a cold tuner must still spend min_trials x
    len(configs) dispatches exploring."""
    path = os.path.join(tmp_path, "tuner.json")
    store = TunerStore(path)
    assert store.load() is None and not store.exists()
    store.save(_trained_tuner())
    assert store.exists()

    warm = KernelTuner(alpha=0.3, min_trials=2)
    assert store.load_into(warm)
    assert warm.select(KEY, CONFIGS) == (8, 256, 512)  # no exploration

    # a cold tuner spends min_trials x len(configs) rounds exploring every
    # candidate before it can exploit — the warm start skips all of that
    cold = KernelTuner(alpha=0.3, min_trials=2)
    explored = []
    for _ in range(2 * len(CONFIGS)):
        c = cold.select(KEY, CONFIGS)
        explored.append(c)
        cold.report(KEY, c, SPEEDS[c])
    assert sorted(explored) == sorted(CONFIGS * 2)


def test_tuner_store_refuses_alpha_mismatch(tmp_path):
    path = os.path.join(tmp_path, "tuner.json")
    TunerStore(path).save(_trained_tuner())  # alpha=0.3
    other = KernelTuner(alpha=0.5, min_trials=2)
    assert not TunerStore(path).load_into(other)
    assert other.to_json() == KernelTuner(alpha=0.5, min_trials=2).to_json()
