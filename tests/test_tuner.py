"""Tests for the kernel-config tuner (per-ISA table analogue)."""

from repro.core import KernelTuner, shape_class


def test_shape_class_buckets():
    assert shape_class(1000, 4096) == (1024, 4096)
    assert shape_class(1, 1) == (1, 1)


def test_tuner_warmup_then_argmin():
    t = KernelTuner(alpha=0.3, min_trials=2)
    key = ("q4_matmul", shape_class(1024, 4096, 4096))
    configs = ["a", "b", "c"]
    # Warmup: every config must be tried min_trials times.
    seen = []
    for _ in range(6):
        c = t.select(key, configs)
        seen.append(c)
        t.report(key, c, {"a": 3.0, "b": 1.0, "c": 2.0}[c])
    assert sorted(seen) == ["a", "a", "b", "b", "c", "c"]
    assert t.select(key, configs) == "b"
    assert t.best(key) == "b"


def test_tuner_readapts_on_drift():
    t = KernelTuner(alpha=0.3, min_trials=1)
    key = "k"
    for c, s in [("a", 1.0), ("b", 2.0)]:
        t.select(key, ["a", "b"])
        t.report(key, c, s)
    assert t.select(key, ["a", "b"]) == "a"
    # Environment drifts: config a becomes slow.
    for _ in range(10):
        t.report(key, "a", 5.0)
    assert t.select(key, ["a", "b"]) == "b"
