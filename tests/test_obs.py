"""Observability subsystem (PR 10): virtual-clock tracing, metrics
exposition, and the anomaly flight recorder.

Five groups:

* tracer — SpanTracer scope/track bookkeeping, Perfetto schema validation
  (positive and negative), and byte-identical traces across same-seed
  fleet runs;
* zero-cost — with no tracer (or a tracer lacking the span hooks, like
  the race detector) the emit hooks never evaluate their payload
  callables;
* recorder — bounded ring, SLO-burn self-trip on a seeded fleet run,
  contract (IV00x) trips dumping the ring, crash-proof trip;
* metrics — counters/gauges/histograms, Prometheus text exposition and
  its lint (positive and negative), LatencyReport.to_dict/publish;
* audit — the compiled decode step stays zero-host-callback (JA001) with
  a SpanTracer installed.
"""

import json

import jax
import numpy as np
import pytest

from repro.core import events as _ev
from repro.fleet import Cluster, FleetRouter, NodeSpec, fleet_requests
from repro.models import init_params
from repro.models.transformer import ModelConfig
from repro.obs import (
    FlightRecorder,
    MetricsRegistry,
    SpanTracer,
    TPOT_BUCKETS,
    TTFT_BUCKETS,
    lint_exposition,
    validate_trace,
)
from repro.serving import LatencyReport

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32")

SPECS = (
    NodeSpec("fast", "ultra-125h", max_slots=3),
    NodeSpec("mid", "core-12900k", max_slots=3),
)


@pytest.fixture(scope="module")
def model():
    return CFG, init_params(CFG, jax.random.key(0))


@pytest.fixture(autouse=True)
def _clean_hooks():
    """No test may leak an installed tracer/recorder into the next."""
    yield
    _ev.install(None)
    _ev.install_recorder(None)


def traced_fleet_run(model, *, seed=1, n=10, recorder=None):
    """One small two-node fleet run under a fresh SpanTracer (and an
    optional recorder); returns the tracer."""
    cfg, params = model
    cluster = Cluster.build(SPECS, cfg, params, max_seq=40, seed=0)
    router = FleetRouter(cluster, slo_ttft=2.0, slo_tpot=0.25)
    requests = fleet_requests(n, base_rate=8.0, vocab_size=cfg.vocab_size,
                              prompt_len=(4, 12), max_new_tokens=(3, 5),
                              seed=seed)
    tracer = SpanTracer()
    prev = _ev.install(tracer)
    prev_rec = _ev.install_recorder(recorder) if recorder is not None else None
    try:
        router.run(requests)
    finally:
        _ev.install(prev)
        if recorder is not None:
            _ev.install_recorder(prev_rec)
    return tracer


def trace_bytes(tracer) -> bytes:
    return json.dumps(tracer.to_chrome(), separators=(",", ":"),
                      sort_keys=True).encode()


# ------------------------------------------------------------------ tracer --
def test_tracer_scopes_and_ids():
    t = SpanTracer()
    t.span("core0", "membw", 0.0, 1e-3, cat="pool")
    t.push_scope("node:big")
    t.push_scope("replica0")
    t.span("core0", "membw", 0.0, 2e-3)
    t.counter("queue", 1e-3, {"depth": 3})
    t.pop_scope()
    t.pop_scope()
    t.instant("fleet", "route:big", 2e-3, {"rid": 1})
    evs = t.chrome_events()
    procs = {e["args"]["name"]: e["pid"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert set(procs) == {"main", "node:big/replica0"}
    # first-seen pid order, distinct pids, spans land in their scope's pid
    assert procs["main"] == 1 and procs["node:big/replica0"] == 2
    spans = [e for e in evs if e["ph"] == "X"]
    assert [s["pid"] for s in spans] == [1, 2]
    # microsecond conversion
    assert spans[0]["dur"] == 1000.0
    assert t.n_spans == 2 and t.n_counters == 1 and t.n_instants == 1
    assert validate_trace(t.to_chrome()) == []


def test_validate_trace_flags_bad_events():
    bad = {"traceEvents": [
        {"ph": "Z", "pid": 1, "tid": 1, "name": "x", "ts": 0},
        {"ph": "X", "pid": 1, "tid": 1, "name": "y", "ts": -1, "dur": 1},
    ]}
    problems = validate_trace(bad)
    assert any("unknown ph" in p for p in problems)
    assert any("bad ts" in p for p in problems)
    # body event referencing a pid with no process_name metadata
    assert any("process_name" in p for p in problems)
    assert validate_trace({"nope": 1}) != []


def test_fleet_trace_covers_all_three_levels(model):
    t = traced_fleet_run(model)
    evs = t.chrome_events()
    procs = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    tracks = {e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "thread_name"}
    # core level: pool sub-task spans inside each replica process
    assert any(p.startswith("node:") and "/replica" in p for p in procs)
    assert "core0" in tracks
    # machine level: phase regions, engine iterations, queue depth
    assert {"phase:prefill", "phase:decode", "engine", "queue"} <= tracks
    # fleet level: routing instants + node ratio counters in proc "main"
    assert "fleet" in tracks
    assert any(e["ph"] == "i" and e["name"].startswith("route:")
               for e in evs)
    assert any(tr.startswith("ratio:fleet:") for tr in tracks)
    # counter tracks for ratio weights / bandwidth fraction / capacity
    assert any(tr.startswith("ratio:") for tr in tracks)
    assert any(tr.startswith("bw:") for tr in tracks)
    assert "capacity" in tracks
    assert validate_trace(t.to_chrome()) == []


def test_fleet_trace_byte_identical_same_seed(model):
    a = traced_fleet_run(model, seed=3)
    b = traced_fleet_run(model, seed=3)
    assert trace_bytes(a) == trace_bytes(b)
    c = traced_fleet_run(model, seed=4)
    assert trace_bytes(a) != trace_bytes(c)


def test_tracer_write_is_deterministic(model, tmp_path):
    t = traced_fleet_run(model)
    p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
    t.write(str(p1))
    t.write(str(p2))
    assert p1.read_bytes() == p2.read_bytes()
    assert validate_trace(str(p1)) == []


# --------------------------------------------------------------- zero-cost --
def test_disabled_hooks_never_evaluate_payloads():
    assert _ev.TRACER is None

    def boom():
        raise AssertionError("payload evaluated on the disabled path")

    _ev.emit_span("core0", "x", 0.0, 1.0, args=boom)
    _ev.emit_counter("queue", 0.0, boom)
    _ev.emit_instant("fleet", "x", 0.0, args=boom)
    _ev.push_scope("nope")
    _ev.pop_scope()
    _ev.record("ratio", "k", t=0.0)   # RECORDER is None: dropped


def test_span_hooks_are_noops_for_race_tracer():
    """A tracer implementing only ``emit`` (the race detector) must not
    receive spans — and the payload callables must stay unevaluated."""

    class RaceOnly:
        def __init__(self):
            self.events = []

        def emit(self, event):
            self.events.append(event)

    def boom():
        raise AssertionError("args evaluated for a span-less tracer")

    rt = RaceOnly()
    prev = _ev.install(rt)
    try:
        _ev.emit_span("core0", "x", 0.0, 1.0, args=boom)
        _ev.emit_counter("queue", 0.0, boom)
        _ev.emit_instant("fleet", "x", 0.0, args=boom)
        _ev.push_scope("s")
        _ev.pop_scope()
        _ev.emit_read("obj", "f")      # the hook it does implement works
    finally:
        _ev.install(prev)
    assert len(rt.events) == 1


# ---------------------------------------------------------------- recorder --
def test_recorder_ring_is_bounded():
    r = FlightRecorder(capacity=4)
    for i in range(10):
        r.record("ratio", f"k{i}", float(i), {"i": i})
    assert len(r) == 4
    snap = r.snapshot("test")
    assert snap["n_records"] == 4 and snap["n_dropped"] == 6
    assert [rec["key"] for rec in snap["records"]] == ["k6", "k7", "k8", "k9"]


def test_recorder_slo_burn_trips_on_seeded_run(model, tmp_path):
    """A fleet run against an impossibly tight TTFT SLO must burn: every
    latency record violates, and ``burn_window`` consecutive violations
    dump the ring to disk."""
    path = tmp_path / "flight.json"
    rec = FlightRecorder(path=str(path), slo_ttft=1e-6, burn_window=3)
    traced_fleet_run(model, recorder=rec)
    assert rec.trips, "SLO burn never tripped the recorder"
    assert rec.trips[0]["reason"].startswith("slo_burn")
    dump = json.loads(path.read_text())
    assert dump["schema"] == "repro.obs.flight_recorder/1"
    kinds = {r["kind"] for r in dump["records"]}
    assert "latency" in kinds and "ratio" in kinds and "route" in kinds


def test_recorder_no_trip_within_slo(model):
    rec = FlightRecorder(slo_ttft=1e9, slo_tpot=1e9, burn_window=3)
    traced_fleet_run(model, recorder=rec)
    assert rec.trips == []
    assert any(r.kind == "latency" for r in rec.records())


def test_contract_violation_trips_recorder(tmp_path):
    from repro.analysis import invariants

    path = tmp_path / "contract.json"
    rec = FlightRecorder(path=str(path))
    rec.record("ratio", "membw/head", 1.0, {"ratios": [0.5, 0.5]})
    prev = _ev.install_recorder(rec)
    try:
        with pytest.raises(invariants.ContractViolation):
            invariants.check_ema_step([1.0], [1.0], [-1.0])
    finally:
        _ev.install_recorder(prev)
    assert rec.trips and rec.trips[0]["reason"].startswith("contract IV001")
    dump = json.loads(path.read_text())
    assert dump["records"][0]["key"] == "membw/head"


def test_recorder_trip_never_raises_on_bad_path():
    rec = FlightRecorder(path="/nonexistent-dir/nope/flight.json")
    rec.record("capacity", "core0", 0.0, {"action": "park"})
    dump = rec.trip("test")          # OSError swallowed
    assert dump["n_records"] == 1


def test_capacity_events_are_recorded():
    from repro.core.hybrid_sim import make_machine

    rec = FlightRecorder()
    prev = _ev.install_recorder(rec)
    try:
        m = make_machine("ultra-125h")
        m.park(0, t_start=1.0)
        m.set_freq_scale(1, 2.0, t_start=2.0, t_end=3.0)
        m.unpark(0)
    finally:
        _ev.install_recorder(prev)
    actions = [(r.kind, r.payload.get("action")) for r in rec.records()]
    assert ("capacity", "park") in actions
    assert ("capacity", "scale") in actions
    assert ("capacity", "unpark") in actions
    # payloads are JSON-safe (open-ended windows must not serialize as inf)
    json.dumps([r.to_dict() for r in rec.records()])


# ----------------------------------------------------------------- metrics --
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests")
    c.inc(outcome="served")
    c.inc(2, outcome="served")
    c.inc(outcome="shed")
    assert c.value(outcome="served") == 3
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("queue_depth")
    g.set(5)
    g.inc(-2)
    assert g.value() == 3
    h = reg.histogram("ttft_seconds", "ttft", buckets=TTFT_BUCKETS)
    h.observe_many([0.05, 0.3, 99.0])
    assert h.count() == 3
    samples = dict(((n, tuple(sorted(l.items()))), v)
                   for n, l, v in h.samples())
    assert samples[("ttft_seconds_bucket", (("le", "+Inf"),))] == 3
    assert samples[("ttft_seconds_count", ())] == 3
    # re-registration returns the same object; kind mismatch raises
    assert reg.counter("reqs_total") is c
    with pytest.raises(ValueError):
        reg.gauge("reqs_total")


def test_prometheus_text_passes_exposition_lint():
    reg = MetricsRegistry()
    reg.counter("repro_requests_total", "finished").inc(4, outcome="served")
    reg.gauge("repro_goodput", "goodput").set(1.5)
    h = reg.histogram("repro_ttft_seconds", "ttft", buckets=TTFT_BUCKETS)
    h.observe_many([0.05, 0.2, 0.9, 4.0])
    text = reg.prometheus_text()
    assert lint_exposition(text) == []
    assert "# TYPE repro_ttft_seconds histogram" in text
    assert 'le="+Inf"' in text


def test_exposition_lint_flags_problems():
    assert any("no TYPE" in p for p in lint_exposition("orphan_metric 1\n"))
    bad_hist = (
        "# TYPE h histogram\n"
        'h_bucket{le="1"} 5\n'
        'h_bucket{le="+Inf"} 3\n'     # cumulative count decreases
        "h_sum 2\n"
        "h_count 3\n")
    assert any("decreases" in p for p in lint_exposition(bad_hist))
    no_inf = (
        "# TYPE h histogram\n"
        'h_bucket{le="1"} 5\n'
        "h_sum 2\nh_count 5\n")
    assert any("+Inf" in p for p in lint_exposition(no_inf))
    assert any("non-numeric" in p
               for p in lint_exposition("# TYPE x counter\nx nope\n"))


def test_latency_report_to_dict_schema():
    rep = LatencyReport(
        n_requests=4, n_finished=3, duration=2.0, generated_tokens=12,
        ttft={50: 0.1, 90: 0.2, 99: 0.3}, tpot={50: 0.05, 90: 0.06, 99: 0.07},
        goodput=1.5, clock="virtual", wall_duration=0.8,
        ttft_samples=(0.1, 0.2), tpot_samples=(0.05,))
    d = rep.to_dict()
    assert d["schema"] == "repro.serving.latency_report/1"
    assert set(d) == {
        "schema", "n_requests", "n_finished", "n_shed", "n_degraded",
        "clock", "duration_s", "wall_duration_s", "generated_tokens",
        "throughput_tok_s", "goodput_req_s", "ttft_s", "tpot_s"}
    assert d["ttft_s"] == {"p50": 0.1, "p90": 0.2, "p99": 0.3}
    assert d["throughput_tok_s"] == 6.0
    json.dumps(d)   # JSON-safe
    # NaN percentiles (nothing served) become None, not Infinity/NaN
    empty = LatencyReport.from_requests([])
    assert empty.to_dict()["ttft_s"]["p50"] is None
    json.dumps(empty.to_dict())


def test_latency_report_publish():
    rep = LatencyReport(
        n_requests=4, n_finished=4, duration=2.0, generated_tokens=12,
        ttft={50: 0.1}, tpot={50: 0.05}, goodput=1.5, n_shed=1,
        ttft_samples=(0.05, 0.3, 1.9), tpot_samples=(0.02, 0.3))
    reg = MetricsRegistry()
    rep.publish(reg)
    assert reg.get("repro_ttft_seconds").count() == 3
    assert reg.get("repro_tpot_seconds").count() == 2
    assert reg.get("repro_requests_total").value(outcome="served") == 3
    assert reg.get("repro_requests_total").value(outcome="shed") == 1
    assert reg.get("repro_goodput_requests_per_second").value() == 1.5
    assert lint_exposition(reg.prometheus_text()) == []
    # buckets are the explicit SLO-matched sets
    assert reg.get("repro_ttft_seconds").buckets == TTFT_BUCKETS
    assert reg.get("repro_tpot_seconds").buckets == TPOT_BUCKETS


# ------------------------------------------------------------------- audit --
def test_compiled_step_zero_callbacks_with_tracing_enabled():
    """JA001 re-audit: installing the span tracer must not push host
    callbacks into the compiled decode step (spans are emitted host-side
    between steps, never in-graph)."""
    from repro.analysis.jaxpr_audit import (audit_step, count_callbacks,
                                            trace_compiled_step)
    from repro.configs import reduced_config
    from repro.kernels import GEMV_ISA, HybridKernelDispatcher
    from repro.models import BalancedTrunk

    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    disp = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
    compiled = BalancedTrunk.from_params(cfg, params, disp, quant="q4",
                                         mode="compiled")
    tracer = SpanTracer()
    prev = _ev.install(tracer)
    try:
        step = trace_compiled_step(cfg, params, compiled, isa=GEMV_ISA)
    finally:
        _ev.install(prev)
    assert audit_step(step) == []
    assert count_callbacks(step.jaxpr) == {}
