"""Sharding-spec tests on a small forced-multi-device mesh (subprocess so
the 8-device XLA flag never leaks into other tests)."""

import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.configs import reduced_config
    from repro.launch.mesh import make_debug_mesh
    from repro.models import abstract_params, init_params, loss_fn
    from repro.sharding import (activation_sharding, batch_shardings,
                                param_shardings, state_shardings)
    from repro.training import AdamWConfig, init_opt_state, make_train_step

    out = {}
    mesh = make_debug_mesh(4, 2)
    cfg = reduced_config("granite-8b")
    ap = abstract_params(cfg)
    ps = param_shardings(mesh, ap)

    # every leaf got a NamedSharding with divisibility respected
    def chk(path, leaf, sh):
        for dim, ax in zip(leaf.shape, list(sh.spec) + [None] * 8):
            n = 1
            if ax is not None:
                axes = ax if isinstance(ax, tuple) else (ax,)
                for a in axes:
                    n *= mesh.shape[a]
            assert dim % n == 0, (path, leaf.shape, sh.spec)
    jax.tree_util.tree_map_with_path(
        lambda p, l, s: chk(p, l, s), ap, ps)
    out["divisible"] = True

    # serve mode drops fsdp axes
    ps_serve = param_shardings(mesh, ap, mode="serve")
    specs = [s.spec for s in jax.tree.leaves(ps_serve)]
    assert all("data" not in str(sp) or "model" in str(sp) or sp == P()
               for sp in specs) or True
    out["serve_mode"] = True

    # end-to-end: sharded train step on 8 host devices runs and matches
    # the unsharded loss
    params = init_params(cfg, jax.random.key(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt_state(params, opt_cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 8, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    step = make_train_step(cfg, opt_cfg, remat=True)
    with mesh, activation_sharding(mesh):
        b_sh = batch_shardings(mesh, jax.eval_shape(lambda: batch),
                               batch_dim=1)
        sharded = jax.jit(step, in_shardings=(ps, None, b_sh))
        p2, o2, m2 = sharded(params, opt, batch)
    loss_sharded = float(m2["loss"])

    p3, o3, m3 = jax.jit(step)(params, opt, batch)
    out["loss_sharded"] = loss_sharded
    out["loss_plain"] = float(m3["loss"])

    # decode state shardings build for every arch family
    from repro.models import abstract_state
    for arch in ("granite-8b", "jamba-1.5-large-398b", "xlstm-1.3b"):
        c = reduced_config(arch)
        st = abstract_state(c, 4, 32)
        state_shardings(mesh, st, 4, phase="decode")
        state_shardings(mesh, st, 4, phase="prefill")
    out["states"] = True
    print("RESULT:" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def result():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


def test_param_shardings_divisible(result):
    assert result["divisible"]


def test_sharded_step_matches_plain(result):
    assert result["loss_plain"] == pytest.approx(result["loss_sharded"],
                                                 rel=2e-2)


def test_state_shardings_all_families(result):
    assert result["states"]
