"""Two-level ratio learning end to end (ROADMAP scale-test item).

An :class:`~repro.serving.InflightDispatcher` routes open-loop traffic
across heterogeneous virtual replicas, each a continuous-batching engine
whose *whole trunk* decodes through balanced per-core shard dispatch — so
the paper's loop runs at both levels simultaneously:

* level 1 (replica): per-phase tokens/s ratios over the replica fleet,
  learned from iteration feedback, steering request routing (Eq. 3 at the
  serving layer);
* level 2 (core): per-(ISA x layer kind) ratios inside each replica's
  :class:`~repro.kernels.HybridKernelDispatcher`, learned from shard times
  of every q/k/v/o / up/gate/down / head dispatch.

The tests assert that both tables converge to the planted heterogeneity
and that learned routing beats a round-robin baseline on goodput under
identical traffic.
"""

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core import CoreSpec, SimulatedHybridCPU
from repro.kernels import HybridKernelDispatcher, kernel_key
from repro.models import BalancedTrunk, init_params
from repro.runtime import RatioTable
from repro.serving import (
    DECODE,
    PREFILL,
    ContinuousBatchingEngine,
    InflightDispatcher,
    LatencyReport,
    LinearPhaseCost,
    poisson_requests,
)

# Replica heterogeneity: replica 1 is SLOWDOWN x slower in both phases.
SLOWDOWN = 3.0
N_REQUESTS = 16
STEPS = 8


def small_hybrid(seed=0) -> SimulatedHybridCPU:
    """4-core hybrid machine (2 P + 2 E, P = 2x E everywhere): small core
    count keeps granularity-rounding noise well below the planted 2x
    spread, so level-2 convergence is tight."""
    cores = [CoreSpec(f"P{i}", "P", {"avx_vnni": 200e9, "avx2": 100e9,
                                     "membw": 8e9}, jitter=0.01)
             for i in range(2)]
    cores += [CoreSpec(f"E{i}", "E", {"avx_vnni": 70e9, "avx2": 35e9,
                                      "membw": 4e9}, jitter=0.01)
              for i in range(2)]
    return SimulatedHybridCPU(cores=cores, seed=seed)


@pytest.fixture(scope="module")
def model():
    cfg = reduced_config("granite-8b")
    return cfg, init_params(cfg, jax.random.key(0))


def build_fleet(model):
    """Two balanced-trunk engines: replica 0 fast, replica 1 SLOWDOWN x
    slower (deterministic linear cost clocks); each with its own kernel
    dispatcher over its own simulated hybrid machine."""
    cfg, params = model
    engines, disps = [], []
    for i, speed in enumerate((1.0, SLOWDOWN)):
        disp = HybridKernelDispatcher.virtual(small_hybrid(seed=i),
                                              execute=True)
        trunk = BalancedTrunk.from_params(cfg, params, disp, quant="fp32")
        cost = LinearPhaseCost(prefill_per_token=1e-3 * speed,
                               decode_per_step=1e-3 * speed,
                               decode_per_active=2e-3 * speed)
        engines.append(ContinuousBatchingEngine(
            cfg, params, max_slots=2, max_seq=24, prefill_chunk=8,
            cost_model=cost, balanced_trunk=trunk))
        disps.append(disp)
    return engines, disps


def traffic(cfg):
    return poisson_requests(N_REQUESTS, rate=30.0,
                            vocab_size=cfg.vocab_size, prompt_len=8,
                            max_new_tokens=STEPS, seed=0)


def drive(dispatcher, requests):
    """Open-loop replay: progress in-flight work up to each arrival so
    feedback from earlier requests steers later routing."""
    routed = np.zeros(len(dispatcher.engines), dtype=np.int64)
    for r in requests:
        while dispatcher.has_work and dispatcher.now < r.arrival_time:
            dispatcher.step()
        i, _ = dispatcher.submit(r)
        routed[i] += 1
    dispatcher.run_until_idle()
    return routed


@pytest.fixture(scope="module")
def learned_run(model):
    cfg, _ = model
    engines, disps = build_fleet(model)
    table = RatioTable(2, alpha=0.3)
    dispatcher = InflightDispatcher(engines, table=table)
    requests = traffic(cfg)
    routed = drive(dispatcher, requests)
    return dict(table=table, disps=disps, requests=requests, routed=routed,
                makespan=dispatcher.now)


def test_level1_replica_ratios_converge(learned_run):
    """Replica-level per-phase ratios learn the planted SLOWDOWN within a
    generous band, in both phases, and most traffic lands on the fast
    replica."""
    table = learned_run["table"]
    for phase in (PREFILL, DECODE):
        r = table.ratios(phase)
        assert r[0] > r[1], f"{phase}: fast replica not favored: {r}"
        assert 1.5 < r[0] / r[1] < 2.5 * SLOWDOWN, f"{phase}: {r}"
    routed = learned_run["routed"]
    assert routed[0] > routed[1]
    assert routed.sum() == N_REQUESTS


def test_level2_kernel_ratios_converge(learned_run):
    """Core-level per-kind tables inside the fast replica converge to the
    machine's true membw throughput ratios (the biggest-N kind gives the
    tightest estimate), and every (phase ISA x kind) key was learned."""
    disp = learned_run["disps"][0]
    kinds = ("attn_proj", "mlp_up", "mlp_down", "head")
    expect = {kernel_key(isa, kind)
              for isa in ("avx_vnni", "membw") for kind in kinds}
    assert expect <= set(disp.table.keys())
    tp = disp.machine.true_throughput("membw")
    got = disp.table.ratios(kernel_key("membw", "head"))  # N=512: tight
    np.testing.assert_allclose(got, tp / tp.mean(), rtol=0.15)
    # decode-phase bytes accounting covered the whole trunk's traffic
    assert disp.achieved_bandwidth("membw") > 0


def test_dispatcher_goodput_beats_round_robin(model, learned_run):
    """Same traffic, fresh fleet, blind round-robin routing: the learned
    dispatcher must finish sooner and deliver higher goodput (all requests
    complete under both policies, so goodput compares total latency)."""
    cfg, _ = model
    engines, _ = build_fleet(model)
    requests = traffic(cfg)
    for j, r in enumerate(requests):
        while (any(e.has_work for e in engines)
               and max(e.now for e in engines) < r.arrival_time):
            for e in engines:
                e.step()
        engines[j % len(engines)].submit(r)
    while any(e.has_work for e in engines):
        for e in engines:
            e.step()
    rr_makespan = max(e.now for e in engines)
    rr_report = LatencyReport.from_requests(requests)
    learned_report = LatencyReport.from_requests(learned_run["requests"])
    assert all(len(r.generated) == STEPS for r in requests)
    assert all(len(r.generated) == STEPS for r in learned_run["requests"])
    assert learned_run["makespan"] < rr_makespan
    assert learned_report.goodput > rr_report.goodput
