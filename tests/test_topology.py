"""repro.topology: NUMA machine model, two-level socket-local dispatch,
and NUMA-aware weight placement.

Covers the PR-5 acceptance claims — socket-local dynamic dispatch sustains
>= 0.90 of *aggregate* streaming bandwidth on both simulated dual-socket
machines while the socket-oblivious baseline stays <= 0.85 — plus the
structural contracts: the flat machine is the 1-socket special case,
kernel outputs through the socket split are identical to the monolithic
kernels, the outer ratio table learns true relative socket throughput on
a heterogeneous topology, placement pins weights and prices remote
streaming, and the serving engine adopts/places topology-bound trunks.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import CoreSpec, SimulatedHybridCPU, make_machine
from repro.core.hybrid_sim import make_12900k, make_ultra_125h
from repro.kernels import GEMV_ISA, HybridKernelDispatcher, ops, ref
from repro.quant import quantize_q4_0, quantize_s8_symmetric
from repro.runtime import KernelSpec
from repro.topology import (
    MachineTopology,
    SocketSpec,
    TOPOLOGIES,
    TopologyDispatcher,
    make_topology,
    place_rows,
    place_trunk,
)

RNG = np.random.default_rng(0)
DUALS = sorted(TOPOLOGIES)

GEMV_SPEC = KernelSpec("q4_gemv", isa=GEMV_ISA, granularity=8,
                       work_per_unit=4096 * 0.5625)


def _hetero_topology(slow: float = 0.5) -> MachineTopology:
    """Two unequal sockets: a 125H cluster next to one with every
    throughput scaled by ``slow`` — the outer split has something real to
    learn."""
    fast = make_ultra_125h(seed=0).cores
    slow_cores = [CoreSpec(name=f"s1.{c.name}", kind=c.kind,
                           throughput={k: v * slow
                                       for k, v in c.throughput.items()},
                           jitter=c.jitter)
                  for c in fast]
    return MachineTopology(
        sockets=[SocketSpec("socket0", list(fast)),
                 SocketSpec("socket1", slow_cores)],
        cross_socket_penalty=1.8, name="hetero")


# ---------------------------------------------------------- machine model --
def test_dual_machines_shape_and_bandwidth():
    for name, per_socket in (("dual-125h", make_ultra_125h),
                             ("2s-12900k", make_12900k)):
        topo = make_topology(name)
        flat = per_socket()
        assert topo.n_sockets == 2
        assert topo.n_cores == 2 * flat.n_cores
        assert topo.aggregate_bandwidth == pytest.approx(
            2 * flat.socket_bandwidth)
        for s in range(2):
            assert topo.socket_bandwidth(s) == pytest.approx(
                flat.socket_bandwidth)
        np.testing.assert_allclose(topo.bandwidth_shares(), [0.5, 0.5])


def test_domains_and_socket_of():
    topo = make_topology("dual-125h")
    d0, d1 = topo.domains()
    assert (d0.core_start, d0.core_end) == (0, 14)
    assert (d1.core_start, d1.core_end) == (14, 28)
    assert topo.socket_of(0) == 0 and topo.socket_of(13) == 0
    assert topo.socket_of(14) == 1 and topo.socket_of(27) == 1
    with pytest.raises(IndexError):
        topo.socket_of(28)


def test_flat_machine_is_one_socket_special_case():
    topo = make_topology("ultra-125h")
    flat = make_ultra_125h()
    assert topo.n_sockets == 1
    assert topo.oblivious_blend == 1.0
    assert topo.aggregate_bandwidth == pytest.approx(flat.socket_bandwidth)


def test_oblivious_blend_interleave_model():
    topo = make_topology("dual-125h")
    # 2 sockets, interleaved pages: half the bytes remote at penalty 1.8
    assert topo.oblivious_blend == pytest.approx(1.0 + 0.8 * 0.5)


def test_flattened_view_merges_cores_not_pools():
    topo = make_topology("2s-12900k")
    flat = topo.flattened()
    assert isinstance(flat, SimulatedHybridCPU)
    assert flat.n_cores == topo.n_cores
    assert flat.socket_bandwidth == pytest.approx(topo.aggregate_bandwidth)


def test_per_socket_machines_have_distinct_jitter_streams():
    topo = make_topology("dual-125h", seed=7)
    t0 = topo.machines[0].task_time(0, "membw", 1e9, 0.0)
    t1 = topo.machines[1].task_time(0, "membw", 1e9, 0.0)
    assert t0 != t1  # same core spec, different seeded rng


# --------------------------------------------------- make_machine satellite --
def test_make_machine_forwards_seed_to_topologies():
    topo = make_machine("dual-125h", seed=11)
    assert isinstance(topo, MachineTopology)
    assert topo.seed == 11
    assert topo.machines[0].seed == 11 and topo.machines[1].seed == 12


def test_make_machine_unknown_error_lists_topology_machines():
    with pytest.raises(KeyError, match="topology machines"):
        make_machine("no-such-machine")
    with pytest.raises(KeyError, match="dual-125h"):
        make_machine("no-such-machine")


def test_make_topology_unknown_error():
    with pytest.raises(KeyError, match="topology machines"):
        make_topology("no-such-machine")


def test_flat_dispatcher_refuses_topologies():
    with pytest.raises(ValueError, match="TopologyDispatcher"):
        HybridKernelDispatcher.virtual("dual-125h")


# --------------------------------------------------- the headline claims ---
@pytest.mark.parametrize("machine", DUALS)
def test_socket_local_beats_oblivious_bandwidth(machine):
    """PR-5 acceptance: socket-local dynamic dispatch >= 0.90 of aggregate
    bandwidth; the socket-oblivious baseline (interleaved pages paying the
    fabric penalty) <= 0.85."""
    def frac(socket_local):
        disp = TopologyDispatcher(machine, socket_local=socket_local)
        for i in range(40):
            if i == 20:
                disp.reset_bandwidth_accounting()
            disp.dispatch(GEMV_SPEC, 4096, bytes_per_unit=4096 * 0.5625)
        return disp.achieved_bandwidth_fraction()

    local, oblivious = frac(True), frac(False)
    assert local >= 0.90, f"{machine}: socket-local {local:.2%}"
    assert oblivious <= 0.85, f"{machine}: oblivious {oblivious:.2%}"


@pytest.mark.parametrize("machine", DUALS)
def test_per_socket_fractions_reported(machine):
    disp = TopologyDispatcher(machine)
    for i in range(30):
        if i == 15:
            disp.reset_bandwidth_accounting()
        disp.dispatch(GEMV_SPEC, 4096, bytes_per_unit=4096 * 0.5625)
    for s in range(disp.n_sockets):
        f = disp.achieved_bandwidth_fraction(socket=s)
        assert 0.85 < f <= 1.0
    agg = disp.achieved_bandwidth_fraction()
    assert agg <= max(disp.achieved_bandwidth_fraction(socket=s)
                      for s in range(disp.n_sockets)) + 1e-9


def test_socket_table_converges_on_heterogeneous_sockets():
    """The outer units-feedback loop learns true relative socket
    throughput: a half-speed socket ends up with ~1/3 of the rows."""
    topo = _hetero_topology(slow=0.5)
    disp = TopologyDispatcher(topo)
    counts = None
    for _ in range(40):
        st = disp.dispatch(GEMV_SPEC, 4096)
        counts = st.counts
    ratios = disp.socket_ratios(GEMV_ISA)
    assert ratios[0] / ratios[1] == pytest.approx(2.0, rel=0.15)
    assert counts[0] / counts.sum() == pytest.approx(2 / 3, rel=0.1)


def test_oblivious_has_no_socket_level_views():
    disp = TopologyDispatcher("dual-125h", socket_local=False)
    disp.dispatch(GEMV_SPEC, 4096, bytes_per_unit=4096 * 0.5625)
    with pytest.raises(ValueError, match="socket"):
        disp.socket_ratios(GEMV_ISA)
    with pytest.raises(ValueError, match="oblivious"):
        disp.achieved_bandwidth(socket=0)


# ----------------------------------------------- kernels through the split --
@pytest.mark.parametrize("n,k", [(300, 128), (101, 64), (5, 64)])
def test_topology_q4_matmul_identical_to_monolithic(n, k):
    x = jnp.asarray(RNG.normal(size=(3, k)).astype(np.float32))
    qw = quantize_q4_0(jnp.asarray(RNG.normal(size=(n, k)).astype(np.float32)))
    disp = TopologyDispatcher("dual-125h", execute=True)
    got = disp.q4_matmul(x, qw, blocks=(8, 256, k))
    want = ops.q4_matmul(x, qw, blocks=(8, 256, k), interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_topology_int8_gemm_identical():
    a = jnp.asarray(RNG.integers(0, 256, size=(8, 128)), dtype=jnp.uint8)
    w = jnp.asarray(RNG.integers(-127, 128, size=(200, 128)), dtype=jnp.int8)
    disp = TopologyDispatcher("2s-12900k", execute=True)
    got = disp.int8_gemm(a, w)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.int8_gemm_ref(a, w)))


def test_topology_f32_matmul_shard_exact_and_matches_flat():
    w = RNG.normal(size=(96, 64)).astype(np.float32)
    x = jnp.asarray(RNG.normal(size=(4, 64)).astype(np.float32))
    topo_disp = TopologyDispatcher("ultra-125h", execute=True)  # 1 socket
    flat_disp = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
    got = np.asarray(topo_disp.f32_matmul(x, w))
    np.testing.assert_allclose(got, np.asarray(x) @ w.T, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(got, np.asarray(flat_disp.f32_matmul(x, w)))


def test_oblivious_kernels_still_correct():
    """The penalty inflates modelled time, never the computed values."""
    x = jnp.asarray(RNG.normal(size=(2, 64)).astype(np.float32))
    w = RNG.normal(size=(48, 64)).astype(np.float32)
    disp = TopologyDispatcher("dual-125h", socket_local=False, execute=True)
    np.testing.assert_allclose(np.asarray(disp.f32_matmul(x, w)),
                               np.asarray(x) @ w.T, rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------- placement ---
def test_place_rows_proportional_and_contiguous():
    ranges = place_rows(100, [0.5, 0.5])
    assert ranges == ((0, 50), (50, 100))
    r3 = place_rows(90, [2 / 3, 1 / 3])
    assert r3[0][1] - r3[0][0] == 60 and r3[1][1] - r3[1][0] == 30


def test_register_placement_validates_ranges():
    disp = TopologyDispatcher("dual-125h")
    w = np.zeros((10, 4), np.float32)
    with pytest.raises(ValueError, match="contiguous"):
        disp.register_placement(w, [(0, 5), (6, 10)])  # gap
    with pytest.raises(ValueError, match="one range per socket"):
        disp.register_placement(w, [(0, 10)])
    disp.register_placement(w, [(0, 4), (4, 10)])
    assert disp.placement_for(w, 10) == ((0, 4), (4, 10))


def test_remote_streaming_pays_the_fabric_penalty():
    """Dispatching a range entirely resident on the other socket costs
    cross_socket_penalty per byte; local streaming costs 1."""
    disp = TopologyDispatcher("dual-125h")
    placement = ((0, 100), (100, 200))
    assert disp._work_scale(GEMV_ISA, 0, (0, 100), placement) == 1.0
    assert disp._work_scale(GEMV_ISA, 1, (0, 100), placement) \
        == pytest.approx(1.8)
    assert disp._work_scale(GEMV_ISA, 1, (50, 150), placement) \
        == pytest.approx(1.4)
    # compute-bound regions stream comparatively few bytes: no penalty
    assert disp._work_scale("avx_vnni", 1, (0, 100), placement) == 1.0


def test_misplaced_weights_lower_achieved_bandwidth():
    """A weight pinned entirely to socket 0 forces socket 1's share across
    the fabric; the achieved fraction must honestly drop."""
    def frac(misplace):
        disp = TopologyDispatcher("dual-125h")
        w = np.zeros((4096, 1), np.float32)  # identity key only
        if misplace:
            disp.register_placement(w, [(0, 4096), (4096, 4096)])
        for i in range(30):
            if i == 15:
                disp.reset_bandwidth_accounting()
            disp.dispatch(GEMV_SPEC, 4096, bytes_per_unit=4096 * 0.5625,
                          weight=w)
        return disp.achieved_bandwidth_fraction()

    good, bad = frac(False), frac(True)
    assert good >= 0.90
    assert bad < good - 0.1


def test_place_trunk_pins_every_banked_weight():
    from repro.configs import reduced_config
    from repro.models import BalancedTrunk, init_params

    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    disp = TopologyDispatcher("dual-125h", execute=True)
    trunk = BalancedTrunk.from_params(cfg, params, disp, quant="q4")
    placement = place_trunk(trunk)
    n_banked = sum(len(v) for v in trunk.bank.values()) + 1  # + head
    assert placement.n_layers == n_banked
    assert len(disp._placement) == n_banked
    np.testing.assert_allclose(placement.socket_bytes / placement.total_bytes,
                               placement.shares, atol=0.05)
    assert any("resident" in line for line in placement.lines())


def test_place_trunk_requires_topology_dispatcher():
    from repro.configs import reduced_config
    from repro.models import BalancedTrunk, init_params

    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    flat = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
    trunk = BalancedTrunk.from_params(cfg, params, flat, quant="fp32")
    with pytest.raises(ValueError, match="TopologyDispatcher"):
        place_trunk(trunk)
    oblivious = TopologyDispatcher("dual-125h", socket_local=False,
                                   execute=True)
    trunk2 = BalancedTrunk.from_params(cfg, params, oblivious, quant="fp32")
    with pytest.raises(ValueError, match="oblivious"):
        place_trunk(trunk2)


# ----------------------------------------------------- engine integration --
def _topology_engine(machine="dual-125h", quant="fp32", topology=None,
                     n_requests=3, steps=4):
    from repro.configs import reduced_config
    from repro.models import BalancedTrunk, init_params
    from repro.serving import (
        ContinuousBatchingEngine,
        HybridPhaseCost,
        poisson_requests,
    )

    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    disp = TopologyDispatcher(machine, execute=True)
    trunk = BalancedTrunk.from_params(cfg, params, disp, quant=quant)
    engine = ContinuousBatchingEngine(
        cfg, params, max_slots=2, max_seq=16, prefill_chunk=4,
        cost_model=HybridPhaseCost(machine), balanced_trunk=trunk,
        topology=topology)
    requests = poisson_requests(n_requests, rate=100.0,
                                vocab_size=cfg.vocab_size,
                                prompt_len=6, max_new_tokens=steps, seed=0)
    for r in requests:
        engine.submit(r)
    engine.run_until_idle()
    return engine, requests, disp


def test_engine_adopts_and_places_topology_trunk():
    engine, requests, disp = _topology_engine()
    assert all(len(r.generated) == 4 for r in requests)
    assert engine.topology is disp.topology
    assert engine.placement is not None and engine.placement.n_layers > 0
    # both levels learned decode-phase keys from real dispatches
    assert "membw/attn_proj" in disp.table.keys()
    assert "membw/attn_proj" in disp.socket_dispatchers[0].table.keys()
    assert disp.achieved_bandwidth(GEMV_ISA) > 0
    for s in range(disp.n_sockets):
        assert disp.achieved_bandwidth(GEMV_ISA, socket=s) > 0


def test_engine_topology_name_validation():
    engine, _, _ = _topology_engine(topology="dual-125h")
    assert engine.topology.name == "dual-125h"
    with pytest.raises(ValueError, match="balanced over"):
        _topology_engine(topology="2s-12900k")


def test_engine_topology_requires_topology_trunk():
    from repro.configs import reduced_config
    from repro.models import init_params
    from repro.serving import ContinuousBatchingEngine

    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    with pytest.raises(ValueError, match="TopologyDispatcher"):
        ContinuousBatchingEngine(cfg, params, max_slots=2, max_seq=16,
                                 topology="dual-125h")


def test_phase_cost_accepts_topology_as_flattened_clock():
    from repro.serving import HybridPhaseCost

    cost = HybridPhaseCost("dual-125h")
    assert cost.machine.n_cores == 28
    assert cost.machine.socket_bandwidth == pytest.approx(
        make_topology("dual-125h").aggregate_bandwidth)
    assert cost.decode_seconds(2, ctx=8) > 0
