"""Compiled balanced decode: zero-callback shard lowering vs the bridge.

Covers the PR-7 acceptance gates:
* the double-buffered Q4 kernel is bit-identical to the plain kernel;
* every projection kind x quant mode matches the bridged path (Q4
  bit-exact under pinned blocks, int8/fp32 within float tolerance);
* the compiled decode step's jaxpr contains ZERO io_callback ops (the
  bridged step's contains many);
* engine-level token identity: a compiled trunk generates exactly the
  tokens the bridged trunk does, for all three quant modes and for the
  socket-local NUMA topology;
* the cost-tape feedback keeps the ratio loop learning (hybrid cores
  differentiate, bandwidth accounting accrues).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.compiled import CompiledDispatcher, q4_blocks
from repro.kernels.dispatch import GEMV_ISA, HybridKernelDispatcher, kernel_key
from repro.runtime import OffsetSnapshot, OffsetSpec


# ------------------------------------------------------ offset snapshot --
def test_offset_spec_validation():
    with pytest.raises(ValueError):
        OffsetSpec("x", total=-1)
    with pytest.raises(ValueError):
        OffsetSpec("x", total=8, granularity=0)


def test_offset_snapshot_refresh_and_mirror():
    plans = {"a": np.array([3, 5, 0]), "b": np.array([4, 4, 4])}
    snap = OffsetSnapshot(lambda spec: plans[spec.name])
    snap.register(OffsetSpec("a", total=8))
    snap.register(OffsetSpec("a", total=8))  # idempotent
    snap.register(OffsetSpec("b", total=12))
    with pytest.raises(ValueError):  # shape change refused
        snap.register(OffsetSpec("a", total=9))
    dev = snap.refresh()
    assert sorted(dev) == ["a", "b"]
    np.testing.assert_array_equal(np.asarray(dev["a"]), [0, 3, 8, 8])
    np.testing.assert_array_equal(snap.boundaries("b"), [0, 4, 8, 12])
    np.testing.assert_array_equal(snap.counts("a"), [3, 5, 0])
    plans["a"] = np.array([1, 1, 1])  # planner no longer covers total
    with pytest.raises(ValueError):
        snap.refresh()


# ------------------------------------------------- double-buffered kernel --
@pytest.mark.parametrize("shape,blocks", [
    ((8, 256, 512), (8, 256, 512)),
    ((8, 512, 1024), (8, 256, 256)),
    ((16, 256, 256), (8, 128, 128)),
])
def test_q4_db_kernel_bit_identical(shape, blocks):
    """The hand-pipelined (async-copy double-buffered) Q4 kernel keeps the
    plain kernel's accumulation order exactly -> bitwise-equal outputs."""
    from repro.kernels.q4_matmul import q4_matmul_pallas, q4_matmul_pallas_db
    from repro.quant.q4 import quantize_q4_0

    m, n, k = shape
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    qw = quantize_q4_0(jnp.asarray(
        rng.standard_normal((n, k)).astype(np.float32)))
    a = q4_matmul_pallas(x, qw, blocks=blocks, interpret=True)
    b = q4_matmul_pallas_db(x, qw, blocks=blocks, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_q4_blocks_fixup_matches_ops_layer():
    assert q4_blocks(512) == (8, 256, 512)
    assert q4_blocks(192) == (8, 256, 64)
    assert q4_blocks(32) == (8, 256, 32)


# ------------------------------------------------- per-projection identity --
def _trunks(quant, machine="ultra-125h"):
    from repro.configs import reduced_config
    from repro.models import BalancedTrunk, init_params

    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    disp = HybridKernelDispatcher.virtual(machine, execute=True)
    bridged = BalancedTrunk.from_params(cfg, params, disp, quant=quant,
                                        pin_q4_blocks=True)
    compiled = BalancedTrunk.from_params(cfg, params, disp, quant=quant,
                                         mode="compiled")
    return cfg, params, disp, bridged, compiled


# the paper trunk's 7 projection kinds (4 attn + 3 swiglu MLP), plus head
PROJECTIONS = [("attn", "wq"), ("attn", "wk"), ("attn", "wv"),
               ("attn", "wo"), ("ffn", "wi"), ("ffn", "wg"), ("ffn", "wo")]


@pytest.mark.parametrize("quant", ["q4", "int8", "fp32"])
def test_compiled_projections_match_bridged(quant):
    """Every projection kind of the trunk (and the head) produces the
    bridged path's output through the compiled lowering — bit-exact for
    Q4 (same pinned blocks => same accumulation order), float-tight for
    int8/fp32 (shard split changes the f32 reduction order only)."""
    cfg, params, disp, bridged, compiled = _trunks(quant)
    offsets = compiled.compiled_refresh()
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(
        (2, cfg.d_model)).astype(np.float32))
    checked = 0
    for group, name in PROJECTIONS:
        if (0, group, name) not in bridged.bank:
            continue
        proj_b = bridged.projector(0, 0, group, GEMV_ISA)
        proj_c = compiled.projector(0, 0, group, GEMV_ISA, offsets=offsets)
        xin = x
        if (group, name) == ("ffn", "wo"):  # mlp_down eats (., d_ff)
            xin = jnp.asarray(rng.standard_normal(
                (2, cfg.d_ff)).astype(np.float32))
        a = np.asarray(proj_b(name, xin, None))
        b = np.asarray(proj_c(name, xin, None))
        if quant == "q4":
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-5)
        checked += 1
    assert checked == len(PROJECTIONS)
    a = np.asarray(bridged.apply_head(x, isa=GEMV_ISA))
    b = np.asarray(compiled.apply_head(x, isa=GEMV_ISA, offsets=offsets))
    if quant == "q4":
        np.testing.assert_array_equal(a, b)
    else:
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-5)


# ------------------------------------------------------- zero callbacks --
def test_compiled_decode_step_has_zero_io_callbacks():
    """The whole compiled decode step — trunk projections AND head — traces
    without a single host callback; the bridged step carries one per
    region (that's the raw-speed ceiling this PR removes).  The hand-rolled
    ``str(jaxpr).count("io_callback")`` assertion now lives in
    ``repro.analysis.jaxpr_audit``, which additionally walks closed calls
    and taints the offset arrays (JA002)."""
    from repro.analysis.jaxpr_audit import (
        audit_step, count_callbacks, expected_bridge_callbacks,
        trace_bridged_step, trace_compiled_step)

    cfg, params, disp, bridged, compiled = _trunks("q4")

    step = trace_compiled_step(cfg, params, compiled, isa=GEMV_ISA)
    assert audit_step(step) == []            # JA001 + JA002 both clean
    assert count_callbacks(step.jaxpr) == {}

    bstep = trace_bridged_step(cfg, params, bridged, isa=GEMV_ISA)
    want = expected_bridge_callbacks(bridged)
    assert want > 0
    assert audit_step(bstep, expected=want) == []   # JA003 + JA004 clean
    assert count_callbacks(bstep.jaxpr).get("io_callback", 0) == want


# -------------------------------------------------- engine token identity --
def _run_engine(trunk_kw, quant, machine="ultra-125h", topology=None,
                n_requests=3, steps=4):
    from repro.configs import reduced_config
    from repro.models import BalancedTrunk, init_params
    from repro.serving import (
        ContinuousBatchingEngine,
        HybridPhaseCost,
        poisson_requests,
    )

    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    if topology is not None:
        from repro.topology import TopologyDispatcher

        disp = TopologyDispatcher(topology, execute=True)
        clock = topology
    else:
        disp = HybridKernelDispatcher.virtual(machine, execute=True)
        clock = machine
    trunk = BalancedTrunk.from_params(cfg, params, disp, quant=quant,
                                      **trunk_kw)
    engine = ContinuousBatchingEngine(
        cfg, params, max_slots=2, max_seq=16, prefill_chunk=4,
        cost_model=HybridPhaseCost(clock), balanced_trunk=trunk)
    requests = poisson_requests(n_requests, rate=100.0,
                                vocab_size=cfg.vocab_size,
                                prompt_len=6, max_new_tokens=steps, seed=0)
    for r in requests:
        engine.submit(r)
    engine.run_until_idle()
    return requests, disp


@pytest.mark.parametrize("quant", ["q4", "int8", "fp32"])
def test_compiled_engine_tokens_identical_to_bridged(quant):
    bridged, _ = _run_engine(dict(jit_bridge=True, pin_q4_blocks=True), quant)
    compiled, disp = _run_engine(dict(mode="compiled"), quant)
    for a, b in zip(bridged, compiled):
        assert a.generated == b.generated
    # the between-step feedback kept the ratio loop learning: every
    # (phase ISA x kind) key exists and the hybrid cores differentiated
    kinds = ("attn_proj", "mlp_up", "mlp_down", "head")
    expect = {kernel_key(isa, kind)
              for isa in ("avx_vnni", "membw") for kind in kinds}
    assert expect <= set(disp.table.keys())
    spread = disp.table.ratios(kernel_key(GEMV_ISA, "mlp_up"))
    assert spread.max() / spread.min() > 1.1
    assert disp.achieved_bandwidth(GEMV_ISA) > 0


def test_compiled_engine_tokens_identical_on_numa_topology():
    """Socket-local two-level dispatch survives the compiled lowering:
    same tokens, and the topology's outer (socket) accounting accrues."""
    bridged, _ = _run_engine(dict(jit_bridge=True, pin_q4_blocks=True),
                             "q4", topology="dual-125h")
    compiled, topo = _run_engine(dict(mode="compiled"), "q4",
                                 topology="dual-125h")
    for a, b in zip(bridged, compiled):
        assert a.generated == b.generated
    assert len(topo.stats) > 0                      # outer-level reports
    assert topo._bytes.get(GEMV_ISA, 0.0) > 0       # aggregate accounting
    assert len(topo.socket_ratios(kernel_key(GEMV_ISA, "mlp_up"))) == 2


def test_compiled_eager_apply_and_feedback_roundtrip():
    """CompiledDispatcher standalone: apply eagerly, feed the recorded
    sizes back, and the snapshot re-plans away from even splits."""
    from repro.models.layers import BalancedQuantLinear

    rng = np.random.default_rng(0)
    disp = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
    cd = CompiledDispatcher(disp)
    layer = BalancedQuantLinear.from_dense(
        rng.standard_normal((64, 256)).astype(np.float32), disp)
    spec = cd.spec_for(layer, GEMV_ISA, "attn_proj")
    x = jnp.asarray(rng.standard_normal((2, 256)).astype(np.float32))

    def step(x, offs):
        tape = cd.tape_begin()
        y = cd.apply(layer, x, isa=GEMV_ISA, kind="attn_proj", offsets=offs)
        return y, cd.tape_end(tape)

    offs = cd.refresh()
    first = cd.snapshot.counts(spec.name).copy()
    for _ in range(4):
        _, recs = jax.jit(step)(x, offs)
        offs = cd.feedback(jax.device_get(recs))
    assert disp.table.ratios(spec.key).max() > 1.0
    assert not np.array_equal(first, cd.snapshot.counts(spec.name))
    # replayed sizes must cover the region exactly
    bad = [{"spec": np.int32(spec.spec_id), "m": np.int32(2),
            "sizes": np.zeros(disp.n_workers, np.int32)}]
    with pytest.raises(ValueError):
        cd.feedback(bad)


# ------------------------------------------------- zero-width shards --
@pytest.mark.parametrize("quant", ["q4", "int8", "fp32"])
def test_zero_width_shards_all_projections_match_dense(quant):
    """Elastic capacity through the compiled path: parking half the
    dispatcher's workers re-plans every registered spec to zero-width
    shard slices (``b[w] == b[w + 1]``) through fixed ``(n_workers + 1,)``
    boundary arrays — no retrace — and every projection kind (and the
    head) produces the dense split's output exactly: the boundaries only
    feed the cost tape, the monolithic kernels never see them."""
    cfg, params, disp, bridged, compiled = _trunks(quant)
    dense = compiled.compiled_refresh()
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, cfg.d_model)).astype(np.float32))
    xff = jnp.asarray(rng.standard_normal((2, cfg.d_ff)).astype(np.float32))

    def outputs(offsets):
        outs = {}
        for group, name in PROJECTIONS:
            proj = compiled.projector(0, 0, group, GEMV_ISA, offsets=offsets)
            xin = xff if (group, name) == ("ffn", "wo") else x
            outs[(group, name)] = np.asarray(proj(name, xin, None))
        outs["head"] = np.asarray(
            compiled.apply_head(x, isa=GEMV_ISA, offsets=offsets))
        return outs

    ref = outputs(dense)
    n = disp.n_workers
    for c in range(n // 2, n):
        disp.set_active(c, False)
    masked = compiled.compiled_refresh()
    snap = compiled._compiled().snapshot
    for name in snap.names:
        b = snap.boundaries(name)
        assert b.shape == (n + 1,)                   # fixed width: no retrace
        assert (np.diff(b) >= 0).all()               # monotone non-decreasing
        counts = snap.counts(name)
        assert (counts[n // 2:] == 0).all()          # parked => zero-width
        assert counts.sum() == snap.spec(name).total
        # IV003 accepts equal adjacent boundaries (zero-width is legal)
        from repro.analysis import invariants
        with invariants.contracts():
            invariants.check_offset_boundaries(b, snap.spec(name).total)
    got = outputs(masked)
    for key, a in ref.items():
        np.testing.assert_array_equal(a, got[key])   # bit-identical, all quants


def test_compiled_engine_tokens_identical_across_park_events():
    """Engine-level elasticity: a park window landing mid-serve (on both
    the kernel dispatcher's machine and the phase-cost clock) changes the
    virtual timing but not one generated token, and the compiled step
    still audits to zero host callbacks after masked re-planning."""
    from repro.analysis.jaxpr_audit import (
        audit_step, count_callbacks, trace_compiled_step)
    from repro.configs import reduced_config
    from repro.models import BalancedTrunk, init_params
    from repro.serving import (
        ContinuousBatchingEngine,
        HybridPhaseCost,
        poisson_requests,
    )

    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))

    def run(park: bool):
        disp = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
        trunk = BalancedTrunk.from_params(cfg, params, disp, quant="q4",
                                          mode="compiled")
        cost = HybridPhaseCost("ultra-125h")
        engine = ContinuousBatchingEngine(
            cfg, params, max_slots=2, max_seq=16, prefill_chunk=4,
            cost_model=cost, balanced_trunk=trunk)
        requests = poisson_requests(3, rate=100.0,
                                    vocab_size=cfg.vocab_size,
                                    prompt_len=6, max_new_tokens=4, seed=0)
        for r in requests:
            engine.submit(r)
        if park:
            for _ in range(2):
                engine.step()
            n = disp.n_workers
            for c in range(n // 2, n):   # a socket's worth, mid-serve
                disp.machine.park(c)
                cost.machine.park(c)
        engine.run_until_idle()
        return requests, trunk

    base, _ = run(park=False)
    parked, trunk = run(park=True)
    for a, b in zip(base, parked):
        assert a.generated == b.generated
    step = trace_compiled_step(cfg, params, trunk, isa=GEMV_ISA)
    assert audit_step(step) == []
    assert count_callbacks(step.jaxpr) == {}
