"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Shapes are kept modest because interpret mode executes the kernel body in
Python on CPU; divisible and non-divisible (padded) shapes are both swept.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import int8_gemm, int8_linear, q4_matmul, TunedMatmul
from repro.kernels import ref
from repro.quant import (
    quantize_q4_0,
    dequantize_q4_0,
    quantize_u8_dynamic,
    quantize_s8_symmetric,
    dequantize_u8,
    dequantize_s8,
)

RNG = np.random.default_rng(0)


# ------------------------------------------------------------------ Q4_0 ---
def test_q4_roundtrip_exact_codes():
    """Quantize->dequantize->quantize is idempotent (codes are stable)."""
    w = RNG.normal(size=(8, 64)).astype(np.float32)
    qw = quantize_q4_0(jnp.asarray(w))
    w2 = dequantize_q4_0(qw)
    qw2 = quantize_q4_0(w2)
    np.testing.assert_array_equal(np.asarray(qw.packed), np.asarray(qw2.packed))


def test_q4_quant_error_bounded():
    w = RNG.normal(size=(16, 128)).astype(np.float32)
    qw = quantize_q4_0(jnp.asarray(w))
    w2 = np.asarray(dequantize_q4_0(qw))
    # Q4_0 codes span [-8, 7]*d: interior error <= |d|/2 but the side the
    # code range doesn't reach (asymmetry) can err up to one full step |d|
    # (plus fp16 scale rounding).
    group_max = np.abs(w.reshape(16, -1, 32)).max(-1)
    bound = (group_max / 8).repeat(32, -1).reshape(16, 128) + 1e-6
    assert np.all(np.abs(w - w2) <= bound * 1.01)


@pytest.mark.parametrize("m,n,k", [
    (8, 256, 512),      # exactly one block
    (16, 512, 1024),    # multi-block in every dim
    (8, 256, 1536),     # 3 k-steps
    (1, 100, 512),      # GEMV with N padding
    (5, 256, 512),      # M padding
    (9, 300, 512),      # M and N padding
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_q4_matmul_matches_ref(m, n, k, dtype):
    x = jnp.asarray(RNG.normal(size=(m, k)), dtype=dtype)
    w = jnp.asarray(RNG.normal(size=(n, k)).astype(np.float32))
    qw = quantize_q4_0(w)
    got = q4_matmul(x, qw, interpret=True)
    want = ref.q4_matmul_ref(x, qw)
    assert got.shape == (m, n) and got.dtype == dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want, dtype=np.float32),
        rtol=tol, atol=tol * k,
    )


@pytest.mark.parametrize("blocks", [(8, 256, 512), (8, 128, 1024), (128, 128, 512)])
def test_q4_matmul_block_sweep(blocks):
    m, n, k = 16, 512, 1024
    x = jnp.asarray(RNG.normal(size=(m, k)).astype(np.float32))
    qw = quantize_q4_0(jnp.asarray(RNG.normal(size=(n, k)).astype(np.float32)))
    got = q4_matmul(x, qw, blocks=blocks, interpret=True)
    want = ref.q4_matmul_ref(x, qw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-2)


# ------------------------------------------------------------------ INT8 ---
@pytest.mark.parametrize("m,n,k", [
    (128, 128, 256),     # one block
    (256, 256, 512),     # multi-block
    (100, 120, 200),     # all dims padded
    (1, 128, 256),       # GEMV row
])
def test_int8_gemm_exact(m, n, k):
    a = jnp.asarray(RNG.integers(0, 256, size=(m, k)), dtype=jnp.uint8)
    w = jnp.asarray(RNG.integers(-127, 128, size=(n, k)), dtype=jnp.int8)
    got = int8_gemm(a, w, interpret=True)
    want = ref.int8_gemm_ref(a, w)
    # integer accumulation must be bit-exact
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("blocks", [(128, 128, 256), (64, 128, 512)])
def test_int8_gemm_block_sweep(blocks):
    a = jnp.asarray(RNG.integers(0, 256, size=(64, 512)), dtype=jnp.uint8)
    w = jnp.asarray(RNG.integers(-127, 128, size=(128, 512)), dtype=jnp.int8)
    got = int8_gemm(a, w, blocks=blocks, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.int8_gemm_ref(a, w)))


def test_int8_linear_dequant_close_to_f32():
    """Quantized linear approximates the float matmul (paper's GEMM path)."""
    x = RNG.normal(size=(32, 256)).astype(np.float32)
    w = RNG.normal(size=(64, 256)).astype(np.float32)
    qa = quantize_u8_dynamic(jnp.asarray(x))
    qw = quantize_s8_symmetric(jnp.asarray(w))
    got = int8_linear(qa, qw, interpret=True)
    want = np.asarray(dequantize_u8(qa)) @ np.asarray(dequantize_s8(qw)).T
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-3)
    # and the quantized result is close to the unquantized one
    full = x @ w.T
    err = np.abs(np.asarray(got) - full).max() / np.abs(full).max()
    assert err < 0.05


# ----------------------------------------------------------------- tuner ---
def test_tuned_matmul_dispatch():
    tm = TunedMatmul(interpret=True)
    x = jnp.asarray(RNG.normal(size=(8, 512)).astype(np.float32))
    qw = quantize_q4_0(jnp.asarray(RNG.normal(size=(256, 512)).astype(np.float32)))
    for _ in range(3):
        out = tm.q4(x, qw)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.q4_matmul_ref(x, qw)),
        rtol=2e-5, atol=1e-2,
    )
