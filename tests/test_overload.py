"""Overload study: goodput vs open-loop arrival rate (bench_serving
``--sweep``).

Past the engine's saturation rate, pushing arrivals harder can only grow
queueing delay, so SLO-meeting goodput must be monotone non-increasing —
and at heavy overload it must be strictly below the at-saturation value.
Everything is virtual-clock deterministic (seeded arrivals, seeded machine
jitter), so the assertions are exact up to float noise.
"""

import pytest

from benchmarks.bench_serving import (
    SWEEP,
    SWEEP_SATURATION,
    run_sweep,
)

RATES = (SWEEP_SATURATION, 4 * SWEEP_SATURATION, 16 * SWEEP_SATURATION)


@pytest.fixture(scope="module")
def sweep():
    return run_sweep("ultra-125h", SWEEP, RATES)


def test_sweep_covers_requested_rates(sweep):
    assert [rate for rate, _ in sweep] == sorted(RATES)
    for _, rep in sweep:
        assert rep.n_finished == SWEEP["n_requests"]


def test_goodput_monotone_nonincreasing_past_saturation(sweep):
    good = [rep.goodput for _, rep in sweep]
    for prev, nxt in zip(good, good[1:]):
        assert nxt <= prev + 1e-9, f"goodput rose past saturation: {good}"
    # heavy overload actually degrades goodput (not merely flat): queueing
    # pushes later requests past the TTFT SLO
    assert good[-1] < good[0]


def test_throughput_saturates_not_collapses(sweep):
    """Token throughput is service-bound past saturation: roughly constant
    across rates (continuous batching keeps slots busy; overload shows up
    in latency SLOs, not in tokens/s)."""
    tput = [rep.throughput for _, rep in sweep]
    assert min(tput) > 0
    assert max(tput) / min(tput) < 1.25
