"""Hybrid kernel dispatch (kernels.dispatch) + worker-pool timing fixes.

Covers the PR-3 regressions — duplicate per-worker sub-tasks must
accumulate (not last-write-win), background-load intervals must integrate
over the task's own time span — and the dispatch layer's contracts: shard
outputs identical to the monolithic kernels, ratio convergence and
achieved-bandwidth fractions on the simulated hybrid machines, and the
balanced model-layer wrappers.

PR-4 additions: the balanced *trunk* — shard-vs-monolithic identity for
every projection kind (q/k/v/o, up/gate/down, head) across quantized and
fp32 paths, odd N / N < n_cores / single-core edge cases, the io_callback
jit bridge vs its eager fallback, and the engine's ``balanced_trunk``
end-to-end wiring.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import CoreSpec, SimulatedHybridCPU, make_machine
from repro.core.pool import SubTask, ThreadWorkerPool, VirtualWorkerPool
from repro.kernels import (
    GEMV_ISA,
    HybridKernelDispatcher,
    bridged_linear,
    int8_linear,
    kernel_key,
    ops,
    ref,
)
from repro.models.layers import (
    BalancedFp32Linear,
    BalancedLinear,
    BalancedQuantLinear,
)
from repro.quant import (
    quantize_q4_0,
    quantize_s8_symmetric,
    quantize_u8_dynamic,
)
from repro.runtime import KernelSpec

RNG = np.random.default_rng(0)

ALL_ISAS = {"avx_vnni": 100e9, "avx2": 50e9, "membw": 8e9}


def one_core_machine(tp: float = 1.0, background=()):
    """Deterministic single-core machine: jitter 0, throughput ``tp``."""
    m = SimulatedHybridCPU(
        cores=[CoreSpec("C0", "P", {"avx2": tp}, jitter=0.0)])
    m.background.extend(background)
    return m


def single_core_all_isas():
    """One core with every dispatch ISA (single-core edge cases)."""
    return SimulatedHybridCPU(
        cores=[CoreSpec("C0", "P", dict(ALL_ISAS), jitter=0.0)])


# ------------------------------------------------- pool: multi-subtask ----
def test_thread_pool_runs_all_subtasks_per_worker():
    """Regression: two sub-tasks for the same worker used to last-write-win
    (the first one's work silently dropped)."""
    out = np.zeros(8)
    fn = lambda start, size: out.__setitem__(slice(start, start + size), 1)
    pool = ThreadWorkerPool(2)
    try:
        times = pool.run([
            SubTask(worker=0, start=0, size=2, work=2, fn=fn),
            SubTask(worker=0, start=2, size=2, work=2, fn=fn),
            SubTask(worker=1, start=4, size=4, work=4, fn=fn),
        ])
    finally:
        pool.close()
    np.testing.assert_array_equal(out, 1.0)
    assert times[0] > 0 and times[1] > 0


def test_thread_pool_propagates_shard_errors_without_deadlock():
    """A raising shard fn must surface in run() (not kill the worker thread
    and hang the join), and the pool must stay usable afterwards."""
    def bad(start, size):
        raise RuntimeError("boom")

    pool = ThreadWorkerPool(2)
    try:
        with pytest.raises(RuntimeError, match="boom"):
            pool.run([SubTask(worker=0, start=0, size=1, work=1, fn=bad)])
        times = pool.run([SubTask(worker=0, start=0, size=1, work=1,
                                  fn=lambda s, z: None)])
        assert times[0] >= 0
    finally:
        pool.close()


def test_virtual_pool_accumulates_duplicate_worker_times():
    """Regression: ``times[st.worker] =`` dropped all but the last
    sub-task's time; chunked shard dispatch needs the sum."""
    pool = VirtualWorkerPool(one_core_machine(tp=1.0), isa="avx2")
    times = pool.run([
        SubTask(worker=0, start=0, size=1, work=3.0),
        SubTask(worker=0, start=1, size=1, work=4.0),
    ])
    np.testing.assert_allclose(times[0], 7.0)
    assert pool.clock == pytest.approx(7.0)


# ------------------------------------- background-interval integration ----
def test_background_starting_mid_task_is_applied():
    """A throttle interval that begins mid-task used to be missed entirely
    (slowdown sampled once at region start)."""
    m = one_core_machine(tp=1.0, background=[(5.0, 1e9, 0, 2.0)])
    # 10 base-seconds from t=0: 5s unthrottled, remaining 5 at 2x -> 15s.
    assert m.task_time(0, "avx2", 10.0, 0.0) == pytest.approx(15.0)


def test_background_ending_mid_task_not_over_applied():
    """An interval that ends mid-task used to throttle the whole task."""
    m = one_core_machine(tp=1.0, background=[(0.0, 2.0, 0, 3.0)])
    # 2 wall-seconds at 3x consume 2/3 base; the rest runs unthrottled.
    assert m.task_time(0, "avx2", 10.0, 0.0) == pytest.approx(
        2.0 + (10.0 - 2.0 / 3.0))


def test_constant_background_matches_point_sample():
    """An interval covering the whole task reduces to the old behaviour."""
    m = one_core_machine(tp=1.0, background=[(0.0, 1e9, 0, 3.0)])
    assert m.task_time(0, "avx2", 10.0, 0.0) == pytest.approx(30.0)


def test_virtual_pool_sequential_subtasks_hit_their_own_interval():
    """The second sub-task of a worker starts at the virtual instant the
    first finished — a throttle starting between them lands on it."""
    m = one_core_machine(tp=1.0, background=[(5.0, 1e9, 0, 2.0)])
    pool = VirtualWorkerPool(m, isa="avx2")
    times = pool.run([
        SubTask(worker=0, start=0, size=1, work=5.0),   # t in [0, 5): clean
        SubTask(worker=0, start=1, size=1, work=5.0),   # starts at 5: 2x
    ])
    np.testing.assert_allclose(times[0], 5.0 + 10.0)


# --------------------------------------------- dispatch: shard outputs ----
def test_q4_shards_byte_identical_to_monolithic():
    x = jnp.asarray(RNG.normal(size=(4, 512)).astype(np.float32))
    qw = quantize_q4_0(jnp.asarray(RNG.normal(size=(300, 512)).astype(np.float32)))
    disp = HybridKernelDispatcher.virtual("core-12900k", execute=True)
    got = disp.q4_matmul(x, qw, blocks=(8, 256, 512))
    want = ops.q4_matmul(x, qw, blocks=(8, 256, 512), interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int8_shards_identical_via_thread_pool():
    a = jnp.asarray(RNG.integers(0, 256, size=(16, 256)), dtype=jnp.uint8)
    w = jnp.asarray(RNG.integers(-127, 128, size=(200, 256)), dtype=jnp.int8)
    disp = HybridKernelDispatcher.threaded(4)
    try:
        for _ in range(2):  # tuner explores different shard blocks; s32 exact
            got = disp.int8_gemm(a, w)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(ref.int8_gemm_ref(a, w)))
    finally:
        disp.close()


def test_virtual_dispatcher_without_execute_refuses_kernels():
    disp = HybridKernelDispatcher.virtual("ultra-125h")  # execute=False
    x = jnp.zeros((1, 64), jnp.float32)
    qw = quantize_q4_0(jnp.asarray(RNG.normal(size=(32, 64)).astype(np.float32)))
    with pytest.raises(ValueError, match="execute"):
        disp.q4_matmul(x, qw)


# ------------------------------------- dispatch: the paper's claims -------
GEMV_SPEC = KernelSpec("q4_gemv", isa=GEMV_ISA, granularity=8,
                       work_per_unit=4096 * 0.5625)


@pytest.mark.parametrize("machine", ["ultra-125h", "core-12900k"])
def test_dynamic_dispatch_reaches_bandwidth_fraction(machine):
    """Paper Fig. 2: dynamic shard dispatch sustains >90% of the socket's
    streaming bandwidth; static (equal shards) stays materially lower."""
    def frac(dynamic, iters):
        disp = HybridKernelDispatcher.virtual(machine, dynamic=dynamic)
        for _ in range(iters):
            disp.dispatch(GEMV_SPEC, 4096, bytes_per_unit=4096 * 0.5625)
        tail = disp.stats[-10:]
        moved = sum(st.bytes for st in tail)
        busy = sum(st.makespan for st in tail)
        return (moved / busy) / disp.machine.socket_bandwidth

    dyn, sta = frac(True, 40), frac(False, 10)
    assert dyn > 0.90, f"{machine}: dynamic achieved {dyn:.2%}"
    assert dyn > sta + 0.05, f"{machine}: dynamic {dyn:.2%} vs static {sta:.2%}"


def test_dispatch_ratios_converge_to_true_throughput():
    machine = make_machine("ultra-125h")
    disp = HybridKernelDispatcher.virtual(machine)
    for _ in range(40):
        disp.dispatch(GEMV_SPEC, 4096)
    ratios = disp.table.ratios(GEMV_ISA)
    tp = machine.true_throughput(GEMV_ISA)
    np.testing.assert_allclose(ratios, tp / tp.mean(), rtol=0.10)


def test_bytes_telemetry_on_region_stats():
    disp = HybridKernelDispatcher.virtual("ultra-125h")
    st = disp.dispatch(GEMV_SPEC, 4096, bytes_per_unit=4096 * 0.5625)
    assert st.bytes == pytest.approx(4096 * 4096 * 0.5625)
    assert st.bandwidth > 0
    assert disp.achieved_bandwidth() == pytest.approx(st.bandwidth)


# --------------------------------------------------- balanced layers ------
def test_balanced_quant_linear_matches_reference():
    w = RNG.normal(size=(96, 64)).astype(np.float32)
    x = jnp.asarray(RNG.normal(size=(4, 64)).astype(np.float32))
    disp = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
    layer = BalancedQuantLinear.from_dense(jnp.asarray(w), disp)
    got = layer(x, isa=GEMV_ISA)
    want = ref.q4_matmul_ref(x, quantize_q4_0(jnp.asarray(w)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-2)
    # 3D hidden states (B, S, d) round-trip through the same dispatch
    x3 = x.reshape(2, 2, 64)
    got3 = layer(x3)
    np.testing.assert_allclose(np.asarray(got3).reshape(4, -1),
                               np.asarray(got), rtol=1e-6, atol=1e-6)


def test_balanced_linear_matches_int8_linear():
    w = RNG.normal(size=(48, 64)).astype(np.float32)
    x = jnp.asarray(RNG.normal(size=(5, 64)).astype(np.float32))
    disp = HybridKernelDispatcher.virtual("core-12900k", execute=True)
    layer = BalancedLinear.from_dense(jnp.asarray(w), disp)
    got = layer(x)
    qa = quantize_u8_dynamic(x)
    qw = quantize_s8_symmetric(jnp.asarray(w))
    want = int8_linear(qa, qw, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------ balanced trunk: identity ------
# Every trunk projection kind across quantized and fp32 paths, including
# odd N, N < n_cores, and a single-core machine.  Sharding is along N, so
# each output element's reduction is untouched: fp32 and int8 (s32
# accumulate) are exact; q4 is allclose to the dequantize-reference.
EDGE_SHAPES = [(101, 64), (5, 64), (300, 128)]  # odd / < n_cores / even


def _edge_dispatchers():
    return [
        HybridKernelDispatcher.virtual(make_machine("ultra-125h"),
                                       execute=True),
        HybridKernelDispatcher.virtual(single_core_all_isas(), execute=True),
    ]


@pytest.mark.parametrize("n,k", EDGE_SHAPES)
def test_balanced_fp32_linear_shard_exact(n, k):
    w = RNG.normal(size=(n, k)).astype(np.float32)
    x = jnp.asarray(RNG.normal(size=(3, k)).astype(np.float32))
    for disp in _edge_dispatchers():
        layer = BalancedFp32Linear.from_dense(w, disp)
        got = np.asarray(layer(x))
        np.testing.assert_allclose(got, np.asarray(x) @ w.T,
                                   rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n,k", EDGE_SHAPES)
def test_balanced_quant_linear_edge_shapes(n, k):
    w = RNG.normal(size=(n, k)).astype(np.float32)
    x = jnp.asarray(RNG.normal(size=(2, k)).astype(np.float32))
    want = np.asarray(ref.q4_matmul_ref(x, quantize_q4_0(jnp.asarray(w))))
    for disp in _edge_dispatchers():
        layer = BalancedQuantLinear.from_dense(jnp.asarray(w), disp)
        got = np.asarray(layer(x, isa=GEMV_ISA, key="membw/attn_proj"))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-2)


@pytest.mark.parametrize("n,k", EDGE_SHAPES)
def test_balanced_int8_linear_edge_shapes(n, k):
    w = RNG.normal(size=(n, k)).astype(np.float32)
    x = jnp.asarray(RNG.normal(size=(2, k)).astype(np.float32))
    want = np.asarray(int8_linear(quantize_u8_dynamic(x),
                                  quantize_s8_symmetric(jnp.asarray(w)),
                                  interpret=True))
    for disp in _edge_dispatchers():
        layer = BalancedLinear.from_dense(jnp.asarray(w), disp)
        got = np.asarray(layer(x, key="avx_vnni/mlp_up"))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def _trunk_fixture(quant):
    from repro.configs import reduced_config
    from repro.models import BalancedTrunk, init_params

    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    disp = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
    trunk = BalancedTrunk.from_params(cfg, params, disp, quant=quant)
    return cfg, params, disp, trunk


@pytest.mark.parametrize("quant", ["q4", "int8", "fp32"])
def test_trunk_projections_match_monolithic(quant):
    """Every banked projection (wq/wk/wv/wo, wi/wg/wo, head) matches the
    monolithic execution of the same quantized weight."""
    cfg, params, disp, trunk = _trunk_fixture(quant)
    names = {(g, n) for (_, g, n) in trunk.bank}
    assert names == {("attn", "wq"), ("attn", "wk"), ("attn", "wv"),
                     ("attn", "wo"), ("ffn", "wi"), ("ffn", "wg"),
                     ("ffn", "wo")}
    x = jnp.asarray(RNG.normal(size=(3, cfg.d_model)).astype(np.float32))
    for (j, group, name), layers in trunk.bank.items():
        for r, layer in enumerate(layers):
            w = np.asarray(
                params["period"][j]["mixer" if group == "attn" else "ffn"]
                [name][r]).T  # (N, K)
            xin = x if w.shape[1] == cfg.d_model else jnp.asarray(
                RNG.normal(size=(3, w.shape[1])).astype(np.float32))
            got = np.asarray(layer(xin, isa=GEMV_ISA))
            if quant == "fp32":
                want = np.asarray(xin) @ w.T
                tol = dict(rtol=1e-6, atol=1e-6)
            elif quant == "q4":
                want = np.asarray(
                    ref.q4_matmul_ref(xin, quantize_q4_0(jnp.asarray(w))))
                tol = dict(rtol=2e-5, atol=1e-2)
            else:
                want = np.asarray(int8_linear(
                    quantize_u8_dynamic(xin),
                    quantize_s8_symmetric(jnp.asarray(w)), interpret=True))
                tol = dict(rtol=1e-6, atol=1e-6)
            np.testing.assert_allclose(got, want, **tol)
    # the head is banked too (kind "head")
    assert trunk.head is not None


def test_trunk_forward_allclose_to_monolithic_forward():
    """Acceptance: fp32 balanced-trunk decode-step outputs allclose to the
    plain jitted forward — eagerly and through the jitted io_callback
    bridge, with and without state."""
    from repro.models import forward, init_state

    cfg, params, disp, trunk = _trunk_fixture("fp32")
    tok = jnp.asarray(RNG.integers(0, cfg.vocab_size, size=(2, 6)),
                      dtype=jnp.int32)
    ref_out = forward(cfg, params, tok)
    got = forward(cfg, params, tok, trunk=trunk, trunk_isa="membw")
    np.testing.assert_allclose(np.asarray(got.logits),
                               np.asarray(ref_out.logits),
                               rtol=1e-4, atol=1e-4)

    state = init_state(cfg, 2, 16)
    f = jax.jit(lambda p, t, s: forward(cfg, p, t, state=s, trunk=trunk,
                                        trunk_isa="membw"))
    jit_out = f(params, tok, state)
    ref_state = forward(cfg, params, tok, state=init_state(cfg, 2, 16))
    np.testing.assert_allclose(np.asarray(jit_out.logits),
                               np.asarray(ref_state.logits),
                               rtol=1e-4, atol=1e-4)
    # per-kind decode keys were learned by the jitted pass
    assert {"membw/attn_proj", "membw/mlp_up",
            "membw/mlp_down"} <= set(disp.table.keys())


def test_bridge_refuses_tracing_when_disallowed():
    disp = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
    layer = BalancedFp32Linear.from_dense(
        RNG.normal(size=(8, 16)).astype(np.float32), disp)
    x = jnp.zeros((2, 16), jnp.float32)
    with pytest.raises(RuntimeError, match="jit_bridge"):
        jax.jit(lambda x: bridged_linear(layer, x, isa=GEMV_ISA,
                                         allow_callback=False))(x)
    # eager call works regardless
    out = bridged_linear(layer, x, isa=GEMV_ISA, allow_callback=False)
    assert out.shape == (2, 8)


# ------------------------------------------- engine hot-path wiring -------
def test_engine_decodes_through_balanced_head():
    """ContinuousBatchingEngine + balanced Q4 LM head: requests finish,
    both per-phase ISA keys are learned from real shard dispatches, and
    bandwidth accounting accumulates."""
    from repro.configs import reduced_config
    from repro.models import balanced_lm_head, init_params
    from repro.serving import (
        ContinuousBatchingEngine,
        HybridPhaseCost,
        poisson_requests,
    )

    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    disp = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
    engine = ContinuousBatchingEngine(
        cfg, params, max_slots=2, max_seq=16, prefill_chunk=4,
        cost_model=HybridPhaseCost("ultra-125h"),
        balanced_head=balanced_lm_head(cfg, params, disp))
    requests = poisson_requests(3, rate=100.0, vocab_size=cfg.vocab_size,
                                prompt_len=6, max_new_tokens=4, seed=0)
    for r in requests:
        engine.submit(r)
    engine.run_until_idle()
    assert all(len(r.generated) == 4 for r in requests)
    assert sorted(disp.table.keys()) == ["avx_vnni", "membw"]
    # decode GEMVs moved bytes through the membw-keyed regions
    assert disp.achieved_bandwidth(GEMV_ISA) > 0
    spread = disp.table.ratios(GEMV_ISA)
    assert spread.max() / spread.min() > 1.1  # hybrid cores differentiated


def _run_trunk_engine(quant, jit_bridge, n_requests=3, steps=4, fused=True):
    from repro.configs import reduced_config
    from repro.models import BalancedTrunk, init_params
    from repro.serving import (
        ContinuousBatchingEngine,
        HybridPhaseCost,
        poisson_requests,
    )

    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))
    disp = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
    trunk = BalancedTrunk.from_params(cfg, params, disp, quant=quant,
                                      jit_bridge=jit_bridge, fused=fused)
    engine = ContinuousBatchingEngine(
        cfg, params, max_slots=2, max_seq=16, prefill_chunk=4,
        cost_model=HybridPhaseCost("ultra-125h"), balanced_trunk=trunk)
    requests = poisson_requests(n_requests, rate=100.0,
                                vocab_size=cfg.vocab_size,
                                prompt_len=6, max_new_tokens=steps, seed=0)
    for r in requests:
        engine.submit(r)
    engine.run_until_idle()
    return requests, disp


def test_engine_decodes_through_balanced_trunk():
    """Whole-trunk balanced dispatch on the engine hot path: requests
    finish, every (phase ISA x layer kind) table key is learned, and the
    decode-phase bytes accounting covers the whole step (attn + MLP + head
    traffic, far more than the head alone)."""
    requests, disp = _run_trunk_engine("q4", jit_bridge=True)
    assert all(len(r.generated) == 4 for r in requests)
    kinds = ("attn_proj", "mlp_up", "mlp_down", "head")
    expect = {kernel_key(isa, kind)
              for isa in ("avx_vnni", "membw") for kind in kinds}
    assert expect <= set(disp.table.keys())
    assert disp.achieved_bandwidth(GEMV_ISA) > 0
    # decode step bytes: trunk projections + head vs head alone — granite
    # reduced moves ~3.4x the head's bytes per step through the trunk
    head_bytes_per_step = 512 * 64 * 0.5625
    assert disp._bytes[GEMV_ISA] > 2 * head_bytes_per_step


def test_trunk_eager_fallback_matches_jit_bridge():
    """jit_bridge=False runs the same trunk eagerly (tracing disallowed);
    fp32 shard dispatch is exact, so generated tokens must be identical."""
    jit_reqs, _ = _run_trunk_engine("fp32", jit_bridge=True)
    eager_reqs, _ = _run_trunk_engine("fp32", jit_bridge=False)
    for a, b in zip(jit_reqs, eager_reqs):
        assert a.generated == b.generated


# ------------------------------------------------ fused q/k/v callbacks ---
@pytest.mark.parametrize("jit_bridge", [True, False])
def test_fused_qkv_token_identical_to_per_matmul(jit_bridge):
    """Fusing q/k/v into one jit-bridge round trip must not change a
    single token: the host side runs the same three balanced regions in
    the same program order, so fp32 outputs are bit-identical."""
    fused_reqs, fused_disp = _run_trunk_engine("fp32", jit_bridge=jit_bridge,
                                               fused=True)
    plain_reqs, plain_disp = _run_trunk_engine("fp32", jit_bridge=jit_bridge,
                                               fused=False)
    for a, b in zip(fused_reqs, plain_reqs):
        assert a.generated == b.generated
    # the ratio tables saw identical (region, time) sequences too
    for key in plain_disp.table.keys():
        np.testing.assert_allclose(fused_disp.table.ratios(key),
                                   plain_disp.table.ratios(key))


def test_fused_qkv_one_callback_per_attention_layer():
    """The jitted decode step carries one io_callback for q/k/v per
    attention layer (plus one each for wo / wi / wg / down): 4 fewer
    round trips than the per-matmul path on the 2-layer reduced config.
    Counted and contract-checked through the jaxpr auditor rather than a
    string count over the printed jaxpr."""
    from repro.analysis.jaxpr_audit import (
        audit_step, count_callbacks, expected_bridge_callbacks,
        trace_bridged_step)
    from repro.configs import reduced_config
    from repro.models import BalancedTrunk, init_params

    cfg = reduced_config("granite-8b")
    params = init_params(cfg, jax.random.key(0))

    def n_callbacks(fused):
        disp = HybridKernelDispatcher.virtual("ultra-125h", execute=True)
        trunk = BalancedTrunk.from_params(cfg, params, disp, quant="fp32",
                                          fused=fused)
        step = trace_bridged_step(cfg, params, trunk, isa="membw")
        want = expected_bridge_callbacks(trunk)
        # JA003 (count matches per-layer contract) + JA004 (all ordered)
        assert audit_step(step, expected=want) == []
        return count_callbacks(step.jaxpr).get("io_callback", 0)

    fused, plain = n_callbacks(True), n_callbacks(False)
    n_attn = sum(1 for mixer, _ in cfg.layer_plan() if mixer == "attn")
    assert fused == plain - 2 * n_attn
    assert fused < plain
